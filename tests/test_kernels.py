"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret=True).

Every Pallas kernel asserts allclose (bit-exact where the math is integer)
against its ref.py across a sweep of shapes, including non-divisible edges
that exercise the padding paths in ops.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stochastic as sc
from repro.core.odin_linear import get_luts
from repro.kernels.act_pool import act_pool, act_pool_ref
from repro.kernels.int8_mm import int8_matmul, int8_mm_pallas, int8_mm_ref
from repro.kernels.sc_mac import sc_matmul_pallas, sc_matmul_hybrid_ref, sc_matmul_tree_ref
from repro.kernels.sc_mac.ref import ranks_from_lut

SPEC = sc.StreamSpec(256, 256)
LUT_A, LUT_W, SELECTS = get_luts(256, 256, 0)


# ---------------------------------------------------------------------------
# sc_mac
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [(1, 1, 1), (3, 17, 5), (8, 64, 8),
                                   (5, 33, 11), (16, 128, 4)])
@pytest.mark.parametrize("dtype", [jnp.uint8, jnp.int32])
def test_sc_mac_tree_regime_exact(M, K, N, dtype):
    rng = np.random.default_rng(M * 1000 + K * 10 + N)
    a = jnp.asarray(rng.integers(0, 256, (M, K)), dtype)
    w = jnp.asarray(rng.integers(0, 256, (K, N)), dtype)
    pal = sc_matmul_pallas(a, w, LUT_A, LUT_W, SELECTS, SPEC, interpret=True)
    core = sc.sc_matmul(a.astype(jnp.int32), w.astype(jnp.int32),
                        LUT_A, LUT_W, SELECTS, SPEC)
    np.testing.assert_array_equal(np.asarray(pal), np.asarray(core))
    ref = sc_matmul_tree_ref(a, w, LUT_A, LUT_W, SELECTS, SPEC)
    np.testing.assert_array_equal(np.asarray(pal), np.asarray(ref))


@pytest.mark.parametrize("M,K,N,max_tree_k", [(4, 70, 6, 32), (2, 200, 3, 64)])
def test_sc_mac_hybrid_regime(M, K, N, max_tree_k):
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.integers(0, 256, (M, K)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 256, (K, N)), jnp.int32)
    pal = sc_matmul_pallas(a, w, LUT_A, LUT_W, SELECTS, SPEC, interpret=True,
                           max_tree_k=max_tree_k)
    ref = sc_matmul_hybrid_ref(a, w, LUT_A, LUT_W, SELECTS, SPEC, block_k=max_tree_k)
    khat = 1 << sc.tree_depth(K)
    np.testing.assert_allclose(np.asarray(pal),
                               np.asarray(ref) * (max_tree_k / khat), rtol=1e-6)


def test_sc_mac_nondefault_stream_geometry():
    spec = sc.StreamSpec(128, 128)
    la, lw, sel = get_luts(128, 128, 3)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 128, (4, 12)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 128, (12, 4)), jnp.int32)
    pal = sc_matmul_pallas(a, w, la, lw, sel, spec, interpret=True)
    core = sc.sc_matmul(a, w, la, lw, sel, spec)
    np.testing.assert_array_equal(np.asarray(pal), np.asarray(core))


def test_ranks_roundtrip():
    ranks = ranks_from_lut(LUT_A, 256)
    assert ranks.shape == (8, 32)
    # rebuilding streams from ranks == LUT rows (comparator == LUT identity)
    vals = jnp.arange(256)[:, None, None]
    bits = (vals > ranks[None]).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    rebuilt = (bits * weights).sum(-1, dtype=jnp.uint32)
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(LUT_A))


# ---------------------------------------------------------------------------
# int8_mm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (16, 32, 8, 8, 8, 8), (128, 128, 128, 128, 128, 128),
    (33, 70, 9, 16, 16, 32), (1, 300, 1, 8, 8, 64),
])
def test_int8_mm_exact(M, K, N, bm, bn, bk):
    rng = np.random.default_rng(M + K + N)
    a = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (K, N)), jnp.int8)
    sa = jnp.asarray(rng.uniform(0.001, 1.0, (M,)), jnp.float32)
    sw = jnp.asarray(rng.uniform(0.001, 1.0, (N,)), jnp.float32)
    y = int8_mm_pallas(a, w, sa, sw, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(int8_mm_ref(a, w, sa, sw)),
                               rtol=1e-6)


def test_int8_matmul_quant_quality():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(20, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 12)), jnp.float32)
    y = int8_matmul(x, w)
    ref = x @ w
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 0.02


# ---------------------------------------------------------------------------
# act_pool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,W,C,pool", [
    (1, 4, 4, 8, 2), (2, 28, 28, 10, 2), (3, 12, 12, 16, 3), (1, 6, 6, 1, 2),
])
def test_act_pool_exact(B, H, W, C, pool):
    rng = np.random.default_rng(B * H + C)
    x = jnp.asarray(rng.integers(-300, 600, (B, H, W, C)), jnp.int32)
    y = act_pool(x, pool=pool)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(act_pool_ref(x, pool)))
    assert int(y.min()) >= 0 and int(y.max()) <= 255


def test_act_pool_saturation_semantics():
    """The 8-bit ReLU block clamps to [0, 255] — ODIN's S_TO_B output width."""
    x = jnp.array([[[[-5, 0, 255, 300]]]], jnp.int32).reshape(1, 2, 2, 1)
    y = act_pool(x)
    assert int(y[0, 0, 0, 0]) == 255


@pytest.mark.parametrize("act,pool_kind", [("relu", "avg"), ("tanh", "max"),
                                           ("tanh", "avg")])
def test_act_pool_extended_variants(act, pool_kind):
    """§IV-B.2 extensibility: tanh activation and average pooling."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(-100, 400, (2, 8, 8, 8)), jnp.int32)
    y = act_pool(x, act=act, pool_kind=pool_kind)
    yr = act_pool_ref(x, act=act, pool_kind=pool_kind)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    assert int(y.min()) >= 0 and int(y.max()) <= 255


def test_act_pool_tanh_is_8bit_lut_consistent():
    """The closed form equals a 256-entry LUT over the popcount domain."""
    vals = jnp.arange(256, dtype=jnp.int32).reshape(1, 16, 16, 1)
    y = act_pool(vals, act="tanh", pool_kind="max")
    lut = jnp.clip(jnp.round(255.0 * jnp.tanh(jnp.arange(256.0) / 64.0)), 0, 255)
    manual = lut[np.arange(256).reshape(16, 16)].reshape(1, 8, 2, 8, 2)[0]
    expect = np.asarray(manual).reshape(8, 2, 8, 2).max(axis=(1, 3))
    np.testing.assert_array_equal(np.asarray(y[0, :, :, 0]), expect)
