"""ODIN execution-mode parity: exact vs int8 vs sc share one quant boundary."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.odin_linear import OdinConfig, get_luts, odin_linear
from repro.core.quant import dequantize, quantize_signed_tworail, quantize_unipolar


def _xw(key, M, K, N, unipolar_x=False):
    kx, kw = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    if unipolar_x:
        x = jax.nn.relu(x)
    w = jax.random.normal(kw, (K, N), jnp.float32) * 0.3
    return x, w


# ---------------------------------------------------------------------------
# quantizers
# ---------------------------------------------------------------------------

def test_tworail_reconstruction():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    pos, neg, qp = quantize_signed_tworail(w)
    w_hat = (pos.astype(jnp.float32) - neg.astype(jnp.float32)) * qp.scale
    assert float(jnp.abs(w_hat - w).max()) <= float(qp.scale) * 0.5 + 1e-7
    # exactly one rail nonzero per element
    assert not bool(((pos > 0) & (neg > 0)).any())


def test_unipolar_roundtrip():
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (64,)))
    q, qp = quantize_unipolar(x)
    x_hat = dequantize(q, qp)
    assert float(jnp.abs(x_hat - x).max()) <= float(qp.scale) * 0.5 + 1e-7


# ---------------------------------------------------------------------------
# mode parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("signed", [True, False])
def test_int8_mode_close_to_exact(signed):
    x, w = _xw(2, 12, 48, 10, unipolar_x=not signed)
    y_exact = odin_linear(x, w, OdinConfig(mode="exact"))
    y_int8 = odin_linear(x, w, OdinConfig(mode="int8", signed_activations=signed))
    rel = float(jnp.abs(y_int8 - y_exact).max() / (jnp.abs(y_exact).max() + 1e-9))
    assert rel < 0.03, rel


def test_sc_mode_close_to_int8_unipolar():
    """SC (bit-faithful) tracks its own expectation (the int8 surrogate).

    Unipolar activations × positive-leaning weights (the paper's post-ReLU
    CNN regime): the rails carry the full signal magnitude, so SC noise is
    small relative to the output.
    """
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(20), (4, 64)))
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(21), (64, 5))) * 0.3
    y_int8 = odin_linear(x, w, OdinConfig(mode="int8", signed_activations=False))
    y_sc = odin_linear(x, w, OdinConfig(mode="sc", signed_activations=False))
    denom = float(jnp.abs(y_int8).max() + 1e-9)
    # The realized LUT permutations (and so the sampled MUX-tree noise) depend
    # on the jax version's PRNG implementation: the max statistic over these
    # 20 outputs measures 0.39 on jax 0.4.37.  The mean is the stable bound.
    assert float(jnp.abs(y_sc - y_int8).max() / denom) < 0.5
    assert float(jnp.abs(y_sc - y_int8).mean() / denom) < 0.13


def test_sc_signed_cancellation_noise_documented():
    """Signed zero-mean operands are SC's worst case: rail magnitudes grow
    ~K while the signed signal grows ~√K, so relative noise grows with K.
    This asserts the *structure* of that noise (bounded by the 4-rail
    subsampling envelope, unbiased in the mean), which is the property the
    two-rail design note in core/quant.py relies on.
    """
    x, w = _xw(3, 4, 64, 5)
    y_int8 = odin_linear(x, w, OdinConfig(mode="int8"))
    y_sc = odin_linear(x, w, OdinConfig(mode="sc"))
    # envelope: 4 rails × 4σ of MUX-tree subsample noise, in output units
    from repro.core.quant import quantize_signed_tworail
    _, _, aq = quantize_signed_tworail(x.reshape(-1, x.shape[-1]))
    _, _, wq = quantize_signed_tworail(w)
    khat = 64
    pop_sigma = np.sqrt(64.0)                     # √(max pop) scale at K̂=64
    env = 4 * 4 * pop_sigma * (khat * 256**2 / 256) * float(aq.scale * wq.scale)
    assert float(jnp.abs(y_sc - y_int8).max()) < env
    # unbiased: mean error across the matrix ≪ the noise envelope
    assert abs(float((y_sc - y_int8).mean())) < env / 8


def test_sc_pallas_equals_sc_jnp():
    """The fused kernel is bit-identical to the jnp SC pipeline end-to-end."""
    x, w = _xw(4, 5, 16, 4)
    y_ref = odin_linear(x, w, OdinConfig(mode="sc", use_pallas=False))
    y_pal = odin_linear(x, w, OdinConfig(mode="sc", use_pallas=True, interpret=True))
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_pal))


def test_round_popcount_changes_grid():
    """S_TO_B 8-bit rounding snaps results onto the popcount grid.

    At full-tree scaling the grid step is K̂·L²/stream_len dot-units — very
    coarse for large K̂ (the same information-theoretic limit behind the
    full-tree accuracy collapse).  Assert the *grid semantics*: outputs are
    grid multiples and each rail errs ≤ half a step.
    """
    x, w = _xw(5, 4, 300, 3)
    y_plain = odin_linear(x, w, OdinConfig(mode="int8"))
    y_round = odin_linear(x, w, OdinConfig(mode="int8", round_popcount=True))
    assert float(jnp.abs(y_plain - y_round).max()) > 0  # grid is coarser
    # grid check: y_round/(step·scales) must be integral (4 rails: sums of
    # 4 integers are integers)
    from repro.core.quant import quantize_signed_tworail
    _, _, aq = quantize_signed_tworail(x.reshape(-1, x.shape[-1]))
    _, _, wq = quantize_signed_tworail(w)
    khat = 512                                   # next pow2 of K=300
    step = (khat * 256**2 / 256) * float(aq.scale * wq.scale)
    frac = np.asarray(jnp.abs(y_round / step - jnp.round(y_round / step)))
    assert frac.max() < 1e-3
    # per-rail rounding error ≤ step/2 each, 4 rails ⇒ ≤ 2 steps total
    assert float(jnp.abs(y_plain - y_round).max()) <= 2.0 * step + 1e-6


def test_exact_mode_is_matmul():
    x, w = _xw(6, 8, 16, 8)
    np.testing.assert_allclose(np.asarray(odin_linear(x, w, OdinConfig())),
                               np.asarray(x @ w), rtol=1e-6)


def test_batched_leading_dims():
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 3, 20), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(8), (20, 6), jnp.float32)
    y = odin_linear(x, w, OdinConfig(mode="int8"))
    assert y.shape == (2, 3, 6)
    rel = float(jnp.abs(y - x @ w).max() / jnp.abs(x @ w).max())
    assert rel < 0.05
