"""AdamW (int8 moments) and gradient-compression correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep; collection must not hard-fail
from hypothesis import given, settings, strategies as st

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import compress_int8, decompress_int8


def _quadratic_losses(moment_dtype: str, steps: int = 120):
    """Minimize ‖Wx − y‖² and return the loss trace."""
    cfg = AdamWConfig(moment_dtype=moment_dtype, weight_decay=0.0)
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (16, 8))
    params = {"w": jnp.zeros((16, 8))}
    state = adamw_init(params, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y = x @ w_true

    def loss_fn(p):
        return ((x @ p["w"] - y) ** 2).mean()

    @jax.jit
    def step(p, s):
        l, g = jax.value_and_grad(loss_fn)(p)
        p, s = adamw_update(g, p, s, 3e-2, cfg)
        return p, s, l

    losses = []
    for _ in range(steps):
        params, state, l = step(params, state)
        losses.append(float(l))
    return losses


def test_adamw_fp32_converges():
    losses = _quadratic_losses("float32")
    assert losses[-1] < 1e-3 * losses[0]


def test_adamw_int8_moments_convergence_parity():
    """int8 block-quantized moments converge like fp32 (the 8-bit theme)."""
    l_fp = _quadratic_losses("float32")
    l_q = _quadratic_losses("int8")
    assert l_q[-1] < 1e-2 * l_q[0]
    assert l_q[-1] < 10 * max(l_fp[-1], 1e-9)


def test_int8_state_is_actually_int8():
    params = {"w": jnp.zeros((4, 300))}
    state = adamw_init(params, AdamWConfig(moment_dtype="int8", block=128))
    assert state["mu"]["w"]["q"].dtype == jnp.int8
    assert state["mu"]["w"]["s"].shape == (4, 3)   # ceil(300/128) scales


def test_weight_decay_skips_1d_params():
    cfg = AdamWConfig(moment_dtype="float32", weight_decay=1.0)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = adamw_init(params, cfg)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _ = adamw_update(zeros, params, state, 0.1, cfg)
    assert float(jnp.abs(p2["w"] - params["w"]).max()) > 0   # decayed
    np.testing.assert_allclose(np.asarray(p2["b"]), np.asarray(params["b"]))


# ---------------------------------------------------------------------------
# int8 stochastic-rounded compression
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(4, 400))
@settings(max_examples=25, deadline=None)
def test_compress_roundtrip_error_bound(seed, n):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(1, n)) * rng.uniform(1e-4, 10), jnp.float32)
    q, s = compress_int8(g, jax.random.PRNGKey(seed % 1000))
    g_hat = decompress_int8(q, s)
    step = np.asarray(s).max()
    assert float(jnp.abs(g_hat - g).max()) <= step + 1e-7


def test_compress_unbiased():
    """E[dequant(quant(g))] = g — stochastic rounding kills systematic bias."""
    g = jnp.full((1, 64), 0.3337, jnp.float32)
    acc = np.zeros((1, 64))
    trials = 400
    for i in range(trials):
        q, s = compress_int8(g, jax.random.PRNGKey(i))
        acc += np.asarray(decompress_int8(q, s))
    mean = acc / trials
    step = 0.3337 / 127
    assert np.abs(mean - 0.3337).max() < 0.25 * step


def test_compress_payload_is_int8():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(1, 256)), jnp.float32)
    q, s = compress_int8(g, jax.random.PRNGKey(0))
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert q.size == 256 and s.size == 1         # 4× fewer wire bytes vs fp32
