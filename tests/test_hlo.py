"""HLO analyzer correctness: trip counts, dot FLOPs, collective bytes."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.launch.hlo import analyze_module, collective_bytes, roofline_terms

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# A crafted module exercising: while trip count, fused dot, collectives.
HLO = """
HloModule test

%fused_mul (p0: f32[8,16], p1: f32[16,4]) -> f32[8,4] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %p1 = f32[16,4]{1,0} parameter(1)
  ROOT %dot.1 = f32[8,4]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%body (arg: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %arg = (s32[], f32[8,4]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,4]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[8,4]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,4]) tuple(%i2, %ar)
}

%cond (arg: (s32[], f32[8,4])) -> pred[] {
  %arg = (s32[], f32[8,4]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (a: f32[8,16], b: f32[16,4]) -> (s32[], f32[8,4]) {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[16,4]{1,0} parameter(1)
  %f = f32[8,4]{1,0} fusion(%a, %b), kind=kOutput, calls=%fused_mul
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,4]) tuple(%zero, %f)
  ROOT %w = (s32[], f32[8,4]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""


def test_analyzer_on_crafted_module():
    costs = analyze_module(HLO)
    # one dot: 2·8·4·16 = 1024 flops, in a fusion called once
    assert costs.flops == 1024
    # all-reduce of f32[8,4] = 128 B payload, ×5 trips
    assert costs.collectives["all-reduce"] == 128 * 5
    assert costs.collective_wire == 2 * 128 * 5   # ring model doubles AR
    assert costs.n_whiles == 1 and costs.n_unknown_trip == 0
    # memory: fusion (a 512 + b 256 + out 128) once + loop body AR ops ×5
    assert costs.memory_bytes > 0


def test_roofline_terms_bottleneck():
    t = roofline_terms(197e12, 100e9, 1e9)       # 1 s compute, <1 s others
    assert t["bottleneck"] == "compute"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    t2 = roofline_terms(1e12, 819e9, 500e9)
    assert t2["bottleneck"] == "collective"


def test_analyzer_against_real_jit():
    """End-to-end: scan of matmuls — analyzer flops must scale with length."""
    code = r"""
import jax, jax.numpy as jnp, sys
sys.path.insert(0, %r)
from repro.launch.hlo import analyze_module
def make(n):
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y
    return jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile().as_text()
a5 = analyze_module(make(5)).flops
a10 = analyze_module(make(10)).flops
assert a5 > 0, a5
ratio = a10 / a5
assert 1.8 < ratio < 2.2, ratio
print("OK", a5, a10)
""" % SRC
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300)
    assert p.returncode == 0, p.stderr
    assert "OK" in p.stdout
