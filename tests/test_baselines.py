"""Fig. 6 reproduction bands (see benchmarks/fig6_comparison.py docstring)."""
import pytest

from benchmarks import fig6_comparison


@pytest.fixture(scope="module")
def fig6():
    return fig6_comparison.run(verbose=False)


def test_odin_faster_than_everything(fig6):
    for res in fig6["results"]["literature"].values():
        for v in res["speedup"].values():
            assert v > 1.0


def test_isaac_speed_bands(fig6):
    b = fig6["bands"]
    lo, hi = b["isaac_speed_vgg"]
    assert 3 <= lo <= 20            # paper floor: 5.8×
    lo_c, hi_c = b["isaac_speed_cnn"]
    assert 5 <= hi_c <= 200         # paper ceiling: 90.8×
    assert hi_c > hi                # CNN margin exceeds VGG margin (paper §VI-B)


def test_cpu_speed_scale(fig6):
    # paper: up to 438× (VGG) / 569× (CNN)
    assert 100 <= fig6["bands"]["cpu_speed_max"] <= 2000


def test_energy_accounting_finding(fig6):
    """The documented calibration: literature PCRAM energies → ODIN wins vs
    ISAAC by single digits; the paper's 3-digit bands need add-on-only
    accounting.  Both directions must hold or the finding text is stale."""
    b = fig6["bands"]
    assert b["isaac_energy_vgg_lit"][0] > 1.0          # still wins
    assert b["isaac_energy_vgg_lit"][1] < 100          # nowhere near 1554×
    assert b["isaac_energy_vgg_implied"][0] > 50       # add-on-only: 3 digits
    lo, hi = b["isaac_energy_cnn_implied"]
    assert lo < 23.2 < hi * 1.5                        # brackets paper's 23.2×


def test_unpipelined_isaac_slower_than_pipelined(fig6):
    for res in fig6["results"]["literature"].values():
        assert res["speedup"]["ISAAC-unpipelined"] >= res["speedup"]["ISAAC-pipelined"]


def test_vgg_margin_smaller_than_cnn(fig6):
    """Paper §VI-B: conversion overheads shrink ODIN's VGG margin."""
    res = fig6["results"]["literature"]
    vgg = min(res[n]["speedup"]["ISAAC-pipelined"] for n in ("VGG1", "VGG2"))
    cnn = min(res[n]["speedup"]["ISAAC-pipelined"] for n in ("CNN1", "CNN2"))
    assert cnn > vgg
