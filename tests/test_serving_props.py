"""Property-based invariant suite for the refcounted prefix-sharing pool.

Random interleavings of admit / decode / preempt / resume / finish run
against the *pure bookkeeping* layer (Scheduler + BlockPool + PrefixCache —
no jax), checking after every step that

* every block's refcount equals the number of running tables referencing it
  plus the prefix cache's claim plus any swapped request's retained
  (sharing-aware swap) claims,
* no block is simultaneously free and referenced (or retired and either),
* total pool accounting is conserved (free ∪ referenced ∪ retired
  partitions the pool on the device tier; free + referenced == n_blocks on
  the swap tier),
* tables never alias a block twice, always cover their request's cached
  rows, and every block the next decode dispatch may write (the full
  ``write_span`` under speculative emission) is table-exclusive,

and at drain time that every request finished with its full token budget.
Scenarios may run with speculative emission (``spec_k > 0``): each decode
step emits 1..K+1 tokens per running request behind an accept-aware
``grant_horizon`` pre-extension, and a request's cached length never drops
below its pre-step committed value.
The same scenario machinery runs two ways: hypothesis-driven (random
structure shrunk to minimal counterexamples; CI runs the ``ci`` profile with
a pinned derandomized seed) and a seeded numpy sweep so the properties are
exercised even where hypothesis is not installed.

The end-to-end property — a prefix-shared engine is token-identical to an
unshared run of the same stream — lives at the bottom (jax, slow-marked).
"""
import collections

import numpy as np
import pytest

from serving_harness import materialize, mixed_spec, run_workload

from repro.serving.blocks import BlockPool, SwapTicket
from repro.serving.scheduler import (PrefixCache, Request, RequestState,
                                     Scheduler)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # container without test extras
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")


# ---------------------------------------------------------------------------
# scenario driver (pure bookkeeping — mirrors ServingEngine.step)
# ---------------------------------------------------------------------------

class ReqSpec:
    """One synthetic request: which shared prompt bank it draws from, how
    much unique tail, its budget and arrival step."""

    def __init__(self, group: int, prefix_len: int, tail: list,
                 max_new: int, arrival: int):
        self.group = group
        self.prefix_len = prefix_len
        self.tail = tail
        self.max_new = max_new
        self.arrival = arrival


class PoolInvariantDriver:
    """Drives a Scheduler the way the engine does, minus the device work.

    Decode emits a deterministic pseudo-token per request so recompute
    replays re-match resident prefixes the same way the engine's would.
    """

    def __init__(self, *, n_blocks: int, block_size: int, slots: int,
                 max_len: int, swap_blocks: int = 0,
                 prefix_sharing: bool = True, banks=None, spec_k: int = 0,
                 chaos_rng=None):
        self.pool = BlockPool(n_blocks, block_size)
        self.cache = (PrefixCache(self.pool, block_size)
                      if prefix_sharing else None)
        self.swap = BlockPool(swap_blocks, block_size) if swap_blocks else None
        self.sched = Scheduler(slots, self.pool, max_len,
                               swap_pool=self.swap, prefix_cache=self.cache,
                               write_span=spec_k + 1)
        self.spec_k = spec_k
        self.spec_multi_emits = 0        # decode steps that emitted > 1 token
        self.kept_claims = 0             # swap-out blocks retained on-device
        self.banks = banks or []
        self.done = []
        self.released = []               # chaos-terminated (cancel/fail)
        self.all_reqs = []
        self.t = 0
        # chaos mode: a seeded rng injects cancellations, allocation
        # failures, swap copy faults, and PCRAM bad-block retirements
        # (stuck-at flags + wear-exhaustion burns against a tight endurance
        # budget) at the same seams the engine's fault plan hits — the
        # invariants must hold through ALL of them
        self.chaos = chaos_rng
        self.chaos_hits = collections.Counter()
        if chaos_rng is not None:
            self.pool.endurance_budget = 64

    def submit_spec(self, rid: int, spec: ReqSpec) -> Request:
        bank = self.banks[spec.group] if self.banks else []
        prompt = np.asarray(list(bank[:spec.prefix_len]) + list(spec.tail),
                            np.int32)
        req = Request(rid=rid, prompt=prompt, max_new=spec.max_new,
                      arrival=float(spec.arrival))
        self.sched.submit(req)
        self.all_reqs.append(req)
        return req

    def _emit(self, req: Request) -> None:
        # deterministic token stream: replays hash to the same replay tokens
        req.generated.append(np.int32((req.rid * 31 + req.n_generated * 7) % 5))
        # bill the decode write to the endurance accounting, like the engine
        # does for every device KV scatter — record_writes raises loudly if
        # a retirement remap ever left a table pointing at a retired block
        bi = (req.cached_len - 1) // self.pool.block_size
        if 0 <= bi < len(req.block_table):
            self.pool.record_writes([(req.block_table[bi], 1)], float(self.t))

    def step(self) -> None:
        if self.chaos is not None:
            self._chaos_pre()
        plan = self.sched.plan(float(self.t))
        for req, mode, swap_ids, old_slot, dev_ids in plan.preempt:
            if mode == "swap":
                if self.chaos is not None and self.chaos.random() < 0.25:
                    # injected swap-out copy fault: the engine downgrades
                    # the victim to recompute before any ticket exists
                    self.sched.fail_swap_out(req)
                    self.chaos_hits["swap_out_fault"] += 1
                    continue
                req.ticket = SwapTicket(swap_ids, req.cached_len,
                                        skip_blocks=len(req.kept_blocks))
                self.kept_claims += len(req.kept_blocks)
        for req in plan.resume:
            if self.chaos is not None and self.chaos.random() < 0.25:
                # injected swap-in copy fault: placement torn down, request
                # requeued as recompute, ticket blocks freed by the scheduler
                self.sched.fail_resume(req)
                self.chaos_hits["swap_in_fault"] += 1
                continue
            self.swap.free(req.ticket.block_ids)
            req.ticket = None
        for req in plan.admit:
            if req.n_generated == 0:     # fresh prefill emits the first token
                self._emit(req)
        for req in list(self.sched.running.values()):
            if req.done:
                self.sched.complete(req, float(self.t))
                self.done.append(req)
        per = 1
        if self.spec_k and self.sched.running:
            # accept-aware pre-extension, exactly like the engine's dispatch;
            # 0 ⇒ the pool cannot cover a verify tile — plain single step
            if self.sched.grant_horizon(1, float(self.t),
                                        spec_k=self.spec_k):
                per = self.spec_k + 1
        for slot in sorted(self.sched.running):
            req = self.sched.running[slot]
            committed = req.cached_len
            # deterministic accepted-run length in [1, min(per, remaining)]
            m = 1 + (req.rid * 13 + req.n_generated * 7) % per
            m = max(1, min(m, req.remaining))
            self.spec_multi_emits += m > 1
            for _ in range(m):
                self._emit(req)
            assert req.cached_len >= committed   # rollback floor
            if req.done:
                self.sched.complete(req, float(self.t))
                self.done.append(req)
        self.t += 1
        self.check_invariants()

    def _chaos_pre(self) -> None:
        """Pre-plan chaos: random cancellations (any live state) and armed
        allocation failures — the terminal-lifecycle and denial seams."""
        live = [r for r in self.all_reqs if not r.terminal]
        if live and self.chaos.random() < 0.15:
            req = live[int(self.chaos.integers(0, len(live)))]
            self.chaos_hits[f"cancel_{req.state.value}"] += 1
            self.sched.release(req, RequestState.CANCELLED, float(self.t),
                               "chaos")
            self.released.append(req)
        if self.chaos.random() < 0.15:
            self.pool.arm_alloc_failures(int(self.chaos.integers(1, 3)))
            self.chaos_hits["alloc_armed"] += 1
        # PCRAM bad-block chaos: stuck-at flags and wear-exhaustion burns,
        # both landing in the same retire_blocks drain/remap path the engine
        # uses.  Retirement is capacity-bounded: never shrink usable_blocks
        # below what the largest still-live request needs, or the scheduler
        # (correctly) can never drain the queue.
        need = max((self.pool.blocks_for(len(r.prompt) + r.max_new)
                    for r in self.all_reqs if not r.terminal), default=0)
        headroom = self.pool.usable_blocks - max(need, 1)
        if headroom >= 1 and self.chaos.random() < 0.15:
            bid = int(self.chaos.integers(0, self.pool.n_blocks))
            if bid not in self.pool.retired:
                copies = self.sched.retire_blocks([bid])
                self.chaos_hits["retire_stuck"] += 1
                self.chaos_hits["retire_remap"] += len(copies)
        if headroom >= 1 and self.chaos.random() < 0.1:
            live = [b for b in range(self.pool.n_blocks)
                    if b not in self.pool.retired]
            bid = live[int(self.chaos.integers(0, len(live)))]
            self.pool.record_writes([(bid, self.pool.endurance_budget)],
                                    float(self.t))
            worn = self.pool.over_budget()
            assert bid in worn
            copies = self.sched.retire_blocks(worn[:1])
            self.chaos_hits["retire_worn"] += 1
            self.chaos_hits["retire_remap"] += len(copies)

    def run(self, specs, max_steps: int = 3000) -> None:
        for rid, spec in enumerate(specs):
            self.submit_spec(rid, spec)
        while self.sched.has_work:
            self.step()
            assert self.t < max_steps, "scheduler failed to drain"
        # drain-time properties: every request reached exactly one terminal
        # state; completed ones used their full budget; pools fully released
        assert all(r.terminal for r in self.all_reqs)
        done_rids = sorted(r.rid for r in self.done)
        rel_rids = sorted(r.rid for r in self.released)
        assert sorted(done_rids + rel_rids) == list(range(len(specs)))
        assert all(r.n_generated >= r.max_new for r in self.done)
        counts = self._table_counts()
        assert not counts                # no table holds blocks any more
        if self.swap:
            assert self.swap.used_blocks == 0

    # -- invariants ---------------------------------------------------------

    def _table_counts(self):
        counts = collections.Counter()
        for r in self.sched.running.values():
            counts.update(r.block_table)
        return counts

    def check_invariants(self) -> None:
        free, refs = self.pool.snapshot()
        counts = self._table_counts()
        if self.cache is not None:
            for b in self.cache.held_blocks():
                counts[b] += 1
        for r in self.sched.swapped:     # sharing-aware swap retained claims
            counts.update(r.kept_blocks)
        # every refcount equals the number of tables referencing the block
        # (plus the cache's and swapped-retained claims); free / referenced /
        # retired partition the pool (pairwise disjoint, conserved in total)
        retired = self.pool.retired
        assert dict(counts) == refs, (dict(counts), refs)
        assert not (set(free) & set(refs))
        assert not (set(free) & retired)
        assert not (set(refs) & retired)
        assert len(free) == len(set(free))
        assert len(free) + len(refs) + len(retired) == self.pool.n_blocks
        assert self.pool.usable_blocks == self.pool.n_blocks - len(retired)
        bs = self.pool.block_size
        for r in self.sched.running.values():
            assert len(r.block_table) == len(set(r.block_table))
            assert len(r.block_table) >= self.pool.blocks_for(r.cached_len)
            # every block the next dispatch may write (the write_span rows
            # under speculative emission) must be table-exclusive (blocks
            # may not exist yet — growth/grant pre-extension adds them)
            first = r.cached_len // bs
            last = (r.cached_len + self.sched.write_span - 1) // bs
            for idx in range(first, min(last + 1, len(r.block_table))):
                wb = r.block_table[idx]
                # never write into a retired (bad) block — retirement must
                # have remapped every live table before the next dispatch
                assert wb not in retired
                held = 1 if (self.cache is not None
                             and self.cache.holds(wb)) else 0
                assert self.pool.refs(wb) - held == 1
        for r in self.sched.swapped:
            # retained blocks stay allocated and content-immutable: nobody
            # may hold them as a write block... their claims are accounted
            # above; here just require they are still live
            for b in r.kept_blocks:
                assert self.pool.refs(b) >= 1
        # swap-tier conservation: tickets of swapped requests own the tier
        if self.swap is not None:
            ticket_blocks = [b for r in self.sched.swapped
                             for b in r.ticket.block_ids]
            assert len(ticket_blocks) == len(set(ticket_blocks))
            assert len(ticket_blocks) == self.swap.used_blocks


def _scenario_from_rng(rng: np.random.Generator):
    """One random scenario: pool geometry + a request stream with colliding
    shared prompt prefixes (the knob that makes sharing/COW/eviction fire)."""
    bs = int(rng.choice([2, 4]))
    slots = int(rng.integers(1, 5))
    n_blocks = int(rng.integers(6, 25))
    swap_blocks = int(rng.choice([0, 0, 12]))
    cap_tokens = n_blocks * bs
    max_len = min(int(rng.integers(3, 9)) * bs, cap_tokens)
    banks = [list(rng.integers(0, 5, size=max_len)) for _ in range(2)]
    specs = []
    for _ in range(int(rng.integers(3, 18))):
        limit = min(max_len, cap_tokens) - 1
        prefix = int(rng.integers(0, min(limit - 1, max_len // 2) + 1))
        tail = list(rng.integers(0, 5, size=int(rng.integers(1, 4))))
        budget = limit - prefix - len(tail)
        if budget < 1:
            continue
        max_new = int(rng.integers(1, budget + 1))
        specs.append(ReqSpec(int(rng.integers(0, 2)), prefix, tail, max_new,
                             arrival=int(rng.integers(0, 12))))
    sharing = bool(rng.random() < 0.8)
    spec_k = int(rng.choice([0, 0, 2, 3]))    # speculative emission widths
    return dict(n_blocks=n_blocks, block_size=bs, slots=slots,
                max_len=max_len, swap_blocks=swap_blocks,
                prefix_sharing=sharing, banks=banks, spec_k=spec_k), specs


def _run_scenario(kw, specs):
    driver = PoolInvariantDriver(**kw)
    driver.run(specs)
    return driver


# ---------------------------------------------------------------------------
# seeded sweep (always runs, hypothesis or not)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(25))
def test_pool_invariants_random_interleavings_seeded(seed):
    kw, specs = _scenario_from_rng(np.random.default_rng(seed))
    if not specs:
        pytest.skip("degenerate scenario")
    driver = _run_scenario(kw, specs)
    # scenarios must collectively exercise the interesting transitions —
    # checked in aggregate below, here just sanity
    assert driver.t > 0


def test_seeded_sweep_covers_preempt_resume_and_sharing():
    """The 25-seed sweep must actually hit preemption (swap + recompute),
    sharing and COW forks somewhere, or the invariants prove nothing."""
    hits = collections.Counter()
    for seed in range(25):
        kw, specs = _scenario_from_rng(np.random.default_rng(seed))
        if not specs:
            continue
        driver = _run_scenario(kw, specs)
        hits["swap"] += sum(r.n_preempt_swap for r in driver.all_reqs)
        hits["recompute"] += sum(r.n_preempt_recompute for r in driver.all_reqs)
        hits["spec"] += driver.spec_multi_emits
        hits["kept"] += driver.kept_claims
        if driver.cache is not None:
            hits["shared"] += driver.cache.hit_tokens
            hits["forks"] += driver.cache.forks
    assert hits["swap"] > 0
    assert hits["recompute"] > 0
    assert hits["shared"] > 0
    assert hits["forks"] > 0
    assert hits["spec"] > 0          # multi-token speculative emission ran


# ---------------------------------------------------------------------------
# hypothesis-driven structure (shrinks to minimal counterexamples)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def scenarios(draw):
        bs = draw(st.sampled_from([2, 4]))
        slots = draw(st.integers(1, 4))
        n_blocks = draw(st.integers(6, 24))
        swap_blocks = draw(st.sampled_from([0, 12]))
        max_len = min(draw(st.integers(3, 8)) * bs, n_blocks * bs)
        banks = [draw(st.lists(st.integers(0, 4), min_size=max_len,
                               max_size=max_len)) for _ in range(2)]
        limit = max_len - 1
        n_reqs = draw(st.integers(1, 14))
        specs = []
        for _ in range(n_reqs):
            prefix = draw(st.integers(0, max(0, min(limit - 2, max_len // 2))))
            tail = draw(st.lists(st.integers(0, 4), min_size=1, max_size=3))
            budget = limit - prefix - len(tail)
            if budget < 1:
                continue
            specs.append(ReqSpec(draw(st.integers(0, 1)), prefix, tail,
                                 draw(st.integers(1, budget)),
                                 draw(st.integers(0, 10))))
        sharing = draw(st.booleans())
        spec_k = draw(st.sampled_from([0, 2, 3]))
        return dict(n_blocks=n_blocks, block_size=bs, slots=slots,
                    max_len=max_len, swap_blocks=swap_blocks,
                    prefix_sharing=sharing, banks=banks, spec_k=spec_k), specs

    @needs_hypothesis
    @settings(deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(scenarios())
    def test_pool_invariants_hypothesis(scn):
        kw, specs = scn
        if specs:
            _run_scenario(kw, specs)


# ---------------------------------------------------------------------------
# end-to-end property: shared == unshared token streams (jax)
# ---------------------------------------------------------------------------

def _engine_shared_vs_unshared(shared_prefix, share_groups, n_blocks,
                               swap_blocks, seed, setup):
    cfg, params = setup
    spec = mixed_spec(n_requests=6, shared_prefix=shared_prefix,
                      share_groups=share_groups, prompt_buckets=(8, 16),
                      gen_buckets=(4, 16))
    base, _ = run_workload(cfg, params, max_len=64, spec=spec, seed=seed,
                           prefix_sharing=False)
    shared, s = run_workload(cfg, params, max_len=64, spec=spec, seed=seed,
                             n_blocks=n_blocks, swap_blocks=swap_blocks,
                             prefix_sharing=True)
    assert base == shared, (
        f"prefix-shared stream diverged (prefix={shared_prefix}, "
        f"groups={share_groups}, n_blocks={n_blocks}, swap={swap_blocks}, "
        f"seed={seed}; prefix stats {s['prefix']})")
    return s


@pytest.fixture(scope="module")
def phi4_setup():
    return materialize("phi4-mini-3.8b")


@pytest.mark.slow
@pytest.mark.parametrize("shared_prefix,groups,n_blocks,swap", [
    (13, 1, None, 0),                    # COW fork, no pressure
    (24, 2, None, 0),                    # two prompt families
    (16, 1, 11, 32),                     # shared blocks through swap preempt
    (16, 2, 11, 0),                      # shared blocks through recompute
])
def test_props_engine_shared_stream_token_identical(
        shared_prefix, groups, n_blocks, swap, phi4_setup):
    s = _engine_shared_vs_unshared(shared_prefix, groups, n_blocks, swap,
                                   seed=3, setup=phi4_setup)
    assert s["prefix"]["hit_tokens"] > 0


if HAVE_HYPOTHESIS:

    @needs_hypothesis
    @pytest.mark.slow
    @settings(max_examples=4, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.function_scoped_fixture])
    @given(shared_prefix=st.integers(8, 28), groups=st.integers(1, 2),
           tight=st.booleans(), seed=st.integers(0, 5))
    def test_props_engine_shared_stream_hypothesis(shared_prefix, groups,
                                                   tight, seed, phi4_setup):
        _engine_shared_vs_unshared(shared_prefix, groups,
                                   12 if tight else None, 0, seed, phi4_setup)
