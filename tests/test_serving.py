"""Tests for repro.serving: block pool invariants, scheduler policy under a
randomized request stream, and end-to-end engine correctness.

The engine tests pin the strongest property available: the continuous-
batching path is *token-for-token* equal to (a) the static-batch loop on a
uniform workload and (b) an unconstrained run when preemption (swap AND
recompute) is forced by a tight block pool, and (c) a prefix-shared run is
token-identical to the unshared engine on shared-prompt streams.  The engine
parity families share one harness (tests/serving_harness.py).
"""
import numpy as np
import pytest

from serving_harness import (HORIZON_ARCHS, PARITY_ARCHS, materialize,
                             mixed_spec, run_workload, token_streams)

from repro.serving.blocks import BlockPool
from repro.serving.scheduler import PrefixCache, Request, RequestState, Scheduler


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------

def test_block_pool_alloc_free_reuse():
    pool = BlockPool(8, 4)
    assert pool.blocks_for(0) == 0
    assert pool.blocks_for(1) == 1
    assert pool.blocks_for(4) == 1
    assert pool.blocks_for(5) == 2
    a = pool.alloc(5)
    b = pool.alloc(3)
    assert pool.free_blocks == 0 and pool.used_blocks == 8
    assert pool.alloc(1) is None                     # exhausted: no change
    assert pool.free_blocks == 0
    assert len(set(a) | set(b)) == 8                 # disjoint ids
    pool.free(b)
    assert pool.free_blocks == 3
    c = pool.alloc(3)
    assert set(c) == set(b)                          # freed blocks are reused
    with pytest.raises(ValueError):
        pool.free([a[0], a[0]])                      # double free detected
    assert pool.alloc(4) is None                     # all-or-nothing

def test_block_pool_exhaustion_and_validation():
    pool = BlockPool(4, 2)
    assert pool.alloc(0) == []                       # empty alloc is a no-op
    with pytest.raises(ValueError):
        pool.alloc(-1)
    with pytest.raises(ValueError):
        BlockPool(-1, 2)
    with pytest.raises(ValueError):
        BlockPool(4, 0)
    a = pool.alloc(4)
    assert pool.alloc(1) is None                     # exhausted
    pool.free(a)
    with pytest.raises(ValueError):
        pool.free([a[0]])                            # double free after bulk free


def test_block_pool_extend_to():
    pool = BlockPool(4, 4)
    table = []
    assert pool.extend_to(table, 0) and table == []
    assert pool.extend_to(table, 9)                  # 3 blocks
    assert len(table) == 3 and pool.free_blocks == 1
    assert pool.extend_to(table, 12) and len(table) == 3   # already covered
    # a grant beyond *total* pool capacity can never be satisfied: it must
    # fail loudly instead of silently reporting "try again later" (the
    # caller would preempt victims forever without ever meeting it)
    with pytest.raises(ValueError):
        pool.extend_to(table, 20)                    # needs 5, pool has 4
    assert len(table) == 3 and pool.free_blocks == 1 # no change on failure
    assert pool.extend_to(table, 16) and len(table) == 4
    # within capacity but currently short stays the quiet all-or-nothing False
    other: list = []
    assert not pool.extend_to(other, 8)
    assert other == []


def test_block_pool_randomized_invariants():
    rng = np.random.default_rng(0)
    pool = BlockPool(32, 2)
    live = []
    for _ in range(500):
        if live and rng.random() < 0.45:
            ids = live.pop(rng.integers(len(live)))
            pool.free(ids)
        else:
            ids = pool.alloc(int(rng.integers(1, 6)))
            if ids is not None:
                live.append(ids)
        held = [b for ids in live for b in ids]
        assert len(held) == len(set(held))                       # no aliasing
        assert pool.free_blocks + len(held) == pool.n_blocks     # conservation


# ---------------------------------------------------------------------------
# scheduler (no jax: pure policy)
# ---------------------------------------------------------------------------

def _mk_req(rid, plen, gen, arrival=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32), max_new=gen,
                   arrival=arrival)


def _drive(req, steps=1):
    """Simulate the engine's per-step token bookkeeping for a running request."""
    for _ in range(steps):
        req.generated.append(0)


def test_scheduler_admission_and_completion():
    pool = BlockPool(64, 4)
    sched = Scheduler(2, pool, max_len=64)
    reqs = [_mk_req(i, 8, 4) for i in range(4)]
    for r in reqs:
        sched.submit(r)
    plan = sched.plan(now=0.0)
    assert [r.rid for r in plan.admit] == [0, 1]     # 2 slots
    assert all(r.state is RequestState.RUNNING for r in plan.admit)
    assert all(len(r.block_table) == pool.blocks_for(9) for r in plan.admit)
    # finish request 0 → its slot and blocks free; next plan admits request 2
    for r in plan.admit:
        _drive(r)                                    # first token from prefill
    reqs[0].generated.extend([0] * 3)
    sched.complete(reqs[0], now=1.0)
    assert reqs[0].state is RequestState.DONE and reqs[0].t_done == 1.0
    plan2 = sched.plan(now=1.0)
    assert [r.rid for r in plan2.admit] == [2]
    assert sum(len(r.block_table) for r in sched.running.values()) == pool.used_blocks


def test_scheduler_respects_arrival_times():
    pool = BlockPool(64, 4)
    sched = Scheduler(4, pool, max_len=64)
    sched.submit(_mk_req(0, 8, 4, arrival=0.0))
    sched.submit(_mk_req(1, 8, 4, arrival=10.0))
    plan = sched.plan(now=0.5)
    assert [r.rid for r in plan.admit] == [0]
    plan = sched.plan(now=10.5)
    assert [r.rid for r in plan.admit] == [1]


def test_scheduler_submit_validation():
    pool = BlockPool(3, 4)                           # 12-token device budget
    sched = Scheduler(2, pool, max_len=16)
    with pytest.raises(ValueError):
        sched.submit(_mk_req(0, 12, 8))              # 20 > max_len 16
    with pytest.raises(ValueError):
        sched.submit(_mk_req(1, 8, 8))               # 16 tokens = 4 blocks > 3
    sched.submit(_mk_req(2, 8, 4, arrival=0.0))      # 12 tokens = 3 blocks: fine


def test_scheduler_growth_preempts_youngest_and_recovers():
    # 2 slots, pool of 6 blocks × 4 tokens.  Two prompt-8 requests admit with
    # 3 blocks each (prompt + first decode row).  Once a request's cached
    # length hits 12 its next decode row needs a 4th block — the pool is
    # empty, so the younger request is preempted (recompute: no swap pool).
    pool = BlockPool(6, 4)
    sched = Scheduler(2, pool, max_len=24)
    r0, r1 = _mk_req(0, 8, 12, arrival=0.0), _mk_req(1, 8, 12, arrival=1.0)
    sched.submit(r0), sched.submit(r1)
    plan = sched.plan(now=2.0)
    assert len(plan.admit) == 2
    assert pool.free_blocks == 0
    _drive(r0, 5), _drive(r1, 5)                     # cached_len 12 → grow
    plan = sched.plan(now=3.0)
    assert [(p[0].rid, p[1]) for p in plan.preempt] == [(1, "recompute")]
    assert r1.state is RequestState.QUEUED and r1.block_table == []
    assert r1.n_preempt_recompute == 1
    assert len(r0.block_table) == 4                  # got its growth block
    # r1 keeps its generated tokens for recompute-readmission
    assert r1.n_generated == 5
    # a preemption step admits/resumes nothing (anti-thrash)
    assert not plan.admit and not plan.resume
    _drive(r0, 7)
    sched.complete(r0, now=4.0)
    plan = sched.plan(now=4.0)
    assert [r.rid for r in plan.admit] == [1]
    assert r1.state is RequestState.RUNNING


def test_scheduler_randomized_stream_conserves_blocks_and_finishes():
    rng = np.random.default_rng(42)
    pool = BlockPool(12, 4)
    sched = Scheduler(3, pool, max_len=32)
    reqs = [_mk_req(i, int(rng.integers(1, 17)), int(rng.integers(1, 13)),
                    arrival=float(rng.uniform(0, 5))) for i in range(25)]
    for r in reqs:
        sched.submit(r)
    done = []
    for step in range(2000):
        if not sched.has_work:
            break
        now = step * 0.1
        plan = sched.plan(now)
        for req in plan.admit:                       # engine: prefill emits token 1
            if req.n_generated == 0:
                req.generated.append(0)
            if req.done:                             # max_new == 1 retires here
                sched.complete(req, now)
                done.append(req)
        for slot in sorted(sched.running):
            req = sched.running[slot]
            req.generated.append(0)
            if req.done:
                sched.complete(req, now)
                done.append(req)
        # invariants every step
        held = [b for r in sched.running.values() for b in r.block_table]
        assert len(held) == len(set(held))
        assert pool.free_blocks + len(held) == pool.n_blocks
        for r in sched.running.values():
            assert len(r.block_table) >= pool.blocks_for(r.cached_len)
    assert sched.has_work is False
    assert sorted(r.rid for r in done) == list(range(25))
    assert all(r.n_generated >= r.max_new for r in done)
    assert pool.used_blocks == 0


def _admit_two(pool_blocks=64, bs=4, slots=2, max_len=64, gens=(12, 5)):
    """Two running requests (first token emitted), rest of the stream waiting."""
    pool = BlockPool(pool_blocks, bs)
    sched = Scheduler(slots, pool, max_len=max_len)
    reqs = [_mk_req(i, 8, g) for i, g in enumerate(gens)]
    for r in reqs:
        sched.submit(r)
    plan = sched.plan(now=0.0)
    for r in plan.admit:
        _drive(r)                                    # first token from prefill
    return pool, sched, reqs


def test_grant_horizon_completion_cap_and_preextension():
    # an *arrived* waiting request blocks the horizon at the earliest running
    # completion: min remaining = min(12-1, 5-1) = 4 → already a power of two
    pool, sched, reqs = _admit_two(gens=(12, 5, 4))
    h = sched.grant_horizon(16, now=0.0)
    assert h == 4
    r0, r1 = reqs[0], reqs[1]
    # tables pre-extended for the whole grant (capped at each budget)
    assert len(r0.block_table) >= pool.blocks_for(r0.cached_len + 4)
    assert len(r1.block_table) >= pool.blocks_for(r1.cached_len + 4)
    # with no pending work the grant runs to max_h, snapped to a power of two
    pool2, sched2, reqs2 = _admit_two(gens=(40, 37))
    assert sched2.grant_horizon(12, now=0.0) == 8    # 12 → 2^3
    # per-slot extension never exceeds the request's own budget
    pool3, sched3, reqs3 = _admit_two(gens=(40, 3))
    h3 = sched3.grant_horizon(16, now=0.0)
    assert h3 == 16
    big, small = reqs3
    assert len(big.block_table) == pool3.blocks_for(big.cached_len + 16)
    assert len(small.block_table) == pool3.blocks_for(small.cached_len + 2)


def test_grant_horizon_block_headroom_shrinks_grant():
    # 6 blocks × 4 tokens, two prompt-8 requests: 3 blocks each, pool empty.
    # cached_len 8 → h=4 fits the existing tables (12 rows = 3 blocks) but
    # h=8 would need a 4th block per slot → the grant halves instead of
    # preempting.
    pool, sched, reqs = _admit_two(pool_blocks=6, bs=4, max_len=24,
                                   gens=(12, 12))
    assert pool.free_blocks == 0
    assert sched.grant_horizon(8, now=0.0) == 4
    assert all(len(r.block_table) == 3 for r in reqs)


def test_grant_horizon_arrival_cap_and_empty():
    pool = BlockPool(64, 4)
    sched = Scheduler(2, pool, max_len=64)
    assert sched.grant_horizon(16, now=0.0) == 0     # nothing running
    sched.submit(_mk_req(0, 8, 30, arrival=0.0))
    sched.submit(_mk_req(1, 8, 30, arrival=5.0))     # future arrival
    for r in sched.plan(now=0.0).admit:
        _drive(r)
    # free slot + future arrival: cap ≈ steps until admission at 1s/step
    assert sched.grant_horizon(16, now=0.0, est_step_time=1.0) == 4  # 5+1→4
    # without an estimate the arrival cap is disabled
    assert sched.grant_horizon(16, now=0.0) == 16


def test_scheduler_table_version_tracks_mutations():
    pool, sched, reqs = _admit_two(gens=(12, 12))
    v = sched.table_version
    assert v > 0                                     # admissions bumped it
    sched.plan(now=1.0)                              # no growth needed yet
    assert sched.table_version == v
    _drive(reqs[0], 8)                               # cached_len 8 → 9: grow
    sched.plan(now=2.0)
    assert sched.table_version > v
    v = sched.table_version
    assert sched.grant_horizon(8, now=2.0) == 8      # pre-extends r1's table
    assert sched.table_version > v
    v = sched.table_version
    reqs[1].generated.extend([0] * 11)
    sched.complete(reqs[1], now=3.0)
    assert sched.table_version > v


# ---------------------------------------------------------------------------
# prefix cache: refcounted sharing + COW forks (pure bookkeeping, no jax)
# ---------------------------------------------------------------------------

def test_block_pool_refcounts_share_free_fork():
    pool = BlockPool(8, 4)
    a = pool.alloc(2)
    pool.share(a)                                     # second claim
    assert all(pool.refs(b) == 2 for b in a)
    pool.free(a)
    assert all(pool.refs(b) == 1 for b in a)          # still allocated
    assert pool.free_blocks == 6
    # COW fork: exclusive → in place; shared → fresh block, claim moved
    assert pool.fork(a[0]) == a[0]
    pool.share([a[0]])
    dst = pool.fork(a[0])
    assert dst not in a and pool.refs(dst) == 1 and pool.refs(a[0]) == 1
    pool.free(a)
    pool.free([dst])
    assert pool.free_blocks == 8 and pool.used_blocks == 0
    with pytest.raises(ValueError):
        pool.share([a[0]])                            # share of a free block
    with pytest.raises(ValueError):
        pool.fork(a[0])


def _sched_with_cache(n_blocks=16, bs=4, slots=4, max_len=64):
    pool = BlockPool(n_blocks, bs)
    cache = PrefixCache(pool, bs)
    sched = Scheduler(slots, pool, max_len=max_len, prefix_cache=cache)
    return pool, cache, sched


def _tok_req(rid, toks, gen, arrival=0.0):
    return Request(rid=rid, prompt=np.asarray(toks, np.int32), max_new=gen,
                   arrival=arrival)


def test_prefix_admission_aliases_blocks_and_allocates_marginal():
    pool, cache, sched = _sched_with_cache()
    base = list(range(11))                           # 2 full blocks + 3 partial
    r0 = _tok_req(0, base + [90], 4)                 # 12 tokens: 3 full blocks
    r1 = _tok_req(1, base + [91], 4)                 # shares 8 full + 3 partial
    sched.submit(r0), sched.submit(r1)
    plan = sched.plan(0.0)
    assert [r.rid for r in plan.admit] == [0, 1]
    g1 = plan.grants[1]
    assert 0 not in plan.grants                      # nothing resident for r0
    assert g1.shared_blocks == 2 and g1.start == 11  # 8 aliased + 3 via fork
    assert g1.fork is not None
    src, dst = g1.fork
    assert src == r0.block_table[2] and dst == r1.block_table[2]
    assert r1.block_table[:2] == r0.block_table[:2]  # aliased ids
    # refcounts: shared full blocks = r0 + r1 + cache; r0's partial = r0 + cache
    for b in r0.block_table[:2]:
        assert pool.refs(b) == 3
    assert pool.refs(src) == 2
    # marginal accounting: r1 allocated only its fork + unshared tail
    need = pool.blocks_for(r1.cached_len + 1)
    held = {b for r in (r0, r1) for b in r.block_table}
    assert len(held) == pool.blocks_for(r0.cached_len + 1) + need - 2
    # completion releases claims; the cache retains the prompt chain but the
    # decode-tail block (no prompt rows) goes back to the free list
    t0 = list(r0.block_table)
    r0.generated.extend([0] * 4)
    sched.complete(r0, 1.0)
    assert r0.block_table == []
    assert all(pool.refs(b) >= 1 for b in t0[:3])    # prompt blocks retained
    assert pool.refs(t0[3]) == 0


def test_prefix_cache_retains_after_completion_and_rematches():
    pool, cache, sched = _sched_with_cache()
    toks = list(range(10))
    r0 = _tok_req(0, toks, 2)
    sched.submit(r0)
    sched.plan(0.0)
    t0 = list(r0.block_table)
    r0.generated.extend([0, 0])
    sched.complete(r0, 1.0)
    assert len(cache) == 3                           # 2 full + 1 partial node
    assert pool.used_blocks == 3                     # retained by the cache
    r1 = _tok_req(1, toks, 2, arrival=2.0)           # identical prompt, later
    sched.submit(r1)
    plan = sched.plan(2.0)
    g = plan.grants[1]
    assert g.shared_blocks == 2 and g.start == 9     # limit = prompt_len - 1
    assert r1.block_table[:2] == t0[:2]
    assert g.fork is not None and g.fork[0] == t0[2]


def test_prefix_cache_evicts_lru_under_pressure():
    pool, cache, sched = _sched_with_cache(n_blocks=6, bs=4, slots=2, max_len=24)
    r0 = _tok_req(0, list(range(8)), 2)              # 2 full blocks + 1 row
    sched.submit(r0)
    sched.plan(0.0)
    r0.generated.extend([0, 0])
    sched.complete(r0, 1.0)
    assert pool.used_blocks == 2 and cache.reclaimable() == 2
    # a non-matching admission needs 6 blocks: the cache must give its 2 back
    r1 = _tok_req(1, [50 + i for i in range(20)], 4, arrival=2.0)
    sched.submit(r1)
    plan = sched.plan(2.0)
    assert [r.rid for r in plan.admit] == [1]
    # r0's chain was evicted to make room (unmatchable now); the cache holds
    # only r1's freshly registered 5-block prompt chain
    ids, p, src = cache.match(np.asarray(list(range(8)), np.int32), limit=7)
    assert ids == [] and p == 0
    assert len(cache) == 5
    held = set(r1.block_table)
    assert pool.free_blocks + len(held) == pool.n_blocks


def test_prefix_shared_block_never_freed_while_referenced():
    """Preempting (recompute) a request that shares prefix blocks must only
    drop its claims: the co-resident request still reads those blocks."""
    pool, cache, sched = _sched_with_cache(n_blocks=8, bs=4, slots=2, max_len=32)
    toks = list(range(8))
    r0 = _tok_req(0, toks, 16, arrival=0.0)
    r1 = _tok_req(1, toks, 16, arrival=0.1)
    sched.submit(r0), sched.submit(r1)
    plan = sched.plan(1.0)
    # limit = prompt_len - 1 = 7: one aliased full block + COW fork of the 2nd
    assert len(plan.admit) == 2 and plan.grants[1].shared_blocks == 1
    assert plan.grants[1].fork is not None
    shared = r0.block_table[:1]
    for r in plan.admit:
        r.generated.append(0)
    # drive both until the pool runs dry → youngest (r1) preempts
    for step in range(32):
        for r in list(sched.running.values()):
            r.generated.append(0)
        plan = sched.plan(2.0 + step)
        if plan.preempt:
            break
    assert plan.preempt and plan.preempt[0][0] is r1
    # r1's claims dropped, but the shared blocks still carry r0 + cache
    for b in shared:
        assert pool.refs(b) == 2
    held = {b for r in sched.running.values() for b in r.block_table}
    assert set(shared) <= held


def test_write_block_guard_detects_missed_cow_fork():
    """If a block the next decode writes is aliased by another table, plan()
    must fail loudly instead of corrupting the shared prefix."""
    pool, cache, sched = _sched_with_cache()
    r0 = _tok_req(0, list(range(9)), 4)
    sched.submit(r0)
    sched.plan(0.0)
    r0.generated.append(0)
    # simulate a missed COW fork: another table aliases r0's write block
    pool.share([r0.block_table[2]])
    with pytest.raises(RuntimeError, match="COW"):
        sched.plan(1.0)


def test_extend_to_capacity_overflow_fails_loudly_in_growth():
    """Regression: a mid-horizon grant whose target exceeds *total* pool
    capacity must raise out of extend_to, not silently under-deliver.  The
    scheduler path cannot reach it (submit validates), so drive extend_to
    the way grant_horizon does with a tight pool."""
    pool = BlockPool(3, 4)
    table = pool.alloc(3)
    with pytest.raises(ValueError, match="exceeds.*capacity|capacity"):
        pool.extend_to(table, 16)                    # 4 blocks > 3 total
    assert len(table) == 3                           # untouched
    # grant_horizon on a tight pool halves the grant instead of tripping it
    pool2 = BlockPool(6, 4)
    sched = Scheduler(2, pool2, max_len=24)
    for i, g in enumerate((12, 12)):
        sched.submit(_mk_req(i, 8, g))
    plan = sched.plan(0.0)
    for r in plan.admit:
        _drive(r)
    assert pool2.free_blocks == 0
    assert sched.grant_horizon(8, now=0.0) == 4      # headroom-capped, no raise


# ---------------------------------------------------------------------------
# paged store: block-table handoff swap (jax, no model)
# ---------------------------------------------------------------------------

def test_paged_store_block_handoff_roundtrip_and_ticket_reuse():
    """Pool-leaf swap is a block-to-block copy keyed by table ids: survive a
    device-block clobber after swap-out, restore into *different* device
    blocks, and reuse freed swap blocks for a second ticket without leakage."""
    import jax
    from repro.launch.steps import init_serving_caches
    from repro.models import registry
    from repro.serving.blocks import PagedKVStore
    cfg = registry.get_smoke("phi4-mini-3.8b")
    caches = init_serving_caches(cfg, batch=2, max_len=32, block_size=8,
                                 n_blocks=8)
    kp = caches[0]["attn"]["k_pool"]                 # [L, 9, 8, Hkv, D]
    assert kp.shape[1] == 9                          # 8 blocks + write-off
    caches[0]["attn"]["k_pool"] = kp.at[:, 1].set(1.0).at[:, 3].set(3.0)
    caches[0]["attn"]["pos"] = caches[0]["attn"]["pos"].at[:, 0].set(12)

    store = PagedKVStore(caches, n_blocks=4, block_size=8)
    sids = store.pool.alloc(2)
    ticket = store.swap_out(caches, slot=0, block_ids=sids, n_tokens=12,
                            dev_ids=[1, 3])
    # the freed device blocks get clobbered by other requests
    caches[0]["attn"]["k_pool"] = caches[0]["attn"]["k_pool"].at[:, 1].set(-7.0).at[:, 3].set(-7.0)
    # resume into a different slot AND different device blocks
    caches2 = store.swap_in(caches, slot=1, ticket=ticket, dev_ids=[0, 2])
    kp2 = np.asarray(caches2[0]["attn"]["k_pool"], np.float32)
    np.testing.assert_array_equal(kp2[:, 0], 1.0)
    np.testing.assert_array_equal(kp2[:, 2], 3.0)
    assert int(caches2[0]["attn"]["pos"][0, 1]) == 12   # side leaf followed
    # swap-block reuse: freed ids serve the next ticket with fresh contents
    store.pool.free(ticket.block_ids)
    sids2 = store.pool.alloc(2)
    assert set(sids2) == set(sids)
    caches2[0]["attn"]["k_pool"] = caches2[0]["attn"]["k_pool"].at[:, 5].set(5.0)
    t2 = store.swap_out(caches2, slot=0, block_ids=sids2, n_tokens=4,
                        dev_ids=[5])
    caches3 = store.swap_in(caches2, slot=0, ticket=t2, dev_ids=[7])
    np.testing.assert_array_equal(
        np.asarray(caches3[0]["attn"]["k_pool"], np.float32)[:, 7], 5.0)


def test_paged_store_requires_dev_ids_for_pool_leaves():
    from repro.launch.steps import init_serving_caches
    from repro.models import registry
    from repro.serving.blocks import PagedKVStore
    cfg = registry.get_smoke("phi4-mini-3.8b")
    caches = init_serving_caches(cfg, batch=1, max_len=16, block_size=8,
                                 n_blocks=4)
    store = PagedKVStore(caches, n_blocks=2, block_size=8)
    sids = store.pool.alloc(1)
    with pytest.raises(ValueError):
        store.swap_out(caches, 0, sids, 8)           # no dev_ids


# ---------------------------------------------------------------------------
# engine end-to-end (jax)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module", params=PARITY_ARCHS)
def smoke_setup(request):
    return materialize(request.param)


def test_engine_parity_with_static_serve(smoke_setup):
    # prompt_len 8 keeps the comparison inside hymba's smoke window (8): the
    # static loop's one-shot prefill through a window-sized ring is lossy for
    # longer prompts (pre-existing), while the engine's headroom-padded ring
    # is exact — they legitimately diverge beyond the window.
    from repro.launch.serve import serve, serve_static
    cfg, params = smoke_setup
    g_eng, _ = serve(cfg, batch=3, prompt_len=8, gen=8, seed=0,
                     params=params, verbose=False)
    g_sta, _ = serve_static(cfg, batch=3, prompt_len=8, gen=8, seed=0,
                            params=params, verbose=False)
    np.testing.assert_array_equal(np.asarray(g_eng), np.asarray(g_sta))


def test_engine_chunked_prefill_matches_single_chunk(smoke_setup):
    from repro.launch.serve import serve
    cfg, params = smoke_setup
    g1, _ = serve(cfg, batch=2, prompt_len=16, gen=6, seed=0, params=params,
                  verbose=False)
    g2, _ = serve(cfg, batch=2, prompt_len=16, gen=6, seed=0, params=params,
                  verbose=False, prefill_chunk=4)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def _run_workload(cfg, params, n_blocks, swap_blocks):
    return run_workload(cfg, params, n_blocks=n_blocks, swap_blocks=swap_blocks)


def test_engine_continuous_batching_mixed_lengths(smoke_setup):
    cfg, params = smoke_setup
    toks, summary = _run_workload(cfg, params, n_blocks=None, swap_blocks=0)
    assert summary["preemptions"] == {"swap": 0, "recompute": 0}
    assert summary["generated_tokens"] == sum(len(v) for v in toks.values())
    # per-request ODIN attribution bills exactly the forward passes run:
    # prefill tokens + one decode pass per post-first generated token
    for rec in summary["requests"]:
        assert rec["odin"]["tokens"] == (rec["prefill_tokens"]
                                         + max(0, rec["generated_tokens"] - 1))
        assert rec["odin"]["energy_mj"] > 0
    assert 0 < summary["slot_occupancy"] <= 1


def test_engine_preemption_token_streams_identical(smoke_setup):
    cfg, params = smoke_setup
    base, s0 = _run_workload(cfg, params, n_blocks=None, swap_blocks=0)
    swap, s1 = _run_workload(cfg, params, n_blocks=8, swap_blocks=32)
    rec, s2 = _run_workload(cfg, params, n_blocks=8, swap_blocks=0)
    assert s1["preemptions"]["swap"] > 0              # pressure actually hit
    assert s2["preemptions"]["recompute"] > 0
    assert base == swap
    assert base == rec


def test_engine_vision_extras_survive_recompute_preemption():
    """Recompute replay of a vision-stub request re-prefills prompt+generated;
    pos3d must extend with the degenerate (t,t,t) decode positions instead of
    crashing on the original prompt-length table."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    from repro.serving import ServingEngine
    cfg = registry.get_smoke("qwen2-vl-2b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def mk_reqs():
        out = []
        for i in range(5):
            plen = 16
            out.append(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                max_new=24,
                extras={"patch_embeds": np.zeros((4, cfg.d_model), np.float32),
                        "pos3d": np.repeat(np.arange(plen, dtype=np.int32)[:, None], 3, 1)}))
        return out

    def run(n_blocks):
        toks, s = run_workload(cfg, params, n_blocks=n_blocks,
                               requests=mk_reqs())
        return toks, s["preemptions"]["recompute"]

    rng = np.random.default_rng(0)
    full, _ = run(3 * 6)
    rng = np.random.default_rng(0)
    tight, n_rec = run(9)
    assert n_rec > 0
    assert full == tight


def test_engine_paged_vs_dense_cache_parity():
    """The paged physical block store must be token-for-token equal to the
    PR-1 dense live cache, with and without memory pressure, while holding
    measurably fewer device KV bytes on a tight pool."""
    cfg, params = materialize("phi4-mini-3.8b")
    dense, sd = run_workload(cfg, params, paged=False)
    paged, sp = run_workload(cfg, params, paged=True)
    tight, st = run_workload(cfg, params, paged=True, n_blocks=7)
    assert dense == paged == tight                   # 18 dense-equiv blocks → 7+1
    assert st["preemptions"]["recompute"] > 0        # pressure actually hit
    assert st["kv_cache_bytes"] < sd["kv_cache_bytes"] / 2


def test_engine_sampling_deterministic_per_seed():
    """temperature/top-k decode: same seed reproduces the stream, different
    seeds (and greedy) diverge; greedy stays the default contract."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    from repro.serving import Request, ServingEngine
    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))

    def run(temperature, top_k, sample_seed=0):
        eng = ServingEngine(cfg, slots=2, max_len=32, block_size=8,
                            params=params, temperature=temperature,
                            top_k=top_k, sample_seed=sample_seed)
        reqs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32) + i,
                        max_new=6) for i in range(3)]
        eng.run(reqs)
        return {r.rid: [int(np.asarray(t)) for t in r.generated] for r in reqs}

    greedy = run(0.0, 0)
    s1 = run(1.0, 5)
    assert run(1.0, 5) == s1                         # deterministic per seed
    assert s1 != greedy
    assert run(1.0, 5, sample_seed=7) != s1


def test_sample_tokens_top_k_membership_and_greedy():
    import jax
    import jax.numpy as jnp
    from repro.launch.steps import _sample_tokens
    from repro.models import registry
    cfg = registry.get_smoke("phi4-mini-3.8b")
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 1, 32)), jnp.float32)
    greedy = _sample_tokens(logits, cfg, None, 0.0, 0)
    np.testing.assert_array_equal(
        np.asarray(greedy)[:, 0], np.argmax(np.asarray(logits)[:, 0], -1))
    # traced temperature 0 with a key still selects the argmax
    z = _sample_tokens(logits, cfg, jax.random.PRNGKey(0), jnp.float32(0.0), 5)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(greedy))
    top3 = np.argsort(np.asarray(logits)[:, 0], -1)[:, -3:]
    for i in range(50):
        s = np.asarray(_sample_tokens(logits, cfg, jax.random.PRNGKey(i),
                                      jnp.float32(1.0), 3))[:, 0]
        for b in range(4):
            assert s[b] in top3[b], (b, s[b], top3[b])


# ---------------------------------------------------------------------------
# horizon-batched decode (jax)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module", params=HORIZON_ARCHS)
def horizon_setup(request):
    return materialize(request.param)


def _run_horizon(cfg, params, horizon, **kwargs):
    return run_workload(cfg, params, horizon=horizon, **kwargs)


def test_engine_horizon_token_parity_all_families(horizon_setup):
    """H>1 must be token-for-token identical to H=1 (greedy), with mid-horizon
    budget freezes exercised by the short gen bucket, while actually
    amortizing dispatches."""
    cfg, params = horizon_setup
    base, s1 = _run_horizon(cfg, params, 1)
    fused, s8 = _run_horizon(cfg, params, 8)
    assert base == fused
    assert s8["decode_dispatches"] < s1["decode_dispatches"]
    assert s8["tokens_per_dispatch"] > s1["tokens_per_dispatch"]
    assert s8["decode_tokens"] == s1["decode_tokens"]


def test_engine_horizon_sampled_parity():
    """Sampled decode folds the *global* step counter into the key, so a
    horizon run reproduces the single-step stream when the slot schedule
    matches (all-arrived workload, no preemption)."""
    cfg, params = materialize("phi4-mini-3.8b")
    base, _ = _run_horizon(cfg, params, 1, temperature=1.0, top_k=5)
    fused, _ = _run_horizon(cfg, params, 8, temperature=1.0, top_k=5)
    greedy, _ = _run_horizon(cfg, params, 8)
    assert base == fused
    assert base != greedy


def test_engine_horizon_eos_freeze_mid_horizon():
    """EOS must freeze a slot mid-horizon on-device exactly where the host
    path stops it: pick a token that actually occurs mid-stream in the
    baseline, declare it EOS, and require identical truncated streams."""
    cfg, params = materialize("phi4-mini-3.8b")
    base, _ = _run_horizon(cfg, params, 1)
    rid = idx = eos = None
    for r, stream in sorted(base.items()):   # first token not repeated earlier
        for i in range(2, len(stream) - 1):
            v = stream[i][0]
            if all(s[0] != v for s in stream[:i]):
                rid, idx, eos = r, i, v
                break
        if eos is not None:
            break
    assert eos is not None, "baseline streams have no usable mid-stream token"
    h1, _ = _run_horizon(cfg, params, 1, eos_id=eos)
    h8, _ = _run_horizon(cfg, params, 8, eos_id=eos)
    assert h1 == h8
    assert len(h1[rid]) == idx + 1           # truncated at the EOS token
    assert h1[rid][-1][0] == eos
    assert len(h1[rid]) < len(base[rid])
    # the non-EOS prefix is unchanged
    assert base[rid][:idx + 1] == h1[rid]


def test_engine_horizon_preemption_at_boundary(smoke_setup):
    """A tight pool under a horizon engine: grants shrink to the block
    headroom, preemption (swap AND recompute) lands on horizon boundaries via
    plan(), and greedy token streams stay identical to the unconstrained
    run."""
    cfg, params = smoke_setup
    base, _ = _run_horizon(cfg, params, 1)
    swap, s_sw = _run_horizon(cfg, params, 8, n_blocks=8, swap_blocks=32)
    rec, s_rc = _run_horizon(cfg, params, 8, n_blocks=8, swap_blocks=0)
    assert s_sw["preemptions"]["swap"] > 0
    assert s_rc["preemptions"]["recompute"] > 0
    assert base == swap
    assert base == rec


def test_engine_horizon_timestamps_use_engine_clock():
    """Interpolated horizon timestamps must come from the *engine* clock, so
    an injected deterministic clock yields monotone per-request times and
    non-negative TPOT (regression: mixing in perf_counter spans produced
    timestamps before TTFT under a fake clock)."""
    import itertools
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    from repro.serving import Request, ServingEngine
    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    fake = itertools.count()
    seen = {}
    eng = ServingEngine(cfg, slots=2, max_len=32, block_size=8, params=params,
                        horizon=8, clock=lambda: float(next(fake)),
                        on_token=lambda r, t, now: seen.setdefault(r.rid, []).append(now))
    reqs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32) + i, max_new=6)
            for i in range(3)]
    summary = eng.run(reqs)
    for r in reqs:
        ts = seen[r.rid]
        assert ts == sorted(ts)
        assert r.t_first_token >= 0 and r.t_done >= ts[-1]
    for rec in summary["requests"]:
        assert rec["ttft_s"] >= 0
        assert rec["tpot_s"] is None or rec["tpot_s"] >= 0


def test_engine_horizon_dispatch_observables():
    cfg, params = materialize("phi4-mini-3.8b")
    _, s = _run_horizon(cfg, params, 4)
    assert s["decode_dispatches"] > 0
    assert s["decode_steps"] > s["decode_dispatches"]     # amortization real
    assert s["host_syncs"] <= s["dispatches"]
    assert s["tokens_per_dispatch"] == pytest.approx(
        s["decode_tokens"] / s["decode_dispatches"])


# ---------------------------------------------------------------------------
# prefix sharing end-to-end (jax)
# ---------------------------------------------------------------------------

def _shared_spec(**kw):
    return mixed_spec(n_requests=6, shared_prefix=kw.pop("shared_prefix", 16),
                      prompt_buckets=(8, 16), gen_buckets=(4, 24), **kw)


# phi4 pins the single-codebook paged family; musicgen pins the multi-
# codebook [K, S] prompt hashing + token-block layout.
@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "musicgen-medium"])
def test_engine_prefix_sharing_token_parity_and_savings(arch):
    """Shared-prompt streams must be token-identical with sharing on vs off,
    while actually skipping prefill work and referencing fewer blocks."""
    cfg, params = materialize(arch)
    base, sb = run_workload(cfg, params, max_len=64, spec=_shared_spec(),
                            prefix_sharing=False)
    shared, ss = run_workload(cfg, params, max_len=64, spec=_shared_spec(),
                              prefix_sharing=True)
    assert base == shared
    assert ss["prefix"]["hit_tokens"] > 0
    assert ss["prefix"]["shared_blocks"] > 0
    assert ss["prefill_tokens"] < sb["prefill_tokens"]
    assert (ss["prefix"]["mean_referenced_blocks"]
            < sb["prefix"]["mean_referenced_blocks"])
    # attribution bills only the forwards actually run: shared rows are free
    for rec in ss["requests"]:
        assert rec["odin"]["tokens"] == (rec["prefill_tokens"]
                                         + max(0, rec["generated_tokens"] - 1))


def test_engine_prefix_cow_fork_non_aligned_prefix():
    """Prompts sharing a non-block-aligned prefix take the COW-fork path:
    the partially matched block is copied before the tail overwrites it."""
    cfg, params = materialize("phi4-mini-3.8b")
    spec = _shared_spec(shared_prefix=21, share_groups=2)
    base, _ = run_workload(cfg, params, max_len=64, spec=spec, prefix_sharing=False)
    shared, ss = run_workload(cfg, params, max_len=64, spec=spec, prefix_sharing=True)
    assert base == shared
    assert ss["prefix"]["cow_forks"] > 0
    assert ss["prefix"]["hit_tokens"] > 0


def test_engine_prefix_sharing_preemption_parity(smoke_setup):
    """Sharing + preemption (swap AND recompute) of slots holding shared
    blocks: token streams still match the unconstrained unshared run.  On
    non-fully-paged families (hymba ring+SSM, deepseek MLA) sharing auto-
    disables and this degenerates to the plain preemption parity check."""
    cfg, params = smoke_setup
    spec = _shared_spec()
    base, _ = run_workload(cfg, params, max_len=64, spec=spec, prefix_sharing=False)
    swap, s_sw = run_workload(cfg, params, max_len=64, spec=spec, n_blocks=11,
                              swap_blocks=32)
    rec, s_rc = run_workload(cfg, params, max_len=64, spec=spec, n_blocks=11)
    assert s_sw["preemptions"]["swap"] > 0
    assert s_rc["preemptions"]["recompute"] > 0
    assert base == swap
    assert base == rec


def test_engine_prefix_sharing_horizon_parity():
    """Prefix sharing composes with horizon-batched decode: pre-extended
    tables append exclusive blocks after the shared prefix."""
    cfg, params = materialize("phi4-mini-3.8b")
    base, _ = run_workload(cfg, params, max_len=64, spec=_shared_spec(),
                           prefix_sharing=False)
    fused, s8 = run_workload(cfg, params, max_len=64, spec=_shared_spec(), horizon=8)
    assert base == fused
    assert s8["prefix"]["hit_tokens"] > 0
    assert s8["tokens_per_dispatch"] > 1.0


def test_engine_prefix_cache_retained_across_completion():
    """System-prompt caching: a request arriving after every sharer finished
    still hits the resident chain (the cache's claim outlives the request)."""
    import itertools
    from repro.serving import Request, ServingEngine
    cfg, params = materialize("phi4-mini-3.8b")
    prompt = (np.arange(20, dtype=np.int32) * 7 + 3) % cfg.vocab
    fake = itertools.count()
    eng = ServingEngine(cfg, slots=2, max_len=32, block_size=8, params=params,
                        clock=lambda: float(next(fake)))
    assert eng.prefix_sharing                        # auto-on: fully paged
    reqs = [Request(rid=0, prompt=prompt, max_new=4, arrival=0.0),
            Request(rid=1, prompt=prompt.copy(), max_new=4, arrival=50.0)]
    s = eng.run(reqs)
    assert s["prefix"]["hit_tokens"] == 19           # prompt_len - 1 (16 + 3)
    assert s["prefix"]["cow_forks"] == 1
    assert token_streams(reqs)[0] == token_streams(reqs)[1]


def test_engine_prefix_sharing_eligibility_and_extras_bypass():
    """Non-fully-paged families auto-disable sharing (forcing it raises);
    extras-carrying requests never match or register even when sharing is
    on (their KV is not token-determined)."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    from repro.serving import Request, ServingEngine
    for arch in ("hymba-1.5b", "deepseek-v3-671b", "xlstm-350m"):
        cfg, params = materialize(arch)
        eng = ServingEngine(cfg, slots=2, max_len=32, block_size=8,
                            params=params)
        assert not eng.prefix_sharing
        with pytest.raises(ValueError, match="fully paged"):
            ServingEngine(cfg, slots=2, max_len=32, block_size=8,
                          params=params, prefix_sharing=True)
    cfg = registry.get_smoke("qwen2-vl-2b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    reqs = [Request(rid=i, prompt=prompt.copy(), max_new=4,
                    extras={"patch_embeds": np.full((4, cfg.d_model), i, np.float32),
                            "pos3d": np.repeat(np.arange(16, dtype=np.int32)[:, None], 3, 1)})
            for i in range(3)]
    toks, s = run_workload(cfg, params, slots=3, max_len=32, requests=reqs)
    assert s["prefix"]["hit_tokens"] == 0            # same tokens, different KV
    # different patch embeds ⇒ the streams must NOT be forced equal by sharing
    assert len(toks[0]) == len(toks[1]) == 4


def test_engine_streaming_callback_and_order(smoke_setup):
    from repro.serving import Request, ServingEngine
    cfg, params = smoke_setup
    seen = {}
    eng = ServingEngine(cfg, slots=2, max_len=32, block_size=8, params=params,
                        on_token=lambda r, t, now: seen.setdefault(r.rid, []).append(int(np.asarray(t))))
    reqs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32) + i, max_new=5)
            for i in range(3)]
    eng.run(reqs)
    for r in reqs:
        assert seen[r.rid] == [int(np.asarray(t)) for t in r.generated]
        assert len(seen[r.rid]) == 5


# ---------------------------------------------------------------------------
# n-gram self-speculative decode (scheduler accounting + engine end-to-end)
# ---------------------------------------------------------------------------

# one arch per speculable cache family: paged GQA, MoE-over-paged-GQA, and
# MLA's dense latent cache (spec rides the generic S>1 decode path there)
SPEC_ARCHS = ["phi4-mini-3.8b", "qwen3-moe-235b-a22b", "deepseek-v3-671b"]


def test_ngram_propose_matches_and_fallback():
    import jax.numpy as jnp
    from repro.launch.steps import ngram_propose
    hist = jnp.asarray([
        # bigram (7, 8) seen earlier, followed by 9, 1 → draft [9, 1]
        [-1, -1, 7, 8, 9, 1, 5, 7, 8],
        # no earlier match → repeat the last token
        [-1, -1, -1, 1, 2, 3, 4, 5, 6],
        # most recent match wins: (7, 8) at j=0 and j=3 → follow j=3
        [7, 8, 3, 7, 8, 5, 0, 7, 8],
        # padding never matches real tokens, and boundary drafts clamp ≥ 0
        [-1, -1, -1, -1, -1, -1, -1, 5, 5],
    ], jnp.int32)
    draft = np.asarray(ngram_propose(hist, K=2, n=2))
    np.testing.assert_array_equal(draft[0], [9, 1])
    np.testing.assert_array_equal(draft[1], [6, 6])
    np.testing.assert_array_equal(draft[2], [5, 0])
    assert (draft >= 0).all()


def test_speculable_gates_families():
    from repro.launch.steps import speculable
    from repro.models import registry
    assert speculable(registry.get_smoke("phi4-mini-3.8b"))
    assert speculable(registry.get_smoke("qwen3-moe-235b-a22b"))
    assert speculable(registry.get_smoke("deepseek-v3-671b"))
    assert not speculable(registry.get_smoke("hymba-1.5b"))      # SSM state
    assert not speculable(registry.get_smoke("xlstm-350m"))      # recurrent
    assert not speculable(registry.get_smoke("musicgen-medium")) # codebooks


def test_engine_spec_rejects_unsupported_configs():
    from repro.models import registry
    from repro.serving import ServingEngine
    for arch in ("hymba-1.5b", "xlstm-350m", "musicgen-medium"):
        with pytest.raises(ValueError, match="spec_ngram|recurrent|codebook"):
            ServingEngine(registry.get_smoke(arch), slots=2, max_len=32,
                          block_size=8, spec_ngram=2)
    cfg = registry.get_smoke("phi4-mini-3.8b")
    with pytest.raises(ValueError, match="greedy"):
        ServingEngine(cfg, slots=2, max_len=32, block_size=8, spec_ngram=2,
                      temperature=0.7)
    with pytest.raises(ValueError, match="spec_hist"):
        ServingEngine(cfg, slots=2, max_len=32, block_size=8, spec_ngram=4,
                      spec_hist=5)


def test_grant_horizon_spec_worst_case_preextension_and_fallback():
    """Speculative grants must pre-extend for the worst case — every inner
    step writes K+1 rows, and a budget-frozen slot still wrote K rows past
    its last accepted token — and must return 0 (plain-decode fallback)
    when the pool cannot cover even one verify tile."""
    pool, sched, reqs = _admit_two(gens=(40, 37))
    h = sched.grant_horizon(4, now=0.0, spec_k=3)
    assert h == 4
    for r in reqs:
        rows = min(sched.max_len, r.cached_len + min(4 * 4, r.remaining + 3))
        assert len(r.block_table) == pool.blocks_for(rows)
    # completion cap counts accept-aware steps: remaining 4 at K=3 can finish
    # in one inner step → grant 1 even with arrived work queued
    pool2, sched2, reqs2 = _admit_two(gens=(5, 5, 8))
    assert sched2.grant_horizon(16, now=0.0, spec_k=3) == 1
    # pool too tight for even one K+1-row tile → 0, single-step fallback
    pool3 = BlockPool(6, 4)
    sched3 = Scheduler(2, pool3, max_len=24, write_span=4)
    for i in range(2):
        sched3.submit(_mk_req(i, 8, 12))
    for r in sched3.plan(0.0).admit:
        _drive(r)
    for r in sched3.running.values():
        _drive(r, 2)                 # cached_len 10: the verify tile (rows
    sched3.plan(0.5)                 # 10..13) crosses into a 4th block
    assert pool3.free_blocks == 0                    # 3 blocks each
    assert sched3.grant_horizon(1, now=0.0, spec_k=3) == 0
    # spec-off grants are unchanged by the spec machinery
    assert sched3.grant_horizon(1, now=0.0) == 1


def test_preempt_keeps_shared_prefix_claims_and_resume_reattaches():
    """Sharing-aware swap: blocks the prefix cache (or a co-reader) still
    holds keep the swapped request's claim instead of round-tripping through
    the swap tier; resume re-attaches them and allocates only the exclusive
    suffix."""
    pool = BlockPool(16, 4)
    cache = PrefixCache(pool, 4)
    swap = BlockPool(8, 4)
    sched = Scheduler(1, pool, max_len=32, swap_pool=swap, prefix_cache=cache)
    toks = np.arange(12, dtype=np.int32)
    req = Request(rid=0, prompt=toks, max_new=8)
    sched.submit(req)
    for r in sched.plan(0.0).admit:
        _drive(r)                                    # first token from prefill
    _drive(req, 2)                                   # cached_len 14: block 3 live
    assert len(req.block_table) == 4
    plan = sched.plan(1.0)
    sched._preempt(req, plan)
    # prompt blocks 0..2 are cache-held (refs 2 before free) → kept; the
    # tail block (rows 12..13, decode-written) is exclusive → swapped
    assert req.state.value == "swapped"
    kept_ids = list(req.kept_blocks)
    assert len(kept_ids) == 3
    assert all(pool.refs(b) == 2 for b in kept_ids)
    assert swap.used_blocks == 1                     # only the suffix block
    # resume: kept blocks lead the new table, only the suffix is allocated
    plan2 = sched.plan(2.0)
    assert plan2.resume == [req]
    assert req.block_table[:3] == kept_ids
    assert req.kept_blocks == []
    assert len(req.block_table) == 4


def test_swap_ticket_skip_roundtrip():
    """A ticket with skip_blocks restores into table rows skip onward and
    never touches the retained leading blocks."""
    from repro.launch.steps import init_serving_caches
    from repro.models import registry
    from repro.serving.blocks import PagedKVStore
    cfg = registry.get_smoke("phi4-mini-3.8b")
    caches = init_serving_caches(cfg, batch=2, max_len=32, block_size=8,
                                 n_blocks=8)
    kp = caches[0]["attn"]["k_pool"]
    caches[0]["attn"]["k_pool"] = kp.at[:, 1].set(1.0).at[:, 3].set(3.0)
    caches[0]["attn"]["pos"] = caches[0]["attn"]["pos"].at[:, 0].set(12)
    store = PagedKVStore(caches, n_blocks=4, block_size=8)
    sids = store.pool.alloc(1)                       # suffix only
    ticket = store.swap_out(caches, slot=0, block_ids=sids, n_tokens=12,
                            dev_ids=[1, 3], skip=1)
    assert ticket.skip_blocks == 1
    # block 1 was retained (never copied): clobber only block 3
    caches[0]["attn"]["k_pool"] = caches[0]["attn"]["k_pool"].at[:, 3].set(-7.0)
    caches2 = store.swap_in(caches, slot=0, ticket=ticket, dev_ids=[1, 6])
    kp2 = np.asarray(caches2[0]["attn"]["k_pool"], np.float32)
    np.testing.assert_array_equal(kp2[:, 1], 1.0)    # retained block intact
    np.testing.assert_array_equal(kp2[:, 6], 3.0)    # suffix restored


@pytest.fixture(scope="module", params=SPEC_ARCHS)
def spec_setup(request):
    return materialize(request.param)


def test_engine_spec_token_parity_all_families(spec_setup):
    """Greedy spec-on streams must be token-identical to spec-off by
    construction (every emitted token is an argmax), across the paged-GQA,
    MoE and MLA cache families, while drafting real work."""
    cfg, params = spec_setup
    base, s0 = run_workload(cfg, params)
    for K in (2, 4):
        spec, s1 = run_workload(cfg, params, spec_ngram=K)
        assert base == spec, f"spec K={K} diverged"
        assert s1["decode_tokens"] == s0["decode_tokens"]
        assert s1["speculation"]["drafted"] > 0
        assert 0 <= s1["speculation"]["accepted"] <= s1["speculation"]["drafted"]


def test_engine_spec_fuses_into_horizon_scan():
    """spec_ngram composes with horizon>1: one dispatch runs h inner
    draft→verify steps; parity holds and dispatches drop vs plain h=1."""
    cfg, params = materialize("phi4-mini-3.8b")
    base, s0 = run_workload(cfg, params)
    spec, s1 = run_workload(cfg, params, spec_ngram=2, horizon=8)
    assert base == spec
    assert s1["decode_dispatches"] < s0["decode_dispatches"]
    assert s1["tokens_per_dispatch"] > s0["tokens_per_dispatch"]


def test_engine_spec_preemption_parity():
    """Tight pools under speculation: worst-case write-span budgeting plus
    swap/recompute preemption must keep streams identical."""
    cfg, params = materialize("phi4-mini-3.8b")
    base, _ = run_workload(cfg, params)
    swap, s_sw = run_workload(cfg, params, spec_ngram=4, n_blocks=8,
                              swap_blocks=32)
    rec, s_rc = run_workload(cfg, params, spec_ngram=4, n_blocks=8)
    assert s_sw["preemptions"]["swap"] > 0
    assert s_rc["preemptions"]["recompute"] > 0
    assert base == swap
    assert base == rec


def test_engine_spec_shared_prefix_parity_and_swap_skip():
    """Speculation over prefix-shared streams: parity with the unshared
    spec-off run, and sharing-aware swap tickets actually skip resident
    blocks under pressure."""
    cfg, params = materialize("phi4-mini-3.8b")
    wspec = mixed_spec(n_requests=6, shared_prefix=24, prompt_buckets=(8, 16),
                       gen_buckets=(4, 16))
    base, _ = run_workload(cfg, params, max_len=64, spec=wspec,
                           prefix_sharing=False)
    spec, s1 = run_workload(cfg, params, max_len=64, spec=wspec,
                            prefix_sharing=True, spec_ngram=4)
    assert base == spec
    pressured, s2 = run_workload(cfg, params, max_len=64, spec=wspec,
                                 prefix_sharing=True, spec_ngram=4,
                                 n_blocks=12, swap_blocks=32)
    assert base == pressured
    if s2["preemptions"]["swap"]:
        assert s2["prefix"]["swap_skipped_blocks"] > 0


def test_engine_spec_eos_parity():
    """EOS inside an accepted run must truncate exactly where the plain
    engine stops (on-device accept truncation + host re-check agree)."""
    cfg, params = materialize("phi4-mini-3.8b")
    base, _ = run_workload(cfg, params)
    rid = idx = eos = None
    for r, stream in sorted(base.items()):
        for i in range(2, len(stream) - 1):
            v = stream[i][0]
            if all(s[0] != v for s in stream[:i]):
                rid, idx, eos = r, i, v
                break
        if eos is not None:
            break
    assert eos is not None
    b_eos, _ = run_workload(cfg, params, eos_id=eos)
    s_eos, _ = run_workload(cfg, params, eos_id=eos, spec_ngram=4)
    assert b_eos == s_eos
    assert len(s_eos[rid]) == idx + 1 and s_eos[rid][-1][0] == eos


def test_engine_spec_rollback_never_below_committed_length():
    """Per-slot KV lengths advance by the accepted count only: stepping the
    engine manually, a slot's length never decreases while the same request
    holds it, never grows past h·(K+1) per dispatch, and stays covered by
    its block table."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    from repro.serving import Request, ServingEngine
    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    K, H = 3, 4
    eng = ServingEngine(cfg, slots=3, max_len=48, block_size=8, params=params,
                        spec_ngram=K, horizon=H)
    rng = np.random.default_rng(0)
    pat = rng.integers(0, cfg.vocab, 4, dtype=np.int32)
    reqs = [Request(rid=i, prompt=np.tile(pat, 3), max_new=24)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    guard = 0
    while eng.sched.has_work:
        before = dict(eng.sched.running)
        len_before = eng._slot_len.copy()
        eng.step()
        for s, req in before.items():
            if eng.sched.running.get(s) is req and req.slot == s:
                grew = int(eng._slot_len[s]) - int(len_before[s])
                assert 0 <= grew <= H * (K + 1)
                assert len(req.block_table) * 8 >= req.cached_len
        guard += 1
        assert guard < 500
    assert all(r.n_generated == 24 for r in reqs)


def test_engine_spec_accepts_on_repetitive_stream():
    """The observables must show real speculation wins on repetition-heavy
    traffic: positive accept rate and more tokens per dispatch than the
    spec-off engine at the same horizon."""
    import dataclasses
    from repro.serving import SCENARIOS, make_requests
    cfg, params = materialize("phi4-mini-3.8b")
    wspec = dataclasses.replace(SCENARIOS["repetitive"], n_requests=4,
                                rate=1e9, gen_buckets=(96,))
    base, s0 = run_workload(cfg, params, slots=3, max_len=144, block_size=16,
                            spec=wspec, horizon=4)
    spec, s1 = run_workload(cfg, params, slots=3, max_len=144, block_size=16,
                            spec=wspec, horizon=4, spec_ngram=4)
    assert base == spec
    assert s1["speculation"]["accept_rate"] > 0.2
    assert s1["tokens_per_dispatch"] > s0["tokens_per_dispatch"]
    assert s1["decode_dispatches"] < s0["decode_dispatches"]


def test_engine_jit_cache_lru_bounded_with_evictions():
    """The fused-executable cache must stay bounded across horizon×spec
    grant combinations, count its evictions, and keep streams identical."""
    cfg, params = materialize("phi4-mini-3.8b")
    base, s0 = run_workload(cfg, params, horizon=8, spec_ngram=2)
    tight, s1 = run_workload(cfg, params, horizon=8, spec_ngram=2,
                             jit_cache=1)
    assert base == tight
    assert s0["jit_evictions"] == 0
    assert s1["jit_evictions"] > 0


def test_engine_spec_history_stays_aligned_including_fallback():
    """The per-slot draft history must track prompt+generated exactly at
    every step — including plain-decode fallback steps when the pool cannot
    cover a verify tile (regression: the fallback emitted a token without
    shifting it into the ring, silently collapsing accept rates)."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    from repro.serving import Request, ServingEngine
    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    # 7 blocks × bs 8 over 2 slots of max_len 48: tight enough that spec
    # grants intermittently fail and fall back to single steps
    eng = ServingEngine(cfg, slots=2, max_len=48, block_size=8, params=params,
                        spec_ngram=3, spec_hist=16, n_blocks=7)
    grants = []
    orig = eng.sched.grant_horizon
    eng.sched.grant_horizon = lambda *a, **kw: grants.append(orig(*a, **kw)) or grants[-1]
    reqs = [Request(rid=i, prompt=np.arange(16, dtype=np.int32) + i, max_new=20)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    guard = 0
    while eng.sched.has_work:
        eng.step()
        for slot, req in eng.sched.running.items():
            ctx = np.concatenate([np.asarray(req.replay_tokens()).ravel(),
                                  np.ravel(req.generated[-1])])
            row = np.asarray(eng._hist[slot])
            n = min(len(ctx), len(row))
            np.testing.assert_array_equal(row[-n:], ctx[-n:].astype(np.int32))
        guard += 1
        assert guard < 400
    assert 0 in grants                   # the fallback path actually ran
    assert any(g >= 1 for g in grants)   # and so did real spec dispatches
