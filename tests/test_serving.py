"""Tests for repro.serving: block pool invariants, scheduler policy under a
randomized request stream, and end-to-end engine correctness.

The engine tests pin the strongest property available: the continuous-
batching path is *token-for-token* equal to (a) the static-batch loop on a
uniform workload and (b) an unconstrained run when preemption (swap AND
recompute) is forced by a tight block pool.
"""
import numpy as np
import pytest

from repro.serving.blocks import BlockPool
from repro.serving.scheduler import Request, RequestState, Scheduler


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------

def test_block_pool_alloc_free_reuse():
    pool = BlockPool(8, 4)
    assert pool.blocks_for(0) == 0
    assert pool.blocks_for(1) == 1
    assert pool.blocks_for(4) == 1
    assert pool.blocks_for(5) == 2
    a = pool.alloc(5)
    b = pool.alloc(3)
    assert pool.free_blocks == 0 and pool.used_blocks == 8
    assert pool.alloc(1) is None                     # exhausted: no change
    assert pool.free_blocks == 0
    assert len(set(a) | set(b)) == 8                 # disjoint ids
    pool.free(b)
    assert pool.free_blocks == 3
    c = pool.alloc(3)
    assert set(c) == set(b)                          # freed blocks are reused
    with pytest.raises(ValueError):
        pool.free([a[0], a[0]])                      # double free detected
    assert pool.alloc(4) is None                     # all-or-nothing

def test_block_pool_exhaustion_and_validation():
    pool = BlockPool(4, 2)
    assert pool.alloc(0) == []                       # empty alloc is a no-op
    with pytest.raises(ValueError):
        pool.alloc(-1)
    with pytest.raises(ValueError):
        BlockPool(-1, 2)
    with pytest.raises(ValueError):
        BlockPool(4, 0)
    a = pool.alloc(4)
    assert pool.alloc(1) is None                     # exhausted
    pool.free(a)
    with pytest.raises(ValueError):
        pool.free([a[0]])                            # double free after bulk free


def test_block_pool_extend_to():
    pool = BlockPool(4, 4)
    table = []
    assert pool.extend_to(table, 0) and table == []
    assert pool.extend_to(table, 9)                  # 3 blocks
    assert len(table) == 3 and pool.free_blocks == 1
    assert pool.extend_to(table, 12) and len(table) == 3   # already covered
    assert not pool.extend_to(table, 20)             # needs 5, has 3+1
    assert len(table) == 3 and pool.free_blocks == 1 # all-or-nothing: no change
    assert pool.extend_to(table, 16) and len(table) == 4


def test_block_pool_randomized_invariants():
    rng = np.random.default_rng(0)
    pool = BlockPool(32, 2)
    live = []
    for _ in range(500):
        if live and rng.random() < 0.45:
            ids = live.pop(rng.integers(len(live)))
            pool.free(ids)
        else:
            ids = pool.alloc(int(rng.integers(1, 6)))
            if ids is not None:
                live.append(ids)
        held = [b for ids in live for b in ids]
        assert len(held) == len(set(held))                       # no aliasing
        assert pool.free_blocks + len(held) == pool.n_blocks     # conservation


# ---------------------------------------------------------------------------
# scheduler (no jax: pure policy)
# ---------------------------------------------------------------------------

def _mk_req(rid, plen, gen, arrival=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32), max_new=gen,
                   arrival=arrival)


def _drive(req, steps=1):
    """Simulate the engine's per-step token bookkeeping for a running request."""
    for _ in range(steps):
        req.generated.append(0)


def test_scheduler_admission_and_completion():
    pool = BlockPool(64, 4)
    sched = Scheduler(2, pool, max_len=64)
    reqs = [_mk_req(i, 8, 4) for i in range(4)]
    for r in reqs:
        sched.submit(r)
    plan = sched.plan(now=0.0)
    assert [r.rid for r in plan.admit] == [0, 1]     # 2 slots
    assert all(r.state is RequestState.RUNNING for r in plan.admit)
    assert all(len(r.block_table) == pool.blocks_for(9) for r in plan.admit)
    # finish request 0 → its slot and blocks free; next plan admits request 2
    for r in plan.admit:
        _drive(r)                                    # first token from prefill
    reqs[0].generated.extend([0] * 3)
    sched.complete(reqs[0], now=1.0)
    assert reqs[0].state is RequestState.DONE and reqs[0].t_done == 1.0
    plan2 = sched.plan(now=1.0)
    assert [r.rid for r in plan2.admit] == [2]
    assert sum(len(r.block_table) for r in sched.running.values()) == pool.used_blocks


def test_scheduler_respects_arrival_times():
    pool = BlockPool(64, 4)
    sched = Scheduler(4, pool, max_len=64)
    sched.submit(_mk_req(0, 8, 4, arrival=0.0))
    sched.submit(_mk_req(1, 8, 4, arrival=10.0))
    plan = sched.plan(now=0.5)
    assert [r.rid for r in plan.admit] == [0]
    plan = sched.plan(now=10.5)
    assert [r.rid for r in plan.admit] == [1]


def test_scheduler_submit_validation():
    pool = BlockPool(3, 4)                           # 12-token device budget
    sched = Scheduler(2, pool, max_len=16)
    with pytest.raises(ValueError):
        sched.submit(_mk_req(0, 12, 8))              # 20 > max_len 16
    with pytest.raises(ValueError):
        sched.submit(_mk_req(1, 8, 8))               # 16 tokens = 4 blocks > 3
    sched.submit(_mk_req(2, 8, 4, arrival=0.0))      # 12 tokens = 3 blocks: fine


def test_scheduler_growth_preempts_youngest_and_recovers():
    # 2 slots, pool of 6 blocks × 4 tokens.  Two prompt-8 requests admit with
    # 3 blocks each (prompt + first decode row).  Once a request's cached
    # length hits 12 its next decode row needs a 4th block — the pool is
    # empty, so the younger request is preempted (recompute: no swap pool).
    pool = BlockPool(6, 4)
    sched = Scheduler(2, pool, max_len=24)
    r0, r1 = _mk_req(0, 8, 12, arrival=0.0), _mk_req(1, 8, 12, arrival=1.0)
    sched.submit(r0), sched.submit(r1)
    plan = sched.plan(now=2.0)
    assert len(plan.admit) == 2
    assert pool.free_blocks == 0
    _drive(r0, 5), _drive(r1, 5)                     # cached_len 12 → grow
    plan = sched.plan(now=3.0)
    assert [(p[0].rid, p[1]) for p in plan.preempt] == [(1, "recompute")]
    assert r1.state is RequestState.QUEUED and r1.block_table == []
    assert r1.n_preempt_recompute == 1
    assert len(r0.block_table) == 4                  # got its growth block
    # r1 keeps its generated tokens for recompute-readmission
    assert r1.n_generated == 5
    # a preemption step admits/resumes nothing (anti-thrash)
    assert not plan.admit and not plan.resume
    _drive(r0, 7)
    sched.complete(r0, now=4.0)
    plan = sched.plan(now=4.0)
    assert [r.rid for r in plan.admit] == [1]
    assert r1.state is RequestState.RUNNING


def test_scheduler_randomized_stream_conserves_blocks_and_finishes():
    rng = np.random.default_rng(42)
    pool = BlockPool(12, 4)
    sched = Scheduler(3, pool, max_len=32)
    reqs = [_mk_req(i, int(rng.integers(1, 17)), int(rng.integers(1, 13)),
                    arrival=float(rng.uniform(0, 5))) for i in range(25)]
    for r in reqs:
        sched.submit(r)
    done = []
    for step in range(2000):
        if not sched.has_work:
            break
        now = step * 0.1
        plan = sched.plan(now)
        for req in plan.admit:                       # engine: prefill emits token 1
            if req.n_generated == 0:
                req.generated.append(0)
            if req.done:                             # max_new == 1 retires here
                sched.complete(req, now)
                done.append(req)
        for slot in sorted(sched.running):
            req = sched.running[slot]
            req.generated.append(0)
            if req.done:
                sched.complete(req, now)
                done.append(req)
        # invariants every step
        held = [b for r in sched.running.values() for b in r.block_table]
        assert len(held) == len(set(held))
        assert pool.free_blocks + len(held) == pool.n_blocks
        for r in sched.running.values():
            assert len(r.block_table) >= pool.blocks_for(r.cached_len)
    assert sched.has_work is False
    assert sorted(r.rid for r in done) == list(range(25))
    assert all(r.n_generated >= r.max_new for r in done)
    assert pool.used_blocks == 0


def _admit_two(pool_blocks=64, bs=4, slots=2, max_len=64, gens=(12, 5)):
    """Two running requests (first token emitted), rest of the stream waiting."""
    pool = BlockPool(pool_blocks, bs)
    sched = Scheduler(slots, pool, max_len=max_len)
    reqs = [_mk_req(i, 8, g) for i, g in enumerate(gens)]
    for r in reqs:
        sched.submit(r)
    plan = sched.plan(now=0.0)
    for r in plan.admit:
        _drive(r)                                    # first token from prefill
    return pool, sched, reqs


def test_grant_horizon_completion_cap_and_preextension():
    # an *arrived* waiting request blocks the horizon at the earliest running
    # completion: min remaining = min(12-1, 5-1) = 4 → already a power of two
    pool, sched, reqs = _admit_two(gens=(12, 5, 4))
    h = sched.grant_horizon(16, now=0.0)
    assert h == 4
    r0, r1 = reqs[0], reqs[1]
    # tables pre-extended for the whole grant (capped at each budget)
    assert len(r0.block_table) >= pool.blocks_for(r0.cached_len + 4)
    assert len(r1.block_table) >= pool.blocks_for(r1.cached_len + 4)
    # with no pending work the grant runs to max_h, snapped to a power of two
    pool2, sched2, reqs2 = _admit_two(gens=(40, 37))
    assert sched2.grant_horizon(12, now=0.0) == 8    # 12 → 2^3
    # per-slot extension never exceeds the request's own budget
    pool3, sched3, reqs3 = _admit_two(gens=(40, 3))
    h3 = sched3.grant_horizon(16, now=0.0)
    assert h3 == 16
    big, small = reqs3
    assert len(big.block_table) == pool3.blocks_for(big.cached_len + 16)
    assert len(small.block_table) == pool3.blocks_for(small.cached_len + 2)


def test_grant_horizon_block_headroom_shrinks_grant():
    # 6 blocks × 4 tokens, two prompt-8 requests: 3 blocks each, pool empty.
    # cached_len 8 → h=4 fits the existing tables (12 rows = 3 blocks) but
    # h=8 would need a 4th block per slot → the grant halves instead of
    # preempting.
    pool, sched, reqs = _admit_two(pool_blocks=6, bs=4, max_len=24,
                                   gens=(12, 12))
    assert pool.free_blocks == 0
    assert sched.grant_horizon(8, now=0.0) == 4
    assert all(len(r.block_table) == 3 for r in reqs)


def test_grant_horizon_arrival_cap_and_empty():
    pool = BlockPool(64, 4)
    sched = Scheduler(2, pool, max_len=64)
    assert sched.grant_horizon(16, now=0.0) == 0     # nothing running
    sched.submit(_mk_req(0, 8, 30, arrival=0.0))
    sched.submit(_mk_req(1, 8, 30, arrival=5.0))     # future arrival
    for r in sched.plan(now=0.0).admit:
        _drive(r)
    # free slot + future arrival: cap ≈ steps until admission at 1s/step
    assert sched.grant_horizon(16, now=0.0, est_step_time=1.0) == 4  # 5+1→4
    # without an estimate the arrival cap is disabled
    assert sched.grant_horizon(16, now=0.0) == 16


def test_scheduler_table_version_tracks_mutations():
    pool, sched, reqs = _admit_two(gens=(12, 12))
    v = sched.table_version
    assert v > 0                                     # admissions bumped it
    sched.plan(now=1.0)                              # no growth needed yet
    assert sched.table_version == v
    _drive(reqs[0], 8)                               # cached_len 8 → 9: grow
    sched.plan(now=2.0)
    assert sched.table_version > v
    v = sched.table_version
    assert sched.grant_horizon(8, now=2.0) == 8      # pre-extends r1's table
    assert sched.table_version > v
    v = sched.table_version
    reqs[1].generated.extend([0] * 11)
    sched.complete(reqs[1], now=3.0)
    assert sched.table_version > v


# ---------------------------------------------------------------------------
# paged store: block-table handoff swap (jax, no model)
# ---------------------------------------------------------------------------

def test_paged_store_block_handoff_roundtrip_and_ticket_reuse():
    """Pool-leaf swap is a block-to-block copy keyed by table ids: survive a
    device-block clobber after swap-out, restore into *different* device
    blocks, and reuse freed swap blocks for a second ticket without leakage."""
    import jax
    from repro.launch.steps import init_serving_caches
    from repro.models import registry
    from repro.serving.blocks import PagedKVStore
    cfg = registry.get_smoke("phi4-mini-3.8b")
    caches = init_serving_caches(cfg, batch=2, max_len=32, block_size=8,
                                 n_blocks=8)
    kp = caches[0]["attn"]["k_pool"]                 # [L, 9, 8, Hkv, D]
    assert kp.shape[1] == 9                          # 8 blocks + write-off
    caches[0]["attn"]["k_pool"] = kp.at[:, 1].set(1.0).at[:, 3].set(3.0)
    caches[0]["attn"]["pos"] = caches[0]["attn"]["pos"].at[:, 0].set(12)

    store = PagedKVStore(caches, n_blocks=4, block_size=8)
    sids = store.pool.alloc(2)
    ticket = store.swap_out(caches, slot=0, block_ids=sids, n_tokens=12,
                            dev_ids=[1, 3])
    # the freed device blocks get clobbered by other requests
    caches[0]["attn"]["k_pool"] = caches[0]["attn"]["k_pool"].at[:, 1].set(-7.0).at[:, 3].set(-7.0)
    # resume into a different slot AND different device blocks
    caches2 = store.swap_in(caches, slot=1, ticket=ticket, dev_ids=[0, 2])
    kp2 = np.asarray(caches2[0]["attn"]["k_pool"], np.float32)
    np.testing.assert_array_equal(kp2[:, 0], 1.0)
    np.testing.assert_array_equal(kp2[:, 2], 3.0)
    assert int(caches2[0]["attn"]["pos"][0, 1]) == 12   # side leaf followed
    # swap-block reuse: freed ids serve the next ticket with fresh contents
    store.pool.free(ticket.block_ids)
    sids2 = store.pool.alloc(2)
    assert set(sids2) == set(sids)
    caches2[0]["attn"]["k_pool"] = caches2[0]["attn"]["k_pool"].at[:, 5].set(5.0)
    t2 = store.swap_out(caches2, slot=0, block_ids=sids2, n_tokens=4,
                        dev_ids=[5])
    caches3 = store.swap_in(caches2, slot=0, ticket=t2, dev_ids=[7])
    np.testing.assert_array_equal(
        np.asarray(caches3[0]["attn"]["k_pool"], np.float32)[:, 7], 5.0)


def test_paged_store_requires_dev_ids_for_pool_leaves():
    from repro.launch.steps import init_serving_caches
    from repro.models import registry
    from repro.serving.blocks import PagedKVStore
    cfg = registry.get_smoke("phi4-mini-3.8b")
    caches = init_serving_caches(cfg, batch=1, max_len=16, block_size=8,
                                 n_blocks=4)
    store = PagedKVStore(caches, n_blocks=2, block_size=8)
    sids = store.pool.alloc(1)
    with pytest.raises(ValueError):
        store.swap_out(caches, 0, sids, 8)           # no dev_ids


# ---------------------------------------------------------------------------
# engine end-to-end (jax)
# ---------------------------------------------------------------------------

# One arch per cache family: dense GQA, sliding-window hybrid (ring buffer +
# SSM state), MLA + MoE (batch-coupled capacity routing is the trap here).
PARITY_ARCHS = ["phi4-mini-3.8b", "hymba-1.5b", "deepseek-v3-671b"]


@pytest.fixture(scope="module", params=PARITY_ARCHS)
def smoke_setup(request):
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    cfg = registry.get_smoke(request.param)
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_engine_parity_with_static_serve(smoke_setup):
    # prompt_len 8 keeps the comparison inside hymba's smoke window (8): the
    # static loop's one-shot prefill through a window-sized ring is lossy for
    # longer prompts (pre-existing), while the engine's headroom-padded ring
    # is exact — they legitimately diverge beyond the window.
    from repro.launch.serve import serve, serve_static
    cfg, params = smoke_setup
    g_eng, _ = serve(cfg, batch=3, prompt_len=8, gen=8, seed=0,
                     params=params, verbose=False)
    g_sta, _ = serve_static(cfg, batch=3, prompt_len=8, gen=8, seed=0,
                            params=params, verbose=False)
    np.testing.assert_array_equal(np.asarray(g_eng), np.asarray(g_sta))


def test_engine_chunked_prefill_matches_single_chunk(smoke_setup):
    from repro.launch.serve import serve
    cfg, params = smoke_setup
    g1, _ = serve(cfg, batch=2, prompt_len=16, gen=6, seed=0, params=params,
                  verbose=False)
    g2, _ = serve(cfg, batch=2, prompt_len=16, gen=6, seed=0, params=params,
                  verbose=False, prefill_chunk=4)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def _run_workload(cfg, params, n_blocks, swap_blocks):
    from repro.serving import ServingEngine, WorkloadSpec, make_requests
    eng = ServingEngine(cfg, slots=3, max_len=48, block_size=8,
                        n_blocks=n_blocks, swap_blocks=swap_blocks,
                        params=params)
    reqs = make_requests(cfg, WorkloadSpec(n_requests=5, rate=1e9,
                                           prompt_buckets=(8, 16),
                                           gen_buckets=(4, 24)), seed=9)
    summary = eng.run(reqs)
    toks = {r.rid: [int(np.asarray(t)) for t in r.generated] for r in reqs}
    return toks, summary


def test_engine_continuous_batching_mixed_lengths(smoke_setup):
    cfg, params = smoke_setup
    toks, summary = _run_workload(cfg, params, n_blocks=None, swap_blocks=0)
    assert summary["preemptions"] == {"swap": 0, "recompute": 0}
    assert summary["generated_tokens"] == sum(len(v) for v in toks.values())
    # per-request ODIN attribution bills exactly the forward passes run:
    # prefill tokens + one decode pass per post-first generated token
    for rec in summary["requests"]:
        assert rec["odin"]["tokens"] == (rec["prefill_tokens"]
                                         + max(0, rec["generated_tokens"] - 1))
        assert rec["odin"]["energy_mj"] > 0
    assert 0 < summary["slot_occupancy"] <= 1


def test_engine_preemption_token_streams_identical(smoke_setup):
    cfg, params = smoke_setup
    base, s0 = _run_workload(cfg, params, n_blocks=None, swap_blocks=0)
    swap, s1 = _run_workload(cfg, params, n_blocks=8, swap_blocks=32)
    rec, s2 = _run_workload(cfg, params, n_blocks=8, swap_blocks=0)
    assert s1["preemptions"]["swap"] > 0              # pressure actually hit
    assert s2["preemptions"]["recompute"] > 0
    assert base == swap
    assert base == rec


def test_engine_vision_extras_survive_recompute_preemption():
    """Recompute replay of a vision-stub request re-prefills prompt+generated;
    pos3d must extend with the degenerate (t,t,t) decode positions instead of
    crashing on the original prompt-length table."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    from repro.serving import ServingEngine
    cfg = registry.get_smoke("qwen2-vl-2b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def mk_reqs():
        out = []
        for i in range(5):
            plen = 16
            out.append(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                max_new=24,
                extras={"patch_embeds": np.zeros((4, cfg.d_model), np.float32),
                        "pos3d": np.repeat(np.arange(plen, dtype=np.int32)[:, None], 3, 1)}))
        return out

    def run(n_blocks):
        eng = ServingEngine(cfg, slots=3, max_len=48, block_size=8,
                            n_blocks=n_blocks, params=params)
        reqs = mk_reqs()
        s = eng.run(reqs)
        return ({r.rid: [int(np.asarray(t).ravel()[0]) for t in r.generated]
                 for r in reqs}, s["preemptions"]["recompute"])

    rng = np.random.default_rng(0)
    full, _ = run(3 * 6)
    rng = np.random.default_rng(0)
    tight, n_rec = run(9)
    assert n_rec > 0
    assert full == tight


def test_engine_paged_vs_dense_cache_parity():
    """The paged physical block store must be token-for-token equal to the
    PR-1 dense live cache, with and without memory pressure, while holding
    measurably fewer device KV bytes on a tight pool."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))

    def run(paged, n_blocks):
        from repro.serving import ServingEngine, WorkloadSpec, make_requests
        eng = ServingEngine(cfg, slots=3, max_len=48, block_size=8,
                            n_blocks=n_blocks, params=params, paged=paged)
        reqs = make_requests(cfg, WorkloadSpec(n_requests=5, rate=1e9,
                                               prompt_buckets=(8, 16),
                                               gen_buckets=(4, 24)), seed=9)
        s = eng.run(reqs)
        return ({r.rid: [int(np.asarray(t)) for t in r.generated] for r in reqs}, s)

    dense, sd = run(False, None)
    paged, sp = run(True, None)
    tight, st = run(True, 7)                         # 18 dense-equivalent blocks → 7+1
    assert dense == paged == tight
    assert st["preemptions"]["recompute"] > 0        # pressure actually hit
    assert st["kv_cache_bytes"] < sd["kv_cache_bytes"] / 2


def test_engine_sampling_deterministic_per_seed():
    """temperature/top-k decode: same seed reproduces the stream, different
    seeds (and greedy) diverge; greedy stays the default contract."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    from repro.serving import Request, ServingEngine
    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))

    def run(temperature, top_k, sample_seed=0):
        eng = ServingEngine(cfg, slots=2, max_len=32, block_size=8,
                            params=params, temperature=temperature,
                            top_k=top_k, sample_seed=sample_seed)
        reqs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32) + i,
                        max_new=6) for i in range(3)]
        eng.run(reqs)
        return {r.rid: [int(np.asarray(t)) for t in r.generated] for r in reqs}

    greedy = run(0.0, 0)
    s1 = run(1.0, 5)
    assert run(1.0, 5) == s1                         # deterministic per seed
    assert s1 != greedy
    assert run(1.0, 5, sample_seed=7) != s1


def test_sample_tokens_top_k_membership_and_greedy():
    import jax
    import jax.numpy as jnp
    from repro.launch.steps import _sample_tokens
    from repro.models import registry
    cfg = registry.get_smoke("phi4-mini-3.8b")
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 1, 32)), jnp.float32)
    greedy = _sample_tokens(logits, cfg, None, 0.0, 0)
    np.testing.assert_array_equal(
        np.asarray(greedy)[:, 0], np.argmax(np.asarray(logits)[:, 0], -1))
    # traced temperature 0 with a key still selects the argmax
    z = _sample_tokens(logits, cfg, jax.random.PRNGKey(0), jnp.float32(0.0), 5)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(greedy))
    top3 = np.argsort(np.asarray(logits)[:, 0], -1)[:, -3:]
    for i in range(50):
        s = np.asarray(_sample_tokens(logits, cfg, jax.random.PRNGKey(i),
                                      jnp.float32(1.0), 3))[:, 0]
        for b in range(4):
            assert s[b] in top3[b], (b, s[b], top3[b])


# ---------------------------------------------------------------------------
# horizon-batched decode (jax)
# ---------------------------------------------------------------------------

# One arch per cache family: paged dense GQA, MoE (drop-free routing) over
# paged GQA, sliding-window ring + SSM state, MLA + MoE, recurrent-only
# xLSTM.  musicgen adds the multi-codebook [B, K, H] token-block layout.
HORIZON_ARCHS = ["phi4-mini-3.8b", "qwen3-moe-235b-a22b", "hymba-1.5b",
                 "deepseek-v3-671b", "xlstm-350m", "musicgen-medium"]


@pytest.fixture(scope="module", params=HORIZON_ARCHS)
def horizon_setup(request):
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    cfg = registry.get_smoke(request.param)
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _run_horizon(cfg, params, horizon, *, n_blocks=None, swap_blocks=0,
                 eos_id=None, temperature=0.0, top_k=0):
    from repro.serving import ServingEngine, WorkloadSpec, make_requests
    eng = ServingEngine(cfg, slots=3, max_len=48, block_size=8,
                        n_blocks=n_blocks, swap_blocks=swap_blocks,
                        params=params, horizon=horizon, eos_id=eos_id,
                        temperature=temperature, top_k=top_k)
    reqs = make_requests(cfg, WorkloadSpec(n_requests=5, rate=1e9,
                                           prompt_buckets=(8, 16),
                                           gen_buckets=(4, 24)), seed=9)
    summary = eng.run(reqs)
    toks = {r.rid: [tuple(np.asarray(t).ravel().tolist()) for t in r.generated]
            for r in reqs}
    return toks, summary


def test_engine_horizon_token_parity_all_families(horizon_setup):
    """H>1 must be token-for-token identical to H=1 (greedy), with mid-horizon
    budget freezes exercised by the short gen bucket, while actually
    amortizing dispatches."""
    cfg, params = horizon_setup
    base, s1 = _run_horizon(cfg, params, 1)
    fused, s8 = _run_horizon(cfg, params, 8)
    assert base == fused
    assert s8["decode_dispatches"] < s1["decode_dispatches"]
    assert s8["tokens_per_dispatch"] > s1["tokens_per_dispatch"]
    assert s8["decode_tokens"] == s1["decode_tokens"]


def test_engine_horizon_sampled_parity():
    """Sampled decode folds the *global* step counter into the key, so a
    horizon run reproduces the single-step stream when the slot schedule
    matches (all-arrived workload, no preemption)."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    base, _ = _run_horizon(cfg, params, 1, temperature=1.0, top_k=5)
    fused, _ = _run_horizon(cfg, params, 8, temperature=1.0, top_k=5)
    greedy, _ = _run_horizon(cfg, params, 8)
    assert base == fused
    assert base != greedy


def test_engine_horizon_eos_freeze_mid_horizon():
    """EOS must freeze a slot mid-horizon on-device exactly where the host
    path stops it: pick a token that actually occurs mid-stream in the
    baseline, declare it EOS, and require identical truncated streams."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    base, _ = _run_horizon(cfg, params, 1)
    rid = idx = eos = None
    for r, stream in sorted(base.items()):   # first token not repeated earlier
        for i in range(2, len(stream) - 1):
            v = stream[i][0]
            if all(s[0] != v for s in stream[:i]):
                rid, idx, eos = r, i, v
                break
        if eos is not None:
            break
    assert eos is not None, "baseline streams have no usable mid-stream token"
    h1, _ = _run_horizon(cfg, params, 1, eos_id=eos)
    h8, _ = _run_horizon(cfg, params, 8, eos_id=eos)
    assert h1 == h8
    assert len(h1[rid]) == idx + 1           # truncated at the EOS token
    assert h1[rid][-1][0] == eos
    assert len(h1[rid]) < len(base[rid])
    # the non-EOS prefix is unchanged
    assert base[rid][:idx + 1] == h1[rid]


def test_engine_horizon_preemption_at_boundary(smoke_setup):
    """A tight pool under a horizon engine: grants shrink to the block
    headroom, preemption (swap AND recompute) lands on horizon boundaries via
    plan(), and greedy token streams stay identical to the unconstrained
    run."""
    cfg, params = smoke_setup
    base, _ = _run_horizon(cfg, params, 1)
    swap, s_sw = _run_horizon(cfg, params, 8, n_blocks=8, swap_blocks=32)
    rec, s_rc = _run_horizon(cfg, params, 8, n_blocks=8, swap_blocks=0)
    assert s_sw["preemptions"]["swap"] > 0
    assert s_rc["preemptions"]["recompute"] > 0
    assert base == swap
    assert base == rec


def test_engine_horizon_timestamps_use_engine_clock():
    """Interpolated horizon timestamps must come from the *engine* clock, so
    an injected deterministic clock yields monotone per-request times and
    non-negative TPOT (regression: mixing in perf_counter spans produced
    timestamps before TTFT under a fake clock)."""
    import itertools
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    from repro.serving import Request, ServingEngine
    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    fake = itertools.count()
    seen = {}
    eng = ServingEngine(cfg, slots=2, max_len=32, block_size=8, params=params,
                        horizon=8, clock=lambda: float(next(fake)),
                        on_token=lambda r, t, now: seen.setdefault(r.rid, []).append(now))
    reqs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32) + i, max_new=6)
            for i in range(3)]
    summary = eng.run(reqs)
    for r in reqs:
        ts = seen[r.rid]
        assert ts == sorted(ts)
        assert r.t_first_token >= 0 and r.t_done >= ts[-1]
    for rec in summary["requests"]:
        assert rec["ttft_s"] >= 0
        assert rec["tpot_s"] is None or rec["tpot_s"] >= 0


def test_engine_horizon_dispatch_observables():
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    _, s = _run_horizon(cfg, params, 4)
    assert s["decode_dispatches"] > 0
    assert s["decode_steps"] > s["decode_dispatches"]     # amortization real
    assert s["host_syncs"] <= s["dispatches"]
    assert s["tokens_per_dispatch"] == pytest.approx(
        s["decode_tokens"] / s["decode_dispatches"])


def test_engine_streaming_callback_and_order(smoke_setup):
    from repro.serving import Request, ServingEngine
    cfg, params = smoke_setup
    seen = {}
    eng = ServingEngine(cfg, slots=2, max_len=32, block_size=8, params=params,
                        on_token=lambda r, t, now: seen.setdefault(r.rid, []).append(int(np.asarray(t))))
    reqs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32) + i, max_new=5)
            for i in range(3)]
    eng.run(reqs)
    for r in reqs:
        assert seen[r.rid] == [int(np.asarray(t)) for t in r.generated]
        assert len(seen[r.rid]) == 5
