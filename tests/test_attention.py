"""Attention correctness: blockwise==direct, decode==teacher-forced, MLA, SWA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnConfig
from repro.nn.attention import (
    _blockwise, _mask_bias, _sdpa, attention, attn_spec, init_cache, sdpa,
)
from repro.nn.module import materialize


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * 0.3


def _direct(q, k, v, q_pos, k_pos, window=0):
    B, Sq = q.shape[0], q.shape[1]
    Sk = k.shape[1]
    qp = jnp.broadcast_to(q_pos, (B, Sq))
    kp = jnp.broadcast_to(k_pos, (B, Sk))
    bias = _mask_bias(qp, kp, window)[:, None]
    return _sdpa(q, k, v, bias, 1.0 / np.sqrt(q.shape[-1]))


@pytest.mark.parametrize("Sq,Sk,window,chunk", [
    (256, 256, 0, 64), (256, 256, 96, 64), (128, 384, 0, 64),
    (250, 250, 0, 64),   # non-divisible → padded path
    (255, 511, 60, 64),
])
def test_blockwise_matches_direct(Sq, Sk, window, chunk):
    B, H, Hkv, D = 2, 4, 2, 16
    q = _rand(0, B, Sq, H, D)
    k = _rand(1, B, Sk, Hkv, D)
    v = _rand(2, B, Sk, Hkv, D)
    q_pos = jnp.arange(Sk - Sq, Sk, dtype=jnp.int32)[None, :] + jnp.zeros((B, 1), jnp.int32)
    k_pos = jnp.arange(Sk, dtype=jnp.int32)[None, :] + jnp.zeros((B, 1), jnp.int32)
    out_b = sdpa(q, k, v, q_pos, k_pos, window, chunk=chunk, blockwise_threshold=1)
    out_d = _direct(q, k, v, q_pos, k_pos, window)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_d), atol=2e-5)


def _decode_parity(cfg: AttnConfig, d_model: int, steps: int = 12):
    """Teacher-forced full forward == prefill + step-by-step decode."""
    key = jax.random.PRNGKey(0)
    params = materialize(attn_spec(cfg, d_model), key)
    B, S = 2, steps
    x = _rand(9, B, S, d_model).astype(jnp.bfloat16)

    full, _ = attention(params, x, cfg)

    cache = init_cache(cfg, B, max_len=S + 4)
    out0, cache = attention(params, x[:, :4], cfg, cache=cache)
    outs = [out0]
    for t in range(4, S):
        o, cache = attention(params, x[:, t:t + 1], cfg, cache=cache)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(inc, np.float32), atol=3e-2)


def test_gqa_decode_parity():
    _decode_parity(AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16), 64)


def test_mha_nope_decode_parity():
    _decode_parity(AttnConfig(kind="gqa", n_heads=4, n_kv_heads=4, d_head=16,
                              rope="none"), 64)


def test_mla_decode_parity():
    cfg = AttnConfig(kind="mla", n_heads=4, n_kv_heads=4, d_head=16,
                     q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16,
                     qk_rope_dim=8, v_head_dim=16)
    _decode_parity(cfg, 64)


def test_sliding_window_ring_buffer():
    """Window cache keeps only ``window`` entries yet matches full attention."""
    cfg = AttnConfig(kind="gqa", n_heads=2, n_kv_heads=2, d_head=16, window=6)
    d_model = 32
    params = materialize(attn_spec(cfg, d_model), jax.random.PRNGKey(1))
    B, S = 1, 16
    x = _rand(5, B, S, d_model).astype(jnp.bfloat16)

    full, _ = attention(params, x, cfg)

    cache = init_cache(cfg, B, max_len=S)
    assert cache["k"].shape[1] == cfg.window      # O(window) state
    outs = []
    for t in range(S):
        o, cache = attention(params, x[:, t:t + 1], cfg, cache=cache)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32)[:, 8:],
                               np.asarray(inc, np.float32)[:, 8:], atol=3e-2)


def test_mask_bias_window_semantics():
    qp = jnp.array([[5]])
    kp = jnp.arange(8)[None]
    bias = _mask_bias(qp, kp, window=3)
    visible = np.asarray(bias[0, 0] == 0.0)
    np.testing.assert_array_equal(visible, [False, False, False, True, True, True, False, False])


@pytest.mark.parametrize("kind", ["gqa", "swa", "mla"])
def test_int8_kv_cache_parity(kind):
    """8-bit KV cache (§Perf-3, the paper's fixed-8-bit-operand adjustment):
    decode against an int8 cache matches exact attention within the
    fixed-point step (1/16)."""
    if kind == "mla":
        cfg = AttnConfig(kind="mla", n_heads=4, n_kv_heads=4, d_head=16,
                         q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16,
                         qk_rope_dim=8, v_head_dim=16)
    else:
        cfg = AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16,
                         window=6 if kind == "swa" else 0)
    d = 64
    params = materialize(attn_spec(cfg, d), jax.random.PRNGKey(0))
    B, S = 2, 12
    x = (_rand(1, B, S, d) * 1.5).astype(jnp.bfloat16)
    full, _ = attention(params, x, cfg)
    cache = init_cache(cfg, B, max_len=S, dtype=jnp.int8)
    assert all(l.dtype == jnp.int8 for k, l in cache.items() if k != "pos")
    outs = []
    for t in range(S):
        o, cache = attention(params, x[:, t:t + 1], cfg, cache=cache)
        outs.append(o)
    inc = jnp.concatenate(outs, 1)
    lo = 8 if kind == "swa" else 0          # ring warm-up region
    err = np.abs(np.asarray(full, np.float32) - np.asarray(inc, np.float32))[:, lo:]
    assert err.max() < 0.15, err.max()


def test_mla_cache_is_compressed():
    """MLA cache stores the latent (r ≪ H·D), the paper-exact DeepSeek trick."""
    cfg = AttnConfig(kind="mla", n_heads=8, n_kv_heads=8, d_head=128,
                     kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
    cache = init_cache(cfg, batch=2, max_len=100)
    assert set(cache) == {"c_kv", "k_rope", "pos"}
    assert cache["c_kv"].shape == (2, 100, 64)
    full_kv = 2 * 100 * 8 * (32 + 32)
    latent = 2 * 100 * (64 + 16)
    assert latent < full_kv / 6
