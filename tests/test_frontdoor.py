"""Async streaming front door: backpressure, tenant QoS, failure semantics.

Chaos-client suite for :class:`repro.serving.frontdoor.FrontDoor`: stream
parity against the synchronous engine, typed admission rejections
(queue-full / degradation / tenant quota / draining), disconnect-cancel,
slow readers, deadline expiry, graceful shutdown mid-burst, and heartbeats.
Every engine test asserts the no-leak invariants: all slots free, pool
blocks down to prefix-cache-held, and every request in exactly one
terminal state.

No pytest-asyncio in the image: async tests are plain functions driving
``asyncio.run`` themselves.
"""
import asyncio

import numpy as np
import pytest

from serving_harness import materialize, mixed_spec, token_streams
from repro.serving import (FrontDoor, Overloaded, Request, ServingEngine,
                           ShuttingDown, TokenBucket, make_requests)


@pytest.fixture(scope="module")
def phi4_setup():
    return materialize("phi4-mini-3.8b")


def _engine(phi4_setup, **kw):
    cfg, params = phi4_setup
    kw.setdefault("slots", 3)
    kw.setdefault("max_len", 48)
    kw.setdefault("block_size", 8)
    return ServingEngine(cfg, params=params, **kw)


def _assert_no_leaks(eng):
    cache = eng.sched.prefix_cache
    held = len(cache.held_blocks()) if cache is not None else 0
    assert eng.pool.used_blocks == held
    assert len(eng.sched.free_slots) == eng.slots
    assert not eng.sched.running and not eng.sched.swapped


def _assert_all_terminal(reqs):
    for r in reqs:
        assert r.terminal, f"rid {r.rid} stuck in {r.state}"
        assert r.t_done is not None


async def _collect(stream):
    """Drain one stream; returns (token tuples, done event, heartbeat count)."""
    toks, done, beats = [], None, 0
    async for ev in stream:
        if ev.kind == "token":
            toks.append(ev.token)
        elif ev.kind == "heartbeat":
            beats += 1
        else:
            done = ev
    return toks, done, beats


# ---------------------------------------------------------------- units

def test_token_bucket_refill_and_debt():
    b = TokenBucket(rate=10.0, burst=5.0, now=0.0)
    assert b.admit_ok(0.0) and b.retry_after_s(0.0) == 0.0
    b.debit(8.0, 0.0)                       # post-hoc billing → negative
    assert b.level == -3.0
    assert not b.admit_ok(0.0)
    # refill past one token: (1 - (-3)) / 10 = 0.4s
    assert b.retry_after_s(0.0) == pytest.approx(0.4)
    assert b.admit_ok(0.5)                  # -3 + 5 = 2 > 0
    b.debit(0.0, 10.0)                      # long idle caps at burst
    assert b.level == 5.0


def test_overloaded_typing():
    e = Overloaded("full", retry_after=1.5, tenant="t0")
    assert isinstance(e, RuntimeError)
    assert e.retry_after == 1.5 and e.tenant == "t0"
    s = ShuttingDown("bye")
    # one except-clause covers both rejection shapes
    assert isinstance(s, Overloaded) and s.retry_after is None


def test_victim_key_ranks_over_quota_first():
    class _Sched:
        victim_key = None
    class _Eng:
        on_token = None
        sched = _Sched()
        _done = []
    fd = FrontDoor.__new__(FrontDoor)      # key logic only, no event loop
    fd.tenant_rate = 1.0
    fd.buckets = {"hog": TokenBucket(1.0, 1.0, 0.0)}
    fd.buckets["hog"].debit(5.0, 0.0)      # over quota
    old_hog = Request(rid=0, prompt=np.zeros(4, np.int32), max_new=4,
                      arrival=0.0, tenant="hog")
    young = Request(rid=1, prompt=np.zeros(4, np.int32), max_new=4,
                    arrival=9.0, tenant="polite")
    # default policy would pick the youngest (rid 1); QoS key overrides
    assert max([old_hog, young], key=fd._victim_key) is old_hog
    fd.buckets["hog"].debit(-10.0, 0.0)    # back under quota
    assert max([old_hog, young], key=fd._victim_key) is young


# ---------------------------------------------------------------- parity

def test_stream_parity_with_sync_engine(phi4_setup):
    ref_reqs = make_requests(phi4_setup[0], mixed_spec(4), seed=9)
    eng0 = _engine(phi4_setup)
    eng0.run(ref_reqs)
    ref = token_streams(ref_reqs)

    eng = _engine(phi4_setup)
    reqs = make_requests(phi4_setup[0], mixed_spec(4), seed=9)

    async def main():
        fd = FrontDoor(eng, max_queue=16)
        await fd.start()
        outs = await asyncio.gather(*[_collect(fd.submit(r)) for r in reqs])
        await fd.aclose()
        return outs

    outs = asyncio.run(main())
    got = {r.rid: t for r, (t, _, _) in zip(reqs, outs)}
    assert got == ref
    for r, (toks, done, _) in zip(reqs, outs):
        assert done is not None and done.state == "done"
        assert done.n_tokens == len(toks) == r.n_generated
    # aclose restored the hooks: the engine is serviceable for direct use
    assert eng.on_token is None and eng.sched.victim_key is None
    _assert_all_terminal(reqs)
    _assert_no_leaks(eng)


def test_token_events_are_incremental(phi4_setup):
    eng = _engine(phi4_setup)
    req = make_requests(phi4_setup[0], mixed_spec(1), seed=9)[0]

    async def main():
        fd = FrontDoor(eng, max_queue=4)
        await fd.start()
        events = []
        async for ev in fd.submit(req):
            events.append(ev)
        await fd.aclose()
        return events

    events = asyncio.run(main())
    toks = [ev for ev in events if ev.kind == "token"]
    assert [ev.index for ev in toks] == list(range(len(toks)))
    # interpolated timestamps: monotone, and the done event is last
    ts = [ev.t for ev in toks]
    assert all(a <= b for a, b in zip(ts, ts[1:]))
    assert events[-1].kind == "done"
    assert sum(ev.kind == "done" for ev in events) == 1


# ---------------------------------------------------------------- backpressure

def test_queue_full_rejects_with_retry_after(phi4_setup):
    eng = _engine(phi4_setup, slots=2)
    reqs = make_requests(phi4_setup[0], mixed_spec(8), seed=9)

    async def main():
        fd = FrontDoor(eng, max_queue=2)
        await fd.start()
        streams, rejected = [], []
        for r in reqs:
            try:
                streams.append(fd.submit(r))
            except Overloaded as e:
                rejected.append(e)
        outs = await asyncio.gather(*[_collect(s) for s in streams])
        await fd.aclose()
        return outs, rejected, fd.summary()

    outs, rejected, summ = asyncio.run(main())
    # nothing was stepped during the submit burst, so everything past the
    # queue bound bounced (2 waiting max; admission to slots needs a step)
    assert rejected, "expected queue-full rejections"
    for e in rejected:
        assert isinstance(e, Overloaded) and not isinstance(e, ShuttingDown)
        assert e.retry_after is not None and e.retry_after >= 0.0
    assert summ["rejected_queue"] == len(rejected)
    assert summ["accepted"] == len(outs)
    for toks, done, _ in outs:
        assert done.state == "done" and len(toks) == done.n_tokens
    _assert_no_leaks(eng)


def test_degradation_denial_rejects_with_retry_after(phi4_setup):
    eng = _engine(phi4_setup, degrade=True)
    eng.degrade.level = 4                   # force admit_deny
    req = make_requests(phi4_setup[0], mixed_spec(1), seed=9)[0]

    async def main():
        fd = FrontDoor(eng, max_queue=8)
        await fd.start()
        try:
            with pytest.raises(Overloaded) as ei:
                fd.submit(req)
            return ei.value, fd.summary()
        finally:
            await fd.aclose()

    exc, summ = asyncio.run(main())
    assert exc.retry_after is not None and exc.retry_after >= 0.0
    assert summ["rejected_degrade"] == 1
    # the same relative hint surfaces in the operator summary
    snap = eng.degrade.snapshot(eng._now())
    assert snap["retry_after_s"] is not None and snap["retry_after_s"] >= 0.0
    assert eng._by_rid == {}                # rejected ⇒ no engine state


# ---------------------------------------------------------------- disconnects

def test_disconnect_mid_stream_cancels_and_frees(phi4_setup):
    eng = _engine(phi4_setup, slots=2)
    spec = mixed_spec(3, gen_buckets=(24,))
    reqs = make_requests(phi4_setup[0], spec, seed=9)

    async def main():
        fd = FrontDoor(eng, max_queue=8)
        await fd.start()

        async def flaky(r):
            stream = fd.submit(r)
            n = 0
            async for ev in stream:
                if ev.kind == "token":
                    n += 1
                    if n >= 3:
                        break
            # the disconnect: closing the generator fires its finally,
            # which cancels the request in the engine
            await stream.aclose()
            return n

        got = await asyncio.gather(_collect(fd.submit(reqs[0])),
                                   flaky(reqs[1]), flaky(reqs[2]))
        # let the driver route the cancellations before closing
        await asyncio.sleep(0)
        await fd.shutdown()
        return got, fd.summary()

    (full, n1, n2), summ = asyncio.run(main())
    assert full[1].state == "done"
    assert n1 == 3 and n2 == 3
    assert summ["disconnect_cancels"] == 2
    assert summ["live_streams"] == 0
    by_state = sorted(r.state.value for r in reqs)
    assert by_state == ["cancelled", "cancelled", "done"]
    for r in reqs[1:]:
        assert r.finish_reason == "disconnect"
    _assert_all_terminal(reqs)
    _assert_no_leaks(eng)


def test_slow_reader_loses_nothing(phi4_setup):
    eng = _engine(phi4_setup)
    reqs = make_requests(phi4_setup[0], mixed_spec(2, gen_buckets=(24,)),
                         seed=9)

    async def main():
        fd = FrontDoor(eng, max_queue=8)
        await fd.start()

        async def slow(r):
            toks = []
            async for ev in fd.submit(r):
                await asyncio.sleep(0.002)    # reader slower than the engine
                if ev.kind == "token":
                    toks.append(ev.token)
            return toks

        fast = _collect(fd.submit(reqs[0]))
        outs = await asyncio.gather(fast, slow(reqs[1]))
        await fd.aclose()
        return outs

    (fast_toks, done, _), slow_toks = asyncio.run(main())
    assert done.state == "done"
    # backpressure never drops events: the slow reader still gets them all
    assert len(slow_toks) == reqs[1].n_generated == 24
    assert len(fast_toks) == reqs[0].n_generated
    _assert_all_terminal(reqs)
    _assert_no_leaks(eng)


def test_deadline_expiry_streams_timeout(phi4_setup):
    eng = _engine(phi4_setup, slots=1)
    reqs = make_requests(phi4_setup[0], mixed_spec(2, gen_buckets=(24,)),
                         seed=9)

    async def main():
        fd = FrontDoor(eng, max_queue=8)
        await fd.start()
        s0 = fd.submit(reqs[0])
        reqs[1].deadline = eng._now()         # expires at the next step top
        s1 = fd.submit(reqs[1])
        outs = await asyncio.gather(_collect(s0), _collect(s1))
        await fd.aclose()
        return outs

    (t0, d0, _), (t1, d1, _) = asyncio.run(main())
    assert d0.state == "done" and len(t0) == 24
    assert d1.state == "timeout" and d1.finish_reason == "deadline"
    _assert_all_terminal(reqs)
    _assert_no_leaks(eng)


# ---------------------------------------------------------------- shutdown

def test_shutdown_mid_burst_flushes_and_rejects_late(phi4_setup):
    eng = _engine(phi4_setup, slots=2)
    reqs = make_requests(phi4_setup[0], mixed_spec(6, gen_buckets=(24,)),
                         seed=9)

    async def main():
        fd = FrontDoor(eng, max_queue=8)
        await fd.start()
        streams = [fd.submit(r) for r in reqs[:5]]
        tasks = [asyncio.ensure_future(_collect(s)) for s in streams]
        # give the engine a few steps so some requests are truly in flight
        for _ in range(30):
            await asyncio.sleep(0)
        shut = asyncio.ensure_future(fd.shutdown())
        await asyncio.sleep(0)
        # late submission during the drain: typed rejection, never a hang
        with pytest.raises(ShuttingDown):
            fd.submit(reqs[5])
        outs = await asyncio.gather(*tasks)
        await shut
        return outs, fd.summary()

    outs, summ = asyncio.run(main())
    assert summ["rejected_draining"] == 1
    states = sorted(d.state for _, d, _ in outs)
    # every admitted stream flushed exactly one terminal event; in-flight
    # requests ran to completion, never-admitted ones cancelled as "drain"
    assert all(s in ("done", "cancelled") for s in states)
    assert "done" in states
    for r, (toks, done, _) in zip(reqs[:5], outs):
        assert done.n_tokens == len(toks) == r.n_generated
        if done.state == "cancelled":
            assert r.finish_reason == "drain" and r.t_admit is None
    _assert_all_terminal(reqs[:5])
    assert not reqs[5].terminal and reqs[5].rid not in eng._by_rid
    _assert_no_leaks(eng)


# ---------------------------------------------------------------- tenants

def test_tenant_quota_storm(phi4_setup):
    eng = _engine(phi4_setup)
    spec = mixed_spec(8, gen_buckets=(8,), n_tenants=2)
    reqs = make_requests(phi4_setup[0], spec, seed=9)
    hog = [r for r in reqs if r.tenant == "t0"]
    polite = [r for r in reqs if r.tenant == "t1"]

    async def main():
        # burst covers ~2 requests of emitted tokens; refill is negligible
        # on this run's wall-clock timescale, so the storm outcome is exact
        fd = FrontDoor(eng, max_queue=16, tenant_rate=1e-3, tenant_burst=12.0)
        await fd.start()
        admitted, rejected = [], []
        for r in hog:
            try:
                admitted.append(asyncio.ensure_future(_collect(fd.submit(r))))
                await asyncio.gather(admitted[-1])   # serialize: drain quota
            except Overloaded as e:
                rejected.append(e)
        polite_outs = await asyncio.gather(
            *[_collect(fd.submit(r)) for r in polite])
        outs = await asyncio.gather(*admitted)
        await fd.aclose()
        return outs, rejected, polite_outs, fd.summary()

    outs, rejected, polite_outs, summ = asyncio.run(main())
    # the hog burns its bucket and starts bouncing; rejections carry the
    # refill-sized hint and the tenant id
    assert rejected and summ["rejected_quota"] == len(rejected)
    for e in rejected:
        assert e.tenant == "t0"
        assert e.retry_after is not None and e.retry_after > 0.0
    # the polite tenant is untouched by the hog's storm
    assert all(d.state == "done" for _, d, _ in polite_outs)
    assert summ["tenant_buckets"]["t0"] <= 0.0
    _assert_no_leaks(eng)


def test_per_tenant_metrics_and_bills(phi4_setup):
    eng = _engine(phi4_setup)
    spec = mixed_spec(4, n_tenants=2)
    reqs = make_requests(phi4_setup[0], spec, seed=9)

    async def main():
        fd = FrontDoor(eng, max_queue=16)
        await fd.start()
        await asyncio.gather(*[_collect(fd.submit(r)) for r in reqs])
        await fd.aclose()

    asyncio.run(main())
    s = eng.summary()
    # per-tenant aggregate: terminal counts, token totals, latency, energy
    assert set(s["tenants"]) == {"t0", "t1"}
    for t, agg in s["tenants"].items():
        assert agg["requests"] == 2
        assert agg["terminal"]["done"] == 2
        assert agg["generated_tokens"] > 0
        assert agg["energy_mj"] > 0.0
        assert agg["ttft_s"]["p50"] >= 0.0
    assert sum(a["generated_tokens"] for a in s["tenants"].values()) \
        == s["engine_stats"]["generated_tokens"]
    # per-request records carry the tenant id
    assert {r["tenant"] for r in s["requests"]} == {"t0", "t1"}
    # windowed per-tenant TTFT/TPOT histograms exist in the registry
    hists = s["metrics"]["histograms"]
    assert "ttft_s/t0" in hists and "ttft_s/t1" in hists


def test_untenanted_summary_keeps_schema(phi4_setup):
    eng = _engine(phi4_setup)
    reqs = make_requests(phi4_setup[0], mixed_spec(2), seed=9)
    eng.run(reqs)
    s = eng.summary()
    assert "tenants" not in s
    assert all(r["tenant"] is None for r in s["requests"])


# ---------------------------------------------------------------- heartbeats

def test_heartbeats_on_idle_streams(phi4_setup):
    eng = _engine(phi4_setup, slots=1)
    reqs = make_requests(phi4_setup[0], mixed_spec(2, gen_buckets=(24,)),
                         seed=9)

    async def main():
        fd = FrontDoor(eng, max_queue=8, heartbeat_s=1e-6)
        await fd.start()
        s0 = fd.submit(reqs[0])

        first_kind = {}

        async def watch(r, stream):
            beats = 0
            async for ev in stream:
                first_kind.setdefault(r.rid, ev.kind)
                if ev.kind == "heartbeat":
                    beats += 1
                    assert ev.state in ("queued", "running", "swapped")
            return beats

        s1 = fd.submit(reqs[1])               # queued behind the only slot
        b0, b1 = await asyncio.gather(watch(reqs[0], s0), watch(reqs[1], s1))
        await fd.aclose()
        return b0, b1, first_kind, fd.summary()

    b0, b1, first_kind, summ = asyncio.run(main())
    # the queued stream heartbeats while it waits for its slot
    assert b1 > 0 and summ["heartbeats"] == b0 + b1
    assert first_kind[reqs[1].rid] == "heartbeat"
    _assert_all_terminal(reqs)
    _assert_no_leaks(eng)
