"""Property tests for the stochastic-number arithmetic core (paper §III-C).

Invariants tested (hypothesis where the domain is wide):
  * B→S → S→B is *exact* (LUT row v has exactly v ones).
  * AND of two *independent* streams is an unbiased product estimator with
    hypergeometric variance; AND with a *shared* LUT computes min (the
    failure mode that motivates the two-LUT completion, DESIGN.md §2).
  * MUX is an exact 0.5-scaled add in expectation; select streams are
    exactly half-density.
  * The MUX tree computes (1/K̂)·Σ and the popcount matmul tracks the
    integer dot within a bound that shrinks as operands grow.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep; collection must not hard-fail
from hypothesis import given, settings, strategies as st

from repro.core import stochastic as sc
from repro.core.odin_linear import get_luts

SPEC = sc.StreamSpec(256, 256)
LUT_A, LUT_W, SELECTS = get_luts(256, 256, 0)


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**32 - 1), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(seed, words):
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 2, words * 32), dtype=bool)
    packed = sc.pack_bits(bits)
    assert packed.shape == (words,)
    assert bool((sc.unpack_bits(packed) == bits).all())


# ---------------------------------------------------------------------------
# conversions
# ---------------------------------------------------------------------------

def test_b_to_s_exact_density():
    vals = jnp.arange(256)
    streams = sc.b_to_s(vals, LUT_A)
    pops = sc.s_to_b(streams)
    np.testing.assert_array_equal(np.asarray(pops), np.arange(256))


def test_roundtrip_both_luts():
    vals = jnp.arange(256)
    for lut in (LUT_A, LUT_W):
        assert bool((sc.s_to_b(sc.b_to_s(vals, lut)) == vals).all())


def test_lut_rows_nested():
    # row v's set bits are a subset of row v+1's (comparator SNG property)
    bits = sc.unpack_bits(LUT_A)
    b = np.asarray(bits)
    assert ((b[:-1] & ~b[1:]).sum()) == 0


# ---------------------------------------------------------------------------
# multiply (AND)
# ---------------------------------------------------------------------------

@given(st.integers(0, 255), st.integers(0, 255))
@settings(max_examples=60, deadline=None)
def test_and_product_bound(a, b):
    """popcount(AND of independent streams) ≈ a·b/256, hypergeometric bound."""
    s = sc.sc_mul(sc.b_to_s(jnp.int32(a), LUT_A), sc.b_to_s(jnp.int32(b), LUT_W))
    pop = int(sc.s_to_b(s))
    exact = a * b / 256.0
    # hypergeometric support: max(0, a+b-256) ≤ pop ≤ min(a,b); 4σ slack
    var = a * b * (256 - a) * (256 - b) / (256.0**2 * 255.0)
    assert max(0, a + b - 256) <= pop <= min(a, b)
    assert abs(pop - exact) <= 4.0 * np.sqrt(var) + 1.0


def test_and_shared_lut_is_min():
    """One shared LUT degenerates AND into min(a, b) — exactly (nested rows)."""
    for a, b in [(0, 0), (7, 200), (128, 128), (255, 3), (90, 91)]:
        s = sc.sc_mul(sc.b_to_s(jnp.int32(a), LUT_A), sc.b_to_s(jnp.int32(b), LUT_A))
        assert int(sc.s_to_b(s)) == min(a, b)


def test_and_unbiased_over_draws():
    """Mean over many independent operand pairs ≈ product (unbiased)."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 256, 512))
    b = jnp.asarray(rng.integers(0, 256, 512))
    pops = sc.s_to_b(sc.sc_mul(sc.b_to_s(a, LUT_A), sc.b_to_s(b, LUT_W)))
    exact = np.asarray(a) * np.asarray(b) / 256.0
    err = np.asarray(pops) - exact
    assert abs(err.mean()) < 1.0          # systematic bias ≪ 1 level
    assert np.abs(err).max() < 4 * np.sqrt(64 * 64) + 8


# ---------------------------------------------------------------------------
# add (MUX) and the tree
# ---------------------------------------------------------------------------

def test_select_streams_half_density():
    sel = sc.make_select_streams(jax.random.PRNGKey(3), 8, SPEC)
    pops = np.asarray(jax.lax.population_count(sel).sum(-1))
    np.testing.assert_array_equal(pops, np.full(8, 128))


@given(st.integers(0, 255), st.integers(0, 255))
@settings(max_examples=40, deadline=None)
def test_mux_scaled_add(a, b):
    # NB: the select-stream seed derives from (a, b) rather than being a
    # searchable strategy — hypothesis would otherwise adversarially optimize
    # the select permutation, where the structural worst case is ±max(a,b)/2,
    # not the ~4σ hypergeometric tail this asserts.
    sel = sc.make_select_streams(jax.random.PRNGKey(a * 257 + b), 1, SPEC)[0]
    s = sc.sc_mux(sc.b_to_s(jnp.int32(a), LUT_A), sc.b_to_s(jnp.int32(b), LUT_W), sel)
    pop = int(sc.s_to_b(s))
    assert abs(pop - (a + b) / 2.0) <= 24  # ~4σ hypergeometric subsample noise


@given(st.integers(1, 24))
@settings(max_examples=25, deadline=None)
def test_mac_tree_scaling(k):
    rng = np.random.default_rng(k * 7919)    # derived, not searchable
    vals = rng.integers(0, 256, k)
    streams = sc.b_to_s(jnp.asarray(vals), LUT_A)
    out = sc.sc_mac_tree(streams, SELECTS)
    pop = int(sc.s_to_b(out))
    khat = 1 << sc.tree_depth(k)
    expect = vals.sum() / khat
    assert abs(pop - expect) <= 4 * np.sqrt(khat) + 4


def test_tree_depth():
    assert [sc.tree_depth(k) for k in (1, 2, 3, 4, 5, 8, 9, 1024)] == \
        [1, 1, 2, 2, 3, 3, 4, 10]


# ---------------------------------------------------------------------------
# full matmul vs the deterministic expectation
# ---------------------------------------------------------------------------

def test_sc_matmul_tracks_expectation():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.integers(0, 256, (6, 24)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 256, (24, 5)), jnp.int32)
    pop = sc.sc_matmul(a, w, LUT_A, LUT_W, SELECTS, SPEC)
    exp = sc.expected_matmul(a, w, SPEC)
    err = np.abs(np.asarray(pop) - np.asarray(exp))
    assert err.mean() < 6.0 and err.max() < 25.0


def test_expected_matmul_scaling():
    """K̂-scaling: doubling K into the same K̂ bucket keeps the scale."""
    a = jnp.ones((1, 3), jnp.int32) * 128
    w = jnp.ones((3, 1), jnp.int32) * 128
    out = sc.expected_matmul(a, w, SPEC)           # K̂=4: 3·(0.5·0.5)/4·256
    np.testing.assert_allclose(np.asarray(out), 256 * 3 * 0.25 / 4, rtol=1e-5)
