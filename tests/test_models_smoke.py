"""Per-arch smoke tests (assignment f): reduced config, one train step +
decode step on CPU, asserting shapes and no NaNs — all 10 architectures."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LM_SHAPES, ShapeConfig
from repro.launch import specs as specs_mod
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import lm, registry
from repro.nn.module import materialize
from repro.optim.adamw import AdamWConfig, adamw_init


@pytest.fixture(scope="module")
def smoke_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = registry.get_smoke(arch)
            params = materialize(lm.param_spec(cfg), jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_train_step_smoke(arch, smoke_state):
    cfg, params = smoke_state(arch)
    opt_cfg = AdamWConfig(moment_dtype="float32")
    opt = adamw_init(params, opt_cfg)
    shape = ShapeConfig("t", 32, 2, "train")
    batch = specs_mod.concrete_batch(cfg, shape, seed=0, step=0)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    p2, o2, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
        if a.dtype in (jnp.float32, jnp.bfloat16)
    )
    assert moved


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_forward_shapes_and_finite(arch, smoke_state):
    cfg, params = smoke_state(arch)
    shape = ShapeConfig("t", 16, 2, "train")
    batch = specs_mod.concrete_batch(cfg, shape, seed=1, step=0)
    logits, _, _ = lm.forward(params, batch["tokens"], cfg,
                              patch_embeds=batch.get("patch_embeds"),
                              pos3d=batch.get("pos3d"))
    if cfg.n_codebooks > 1:
        assert logits.shape == (2, 16, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_prefill_decode_smoke(arch, smoke_state):
    cfg, params = smoke_state(arch)
    B, S, gen = 2, 8, 3
    shape = ShapeConfig("p", S, B, "prefill")
    batch = specs_mod.concrete_batch(cfg, shape, seed=2, step=0)
    prefill = jax.jit(make_prefill_step(cfg, max_len=S + gen))
    decode = jax.jit(make_decode_step(cfg))
    last, caches = prefill(params, batch)
    tok = (jnp.argmax(last, -1).astype(jnp.int32)[:, :, None] if cfg.n_codebooks > 1
           else jnp.argmax(last, -1).astype(jnp.int32)[:, None])
    for _ in range(gen):
        tok, caches = decode(params, caches, tok)
        assert bool((tok >= 0).all()) and bool((tok < cfg.vocab).all())


def test_assigned_cells_enumeration():
    """40 assigned cells = 32 runnable + 8 documented long_500k skips."""
    runnable = registry.cells()
    assert len(runnable) == 32
    skips = [(a, s) for a in registry.ARCH_IDS for s in LM_SHAPES
             if registry.skip_reason(a, s)]
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
    for a, _ in skips:
        assert a not in registry.SUBQUADRATIC


def test_full_configs_match_assignment():
    """Spot-check the exact assigned dims (source-of-truth guard)."""
    c = registry.get_config("deepseek-v3-671b")
    assert c.d_model == 7168 and c.vocab == 129_280 and c.n_layers == 61
    moe = c.blocks[1].moe
    assert moe.n_experts == 256 and moe.top_k == 8 and moe.d_ff == 2048
    assert c.mtp

    c = registry.get_config("llama3-405b")
    assert (c.d_model, c.vocab, c.n_layers) == (16384, 128_256, 126)
    a = c.blocks[0].attn
    assert (a.n_heads, a.n_kv_heads) == (128, 8) and c.blocks[0].d_ff == 53_248

    c = registry.get_config("qwen3-moe-235b-a22b")
    assert c.n_layers == 94 and c.blocks[0].moe.n_experts == 128

    c = registry.get_config("nemotron-4-15b")
    assert c.blocks[0].activation == "relu2" and c.blocks[0].d_ff == 24_576

    c = registry.get_config("hymba-1.5b")
    assert c.d_model == 1600 and c.blocks[0].ssm.state_dim == 16

    c = registry.get_config("musicgen-medium")
    assert c.n_codebooks == 4 and c.vocab == 2048

    c = registry.get_config("xlstm-350m")
    assert c.n_layers == 24 and c.d_model == 1024

    c = registry.get_config("qwen2-vl-2b")
    assert c.vision_stub and c.blocks[0].attn.rope == "mrope"


def test_param_counts_near_nameplate():
    """Total params ≈ the arch's nameplate (loose 25% band)."""
    # xlstm: the ASSIGNED dims (24L × d=1024, d_ff=0 ⇒ cell-internal
    # projections only) yield 229M — the "350m" nameplate assumes the
    # original model's up/down projection factor, which d_ff=0 excludes.
    expected = {"deepseek-v3-671b": 671e9, "llama3-405b": 405e9,
                "qwen3-moe-235b-a22b": 235e9, "phi4-mini-3.8b": 3.8e9,
                "xlstm-350m": 229e6}
    from repro.nn.module import count_params
    for arch, n in expected.items():
        cfg = registry.get_config(arch)
        got = count_params(lm.param_spec(cfg))
        assert 0.75 * n < got < 1.3 * n, (arch, got, n)
