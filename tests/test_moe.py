"""MoE router/dispatch properties and block behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep; collection must not hard-fail
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.nn.moe import dispatch_indices, moe_block, moe_spec
from repro.nn.module import materialize


@given(st.integers(0, 2**31 - 1), st.integers(2, 16), st.integers(1, 8),
       st.integers(4, 64))
@settings(max_examples=40, deadline=None)
def test_dispatch_invariants(seed, n_experts, capacity, A):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, n_experts, A), jnp.int32)
    slot, keep = dispatch_indices(ids, n_experts, capacity)
    slot, keep, ids = np.asarray(slot), np.asarray(keep), np.asarray(ids)

    # kept slots are unique and land in the owning expert's range
    ks = slot[keep]
    assert len(set(ks.tolist())) == len(ks)
    assert ((ks // capacity) == ids[keep]).all()
    # dropped assignments route to the OOB sentinel (never slot 0)
    assert (slot[~keep] == n_experts * capacity).all()
    # per-expert kept count = min(arrivals, capacity)
    for e in range(n_experts):
        arrived = int((ids == e).sum())
        kept = int(((ids == e) & keep).sum())
        assert kept == min(arrived, capacity)


def test_moe_single_expert_equals_dense():
    """E=1, top-1, ample capacity ⇒ MoE == its own expert FFN exactly."""
    cfg = MoEConfig(n_experts=1, top_k=1, d_ff=32, capacity_factor=1.0,
                    aux_free_bias=False)
    d = 16
    p = materialize(moe_spec(cfg, d), jax.random.PRNGKey(0))
    x = (jax.random.normal(jax.random.PRNGKey(1), (2, 10, d)) * 0.5)
    y = moe_block(p, x, cfg)

    xt = x.reshape(-1, d)
    g = jnp.einsum("td,df->tf", xt, p["w_gate"][0])
    u = jnp.einsum("td,df->tf", xt, p["w_up"][0])
    ref = jnp.einsum("tf,fd->td", jax.nn.silu(g) * u, p["w_down"][0]).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref, np.float32),
                               atol=1e-5)


def test_moe_capacity_drop_zeroes_tokens():
    """With capacity 0-ish, overflow tokens contribute nothing (not garbage)."""
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff=8, capacity_factor=0.26,
                    aux_free_bias=False)
    d = 4
    p = materialize(moe_spec(cfg, d), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, d))
    y = moe_block(p, x, cfg)                      # capacity = 1 per expert
    assert bool(jnp.isfinite(y).all())
    # at most 2 tokens (1/expert) can be nonzero
    nonzero = int((jnp.abs(y[0]).sum(-1) > 1e-7).sum())
    assert nonzero <= 2


def test_shared_expert_always_on():
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff=8, n_shared=1, capacity_factor=0.01)
    d = 4
    p = materialize(moe_spec(cfg, d), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 6, d))
    y = moe_block(p, x, cfg)                      # capacity≈0: routed path ~dead
    assert float(jnp.abs(y).sum()) > 0            # shared expert still fires


def test_route_bias_changes_selection_not_gate():
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff=8, aux_free_bias=True,
                    capacity_factor=2.0)
    d = 8
    p = materialize(moe_spec(cfg, d), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, d))
    y0 = moe_block(p, x, cfg)
    # huge bias toward expert 3 → selection flips, output changes
    p2 = dict(p)
    p2["route_bias"] = jnp.array([-10.0, -10.0, -10.0, 10.0], jnp.float32)
    y1 = moe_block(p2, x, cfg)
    assert float(jnp.abs(y1 - y0).max()) > 1e-6


def test_grad_flows_through_dispatch():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16, capacity_factor=2.0)
    d = 8
    p = materialize(moe_spec(cfg, d), jax.random.PRNGKey(0))

    def loss(p, x):
        return (moe_block(p, x, cfg) ** 2).sum()

    x = jax.random.normal(jax.random.PRNGKey(5), (2, 6, d))
    g = jax.grad(loss)(p, x)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
