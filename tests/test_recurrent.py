"""SSM / xLSTM correctness: prefill-vs-decode parity, chunked_scan identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep; collection must not hard-fail
from hypothesis import given, settings, strategies as st

from repro.configs.base import SSMConfig
from repro.nn.module import materialize
from repro.nn.scan_utils import chunked_scan
from repro.nn.ssm import init_ssm_state, ssm_block, ssm_spec
from repro.nn.xlstm import (
    init_mlstm_state, init_slstm_state, mlstm_block, mlstm_spec,
    slstm_block, slstm_spec,
)


# ---------------------------------------------------------------------------
# chunked_scan == lax.scan
# ---------------------------------------------------------------------------

@given(st.integers(1, 40), st.integers(1, 16), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_chunked_scan_matches_lax_scan(S, chunk, seed):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(S, 3)), jnp.float32)

    def step(c, x):
        c = 0.9 * c + x
        return c, c * 2.0

    c_ref, ys_ref = jax.lax.scan(step, jnp.zeros(3), xs)
    c_chk, ys_chk = chunked_scan(step, jnp.zeros(3), xs, chunk=chunk)
    np.testing.assert_allclose(np.asarray(c_ref), np.asarray(c_chk), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ys_ref), np.asarray(ys_chk), rtol=1e-6)


def test_chunked_scan_grad_matches():
    xs = jax.random.normal(jax.random.PRNGKey(0), (37, 4))

    def step(c, x):
        c = jnp.tanh(0.8 * c + x)
        return c, c.sum()

    def loss_ref(xs):
        _, ys = jax.lax.scan(step, jnp.zeros(4), xs)
        return ys.sum()

    def loss_chk(xs):
        _, ys = chunked_scan(step, jnp.zeros(4), xs, chunk=8)
        return ys.sum()

    g_ref = jax.grad(loss_ref)(xs)
    g_chk = jax.grad(loss_chk)(xs)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_chk), rtol=1e-5)


# ---------------------------------------------------------------------------
# prefill vs decode parity
# ---------------------------------------------------------------------------

def test_ssm_decode_parity():
    cfg = SSMConfig(state_dim=4, expand=2, conv_dim=4)
    d = 16
    p = materialize(ssm_spec(cfg, d), jax.random.PRNGKey(0))
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32) * 0.5

    full, _ = ssm_block(p, x, cfg)

    st_ = init_ssm_state(cfg, d, B)
    outs = []
    for t in range(S):
        o, st_ = ssm_block(p, x[:, t:t + 1], cfg, state=st_)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc), atol=2e-4)


def test_mlstm_decode_parity():
    d, H = 32, 4
    p = materialize(mlstm_spec(H, d), jax.random.PRNGKey(0))
    B, S = 2, 12
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.5).astype(jnp.bfloat16)

    full, _ = mlstm_block(p, x, H)

    st_ = init_mlstm_state(H, d, B)
    outs = []
    for t in range(S):
        o, st_ = mlstm_block(p, x[:, t:t + 1], H, state=st_)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32), np.asarray(inc, np.float32),
                               atol=3e-2)


def test_slstm_decode_parity():
    d = 24
    p = materialize(slstm_spec(2, d), jax.random.PRNGKey(0))
    B, S = 2, 9
    x = (jax.random.normal(jax.random.PRNGKey(2), (B, S, d)) * 0.5).astype(jnp.bfloat16)

    full, _ = slstm_block(p, x)

    st_ = init_slstm_state(d, B)
    outs = []
    for t in range(S):
        o, st_ = slstm_block(p, x[:, t:t + 1], state=st_)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32), np.asarray(inc, np.float32),
                               atol=3e-2)


def test_recurrent_state_is_constant_size():
    """O(1) state — the property that qualifies these archs for long_500k."""
    st1 = init_mlstm_state(4, 64, batch=2)
    st2 = init_slstm_state(64, batch=2)
    st3 = init_ssm_state(SSMConfig(state_dim=16), 64, batch=2)
    for s in (st1, st2, st3):
        for leaf in jax.tree.leaves(s):
            assert "524288" not in str(leaf.shape)   # no per-position state


def test_mlstm_stability_long_sequence():
    """Exponential gating with the max-stabilizer must not overflow."""
    d, H = 16, 2
    p = materialize(mlstm_spec(H, d), jax.random.PRNGKey(0))
    x = (jax.random.normal(jax.random.PRNGKey(3), (1, 512, d)) * 3.0).astype(jnp.bfloat16)
    y, _ = mlstm_block(p, x, H)
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())


@pytest.mark.parametrize("chunk", [16, 33, 512])
def test_mlstm_chunkwise_equals_scan(chunk):
    """The chunkwise-parallel mLSTM (§Perf-1, 393× memory-term win) is an
    exact telescoping of the token recurrence — identical outputs & state."""
    d, H = 32, 4
    p = materialize(mlstm_spec(H, d), jax.random.PRNGKey(0))
    B, S = 2, 100
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.7).astype(jnp.bfloat16)
    y_seq, _ = mlstm_block(p, x, H, impl="scan")
    y_chk, _ = mlstm_block(p, x, H, impl="chunkwise", chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_seq, np.float32),
                               np.asarray(y_chk, np.float32), atol=1e-4)
    st = init_mlstm_state(H, d, B)
    _, s1 = mlstm_block(p, x, H, state=st, impl="scan")
    _, s2 = mlstm_block(p, x, H, state=st, impl="chunkwise", chunk=chunk)
    for k_ in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(s1[k_]), np.asarray(s2[k_]), atol=1e-4)
