"""Tests for the fused mixed prefill+decode dispatch (token-budget packing).

Three layers:

1. ``Scheduler.pack_mixed`` properties — the packer never exceeds the row
   budget, always reserves prefill progress, and bounds decode starvation
   under pathological scarcity via its round-robin cursor.
2. End-to-end parity — mixed-on greedy token streams are bit-identical to
   mixed-off (separate prefill/decode launches) across the paged cache
   families, including under recompute preemption; non-fully-paged families
   auto-disable and forcing them raises.
3. The ride-along bugfixes — all dispatch walls live in one injectable
   clock domain (metrics ≡ stats ≡ trace under a deterministic clock), and
   the extras/chunk guard is one shared bound on both the submit and
   prefill paths.
"""
import numpy as np
import pytest

from serving_harness import materialize, mixed_spec, run_workload

from repro.serving import Request, ServingEngine, Tracer, make_requests
from repro.serving.blocks import BlockPool
from repro.serving.scheduler import Scheduler

# the fully paged families: single-codebook GQA, MoE, multi-codebook [K, S]
MIXED_ARCHS = ["phi4-mini-3.8b", "qwen3-moe-235b-a22b", "musicgen-medium"]


# ---------------------------------------------------------------------------
# packer properties (pure scheduler, no engine)
# ---------------------------------------------------------------------------

def _sched_with(n_decoding, prefill_remaining):
    """A scheduler whose running map holds ``n_decoding`` decode-phase slots
    plus one mid-prefill slot per entry of ``prefill_remaining`` (each entry
    is the replay rows that slot still has to stage)."""
    n = n_decoding + len(prefill_remaining)
    sched = Scheduler(n, BlockPool(256, 8), max_len=512)
    slot = 0
    for _ in range(n_decoding):
        r = Request(rid=slot, prompt=np.arange(8, dtype=np.int32),
                    max_new=64, arrival=float(slot))
        r.slot = slot
        r.generated = [np.int32(1)]          # pending token → decode phase
        sched.running[slot] = r
        slot += 1
    for rem in prefill_remaining:
        r = Request(rid=slot, prompt=np.arange(rem + 4, dtype=np.int32),
                    max_new=64, arrival=float(slot))
        r.slot = slot
        r.prefilling = True
        r.prefill_pos = 4                    # rem replay rows left to stage
        sched.running[slot] = r
        slot += 1
    return sched


def test_pack_mixed_never_exceeds_budget():
    """Property: over randomized populations/budgets/chunks, one dispatch
    never packs more than ``budget`` query rows, per-slot prefill parts stay
    within ``chunk``, and assignments stay within each request's replay."""
    rng = np.random.default_rng(0)
    for _ in range(300):
        nd = int(rng.integers(0, 7))
        rems = [int(rng.integers(1, 40)) for _ in range(rng.integers(0, 4))]
        sched = _sched_with(nd, rems)
        budget = int(rng.integers(1, 24))
        chunk = int(rng.integers(1, 16))
        decode, parts = sched.pack_mixed(budget, chunk)
        rows = len(decode) + sum(c for _, _, c in parts)
        assert rows <= budget
        assert len({r.slot for r in decode}) == len(decode)
        for r, start, c in parts:
            assert 1 <= c <= chunk
            assert start == r.prefill_pos
            assert start + c <= r.cached_len


def test_pack_mixed_reserves_prefill_progress():
    """Decode rows pack first, but one row is always reserved for the oldest
    prefilling slot — TTFT can't starve behind a saturated decode population."""
    sched = _sched_with(6, [20])
    decode, parts = sched.pack_mixed(4, 8)
    assert len(decode) == 3                  # budget - reserved prefill row
    assert parts and parts[0][2] == 1        # the reserved row progresses
    # with headroom every decode slot rides and prefill takes the rest
    sched = _sched_with(3, [20])
    decode, parts = sched.pack_mixed(12, 8)
    assert len(decode) == 3
    assert sum(c for _, _, c in parts) == 8  # capped at chunk, not budget


def test_pack_mixed_decode_starvation_bounded():
    """Under pathological scarcity (budget < decode population + 1) the
    round-robin cursor bounds any slot's wait to one rotation:
    ceil(n_decoding / (budget - 1)) consecutive dispatches."""
    budget, n_dec = 4, 7
    sched = _sched_with(n_dec, [64])
    pre = sched.running[n_dec]
    cap = budget - 1                         # one row reserved for prefill
    bound = -(-n_dec // cap)                 # dispatches per full rotation
    last_ride = {s: 0 for s in range(n_dec)}
    for t in range(1, 4 * bound * n_dec):
        decode, parts = sched.pack_mixed(budget, 8)
        assert parts                         # prefill still progresses
        pre.prefill_pos = 4                  # hold it mid-prefill forever
        assert len(decode) == cap
        for r in decode:
            last_ride[r.slot] = t
        for s, last in last_ride.items():
            assert t - last < bound, f"slot {s} starved {t - last} dispatches"


# ---------------------------------------------------------------------------
# end-to-end parity (jax)
# ---------------------------------------------------------------------------

def _staggered(**kw):
    # staggered arrivals so admitted prefills overlap in-flight decodes:
    # mixed tiles must carry both populations, not just chunked prefill
    return mixed_spec(n_requests=6, rate=40.0, gen_buckets=(6, 20), **kw)


@pytest.mark.parametrize("arch", MIXED_ARCHS)
def test_engine_mixed_token_parity(arch):
    """Mixed-on greedy streams are token-for-token equal to mixed-off while
    fused tiles actually carry both decode and prefill rows."""
    cfg, params = materialize(arch)
    base, sb = run_workload(cfg, params, max_len=64, spec=_staggered(),
                            mixed=False)
    fused, sf = run_workload(cfg, params, max_len=64, spec=_staggered(),
                             mixed=True)
    assert base == fused
    assert sb["mixed"]["dispatches"] == 0
    assert sf["mixed"]["dispatches"] > 0
    assert sf["mixed"]["prefill_rows"] > 0
    assert sf["mixed"]["decode_rows"] > 0    # decode rode along, not solo
    assert sf["prefill_tokens"] == sb["prefill_tokens"]


def test_engine_mixed_preemption_parity():
    """Recompute preemption mid-run composes with mixed dispatch: victims
    replay through fused tiles and streams still match the unconstrained
    separate-path run."""
    cfg, params = materialize("phi4-mini-3.8b")
    base, _ = run_workload(cfg, params, max_len=64, spec=_staggered(),
                           mixed=False)
    tight, st = run_workload(cfg, params, max_len=64, spec=_staggered(),
                             mixed=True, n_blocks=9)
    assert st["preemptions"]["recompute"] > 0
    assert st["mixed"]["dispatches"] > 0
    assert base == tight


def test_engine_mixed_budget_throttles_rows():
    """A tiny row budget still converges to identical streams — it just
    takes more, smaller dispatches (the budget is a shape knob, never a
    correctness knob)."""
    cfg, params = materialize("phi4-mini-3.8b")
    base, sb = run_workload(cfg, params, max_len=64, spec=_staggered(),
                            mixed=True)
    small, ss = run_workload(cfg, params, max_len=64, spec=_staggered(),
                             mixed=True, mixed_budget=4)
    assert base == small
    assert ss["mixed"]["dispatches"] > sb["mixed"]["dispatches"]
    assert ss["mixed"]["prefill_rows"] == sb["mixed"]["prefill_rows"]


def test_engine_mixed_eligibility():
    """Non-fully-paged families (hymba ring+SSM state) auto-disable mixed
    dispatch; forcing it raises instead of silently corrupting."""
    cfg, params = materialize("hymba-1.5b")
    eng = ServingEngine(cfg, slots=2, max_len=32, block_size=8, params=params)
    assert eng.mixed is False                # auto-off: not fully paged
    with pytest.raises(ValueError, match="fully paged"):
        ServingEngine(cfg, slots=2, max_len=32, block_size=8, params=params,
                      mixed=True)
    with pytest.raises(ValueError, match="mixed_budget"):
        cfg2, params2 = materialize("phi4-mini-3.8b")
        ServingEngine(cfg2, slots=2, max_len=32, block_size=8, params=params2,
                      mixed=True, mixed_budget=1)


# ---------------------------------------------------------------------------
# satellite bugfixes: clock domain + extras guard
# ---------------------------------------------------------------------------

class _Clock:
    """Deterministic strictly-increasing engine clock."""

    def __init__(self, dt=1e-3):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


def test_engine_dispatch_walls_single_clock_domain():
    """All dispatch walls come from the injectable engine clock: under a
    deterministic clock the metrics histograms, the stats time ledgers and
    the trace span durations agree exactly (regression: perf_counter-based
    walls drifted arbitrarily far from the engine-clock ledgers whenever a
    test or fault plan injected a clock)."""
    cfg, params = materialize("phi4-mini-3.8b")
    tracer = Tracer()
    eng = ServingEngine(cfg, slots=3, max_len=48, block_size=8, params=params,
                        tracer=tracer, clock=_Clock())
    eng.run(make_requests(cfg, _staggered(), seed=9))
    st = eng.stats
    assert st.mixed_dispatches > 0
    ledger = st.prefill_time + st.decode_time
    assert ledger > 0
    hist = sum(h.sum for name, h in eng.metrics.hists.items()
               if name.startswith("dispatch_"))
    assert hist == pytest.approx(ledger, rel=1e-9)
    spans = sum(ev.dur for ev in tracer.events() if ev.ph == "X" and ev.name
                in ("prefill-chunk", "decode", "horizon", "spec-horizon",
                    "mixed"))
    assert spans == pytest.approx(ledger, rel=1e-9)
    # a perf_counter wall under a fake 1 ms/tick clock would be real seconds
    # of jit+compute per dispatch — orders of magnitude off the tick budget
    n_dispatch = st.dispatches
    assert hist < 1.0 * n_dispatch           # every wall is a few fake ticks


def test_extras_chunk_guard_shared_by_submit_and_prefill():
    """One worst-case-replay bound (prompt + max_new - 1 ≤ chunk) guards the
    extras overlay on BOTH paths: submit() rejects up front, and the prefill
    path re-checks the same bound so a request that bypassed submit can
    never be half-served (regression: the paths used different lengths, so
    a request could pass admission then fail at recompute readmission)."""
    cfg, params = materialize("phi4-mini-3.8b")
    eng = ServingEngine(cfg, slots=2, max_len=64, block_size=8, params=params,
                        prefill_chunk=16)
    extras = {"patch_embeds": np.zeros((4, cfg.d_model), np.float32)}
    bad = Request(rid=0, prompt=np.arange(10, dtype=np.int32), max_new=8,
                  extras=extras)              # 10 + 8 - 1 = 17 > 16
    with pytest.raises(ValueError, match="prefill chunk"):
        eng.submit(bad)
    with pytest.raises(ValueError, match="prefill chunk"):
        eng._prefill_request(bad, 0.0, None)  # same bound, same rejection
    ok = Request(rid=1, prompt=np.arange(9, dtype=np.int32), max_new=8,
                 extras=extras)               # 9 + 8 - 1 = 16: boundary fits
    eng.submit(ok)
