"""Tests for repro.serving.trace: the ring-buffered tracer, Chrome-trace
export/validation, the windowed metrics registry — and the engine
integration that threads them through the serving stack.

The engine tests pin the observability contract end to end: every compiled-
step launch emits a dispatch span whose ODIN energy bill sums (with prefill
chunks and spec overhead) exactly to the run's ``odin_total``; request
lifecycle events stay ordered and flow-linked across swap preemption; and
the trace-off path calls zero recorder methods (the <2%-overhead guarantee
is structural, not statistical).
"""
import dataclasses
import json

import numpy as np
import pytest

from serving_harness import materialize, mixed_spec, run_workload

from repro.serving import (NULL_TRACER, EngineStats, LogHistogram,
                           MetricsRegistry, NullTracer, ReliabilityConfig,
                           Request, ServingEngine, Tracer, chrome_trace,
                           make_requests, summarize, validate_chrome_trace)


# ---------------------------------------------------------------------------
# ring buffer
# ---------------------------------------------------------------------------

def test_tracer_ring_drops_oldest_and_counts():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}", "test", "scheduler", ts=float(i))
    assert len(tr) == 4
    assert tr.dropped_events == 6
    assert [ev.name for ev in tr.events()] == ["e6", "e7", "e8", "e9"]
    # drops are recorded in the export so a truncated trace is detectable
    assert tr.to_chrome()["otherData"]["dropped_events"] == 6


def test_tracer_capacity_validation():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_tracer_clock_default_timestamps():
    t = [0.0]
    tr = Tracer()
    tr.set_clock(lambda: t[0])
    t[0] = 2.5
    tr.instant("a", "test", "scheduler")
    assert tr.events()[0].ts == 2.5
    tr.instant("b", "test", "scheduler", ts=1.0)      # explicit ts wins
    assert tr.events()[1].ts == 1.0


# ---------------------------------------------------------------------------
# chrome export + schema validation
# ---------------------------------------------------------------------------

def _sample_tracer():
    tr = Tracer()
    tr.flow_event("s", "request", "scheduler", 7, ts=0.0)
    tr.instant("queued", "lifecycle", "scheduler", ts=0.0,
               args={"rid": 7}, flow=7)
    tr.span("prefill-chunk", "dispatch", "slot 1", 0.1, 0.05,
            args={"rows": 16, "odin_energy_mj": 1.5}, flow=7)
    tr.flow_event("t", "request", "slot 1", 7, ts=0.1)
    tr.counter("kv blocks", "pool", {"used": 3, "free": 5}, ts=0.2)
    tr.span("decode", "dispatch", "dispatch", 0.2, 0.01,
            args={"kind": "decode"})
    tr.flow_event("f", "request", "slot 1", 7, ts=0.3)
    return tr


def test_chrome_trace_schema_valid_and_strict_json(tmp_path):
    tr = _sample_tracer()
    obj = tr.export(str(tmp_path / "t.json"))
    assert validate_chrome_trace(obj) == []
    # the file on disk round-trips strict JSON and matches the object
    loaded = json.loads((tmp_path / "t.json").read_text())
    assert loaded == json.loads(json.dumps(obj, allow_nan=False))
    evs = obj["traceEvents"]
    # metadata names every track; slot lanes sort before scheduler/pool
    names = [e["args"]["name"] for e in evs if e["name"] == "thread_name"]
    assert names[0] == "slot 1"
    assert set(names) == {"slot 1", "scheduler", "pool", "dispatch"}
    # seconds → microseconds
    span = next(e for e in evs if e["ph"] == "X" and e["name"] == "decode")
    assert span["ts"] == pytest.approx(0.2e6) and span["dur"] == pytest.approx(0.01e6)
    # flow anchors carry the id; the finish binds to the enclosing slice
    fin = next(e for e in evs if e["ph"] == "f")
    assert fin["id"] == 7 and fin["bp"] == "e"
    # non-flow events with a flow expose it as args.flow_id
    pre = next(e for e in evs if e["name"] == "prefill-chunk")
    assert pre["args"]["flow_id"] == 7


def test_validate_chrome_trace_rejects_corruption():
    obj = _sample_tracer().to_chrome()
    assert validate_chrome_trace(obj) == []

    bad = json.loads(json.dumps(obj))
    next(e for e in bad["traceEvents"] if e["ph"] == "i")["ts"] = float("nan")
    assert any("bad ts" in e for e in validate_chrome_trace(bad))

    bad = json.loads(json.dumps(obj))
    del next(e for e in bad["traceEvents"] if e["ph"] == "X")["dur"]
    assert any("bad dur" in e for e in validate_chrome_trace(bad))

    bad = json.loads(json.dumps(obj))
    next(e for e in bad["traceEvents"] if e["ph"] == "C")["ph"] = "Z"
    assert any("unknown phase" in e for e in validate_chrome_trace(bad))

    bad = json.loads(json.dumps(obj))
    del next(e for e in bad["traceEvents"] if e["ph"] == "s")["id"]
    assert any("missing id" in e for e in validate_chrome_trace(bad))

    assert validate_chrome_trace([1, 2]) != []
    assert validate_chrome_trace({"traceEvents": "nope"}) != []


def test_validate_flow_ordering_relaxed_under_drops():
    """An orphan flow step is an error in a complete trace but expected when
    the ring dropped its 's' anchor."""
    tr = Tracer()
    tr.flow_event("t", "request", "slot 0", 3, ts=0.0)   # no "s" recorded
    obj = tr.to_chrome()
    assert any("before its 's'" in e for e in validate_chrome_trace(obj))
    obj["otherData"]["dropped_events"] = 5
    assert validate_chrome_trace(obj) == []


def test_flow_phase_validation():
    with pytest.raises(ValueError):
        Tracer().flow_event("x", "request", "slot 0", 1)


# ---------------------------------------------------------------------------
# satellite 1: empty-run summaries are strict JSON
# ---------------------------------------------------------------------------

def test_zero_request_summary_round_trips_strict_json():
    """percentiles([]) must yield None (JSON null), never float('nan') —
    a bare NaN token makes the summary unparseable by any strict reader."""
    summary = summarize([], EngineStats())
    text = json.dumps(summary, allow_nan=False)       # would raise on NaN
    back = json.loads(text)
    assert back["ttft_s"] == {"p50": None, "p90": None, "p99": None}
    assert back["tpot_s"]["p99"] is None
    assert back["generated_tokens"] == 0


# ---------------------------------------------------------------------------
# log histogram + metrics registry
# ---------------------------------------------------------------------------

def test_log_histogram_percentiles_within_bucket_ratio():
    h = LogHistogram(lo=1e-6, hi=1e4, bins_per_decade=6)
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-3.0, sigma=1.0, size=2000)
    for x in xs:
        h.observe(float(x))
    ratio = 10 ** (1 / 6)                             # one bucket's width
    for q in (50, 90, 99):
        exact = float(np.percentile(xs, q))
        est = h.percentile(q)
        assert exact / ratio <= est <= exact * ratio
    s = h.summary()
    assert s["count"] == 2000
    assert s["mean"] == pytest.approx(float(np.mean(xs)))


def test_log_histogram_empty_and_out_of_range():
    h = LogHistogram(lo=1e-3, hi=1e3, bins_per_decade=3)
    assert h.percentile(50) is None
    assert h.summary()["mean"] is None
    h.observe(1e-9)                                   # underflow bucket
    h.observe(1e9)                                    # overflow bucket
    assert h.total == 2
    assert h.percentile(25) == 0.0                    # underflow midpoint
    assert h.percentile(99) == 1e3                    # clamped at hi


def test_log_histogram_delta_summary_windows():
    h = LogHistogram()
    h.observe(0.1)
    marks = h.marks()
    h.observe(0.2)
    h.observe(0.4)
    d = h.delta_summary(marks)
    assert d["count"] == 2
    assert d["mean"] == pytest.approx(0.3)
    assert h.summary()["count"] == 3                  # cumulative unchanged


def test_metrics_registry_rolls_aligned_windows():
    reg = MetricsRegistry(window_s=1.0)
    reg.maybe_roll(0.2, {"tok": 0})                   # opens; boundary at 1.0
    reg.observe("lat_s", 0.01)
    reg.maybe_roll(0.9, {"tok": 3})                   # boundary not reached
    assert reg.windows == []
    reg.observe("lat_s", 0.02)
    reg.maybe_roll(1.1, {"tok": 5})                   # closes [0, 1)
    assert len(reg.windows) == 1
    w = reg.windows[0]
    assert (w["t0"], w["t1"]) == (0.0, 1.0)
    assert w["counters"] == {"tok": 5}
    assert w["histograms"]["lat_s"]["count"] == 2
    # idle gap: boundaries pass with no movement → windows elided
    reg.maybe_roll(4.2, {"tok": 5})
    assert len(reg.windows) == 1
    reg.observe("lat_s", 0.03)
    reg.flush(4.6, {"tok": 9})                        # partial window close
    assert len(reg.windows) == 2
    w = reg.windows[1]
    assert w["t0"] == 4.0 and w["t1"] == pytest.approx(4.6)
    assert w["counters"] == {"tok": 4}
    summary = reg.summary()
    assert summary["histograms"]["lat_s"]["count"] == 3
    json.dumps(summary, allow_nan=False)


def test_metrics_registry_gauges_and_validation():
    with pytest.raises(ValueError):
        MetricsRegistry(window_s=0)
    reg = MetricsRegistry()
    reg.set_gauge("free_blocks", 7)
    assert reg.summary()["gauges"] == {"free_blocks": 7.0}


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

class _Clock:
    """Deterministic strictly-increasing engine clock."""

    def __init__(self, dt=1e-3):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


def _traced_run(**kw):
    cfg, params = materialize("phi4-mini-3.8b")
    tracer = Tracer()
    eng = ServingEngine(cfg, slots=3, max_len=48, block_size=8, params=params,
                        clock=_Clock(), tracer=tracer, **kw)
    reqs = make_requests(cfg, mixed_spec(), seed=9)
    summary = eng.run(reqs)
    return tracer, summary, eng


def test_engine_trace_spans_and_energy_attribution():
    """Every dispatch span carries its ODIN bill; the bills sum to the run's
    odin_total (1%-gate satisfied by construction), and the trace validates."""
    tracer, summary, _ = _traced_run(horizon=4)
    obj = tracer.to_chrome()
    assert validate_chrome_trace(obj) == []
    kinds = {ev.name for ev in tracer.events() if ev.ph == "X"}
    # phi4 is fully paged, so mixed dispatch is auto-on: prefill rides in
    # "mixed" tiles; pure-decode ticks still use decode/horizon dispatches
    assert {"mixed", "horizon"} <= kinds
    span_energy = sum((ev.args or {}).get("odin_energy_mj", 0.0)
                     for ev in tracer.events() if ev.ph == "X")
    assert span_energy == pytest.approx(summary["odin_total"]["energy_mj"],
                                        rel=1e-9)
    # dispatch spans carry the contract args
    for ev in tracer.events():
        if ev.ph == "X" and ev.name in ("decode", "horizon", "spec-horizon"):
            assert {"kind", "h", "spec_k", "slots_active", "tokens", "rows",
                    "host_syncs", "odin_energy_mj"} <= set(ev.args)
        if ev.ph == "X" and ev.name == "mixed":
            assert {"kind", "q_tile", "slots_active", "tokens", "rows",
                    "decode_rows", "prefill_rows", "host_syncs",
                    "odin_energy_mj"} <= set(ev.args)

    # the legacy separate-launch taxonomy survives under --no-mixed, with
    # the same exact span-energy attribution
    tracer, summary, _ = _traced_run(horizon=4, mixed=False)
    kinds = {ev.name for ev in tracer.events() if ev.ph == "X"}
    assert {"prefill-chunk", "horizon"} <= kinds and "mixed" not in kinds
    span_energy = sum((ev.args or {}).get("odin_energy_mj", 0.0)
                     for ev in tracer.events() if ev.ph == "X")
    assert span_energy == pytest.approx(summary["odin_total"]["energy_mj"],
                                        rel=1e-9)


def test_engine_trace_lifecycle_ordering_and_flow_survives_preemption():
    """queued → admit → … → complete stays clock-ordered per request, and the
    flow chain (s at queued, t at admit/swap/resume, f at complete) follows
    the request across a swap preemption."""
    tracer, summary, _ = _traced_run(n_blocks=8, swap_blocks=32)
    assert summary["preemptions"]["swap"] > 0
    by_rid = {}
    for ev in tracer.events():
        if ev.flow is not None:
            by_rid.setdefault(ev.flow, []).append(ev)
    assert by_rid
    preempted = {ev.flow for ev in tracer.events()
                 if ev.name in ("preempt-swap", "swap-copy")}
    assert preempted
    for rid, evs in by_rid.items():
        names = [ev.name for ev in evs]
        assert names[0] == "request" and evs[0].ph == "s"   # flow start
        assert "queued" in names and "admit" in names and "complete" in names
        assert names.index("queued") < names.index("admit") < names.index("complete")
        assert [ev.ph for ev in evs].count("s") == 1
        assert evs[-1].ph == "f"                            # flow finish last
        ts = [ev.ts for ev in evs]
        assert ts == sorted(ts)                             # clock-ordered
    for rid in preempted:
        names = [ev.name for ev in by_rid[rid]]
        if "swap-downgrade" in names:                       # swap tier full —
            continue                                        # requeued instead
        assert "resume" in names                            # swapped back in
        assert names.index("preempt-swap") < names.index("resume")
        assert names.index("resume") < names.index("complete")


def test_engine_trace_scheduler_and_pool_decisions():
    tracer, summary, _ = _traced_run(horizon=4, n_blocks=8, swap_blocks=32)
    names = {ev.name for ev in tracer.events()}
    assert {"admit", "grant_horizon", "alloc", "release"} <= names
    grants = [ev for ev in tracer.events() if ev.name == "grant_horizon"]
    assert all({"max_h", "granted", "available_blocks"} <= set(g.args)
               for g in grants)
    admits = [ev for ev in tracer.events() if ev.name == "admit"]
    assert all({"rid", "slot", "marginal_blocks"} <= set(a.args)
               for a in admits)
    counters = [ev for ev in tracer.events() if ev.ph == "C"]
    assert counters and all("free" in ev.args for ev in counters)


class _SpyTracer(NullTracer):
    """enabled=False recorder that counts any emit that still happens."""

    def __init__(self):
        self.calls = 0

    def span(self, *a, **kw):
        self.calls += 1

    def instant(self, *a, **kw):
        self.calls += 1

    def counter(self, *a, **kw):
        self.calls += 1

    def flow_event(self, *a, **kw):
        self.calls += 1


def test_engine_trace_off_emits_nothing():
    """The no-op path must not merely record nothing — it must never be
    called: every emit site guards on tracer.enabled, so trace-off skips
    even the argument-dict construction."""
    cfg, params = materialize("phi4-mini-3.8b")
    eng = ServingEngine(cfg, slots=3, max_len=48, block_size=8, params=params)
    assert eng.tracer is NULL_TRACER                  # off by default
    spy = _SpyTracer()
    eng = ServingEngine(cfg, slots=3, max_len=48, block_size=8, params=params,
                        n_blocks=8, swap_blocks=32, horizon=4, tracer=spy)
    eng.run(make_requests(cfg, mixed_spec(), seed=9))
    assert spy.calls == 0


def test_engine_stats_fields_all_reported_in_summary():
    """CI consistency check: every EngineStats counter must appear in
    summarize()'s engine_stats mirror — a new dataclass field can never
    silently go unreported."""
    _, summary, _ = _traced_run()
    fields = {f.name for f in dataclasses.fields(EngineStats)}
    assert set(summary["engine_stats"]) == fields
    # the PCRAM reliability counters ride in EngineStats and must therefore
    # be in the mirror too — plus their curated summary section
    assert {"pool_writes", "retired_blocks", "scrub_copies", "scrub_rows",
            "wear_p99", "wear_max"} <= fields
    assert set(summary["reliability"]) == {
        "pool_writes", "retired_blocks", "scrub_copies", "scrub_rows",
        "wear_p99", "wear_max"}
    json.dumps(summary, allow_nan=False)


def test_reliability_scrub_phase_energy_attribution_exact():
    """With the drift scrubber on, scrub rows join ``odin_phases`` as their
    own phase, phase rows/energy still sum exactly to ``odin_total``, and
    every scrub span carries its own ODIN bill so trace-span energies stay
    an exact partition of the run's total."""
    tracer, summary, _ = _traced_run(
        horizon=4,
        reliability=ReliabilityConfig(scrub_rate=2, drift_deadline_s=0.02))
    rel = summary["reliability"]
    assert rel["pool_writes"] > 0 and rel["scrub_rows"] > 0
    phases = summary["odin_phases"]
    assert phases["scrub"]["rows"] == rel["scrub_rows"]
    assert sum(p["rows"] for p in phases.values()) == summary["odin_total"]["tokens"]
    assert sum(p["energy_mj"] for p in phases.values()) == pytest.approx(
        summary["odin_total"]["energy_mj"])
    span_energy = sum((ev.args or {}).get("odin_energy_mj", 0.0)
                      for ev in tracer.events() if ev.ph == "X")
    assert span_energy == pytest.approx(summary["odin_total"]["energy_mj"],
                                        rel=1e-9)
    scrubs = [ev for ev in tracer.events()
              if ev.ph == "X" and ev.name == "scrub"]
    assert scrubs
    assert all({"kind", "blocks", "rows", "odin_energy_mj"} <= set(ev.args)
               for ev in scrubs)
    assert {ev.args["kind"] for ev in scrubs} <= {"drift-refresh",
                                                  "retire-drain"}


def test_engine_metrics_windows_and_histograms():
    _, summary, eng = _traced_run(horizon=4)
    m = summary["metrics"]
    assert m["window_s"] == 1.0
    hists = m["histograms"]
    # mixed dispatch is auto-on for phi4: prefill rows ride in mixed tiles
    # (dispatch_mixed_s); pure-decode ticks still observe dispatch_decode_s
    assert {"ttft_s", "dispatch_mixed_s", "dispatch_decode_s"} <= set(hists)
    assert hists["ttft_s"]["count"] == len(summary["requests"])
    total_disp = sum(w["counters"].get("dispatches", 0) for w in m["windows"])
    assert total_disp == summary["dispatches"]
    json.dumps(m, allow_nan=False)


def test_xla_annotations_smoke():
    """xla_annotations=True must run end-to-end (TraceAnnotation wraps every
    dispatch) without changing tokens."""
    cfg, params = materialize("phi4-mini-3.8b")
    base, _ = run_workload(cfg, params, horizon=4)
    notes, _ = run_workload(cfg, params, horizon=4, xla_annotations=True)
    assert base == notes


# ---------------------------------------------------------------------------
# satellite 2: speculative verify-overhead energy billing
# ---------------------------------------------------------------------------

def test_spec_overhead_rows_billed_per_request_and_in_phases():
    """Rejected draft rows are real forward passes: the per-request ODIN bill
    must exceed the naive prefill+emitted count by exactly the request's
    spec_overhead_rows, and the phase breakdown must sum to odin_total."""
    wspec = mixed_spec(pattern_period=8, prompt_buckets=(32,),
                       gen_buckets=(40,), n_requests=4)
    cfg, params = materialize("phi4-mini-3.8b")
    _, summary = run_workload(cfg, params, max_len=80, block_size=8,
                              spec=wspec, horizon=4, spec_ngram=4)
    st = summary["engine_stats"]
    assert st["spec_drafted"] > 0
    assert st["spec_overhead_rows"] > 0               # some drafts rejected
    assert summary["speculation"]["overhead_rows"] == st["spec_overhead_rows"]
    per_req_overhead = 0
    for rec in summary["requests"]:
        naive = rec["prefill_tokens"] + max(0, rec["generated_tokens"] - 1)
        over = rec["odin"]["spec_overhead"]["rows"]
        assert rec["odin"]["tokens"] == naive + over
        assert rec["odin"]["spec_overhead"]["energy_mj"] >= 0
        per_req_overhead += over
    assert per_req_overhead == st["spec_overhead_rows"]
    phases = summary["odin_phases"]
    assert phases["spec_verify_overhead"]["rows"] == st["spec_overhead_rows"]
    assert sum(p["rows"] for p in phases.values()) == summary["odin_total"]["tokens"]
    assert sum(p["energy_mj"] for p in phases.values()) == pytest.approx(
        summary["odin_total"]["energy_mj"])


def test_spec_off_overhead_is_zero():
    cfg, params = materialize("phi4-mini-3.8b")
    _, summary = run_workload(cfg, params, horizon=4)
    assert summary["engine_stats"]["spec_overhead_rows"] == 0
    for rec in summary["requests"]:
        assert rec["odin"]["spec_overhead"] == {"rows": 0, "energy_mj": 0.0}
