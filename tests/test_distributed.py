"""Multi-device (8 forced host CPUs, subprocess) pjit/shard_map tests.

Each test spawns a fresh interpreter with XLA_FLAGS so the main pytest
process keeps its single real device (the assignment's constraint).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str, timeout=560):
    code = "import os\nos.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
    code += "import sys\nsys.path.insert(0, %r)\n" % SRC
    code += textwrap.dedent(body)
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr[-3000:]}"
    return p.stdout


@pytest.mark.slow
def test_pjit_train_step_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import registry, lm
    from repro.nn.module import materialize
    from repro.launch import specs, steps
    from repro.launch.mesh import make_mesh, param_pspecs, sharding_rules
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.configs.base import ShapeConfig
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = registry.get_smoke("phi4-mini-3.8b")
    params = materialize(lm.param_spec(cfg), jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(moment_dtype="float32")
    opt = adamw_init(params, opt_cfg)
    shape = ShapeConfig("t", 32, 8, "train")
    batch = specs.concrete_batch(cfg, shape, 0, 0)
    step = steps.make_train_step(cfg, opt_cfg)

    # single device
    p1, o1, m1 = jax.jit(step)(params, opt, batch)

    # 4x2 mesh with full sharding rules
    mesh = make_mesh((4, 2), ("data", "model"))
    rules = sharding_rules(mesh, "train")
    pps = param_pspecs(lm.param_spec(cfg), rules, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pps,
                       is_leaf=lambda x: isinstance(x, P))
    osh = steps.optimizer_pspecs(pps, opt_cfg)
    osh = jax.tree.map(lambda s: NamedSharding(mesh, s), osh,
                       is_leaf=lambda x: isinstance(x, P))
    bsh = jax.tree.map(lambda _: NamedSharding(mesh, P(("data",))), batch)
    params_s = jax.device_put(params, psh)
    opt_s = jax.device_put(opt, osh)
    batch_s = jax.device_put(batch, bsh)
    from repro.nn.pcontext import logical_sharding
    with mesh, logical_sharding(mesh, rules):
        p2, o2, m2 = jax.jit(step, in_shardings=(psh, osh, bsh),
                             out_shardings=(psh, osh, None))(params_s, opt_s, batch_s)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-3)
    # params identical up to collective reduction order
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=3e-2)
    print("pjit parity OK", float(m1["loss"]), float(m2["loss"]))
    """)


@pytest.mark.slow
def test_dp_compressed_training_converges():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import registry
    from repro.launch.train import train_loop
    from repro.launch.mesh import make_mesh
    from repro.optim.adamw import AdamWConfig
    import tempfile

    cfg = registry.get_smoke("phi4-mini-3.8b")
    mesh = make_mesh((8,), ("data",))
    with tempfile.TemporaryDirectory() as d:
        _, losses = train_loop(cfg, steps=20, batch=8, seq=64, ckpt_dir=d,
                               grad_compress=True, mesh=mesh,
                               opt_cfg=AdamWConfig(moment_dtype="float32"),
                               base_lr=1e-3)
    assert losses[-1] < losses[0], (losses[0], losses[-1])
    print("compressed DP OK", losses[0], "->", losses[-1])
    """)


@pytest.mark.slow
def test_compressed_psum_in_hlo():
    """The int8 payload must actually appear in the compiled collective."""
    _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.optim.compress import compressed_psum
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map

    from repro.launch.steps import shard_map as sm_compat
    mesh = make_mesh((8,), ("data",))
    def f(g, k):
        return compressed_psum(g, ("data",), k)
    sm = sm_compat(f, mesh=mesh, in_specs=(P("data"), P()), out_specs=P("data"))
    g = jnp.zeros((8, 1, 4096), jnp.float32)
    k = jax.random.PRNGKey(0)
    hlo = jax.jit(sm).lower(g, k).compile().as_text()
    assert "all-reduce" in hlo
    assert "s32[" in hlo  # widened int payload visible in the reduction
    print("compressed psum HLO OK")
    """)


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    """Checkpoint saved unsharded restores onto a (2,2,2) pod mesh."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import ckpt
    from repro.launch.mesh import make_mesh

    tree = {"w": jnp.arange(64.0).reshape(8, 8), "step": jnp.int32(5)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, tree)
        tpl = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        sh = {"w": NamedSharding(mesh, P(("pod", "data"), "model")),
              "step": NamedSharding(mesh, P())}
        out, step = ckpt.restore(d, 5, tpl, shardings=sh)
        assert out["w"].sharding.spec == P(("pod", "data"), "model")
        np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(64.0).reshape(8, 8))
    print("elastic restore OK")
    """)


@pytest.mark.slow
def test_dryrun_smoke_cell_on_8_devices():
    """The dry-run machinery itself on a small mesh (fast compile)."""
    _run("""
    import jax
    from repro.launch.mesh import make_mesh
    from repro.launch.dryrun import run_cell
    mesh = make_mesh((2, 2), ("data", "model"))
    rec = run_cell("xlstm-350m", "train_4k", mesh=mesh, smoke=True)
    assert rec["status"] == "OK", rec
    assert rec["cost"]["flops"] > 0
    assert rec["memory"]["total_bytes"] > 0
    print("dryrun smoke OK", rec["roofline"]["bottleneck"])
    """)
