"""Robustness suite: fault injection, terminal lifecycle, degradation.

Three layers, cheapest first:

* pure-python units — FaultPlan determinism/serialization, the armed
  allocation seam, the degradation ladder's hysteresis, drift noise;
* chaos property sweep — the pure-bookkeeping ``PoolInvariantDriver`` from
  test_serving_props, now driven with a seeded chaos stream (cancellations,
  armed alloc failures, swap copy faults) across 25 seeds: every request
  must reach exactly one terminal state and every pool invariant must hold
  through every fault;
* engine end-to-end — seeded ``FaultPlan``s against the real jax engine:
  no injected fault may escape ``step()`` as an exception, terminal states
  are conserved, a NaN-poisoned slot is quarantined while its co-batched
  neighbours stay bit-identical to a fault-free run, and deadline/cancel
  semantics hold across every cache family.

A falsifying engine-chaos plan is dumped to ``tests/.chaos/`` before the
assertion re-raises, so CI can upload it as an artifact for replay.
"""
import collections
import dataclasses
import json
import pathlib

import numpy as np
import pytest

from serving_harness import (HORIZON_ARCHS, materialize, mixed_spec,
                             run_workload, token_streams)
from test_serving_props import PoolInvariantDriver, _scenario_from_rng

from repro.serving import (DEGRADE_LEVELS, FAULT_SITES, SCENARIOS,
                           DegradationController, DegradeConfig,
                           EngineStallError, FaultEvent, FaultPlan, Request,
                           RequestState, ServingEngine, ShuttingDown,
                           make_requests)
from repro.serving.blocks import BlockPool, PagedKVStore

CHAOS_DIR = pathlib.Path(__file__).parent / ".chaos"


# ---------------------------------------------------------------------------
# fault-plan units (no jax)
# ---------------------------------------------------------------------------

def test_fault_plan_generate_deterministic():
    a = FaultPlan.generate(7, n_steps=64, rate=0.3)
    b = FaultPlan.generate(7, n_steps=64, rate=0.3)
    assert a.events == b.events and a.events
    assert all(ev.site in FAULT_SITES for ev in a.events)
    assert FaultPlan.generate(8, n_steps=64, rate=0.3).events != a.events


def test_fault_plan_json_roundtrip():
    plan = FaultPlan.generate(3, n_steps=32, rate=0.4)
    back = FaultPlan.from_json(plan.to_json())
    assert back.events == plan.events and back.seed == plan.seed
    # fired outcomes are run state, not plan identity: not round-tripped
    plan.record(plan.events[0], "armed")
    assert FaultPlan.from_json(plan.to_json()).fired == []
    snap = plan.snapshot()
    assert snap["n_events"] == len(plan.events)
    assert snap["fired"][0]["outcome"] == "armed"


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(site="meteor", step=0)
    with pytest.raises(ValueError):
        FaultEvent(site="alloc", step=-1)
    with pytest.raises(ValueError):
        FaultEvent(site="alloc", step=0, count=0)


def test_engine_stall_error_carries_summary():
    err = EngineStallError("stalled", summary={"steps": 3})
    assert err.summary == {"steps": 3}
    assert isinstance(err, RuntimeError)    # old except-clauses still catch


def test_block_pool_armed_alloc_failure():
    pool = BlockPool(8, 4)
    pool.arm_alloc_failures(2)
    assert pool.alloc(2) is None            # headroom exists, fault fires
    assert pool.alloc(1) is None
    got = pool.alloc(3)                     # disarmed: back to normal
    assert got is not None and len(got) == 3
    assert pool.alloc(0) == []              # empty allocs never consume arms
    pool.arm_alloc_failures(1)
    assert pool.alloc(0) == []
    assert pool.alloc(1) is None


def test_paged_store_armed_swap_failure():
    store = PagedKVStore.__new__(PagedKVStore)   # seam unit: no device state
    store._fail_out = store._fail_in = 0
    store.arm_swap_failures("out", 1)
    store.arm_swap_failures("in", 2)
    assert (store._fail_out, store._fail_in) == (1, 2)
    with pytest.raises(ValueError):
        store.arm_swap_failures("sideways")


# ---------------------------------------------------------------------------
# degradation ladder (no jax)
# ---------------------------------------------------------------------------

def _pressure(ctl, now, n):
    for i in range(n):
        ctl.observe(now + i, pool_frac=0.95, queue_depth=5, churn=0)


def _calm(ctl, now, n):
    for i in range(n):
        ctl.observe(now + i, pool_frac=0.1, queue_depth=0, churn=0)


def test_degrade_ladder_escalates_one_level_per_trigger():
    ctl = DegradationController(DegradeConfig(up_steps=2, down_steps=3))
    assert ctl.name == "normal"
    _pressure(ctl, 0.0, 2)
    assert ctl.level == 1                   # spec off
    assert ctl.spec_k(4) == 0
    _pressure(ctl, 2.0, 2)
    assert ctl.level == 2                   # horizon shrunk
    assert ctl.horizon_cap(16) == ctl.cfg.min_horizon
    _pressure(ctl, 4.0, 2)
    assert ctl.level == 3 and ctl.release_prefix
    _pressure(ctl, 6.0, 2)
    assert ctl.level == 4 and ctl.deny_admission
    assert ctl.name == DEGRADE_LEVELS[4] == "admit_deny"
    _pressure(ctl, 8.0, 10)
    assert ctl.level == 4                   # saturates, never past the top
    assert ctl.transitions == 4


def test_degrade_ladder_restores_under_hysteresis():
    ctl = DegradationController(DegradeConfig(up_steps=1, down_steps=3))
    _pressure(ctl, 0.0, 2)
    assert ctl.level == 2
    _calm(ctl, 2.0, 2)
    assert ctl.level == 2                   # < down_steps calm: held
    ctl.observe(4.0, pool_frac=0.95, queue_depth=5, churn=0)
    assert ctl.level == 3                   # pressure resets the cool streak
    _calm(ctl, 5.0, 3)
    assert ctl.level == 2                   # one level per restore
    _calm(ctl, 8.0, 6)
    assert ctl.level == 0 and ctl.name == "normal"
    assert ctl.transitions == 6             # 3 up + 3 down


def test_degrade_neutral_zone_resets_both_streaks():
    ctl = DegradationController(DegradeConfig(up_steps=2, down_steps=2))
    ctl.observe(0.0, pool_frac=0.95, queue_depth=5, churn=0)
    ctl.observe(1.0, pool_frac=0.7, queue_depth=1, churn=0)   # neither
    ctl.observe(2.0, pool_frac=0.95, queue_depth=5, churn=0)
    assert ctl.level == 0                   # streak broken, no escalation


def test_degrade_accept_rate_and_churn_triggers():
    cfg = DegradeConfig(up_steps=1)
    ctl = DegradationController(cfg)
    # accept-rate collapse only counts as pressure when the pool is loaded
    ctl.observe(0.0, pool_frac=0.3, queue_depth=0, churn=0, accept_rate=0.0)
    assert ctl.level == 0
    ctl.observe(1.0, pool_frac=0.6, queue_depth=0, churn=0, accept_rate=0.0)
    assert ctl.level == 1
    ctl2 = DegradationController(cfg)
    ctl2.observe(0.0, pool_frac=0.3, queue_depth=0, churn=5)
    assert ctl2.level == 1                  # swap churn alone is pressure


def test_degrade_idle_engine_always_restores():
    """Liveness: with admission denied and nothing running, a deep queue
    must still read as calm — the ladder walks back down and re-admits."""
    ctl = DegradationController(DegradeConfig(up_steps=1, down_steps=2))
    for i in range(4):
        ctl.observe(float(i), pool_frac=0.95, queue_depth=9, churn=0, active=2)
    assert ctl.deny_admission
    for i in range(20):
        ctl.observe(4.0 + i, pool_frac=0.0, queue_depth=9, churn=0, active=0)
    assert ctl.level == 0
    # and queue depth alone, while idle, never escalates in the first place
    ctl2 = DegradationController(DegradeConfig(up_steps=1))
    ctl2.observe(0.0, pool_frac=0.0, queue_depth=50, churn=0, active=0)
    assert ctl2.level == 0


def test_degrade_retry_after_scales_with_step_time():
    ctl = DegradationController(DegradeConfig(retry_after_steps=8.0))
    ctl.observe(0.0, pool_frac=0.1, queue_depth=0, churn=0, est_step_time=0.5)
    assert ctl.retry_after(10.0) == pytest.approx(10.0 + 8.0 * 0.5)


# ---------------------------------------------------------------------------
# PCRAM drift-noise analog (cheap jax)
# ---------------------------------------------------------------------------

def test_odin_drift_noise_seeded_and_gated():
    import jax
    from repro.core.odin_linear import OdinConfig, odin_linear
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    w = jax.random.normal(jax.random.PRNGKey(2), (16, 8))
    base = odin_linear(x, w, OdinConfig(mode="int8"))
    drift = odin_linear(x, w, OdinConfig(mode="int8", drift_noise=0.05,
                                         drift_seed=3))
    drift2 = odin_linear(x, w, OdinConfig(mode="int8", drift_noise=0.05,
                                          drift_seed=3))
    assert not np.allclose(base, drift)
    np.testing.assert_array_equal(np.asarray(drift), np.asarray(drift2))
    other = odin_linear(x, w, OdinConfig(mode="int8", drift_noise=0.05,
                                         drift_seed=4))
    assert not np.array_equal(np.asarray(drift), np.asarray(other))
    # drift stays a perturbation, not a rewrite
    assert np.allclose(base, drift, rtol=0.3, atol=1.0)
    # exact mode is the reference numerics: never perturbed
    e0 = odin_linear(x, w, OdinConfig(mode="exact"))
    e1 = odin_linear(x, w, OdinConfig(mode="exact", drift_noise=0.5))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


# ---------------------------------------------------------------------------
# chaos property sweep over the pure bookkeeping driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(25))
def test_chaos_driver_invariants_seeded(seed):
    kw, specs = _scenario_from_rng(np.random.default_rng(seed))
    if not specs:
        pytest.skip("degenerate scenario")
    driver = PoolInvariantDriver(**kw,
                                 chaos_rng=np.random.default_rng(seed + 1000))
    driver.run(specs)       # asserts invariants per step + terminal at drain
    assert all(r.terminal for r in driver.all_reqs)


def test_chaos_sweep_covers_fault_sites():
    """The chaos sweep must actually hit cancellation from multiple states,
    armed allocation failures and swap copy faults, or it proves nothing."""
    hits = collections.Counter()
    for seed in range(25):
        kw, specs = _scenario_from_rng(np.random.default_rng(seed))
        if not specs:
            continue
        d = PoolInvariantDriver(**kw,
                                chaos_rng=np.random.default_rng(seed + 1000))
        d.run(specs)
        hits.update(d.chaos_hits)
    assert hits["cancel_running"] > 0
    assert hits["cancel_queued"] > 0
    assert hits["alloc_armed"] > 0
    assert hits["swap_out_fault"] > 0
    assert hits["swap_in_fault"] > 0
    # PCRAM bad-block arms: stuck-at flags, wear-exhaustion burns, and at
    # least some retirements that had to drain+remap a *live* block
    assert hits["retire_stuck"] > 0
    assert hits["retire_worn"] > 0
    assert hits["retire_remap"] > 0


# ---------------------------------------------------------------------------
# engine end-to-end (jax)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def phi4_setup():
    return materialize("phi4-mini-3.8b")


def _conserved(summary, n_requests):
    term = summary["terminal"]
    assert sum(term.values()) == n_requests, term
    json.dumps(summary, allow_nan=False)    # reportable under strict JSON


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_engine_chaos_contained(seed, phi4_setup):
    """Seeded FaultPlans against the real engine: no injected fault escapes
    step(), every request lands in exactly one terminal state, and the
    summary stays strict-JSON reportable.  A falsifying plan is written to
    tests/.chaos/ for artifact upload before re-raising."""
    cfg, params = phi4_setup
    plan = FaultPlan.generate(seed, n_steps=64, rate=0.3)
    spec = mixed_spec(5, gen_buckets=(8, 24))
    try:
        _, s = run_workload(cfg, params, slots=3, spec=spec, seed=seed,
                            n_blocks=14, swap_blocks=24, fault_plan=plan,
                            degrade=True, nan_guard=True)
        _conserved(s, 5)
        assert s["fault_plan"]["seed"] == seed
    except Exception:
        CHAOS_DIR.mkdir(exist_ok=True)
        out = CHAOS_DIR / f"falsifying_plan_seed{seed}.json"
        out.write_text(plan.to_json())
        raise


def test_engine_nan_quarantine_cobatch_bit_identical(phi4_setup):
    """A poisoned slot fails alone: the quarantined request's stream is a
    prefix of its fault-free run and every other co-batched greedy stream is
    bit-identical to the fault-free baseline."""
    cfg, params = phi4_setup
    spec = mixed_spec(4, gen_buckets=(16, 32))
    base, s0 = run_workload(cfg, params, slots=3, spec=spec, seed=11)
    plan = FaultPlan(events=(FaultEvent(site="nan_logits", step=8, slot=1),))
    faulted, s1 = run_workload(cfg, params, slots=3, spec=spec, seed=11,
                               fault_plan=plan)
    assert s1["faults"]["nan_quarantined"] == 1
    [failed] = [r for r in s1["requests"] if r["state"] == "failed"]
    assert failed["finish_reason"] == "nan_logits"
    for rid, stream in faulted.items():
        if rid == failed["rid"]:
            assert stream == base[rid][:len(stream)]   # clean prefix
            assert len(stream) < len(base[rid])
        else:
            assert stream == base[rid], f"unfaulted rid {rid} diverged"
    _conserved(s1, 4)


def test_engine_cancel_mid_run_and_idempotent(phi4_setup):
    cfg, params = phi4_setup
    spec = mixed_spec(4, gen_buckets=(24,))
    reqs = make_requests(cfg, spec, seed=5)
    eng = ServingEngine(cfg, slots=2, max_len=48, block_size=8, params=params)
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        eng.step()
        if reqs[0].n_generated >= 3 and reqs[0].rid in [
                r.rid for r in eng.sched.running.values()]:
            break
    assert eng.cancel(0, reason="client")
    assert reqs[0].state is RequestState.CANCELLED
    assert reqs[0].finish_reason == "client"
    assert not eng.cancel(0)                 # idempotent
    assert not eng.cancel(999)               # unknown rid: False, no raise
    assert 0 not in [r.rid for r in eng.sched.running.values()]
    while eng.sched.has_work:
        eng.step()
    s = eng.summary()
    _conserved(s, 4)
    assert s["terminal"]["cancelled"] == 1 and s["terminal"]["done"] == 3
    # the freed slot's blocks went back to the pool
    cache = eng.sched.prefix_cache
    assert eng.pool.used_blocks == (len(cache.held_blocks())
                                    if cache is not None else 0)


def test_engine_cancel_parity_streams_unaffected(phi4_setup):
    """Cancelling one request must not perturb any other greedy stream."""
    cfg, params = phi4_setup
    spec = mixed_spec(4, gen_buckets=(24,))
    base, _ = run_workload(cfg, params, slots=2, spec=spec, seed=5)
    reqs = make_requests(cfg, spec, seed=5)
    eng = ServingEngine(cfg, slots=2, max_len=48, block_size=8, params=params)
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        eng.step()
        if reqs[0].n_generated >= 3:
            break
    eng.cancel(0)
    while eng.sched.has_work:
        eng.step()
    streams = token_streams(reqs)
    for rid in (1, 2, 3):
        assert streams[rid] == base[rid], f"rid {rid} diverged after cancel"
    assert streams[0] == base[0][:len(streams[0])]


def test_engine_deadline_mid_run_timeout(phi4_setup):
    cfg, params = phi4_setup
    spec = mixed_spec(3, gen_buckets=(24,))
    reqs = make_requests(cfg, spec, seed=4)
    eng = ServingEngine(cfg, slots=2, max_len=48, block_size=8, params=params,
                        deadline_s=1e9)
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        eng.step()
        if reqs[0].n_generated >= 2:
            break
    reqs[0].deadline = 0.0                  # already past: expires next sweep
    eng.step()
    assert reqs[0].state is RequestState.TIMEOUT
    assert reqs[0].finish_reason == "deadline"
    while eng.sched.has_work:
        eng.step()
    s = eng.summary()
    _conserved(s, 3)
    assert s["terminal"]["timeout"] == 1 and s["terminal"]["done"] == 2


def test_engine_queue_timeout_expires_waiter(phi4_setup):
    cfg, params = phi4_setup
    p = np.arange(8, dtype=np.int32)
    r0 = Request(rid=0, prompt=p, max_new=6, arrival=0.0)
    r1 = Request(rid=1, prompt=p + 1, max_new=6, arrival=0.0,
                 queue_timeout=1e-9)
    eng = ServingEngine(cfg, slots=1, max_len=32, block_size=8, params=params)
    s = eng.run([r0, r1])
    # one slot: r0 admits first; r1's queue budget expires before admission
    assert r0.state is RequestState.DONE
    assert r1.state is RequestState.TIMEOUT
    assert r1.finish_reason == "queue" and r1.t_first_token is None
    _conserved(s, 2)


def test_engine_drain_cancels_unadmitted(phi4_setup):
    cfg, params = phi4_setup
    p = np.arange(8, dtype=np.int32)
    near = [Request(rid=i, prompt=p + i, max_new=4, arrival=0.0)
            for i in range(2)]
    far = Request(rid=2, prompt=p + 9, max_new=4, arrival=1e9)
    eng = ServingEngine(cfg, slots=2, max_len=32, block_size=8, params=params)
    for r in near + [far]:
        eng.submit(r)
    eng.step()                               # admit the near pair
    s = eng.drain()
    assert all(r.state is RequestState.DONE for r in near)
    assert far.state is RequestState.CANCELLED
    assert far.finish_reason == "drain"
    _conserved(s, 3)
    assert not eng.sched.has_work


def test_engine_stall_error_carries_partial_summary(phi4_setup):
    cfg, params = phi4_setup
    spec = mixed_spec(3, gen_buckets=(24,))
    reqs = make_requests(cfg, spec, seed=2)
    eng = ServingEngine(cfg, slots=2, max_len=48, block_size=8, params=params)
    with pytest.raises(EngineStallError, match="exceeded 2 steps") as ei:
        eng.run(reqs, max_steps=2)
    s = ei.value.summary
    assert s is not None and s["steps"] >= 2
    assert {"terminal", "faults", "degradation"} <= set(s)


def test_engine_degrade_engages_under_flaky_pressure(phi4_setup):
    """The flaky scenario against a tight pool must shed load (transitions
    fire) and still land every request in a terminal state, crash-free."""
    cfg, params = phi4_setup
    spec = dataclasses.replace(SCENARIOS["flaky"], n_requests=8,
                               prompt_buckets=(8, 16), gen_buckets=(8, 24),
                               deadline_buckets=(5.0, 30.0),
                               deadline_weights=None, queue_timeout=30.0)
    _, s = run_workload(cfg, params, slots=2, max_len=48, spec=spec, seed=6,
                        n_blocks=12, degrade=True)
    _conserved(s, 8)
    assert s["degradation"]["transitions"] > 0
    assert s["engine_stats"]["degrade_transitions"] == \
        s["degradation"]["transitions"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", HORIZON_ARCHS)
def test_engine_cancel_deadline_parity_across_archs(arch):
    """Deadline/cancel semantics hold for every cache family: the victim
    reaches its terminal state, everyone else completes with a stream
    bit-identical to the undisturbed run."""
    cfg, params = materialize(arch)
    spec = mixed_spec(3, gen_buckets=(16,))
    base, _ = run_workload(cfg, params, slots=2, spec=spec, seed=7)
    reqs = make_requests(cfg, spec, seed=7)
    eng = ServingEngine(cfg, slots=2, max_len=48, block_size=8, params=params,
                        deadline_s=1e9)
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        eng.step()
        if reqs[0].n_generated >= 2:
            break
    assert eng.cancel(0)
    reqs[1].deadline = 0.0
    while eng.sched.has_work:
        eng.step()
    s = eng.summary()
    streams = token_streams(reqs)
    assert reqs[0].state is RequestState.CANCELLED
    assert reqs[1].state in (RequestState.TIMEOUT, RequestState.DONE)
    assert streams[2] == base[2], f"{arch}: bystander stream diverged"
    _conserved(s, 3)


def test_engine_drain_races_concurrent_cancels(phi4_setup):
    """drain() racing client cancels: cancel a running and a queued request
    just before draining, then drain.  Every request ends in exactly one
    terminal state (no double-finalize, no hang), late submissions get a
    typed ShuttingDown, and all pool blocks return."""
    cfg, params = phi4_setup
    spec = mixed_spec(6, gen_buckets=(24,))
    reqs = make_requests(cfg, spec, seed=5)
    eng = ServingEngine(cfg, slots=2, max_len=48, block_size=8, params=params)
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        eng.step()
        if any(r.n_generated >= 2 for r in eng.sched.running.values()):
            break
    running_rid = next(iter(eng.sched.running.values())).rid
    queued_rid = next(r.rid for _, _, r in eng.sched.waiting
                      if r.t_admit is None)
    assert eng.cancel(running_rid, reason="client")
    assert eng.cancel(queued_rid, reason="client")
    s = eng.drain()
    # the race window: drain's own sweep must not re-finalize the two
    # already-cancelled requests, and cancel-after-drain stays idempotent
    assert not eng.cancel(running_rid)
    assert not eng.cancel(queued_rid)
    _conserved(s, 6)
    assert s["terminal"]["cancelled"] >= 2
    assert s["terminal"]["done"] >= 1       # in-flight work still flushed
    for r in reqs:
        assert r.terminal and r.t_done is not None
    # late submit after drain: typed rejection, never a silent hang
    late = make_requests(cfg, mixed_spec(1), seed=77, start_rid=500)[0]
    with pytest.raises(ShuttingDown):
        eng.submit(late)
    assert late.rid not in eng._by_rid
    cache = eng.sched.prefix_cache
    assert eng.pool.used_blocks == (len(cache.held_blocks())
                                    if cache is not None else 0)
    assert len(eng.sched.free_slots) == 2


def test_engine_drain_late_submit_summary_conserved(phi4_setup):
    """ShuttingDown is raised before any engine state is allocated, so a
    rejected late submit never shows up in the terminal accounting."""
    cfg, params = phi4_setup
    reqs = make_requests(cfg, mixed_spec(2), seed=5)
    eng = ServingEngine(cfg, slots=2, max_len=48, block_size=8, params=params)
    for r in reqs:
        eng.submit(r)
    s = eng.drain()
    _conserved(s, 2)
    late = make_requests(cfg, mixed_spec(1), seed=78, start_rid=600)[0]
    with pytest.raises(ShuttingDown):
        eng.submit(late)
    s2 = eng.summary()
    _conserved(s2, 2)                       # unchanged: no phantom request
    assert isinstance(ShuttingDown("x"), RuntimeError)
