"""PCRAM reliability layer: wear accounting, wear-leveling, retirement, scrub.

Four layers, cheapest first:

* pure-python units — ``ReliabilityConfig`` validation, :func:`wear_gini`,
  ``BlockPool`` wear accounting / ``min_wear`` allocation order /
  retire_free / retire_used / over_budget;
* scheduler bookkeeping — ``retire_blocks`` drains and remaps every claim
  class (running tables, swapped ``kept_blocks``, prefix-cache chains) and
  the free/referenced/retired partition stays conserved;
* allocator-policy property — under a churny alloc/free workload the
  ``min_wear`` free-list order provably narrows the wear distribution
  (Gini) vs. the seed LIFO order;
* engine end-to-end (jax) — the stack's signature invariant: greedy token
  streams are **bit-identical** with reliability on vs. off (wear-leveling,
  budget-driven retirement, drift scrubbing — all of it), stuck_at /
  wear_exhaustion faults are contained with every request terminal, and a
  retirement storm walks capacity pressure into the degradation ladder
  instead of crashing into pool exhaustion.
"""
import numpy as np
import pytest

from serving_harness import materialize, mixed_spec, run_workload

from repro.core.odin_linear import OdinConfig, odin_linear
from repro.serving import (DegradationController, DegradeConfig, FaultEvent,
                           FaultPlan, ReliabilityConfig, Request, RequestState,
                           Scheduler, wear_gini)
from repro.serving.blocks import BlockPool
from repro.serving.scheduler import PrefixCache


# ---------------------------------------------------------------------------
# config + wear_gini units
# ---------------------------------------------------------------------------

def test_reliability_config_validation_and_scrub_gate():
    rel = ReliabilityConfig()
    assert rel.wear_leveling and rel.endurance_budget is None
    assert not rel.scrub_enabled                    # rate 0 ⇒ off
    assert not ReliabilityConfig(scrub_rate=4).scrub_enabled   # no deadline
    assert not ReliabilityConfig(drift_deadline_s=1.0).scrub_enabled
    assert ReliabilityConfig(scrub_rate=1, drift_deadline_s=1.0).scrub_enabled
    with pytest.raises(ValueError):
        ReliabilityConfig(endurance_budget=0)
    with pytest.raises(ValueError):
        ReliabilityConfig(scrub_rate=-1)
    with pytest.raises(ValueError):
        ReliabilityConfig(drift_deadline_s=0.0)


def test_wear_gini_units():
    assert wear_gini([]) == 0.0
    assert wear_gini([0, 0, 0]) == 0.0              # all-zero reads as even
    assert wear_gini([5, 5, 5, 5]) == pytest.approx(0.0)
    # all writes on one block of n → G = (n-1)/n
    assert wear_gini([0, 0, 0, 12]) == pytest.approx(0.75)
    even, skewed = [4, 5, 6, 5], [0, 1, 2, 17]
    assert wear_gini(even) < wear_gini(skewed)


# ---------------------------------------------------------------------------
# BlockPool wear accounting + retirement units
# ---------------------------------------------------------------------------

def test_pool_record_writes_and_budget():
    pool = BlockPool(4, 8, endurance_budget=10)
    assert pool.record_writes([(0, 3), (1, 4), (0, 2)], now=1.5) == 9
    assert pool.wear[0] == 5 and pool.wear[1] == 4 and pool.wear[2] == 0
    assert pool.last_write[0] == 1.5 and pool.last_write[2] == -1.0
    assert pool.total_writes == 9
    assert pool.over_budget() == []
    pool.record_writes([(0, 5)], now=2.0)
    assert pool.over_budget() == [0]
    # zero/negative row counts are ignored, not billed
    assert pool.record_writes([(3, 0)], now=3.0) == 0
    assert pool.last_write[3] == -1.0


def test_pool_retire_free_and_used_conserve_partition():
    pool = BlockPool(6, 8)
    ids = pool.alloc(2)
    pool.retire_free(next(b for b in range(6) if b not in ids))
    assert pool.usable_blocks == 5
    new = pool.retire_used(ids[0])
    assert new is not None and new not in ids
    assert pool.refs(new) == 1 and pool.refs(ids[0]) == 0
    free, refs = pool.snapshot()
    assert len(free) + len(refs) + len(pool.retired) == pool.n_blocks
    assert not (set(free) | set(refs)) & pool.retired
    # refcount transfers wholesale, not reset
    pool.share([ids[1]])
    new2 = pool.retire_used(ids[1])
    assert pool.refs(new2) == 2
    with pytest.raises(ValueError):
        pool.record_writes([(ids[0], 1)])           # write to retired block
    with pytest.raises(ValueError):
        pool.retire_free(new)                       # still referenced
    # pool exhausted ⇒ retire_used returns None and the block stays live
    pool2 = BlockPool(1, 8)
    [b] = pool2.alloc(1)
    assert pool2.retire_used(b) is None
    assert pool2.refs(b) == 1 and not pool2.retired


def test_min_wear_policy_allocates_least_worn_first():
    pool = BlockPool(4, 8, policy="min_wear")
    ids = pool.alloc(4)
    pool.record_writes([(0, 9), (1, 1), (2, 5), (3, 3)])
    pool.free(ids)
    assert pool.alloc(4) == [1, 3, 2, 0]            # ascending wear
    # tie on wear → oldest-freed first
    pool = BlockPool(3, 8, policy="min_wear")
    ids = pool.alloc(3)
    for b in (2, 0, 1):
        pool.free([b])
    assert pool.alloc(3) == [2, 0, 1]


def test_min_wear_narrows_gini_vs_lifo_under_churn():
    """The allocator-policy property the bench gates on: a churny
    alloc/free workload concentrates writes on LIFO's hot top-of-stack
    blocks, while min-wear rotation spreads them."""
    def churn(policy, seed=0):
        rng = np.random.default_rng(seed)
        pool = BlockPool(32, 8, policy=policy)
        held = []
        for t in range(2000):
            if held and rng.random() < 0.5:
                ids = held.pop(int(rng.integers(0, len(held))))
                pool.free(ids)
            else:
                got = pool.alloc(int(rng.integers(1, 4)))
                if got is None:
                    continue
                pool.record_writes([(b, pool.block_size) for b in got],
                                   now=float(t))
                held.append(got)
        return wear_gini(pool.wear)

    g_lifo, g_wl = churn("lifo"), churn("min_wear")
    assert g_wl < g_lifo, (g_wl, g_lifo)
    assert g_wl < 0.5 * g_lifo                      # decisively narrower


# ---------------------------------------------------------------------------
# scheduler retirement: drain + remap every claim class
# ---------------------------------------------------------------------------

def _mini_sched(n_blocks=8, bs=4, slots=2, max_len=32, cache=True):
    pool = BlockPool(n_blocks, bs)
    pc = PrefixCache(pool, bs) if cache else None
    sched = Scheduler(slots, pool, max_len, prefix_cache=pc)
    return pool, pc, sched


def test_retire_blocks_remaps_running_table():
    pool, _, sched = _mini_sched(cache=False)
    req = Request(rid=0, prompt=np.arange(6, dtype=np.int32), max_new=4,
                  arrival=0.0)
    sched.submit(req)
    sched.plan(0.0)
    assert req.slot is not None and req.block_table
    bid = req.block_table[0]
    v0 = sched.table_version
    copies = sched.retire_blocks([bid])
    assert copies and copies[0][0] == bid
    new = copies[0][1]
    assert req.block_table[0] == new and bid not in req.block_table
    assert bid in pool.retired and pool.refs(new) == 1
    assert sched.table_version > v0                 # device mirror refresh
    # idempotent: retiring an already-retired block is a no-op
    assert sched.retire_blocks([bid]) == []


def test_retire_blocks_evicts_cache_only_chain_without_copy():
    pool, pc, sched = _mini_sched()
    req = Request(rid=0, prompt=np.arange(8, dtype=np.int32), max_new=2,
                  arrival=0.0)
    sched.submit(req)
    sched.plan(0.0)
    req.generated.extend(np.int32(i) for i in range(2))
    sched.complete(req, 1.0)
    held = pc.held_blocks()
    assert held                                     # chain retained past life
    bid = held[0]
    copies = sched.retire_blocks([bid])
    assert copies == []                             # evicted, nothing to drain
    assert bid in pool.retired and not pc.holds(bid)
    free, refs = pool.snapshot()
    assert len(free) + len(refs) + len(pool.retired) == pool.n_blocks


def test_retire_blocks_remaps_shared_cache_and_table_claim():
    """A block shared between a running table and the prefix cache keeps
    both claims on the replacement block."""
    pool, pc, sched = _mini_sched()
    prompt = np.arange(8, dtype=np.int32)
    r0 = Request(rid=0, prompt=prompt, max_new=2, arrival=0.0)
    sched.submit(r0)
    sched.plan(0.0)
    shared = [b for b in r0.block_table if pc.holds(b)]
    assert shared                                   # prompt chain is cached
    bid = shared[0]
    refs_before = pool.refs(bid)
    assert refs_before >= 2                         # table + cache claims
    [(src, new)] = sched.retire_blocks([bid])
    assert src == bid
    assert pool.refs(new) == refs_before
    assert new in r0.block_table and pc.holds(new) and not pc.holds(bid)


# ---------------------------------------------------------------------------
# degradation ladder: retirement pressure
# ---------------------------------------------------------------------------

def test_degrade_retired_frac_is_a_pressure_input():
    ctl = DegradationController(DegradeConfig(up_steps=2, retired_hi=0.25))
    # scarred but idle pool (below pool_lo): calm, never escalates
    for t in range(6):
        assert ctl.observe(float(t), pool_frac=0.2, queue_depth=0, churn=0,
                           retired_frac=0.5) == 0
    # scarred AND loaded: escalates after up_steps
    levels = [ctl.observe(10.0 + t, pool_frac=0.6, queue_depth=0, churn=0,
                          retired_frac=0.3) for t in range(4)]
    assert levels[-1] >= 1


# ---------------------------------------------------------------------------
# drift-noise time keying (satellite: OdinConfig.drift_noise)
# ---------------------------------------------------------------------------

def test_drift_noise_keyed_by_step():
    import jax
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (4, 32))
    w = jax.random.normal(jax.random.fold_in(k, 1), (32, 16))
    cfg = OdinConfig(mode="int8", drift_noise=0.05, drift_seed=7)
    y0a = np.asarray(odin_linear(x, w, cfg, drift_step=0))
    y0b = np.asarray(odin_linear(x, w, cfg, drift_step=0))
    y1 = np.asarray(odin_linear(x, w, cfg, drift_step=1))
    default = np.asarray(odin_linear(x, w, cfg))
    assert np.array_equal(y0a, y0b)                 # deterministic per step
    assert np.array_equal(y0a, default)             # default step is 0
    assert not np.array_equal(y0a, y1)              # pattern moves in time
    base = np.asarray(odin_linear(x, w, OdinConfig(mode="int8")))
    assert np.allclose(base, y1, rtol=0.3, atol=1.0)  # still a perturbation


# ---------------------------------------------------------------------------
# engine end-to-end (jax)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def phi4_setup():
    return materialize("phi4-mini-3.8b")


def test_engine_streams_bit_identical_reliability_on_off(phi4_setup):
    """The tentpole invariant: wear-leveled allocation, budget-driven
    retirement AND drift scrubbing only move identical bytes between
    physical block ids — every greedy stream and terminal state is
    bit-identical to the reliability-off run."""
    cfg, params = phi4_setup
    spec = mixed_spec(5, gen_buckets=(8, 24))
    base, s0 = run_workload(cfg, params, spec=spec, seed=7, n_blocks=20,
                            swap_blocks=24, horizon=4)
    rel = ReliabilityConfig(endurance_budget=48, wear_leveling=True,
                            scrub_rate=2, drift_deadline_s=0.02)
    streams, s1 = run_workload(cfg, params, spec=spec, seed=7, n_blocks=20,
                               swap_blocks=24, horizon=4, reliability=rel)
    assert streams == base
    assert {r["rid"]: r["state"] for r in s1["requests"]} == \
           {r["rid"]: r["state"] for r in s0["requests"]}
    r = s1["reliability"]
    assert r["pool_writes"] > 0
    assert r["scrub_rows"] == s1["odin_phases"]["scrub"]["rows"]
    # the baseline run bills wear too (accounting is always on) but never
    # scrubs or retires
    assert s0["reliability"]["pool_writes"] > 0
    assert s0["reliability"]["scrub_rows"] == 0
    assert s0["reliability"]["retired_blocks"] == 0


def test_engine_budget_retirement_drains_and_stays_identical(phi4_setup):
    """A tight endurance budget forces mid-run retirement of live blocks;
    streams still match and the pool partition survives."""
    cfg, params = phi4_setup
    spec = mixed_spec(4, gen_buckets=(16, 32))
    base, _ = run_workload(cfg, params, spec=spec, seed=3, n_blocks=24)
    # wear-leveling OFF keeps wear concentrated on the LIFO hot blocks so a
    # mid-range budget retires a few of them without a capacity storm
    rel = ReliabilityConfig(endurance_budget=12, wear_leveling=False)
    streams, s = run_workload(cfg, params, spec=spec, seed=3, n_blocks=24,
                              reliability=rel)
    assert streams == base
    assert s["reliability"]["retired_blocks"] > 0
    assert s["reliability"]["scrub_copies"] > 0     # retire-drain copies
    assert s["terminal"].get("done", 0) == 4


def test_engine_stuck_at_fault_contained_and_remapped(phi4_setup):
    """A stuck_at fault on a live block retires it before the next dispatch;
    the victim's stream is unperturbed (identical bytes moved)."""
    cfg, params = phi4_setup
    spec = mixed_spec(4, gen_buckets=(16, 24))
    base, _ = run_workload(cfg, params, spec=spec, seed=11)
    plan = FaultPlan(events=(FaultEvent(site="stuck_at", step=6, slot=1),
                             FaultEvent(site="stuck_at", step=9, slot=5)))
    streams, s = run_workload(cfg, params, spec=spec, seed=11,
                              fault_plan=plan)
    assert streams == base
    assert s["reliability"]["retired_blocks"] >= 1
    assert sum(s["terminal"].values()) == 4


def test_engine_wear_exhaustion_storm_all_terminal(phi4_setup):
    """A wear_exhaustion burst retires the most-worn blocks at once; every
    request still reaches exactly one terminal state (capacity-failed
    requests are typed, not livelocked) and nothing escapes step()."""
    cfg, params = phi4_setup
    spec = mixed_spec(5, gen_buckets=(8, 24))
    plan = FaultPlan(events=(FaultEvent(site="wear_exhaustion", step=4,
                                        count=4),
                             FaultEvent(site="wear_exhaustion", step=8,
                                        count=4)))
    streams, s = run_workload(cfg, params, spec=spec, seed=2, n_blocks=14,
                              swap_blocks=24, fault_plan=plan, degrade=True)
    assert sum(s["terminal"].values()) == 5
    assert s["reliability"]["retired_blocks"] > 0
    failed = [r for r in s["requests"] if r["state"] == "failed"]
    assert all(r["finish_reason"] == "capacity" for r in failed)


def test_engine_retirement_storm_engages_degradation_ladder(phi4_setup):
    """Sustained retirement under load is a pressure input: the ladder must
    leave ``normal`` before the pool exhausts."""
    cfg, params = phi4_setup
    spec = mixed_spec(6, gen_buckets=(16, 32))
    events = tuple(FaultEvent(site="wear_exhaustion", step=st, count=2)
                   for st in (3, 5, 7, 9))
    _, s = run_workload(cfg, params, spec=spec, seed=4, n_blocks=16,
                        swap_blocks=24, fault_plan=events and
                        FaultPlan(events=events), degrade=True)
    assert sum(s["terminal"].values()) == 6
    assert s["reliability"]["retired_blocks"] > 0
    assert s["degradation"]["transitions"] > 0
