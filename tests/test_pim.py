"""Transaction-level PCRAM model vs the paper's own numbers (Tables 1–3)."""
import numpy as np
import pytest

from repro.pim.commands import TABLE1_EXPECTED, TABLE3_PJ, command_set
from repro.pim.geometry import OdinModule, PCRAMGeometry, PCRAMTiming
from repro.pim.trace import (
    CNN1, CNN2, FC, PAPER_TOPOLOGIES, VGG1, VGG2, trace_topology,
)

MOD = OdinModule()


# ---------------------------------------------------------------------------
# Table 1 — exact
# ---------------------------------------------------------------------------

def test_table1_command_latencies_exact():
    cs = command_set()
    for name, exp in TABLE1_EXPECTED.items():
        cmd = cs[name]
        assert cmd.reads == exp["reads"], name
        assert cmd.writes == exp["writes"], name
        assert cmd.latency_ns(MOD) == pytest.approx(exp["latency_ns"]), name


def test_primitive_timing_solves_table1():
    """(t_R, t_W) = (48, 60) ns is the unique solution of Table 1's system."""
    t = PCRAMTiming()
    assert 1 * t.t_read_ns + 1 * t.t_write_ns == 108          # ANN_MUL/ACC
    assert 33 * t.t_read_ns + 32 * t.t_write_ns == 3504       # B_TO_S
    assert 32 * t.t_read_ns + 32 * t.t_write_ns == 3456       # S_TO_B/POOL


def test_geometry_invariants():
    g = PCRAMGeometry()
    assert g.blocks_per_row == 32         # 8 Kb row / 256-bit block
    assert g.operands_per_block == 32     # 32 8-bit operands per block
    assert g.banks == 128                 # 1 ch × 8 ranks × 16 banks
    assert g.module_bits() == 8 * 2**30 * 8  # 8 GB accelerator channel


# ---------------------------------------------------------------------------
# Table 2 — FC command counts (the cleanly parseable cells)
# ---------------------------------------------------------------------------

def test_vgg1_fc_reads_writes_match_paper():
    cost = trace_topology(VGG1, MOD, accounting="paper")
    assert cost.fc_reads == pytest.approx(247e6, rel=0.01)    # paper: 247e6
    assert cost.fc_writes == pytest.approx(248e6, rel=0.01)   # paper: 248e6


def test_vgg2_fc_reads_writes_match_paper():
    cost = trace_topology(VGG2, MOD, accounting="paper")
    assert cost.fc_reads == pytest.approx(251e6, rel=0.02)    # paper: 251e6
    assert cost.fc_writes == pytest.approx(252e6, rel=0.02)


def test_vgg_conv_reads_match_paper_band():
    cost = trace_topology(VGG1, MOD, accounting="paper")
    # paper: 58.8e6 reads / 30.3e6 writes; our mapping gives ±5%
    assert cost.conv_reads == pytest.approx(58.8e6, rel=0.05)
    assert cost.conv_writes == pytest.approx(30.3e6, rel=0.05)


def test_fc_memory_requirement_vgg():
    cost = trace_topology(VGG1, MOD)
    assert cost.fc_mem_gbit == pytest.approx(1.93, rel=0.03)  # paper: 1.93 Gb


def test_full_accounting_adds_conversions():
    paper = trace_topology(CNN1, MOD, accounting="paper")
    full = trace_topology(CNN1, MOD, accounting="full")
    assert full.total_energy_pj > paper.total_energy_pj
    fc_cmds_paper = paper.layers[-1].commands
    assert "B_TO_S" not in fc_cmds_paper
    assert "B_TO_S" in full.layers[-1].commands


def test_fc_read_write_is_2x_macs():
    fc = FC(1000, 100)
    from repro.pim.trace import Topology
    cost = trace_topology(Topology("t", [fc]), MOD, accounting="paper")
    assert cost.fc_reads == 2 * fc.macs() - fc.n_out  # MUL + (n_in-1) ACC
    assert cost.layers[0].commands["ANN_MUL"] == 100_000
    assert cost.layers[0].commands["ANN_ACC"] == 999 * 100


# ---------------------------------------------------------------------------
# latency/energy roll-up sanity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(PAPER_TOPOLOGIES))
def test_topology_costs_positive_and_ordered(name):
    cost = trace_topology(PAPER_TOPOLOGIES[name], MOD)
    assert cost.total_latency_ns > 0 and cost.total_energy_pj > 0
    assert cost.total_macs > 0


def test_vgg_costs_dominate_cnn():
    c1 = trace_topology(CNN1, MOD)
    v1 = trace_topology(VGG1, MOD)
    assert v1.total_latency_ns > 100 * c1.total_latency_ns
    assert v1.total_energy_pj > 100 * c1.total_energy_pj


def test_parallelism_speedup():
    serial = OdinModule(partition_pairs=1,
                        geom=PCRAMGeometry(ranks_per_channel=1, banks_per_rank=1))
    fast = OdinModule()
    c_serial = trace_topology(CNN1, serial)
    c_fast = trace_topology(CNN1, fast)
    assert c_fast.total_latency_ns < c_serial.total_latency_ns
    # energy is parallelism-independent (same work)
    assert c_fast.total_energy_pj == pytest.approx(c_serial.total_energy_pj)


def test_table3_constants_present():
    assert TABLE3_PJ["relu"] == 185.0 and TABLE3_PJ["pool"] == 2140.0
    assert TABLE3_PJ["sram_lut"] == pytest.approx(0.297)
