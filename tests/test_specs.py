"""Launcher spec plumbing: abstract inputs, pspec tables, divisibility."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import LM_SHAPES
from repro.launch import specs as specs_mod
from repro.launch.mesh import param_pspecs, sharding_rules
from repro.models import lm, registry
from repro.nn.module import ParamSpec, logical_to_pspec


def test_input_specs_train_shapes():
    info = specs_mod.input_specs("llama3-405b", "train_4k")
    assert info["kind"] == "train"
    acc = info["accum"]
    assert info["batch"]["tokens"].shape == (acc, 256 // acc, 4096)
    assert info["batch"]["tokens"].dtype == jnp.int32


def test_input_specs_decode_has_caches():
    info = specs_mod.input_specs("phi3-medium-14b", "decode_32k")
    assert info["kind"] == "decode"
    assert info["tokens"].shape == (128, 1)
    leaves = jax.tree.leaves(info["caches"])
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    # one K cache leaf is [L, B, S, Hkv, D]
    shapes = {l.shape for l in leaves}
    assert (40, 128, 32768, 10, 128) in shapes


def test_input_specs_musicgen_multicodebook():
    info = specs_mod.input_specs("musicgen-medium", "train_4k")
    assert info["batch"]["tokens"].shape == (256, 4, 4096)


def test_input_specs_vlm_stub():
    info = specs_mod.input_specs("qwen2-vl-2b", "prefill_32k")
    assert "patch_embeds" in info["batch"]
    n_p = info["batch"]["patch_embeds"].shape[1]
    assert int(np.sqrt(n_p)) ** 2 == n_p          # square patch grid


def test_kv_dtype_override_flows_to_caches():
    info = specs_mod.input_specs("musicgen-medium", "decode_32k", kv_dtype="int8")
    dtypes = {str(l.dtype) for k, l in
              jax.tree_util.tree_flatten_with_path(info["caches"])[0]
              if "pos" not in jax.tree_util.keystr(k[-1:])}
    assert dtypes == {"int8"}


def test_abstract_never_allocates():
    """671B abstract params build instantly with zero device memory."""
    cfg = registry.get_config("deepseek-v3-671b")
    import repro.nn.module as nnmod
    tree = nnmod.abstract(lm.param_spec(cfg))
    n = sum(np.prod(l.shape) for l in jax.tree.leaves(tree))
    assert n > 600e9
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in jax.tree.leaves(tree))


class _FakeMesh:
    def __init__(self, sizes):
        self.shape = sizes
        self.axis_names = tuple(sizes)


def test_param_pspecs_drops_nondividing_axes():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = {"vocab": "model", "embed": ("data",)}
    spec = {"embed": ParamSpec((32001, 1600), ("vocab", "embed"))}
    ps = param_pspecs(spec, rules, mesh)["embed"]
    assert ps == P(None, "data")                  # 32001 % 16 ≠ 0 → dropped
    spec2 = {"embed": ParamSpec((32000, 1600), ("vocab", "embed"))}
    ps2 = param_pspecs(spec2, rules, mesh)["embed"]
    assert ps2 == P("model", "data")


def test_logical_to_pspec_drops_repeated_axes():
    rules = {"a": "model", "b": "model"}
    assert logical_to_pspec(("a", "b"), rules) == P("model")


def test_sharding_rules_kinds():
    mesh = _FakeMesh({"data": 16, "model": 16})
    train = sharding_rules(mesh, "train")
    decode = sharding_rules(mesh, "decode")
    assert train["act_seq"] == "model"            # sequence-parallel carries
    assert decode["act_seq"] is None
    assert train["experts"] == "model"            # EP
    over = sharding_rules(mesh, "train", act_seq=None)
    assert over["act_seq"] is None                # §Perf override hook


def test_cells_cover_every_arch():
    archs = {a for a, _ in registry.cells()}
    assert archs == set(registry.ARCH_IDS)
