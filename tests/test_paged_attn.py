"""Paged decode-attention kernel: kernel==reference across GQA geometries
(incl. sliding window and int8 pools), and block-table parity against the
dense decode path on random lengths — the contract that lets the serving
engine swap its dense live cache for the physical block pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attn import paged_attention, paged_attn_ref
from repro.nn.attention import KV_SCALE, _cache_write, sdpa


def _rand_pool(rng, B, H, Hkv, D, bs, P, int8=False):
    N = B * P + 3                      # spare blocks: tables never cover all
    q = jnp.asarray(rng.normal(size=(B, H, D)) * 0.5, jnp.float32)
    if int8:
        kp = jnp.asarray(rng.integers(-127, 128, (N, bs, Hkv, D)), jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, (N, bs, Hkv, D)), jnp.int8)
    else:
        kp = jnp.asarray(rng.normal(size=(N, bs, Hkv, D)) * 0.5, jnp.float32)
        vp = jnp.asarray(rng.normal(size=(N, bs, Hkv, D)) * 0.5, jnp.float32)
    tables = jnp.asarray(rng.permutation(N)[:B * P].reshape(B, P), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, P * bs + 1, B), jnp.int32)
    return q, kp, vp, tables, lengths


@pytest.mark.parametrize("B,H,Hkv,D,bs,P,window,int8", [
    (3, 4, 2, 16, 8, 6, 0, False),     # GQA
    (2, 4, 4, 32, 16, 4, 0, False),    # MHA
    (2, 8, 1, 16, 8, 5, 0, False),     # MQA
    (2, 8, 2, 16, 8, 5, 12, False),    # sliding window
    (1, 4, 2, 16, 4, 3, 5, False),     # window not block-aligned
    (3, 4, 2, 16, 8, 6, 0, True),      # int8 fixed-point pool
])
def test_kernel_matches_reference(B, H, Hkv, D, bs, P, window, int8):
    rng = np.random.default_rng(B * 100 + H)
    q, kp, vp, tables, lengths = _rand_pool(rng, B, H, Hkv, D, bs, P, int8)
    kv_scale = KV_SCALE if int8 else None
    out_k = paged_attention(q, kp, vp, tables, lengths, window=window,
                            kv_scale=kv_scale)
    out_r = paged_attn_ref(q.reshape(B, Hkv, H // Hkv, D), kp, vp, tables,
                           lengths, window=window, kv_scale=kv_scale
                           ).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5)


@pytest.mark.parametrize("int8", [False, True])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_block_table_parity_with_dense_decode(seed, int8):
    """Scatter the same K/V rows into a permuted block pool: the paged kernel
    must reproduce the dense decode attention at every random length."""
    rng = np.random.default_rng(seed)
    B, H, Hkv, D, bs, P = 3, 4, 2, 16, 8, 5
    S = P * bs
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)) * 0.5, jnp.float32)
    lengths = jnp.asarray(rng.integers(1, S + 1, B), jnp.int32)

    # dense decode: visibility by position mask over the full cache
    rows = jnp.arange(S, dtype=jnp.int32)[None, :]
    k_pos = jnp.where(rows < lengths[:, None], rows, jnp.int32(2**30))
    q_pos = (lengths - 1)[:, None]
    cdt = jnp.int8 if int8 else jnp.float32
    kq, vq = _cache_write(k, cdt), _cache_write(v, cdt)
    kd = kq.astype(jnp.float32) / (KV_SCALE if int8 else 1.0)
    vd = vq.astype(jnp.float32) / (KV_SCALE if int8 else 1.0)
    dense = sdpa(q, kd, vd, q_pos, k_pos)[:, 0]

    # paged: same rows through a shuffled block table
    N = B * P + 2
    tables = jnp.asarray(rng.permutation(N)[:B * P].reshape(B, P), jnp.int32)
    kp = jnp.zeros((N, bs, Hkv, D), cdt)
    vp = jnp.zeros((N, bs, Hkv, D), cdt)
    bidx = tables[jnp.arange(B)[:, None], rows // bs]
    kp = kp.at[bidx, rows % bs].set(kq)
    vp = vp.at[bidx, rows % bs].set(vq)
    paged = paged_attention(q[:, 0], kp, vp, tables, lengths,
                            kv_scale=KV_SCALE if int8 else None)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               atol=3e-5, rtol=1e-4)


def _pack_ref(q4, kp, vp, tables, lengths, **kw):
    """Run the reference oracle on a [B, Q, H, D] query block (the ops-layer
    packing: row q·G + g of the kernel tile is query q, group g)."""
    B, Q, H, D = q4.shape
    Hkv = kp.shape[2]
    G = H // Hkv
    qt = q4.reshape(B, Q, Hkv, G, D).transpose(0, 2, 1, 3, 4).reshape(B, Hkv, Q * G, D)
    o = paged_attn_ref(qt, kp, vp, tables, lengths, q_len=Q, **kw)
    return o.reshape(B, Hkv, Q, G, D).transpose(0, 2, 1, 3, 4).reshape(B, Q, H, D)


@pytest.mark.parametrize("B,H,Hkv,D,bs,P,Q,window,int8", [
    (3, 4, 2, 16, 8, 6, 3, 0, False),    # GQA draft tile
    (2, 4, 4, 32, 16, 4, 5, 0, False),   # MHA
    (2, 8, 1, 16, 8, 5, 2, 0, False),    # MQA
    (2, 8, 2, 16, 8, 5, 4, 12, False),   # sliding window
    (1, 4, 2, 16, 4, 3, 3, 5, True),     # window + int8 pool
    (3, 4, 2, 16, 8, 6, 5, 0, True),     # int8 fixed-point pool
])
def test_multi_query_kernel_matches_reference(B, H, Hkv, D, bs, P, Q, window, int8):
    """q_len > 1 (speculative verify tiles): kernel == oracle with per-row
    causal masking of the in-flight draft against the page axis."""
    rng = np.random.default_rng(B * 1000 + H * 10 + Q)
    q1, kp, vp, tables, lengths = _rand_pool(rng, B, H, Hkv, D, bs, P, int8)
    q = jnp.asarray(rng.normal(size=(B, Q, H, D)) * 0.5, jnp.float32)
    lengths = jnp.asarray(rng.integers(Q, P * bs + 1, B), jnp.int32)
    kv_scale = KV_SCALE if int8 else None
    out = paged_attention(q, kp, vp, tables, lengths, window=window,
                          kv_scale=kv_scale)
    ref = _pack_ref(q, kp, vp, tables, lengths, window=window,
                    kv_scale=kv_scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("window", [0, 9])
def test_multi_query_rows_match_sequential_decode_calls(window):
    """The semantic contract speculation rests on: query row j of a Q-token
    tile must equal a plain single-token decode at length - (Q-1-j) — i.e.
    the fused verify scores exactly what Q sequential steps would have."""
    rng = np.random.default_rng(7)
    B, H, Hkv, D, bs, P, Q = 3, 4, 2, 16, 8, 5, 4
    q, kp, vp, tables, _ = _rand_pool(rng, B, H, Hkv, D, bs, P)
    q = jnp.asarray(rng.normal(size=(B, Q, H, D)) * 0.5, jnp.float32)
    lengths = jnp.asarray(rng.integers(Q, P * bs + 1, B), jnp.int32)
    fused = paged_attention(q, kp, vp, tables, lengths, window=window)
    for j in range(Q):
        single = paged_attention(q[:, j], kp, vp, tables,
                                 lengths - (Q - 1 - j), window=window)
        np.testing.assert_allclose(np.asarray(fused[:, j]), np.asarray(single),
                                   atol=3e-5, err_msg=f"query {j}/{Q}")


def test_multi_query_duplicate_tables_and_short_lengths():
    """Draft tiles over cross-slot duplicated block ids (prefix sharing) and
    lengths shorter than the tile (fresh slots): rows whose position would be
    negative must come out finite (fully masked ⇒ zeros), and aliased slots
    must agree with the oracle."""
    rng = np.random.default_rng(5)
    B, H, Hkv, D, bs, P, Q = 4, 4, 2, 16, 8, 5, 4
    q1, kp, vp, tables, _ = _rand_pool(rng, B, H, Hkv, D, bs, P)
    t = np.array(tables)
    t[1, :3] = t[0, :3]
    tables = jnp.asarray(t)
    q = jnp.asarray(rng.normal(size=(B, Q, H, D)) * 0.5, jnp.float32)
    lengths = jnp.asarray([0, 2, Q, 3 * bs], jnp.int32)   # incl. len < Q
    out = paged_attention(q, kp, vp, tables, lengths)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
    ref = _pack_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_zero_length_slot_yields_zeros_not_nan():
    """Idle serving slots decode at length 0 — the kernel must emit exact
    zeros (empty softmax), never NaN (which would poison activity-masked
    engine steps)."""
    rng = np.random.default_rng(0)
    B, H, Hkv, D, bs, P = 2, 4, 2, 16, 8, 4
    q, kp, vp, tables, _ = _rand_pool(rng, B, H, Hkv, D, bs, P)
    lengths = jnp.asarray([0, 16], jnp.int32)
    out = np.asarray(paged_attention(q, kp, vp, tables, lengths))
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out[0], 0.0)
    assert np.abs(out[1]).max() > 0


def test_duplicate_block_ids_across_slots_alias_same_memory():
    """Prefix sharing points *different slots'* tables at the SAME pool
    blocks.  Two slots whose tables share a block prefix (same ids, same
    lengths over that prefix) must read identical K/V through the alias —
    and the reference oracle must agree on arbitrary duplicated tables."""
    rng = np.random.default_rng(11)
    B, H, Hkv, D, bs, P = 4, 4, 2, 16, 8, 5
    q, kp, vp, tables, lengths = _rand_pool(rng, B, H, Hkv, D, bs, P)
    t = np.array(tables)
    t[1, :3] = t[0, :3]                   # slots 0/1 share a 3-block prefix
    t[3] = t[2]                           # slot 3 fully aliases slot 2
    tables = jnp.asarray(t)
    lengths = jnp.asarray([3 * bs, 3 * bs, 17, 17], jnp.int32)
    out = paged_attention(q, kp, vp, tables, lengths)
    ref = paged_attn_ref(q.reshape(B, Hkv, H // Hkv, D), kp, vp, tables,
                         lengths).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    # identical query + fully shared prefix ⇒ identical attention output
    q2 = q.at[1].set(q[0]).at[3].set(q[2])
    out2 = np.asarray(paged_attention(q2, kp, vp, tables, lengths))
    np.testing.assert_allclose(out2[0], out2[1], atol=2e-5)
    np.testing.assert_allclose(out2[2], out2[3], atol=2e-5)


@pytest.mark.parametrize("seed", range(6))
def test_kernel_fuzz_random_geometry_vs_ref_seeded(seed):
    """Seeded slice of the fuzz sweep (runs without hypothesis): random
    (lengths, block_size, window, int8, GQA ratio, duplicated tables) must
    match the reference oracle."""
    _fuzz_case(np.random.default_rng(seed))


def _fuzz_case(rng, geom=None):
    B = int(geom["B"]) if geom else int(rng.integers(1, 4))
    Hkv = int(geom["Hkv"]) if geom else int(rng.integers(1, 3))
    G = int(geom["G"]) if geom else int(rng.integers(1, 5))
    D = int(geom["D"]) if geom else int(rng.choice([8, 16]))
    bs = int(geom["bs"]) if geom else int(rng.choice([4, 8]))
    P = int(geom["P"]) if geom else int(rng.integers(2, 6))
    window = int(geom["window"]) if geom else int(rng.choice([0, 0, 5, 12]))
    int8 = bool(geom["int8"]) if geom else bool(rng.integers(0, 2))
    dup = bool(geom["dup"]) if geom else bool(rng.integers(0, 2))
    Q = int(geom["Q"]) if geom else int(rng.choice([1, 1, 2, 3, 5]))
    H = Hkv * G
    q, kp, vp, tables, lengths = _rand_pool(rng, B, H, Hkv, D, bs, P, int8)
    lengths = jnp.asarray(rng.integers(0, P * bs + 1, B), jnp.int32)
    if dup and B > 1:
        t = np.array(tables)
        k = int(rng.integers(1, P + 1))
        t[1, :k] = t[0, :k]               # cross-slot duplicated ids
        tables = jnp.asarray(t)
    kv_scale = KV_SCALE if int8 else None
    if Q > 1:
        q = jnp.asarray(rng.normal(size=(B, Q, H, D)) * 0.5, jnp.float32)
        out = paged_attention(q, kp, vp, tables, lengths, window=window,
                              kv_scale=kv_scale)
        ref = _pack_ref(q, kp, vp, tables, lengths, window=window,
                        kv_scale=kv_scale)
    else:
        out = paged_attention(q, kp, vp, tables, lengths, window=window,
                              kv_scale=kv_scale)
        ref = paged_attn_ref(q.reshape(B, Hkv, G, D), kp, vp, tables, lengths,
                             window=window, kv_scale=kv_scale).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               err_msg=str((B, Hkv, G, D, bs, P, Q, window,
                                            int8, dup, np.asarray(lengths))))


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @pytest.mark.slow
    @settings(max_examples=20, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data(), seed=st.integers(0, 2**31 - 1))
    def test_kernel_fuzz_random_geometry_vs_ref_hypothesis(data, seed):
        """Hypothesis-driven fuzz over the same geometry space, shrinking
        failures to a minimal (geometry, seed) pair."""
        geom = {
            "B": data.draw(st.integers(1, 3)),
            "Hkv": data.draw(st.integers(1, 2)),
            "G": data.draw(st.integers(1, 4)),
            "D": data.draw(st.sampled_from([8, 16])),
            "bs": data.draw(st.sampled_from([4, 8])),
            "P": data.draw(st.integers(2, 5)),
            "window": data.draw(st.sampled_from([0, 5, 12])),
            "int8": data.draw(st.booleans()),
            "dup": data.draw(st.booleans()),
            "Q": data.draw(st.sampled_from([1, 2, 4])),
        }
        _fuzz_case(np.random.default_rng(seed), geom)
except ImportError:                       # container without test extras
    pass


def test_stale_block_contents_invisible():
    """Rows at or beyond a slot's length live in reallocated blocks that may
    hold a previous occupant's K/V — they must not leak into the output."""
    rng = np.random.default_rng(3)
    B, H, Hkv, D, bs, P = 1, 4, 2, 16, 8, 4
    q, kp, vp, tables, _ = _rand_pool(rng, B, H, Hkv, D, bs, P)
    lengths = jnp.asarray([11], jnp.int32)
    base = paged_attention(q, kp, vp, tables, lengths)
    # poison every pool row the slot cannot see: rest of its own pages + all
    # unreferenced blocks
    rows = jnp.arange(P * bs, dtype=jnp.int32)
    stale = rows >= lengths[0]
    bids = tables[0, rows // bs]
    kp2 = kp.at[bids[stale], (rows % bs)[stale]].set(99.0)
    vp2 = vp.at[bids[stale], (rows % bs)[stale]].set(-99.0)
    out = paged_attention(q, kp2, vp2, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=1e-6)
