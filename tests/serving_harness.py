"""Shared harness for serving-engine parity test families.

Every engine parity family (dense-vs-paged, H>1-vs-H=1, preempted-vs-
unconstrained, prefix-shared-vs-unshared) runs the same shape of experiment:
build an engine with one knob flipped, drive an identical request stream,
and compare token streams + summaries.  This module holds that one copy —
``run_workload`` — plus the smoke-config materializer and the standard
mixed-length workload, so each new parity family is a few lines instead of
another private ``_run_*`` helper.

Token streams are returned as ``{rid: [token-tuple, ...]}`` with each token
flattened to a tuple, which makes single- and multi-codebook models compare
under the same ``==``.
"""
import numpy as np

# One arch per cache family: dense GQA, sliding-window hybrid (ring buffer +
# SSM state), MLA + MoE (batch-coupled capacity routing is the trap here).
PARITY_ARCHS = ["phi4-mini-3.8b", "hymba-1.5b", "deepseek-v3-671b"]

# One arch per cache family plus MoE-over-paged-GQA, recurrent-only xLSTM and
# the multi-codebook [B, K, H] token-block layout.
HORIZON_ARCHS = ["phi4-mini-3.8b", "qwen3-moe-235b-a22b", "hymba-1.5b",
                 "deepseek-v3-671b", "xlstm-350m", "musicgen-medium"]


def materialize(arch: str):
    """(smoke config, materialized params) for one arch id."""
    import jax
    from repro.models import lm as lm_mod, registry
    from repro.nn import module as nnmod
    cfg = registry.get_smoke(arch)
    params = nnmod.materialize(lm_mod.param_spec(cfg), jax.random.PRNGKey(0))
    return cfg, params


def mixed_spec(n_requests: int = 5, **overrides):
    """The standard mixed-length all-arrived stream the parity families use."""
    from repro.serving import WorkloadSpec
    kw = dict(n_requests=n_requests, rate=1e9, prompt_buckets=(8, 16),
              gen_buckets=(4, 24))
    kw.update(overrides)
    return WorkloadSpec(**kw)


def token_streams(requests):
    """{rid: [token-tuple, ...]} — codebook-agnostic comparable form."""
    return {r.rid: [tuple(np.asarray(t).ravel().tolist()) for t in r.generated]
            for r in requests}


def run_workload(cfg, params, *, slots: int = 3, max_len: int = 48,
                 block_size: int = 8, spec=None, seed: int = 9,
                 requests=None, **engine_kwargs):
    """Drive one engine over a request stream; returns (token streams, summary).

    ``engine_kwargs`` carry the knob under test (``paged=``, ``horizon=``,
    ``n_blocks=``/``swap_blocks=``, ``prefix_sharing=``, sampling…).
    ``requests`` overrides the synthetic stream (e.g. extras-carrying
    requests); otherwise ``spec`` (default :func:`mixed_spec`) generates it.
    """
    from repro.serving import ServingEngine, make_requests
    eng = ServingEngine(cfg, slots=slots, max_len=max_len,
                        block_size=block_size, params=params, **engine_kwargs)
    if requests is None:
        requests = make_requests(cfg, spec or mixed_spec(), seed=seed)
    summary = eng.run(requests)
    return token_streams(requests), summary
