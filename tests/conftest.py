import os
import sys

# tests run against the source tree regardless of install state
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: device count is deliberately NOT forced here — smoke tests and
# benches must see the 1 real CPU device.  Multi-device tests spawn
# subprocesses with XLA_FLAGS set (tests/test_distributed.py).
