import os
import sys

# tests run against the source tree regardless of install state
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: device count is deliberately NOT forced here — smoke tests and
# benches must see the 1 real CPU device.  Multi-device tests spawn
# subprocesses with XLA_FLAGS set (tests/test_distributed.py).

# Hypothesis profiles for the property suites (test_serving_props.py,
# test_paged_attn.py fuzz).  "ci" bounds examples and points at an explicit
# on-disk example database so a failing run's falsifying examples can be
# uploaded as a CI artifact and replayed locally; the seed is pinned from
# the CLI (--hypothesis-seed=0) rather than derandomize=True, because
# derandomizing disables the database and would leave the artifact empty.
# Select with --hypothesis-profile=ci.  Optional: the suites fall back to
# seeded sweeps when hypothesis is absent.
try:
    from hypothesis import settings
    from hypothesis.database import DirectoryBasedExampleDatabase

    settings.register_profile(
        "ci", max_examples=40, deadline=None, print_blob=True,
        database=DirectoryBasedExampleDatabase(
            os.path.join(os.path.dirname(__file__), "..", ".hypothesis",
                         "examples")))
    settings.register_profile("dev", max_examples=15, deadline=None)
    settings.load_profile("dev")
except ImportError:
    pass
