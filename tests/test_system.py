"""End-to-end behaviour: training improves loss; serving generates; CNN
accuracy gaps across execution modes match the paper's claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnConfig, BlockConfig, ModelConfig
from repro.core.odin_linear import OdinConfig
from repro.data.synthetic import digits_batch
from repro.launch.serve import serve
from repro.launch.train import train_loop
from repro.models import registry
from repro.nn.cnn import RUNNABLE_CNN1, cnn_forward, cnn_loss, cnn_param_spec
from repro.nn.module import materialize
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

SMALL_LM = ModelConfig(
    name="tiny", d_model=128, vocab=512,
    blocks=(BlockConfig(kind="dense", n_layers=2,
                        attn=AttnConfig(n_heads=4, n_kv_heads=2, d_head=32),
                        d_ff=256),),
)


def _learns(losses, frac):
    head = sum(losses[:5]) / 5
    tail = sum(losses[-5:]) / 5
    assert tail < frac * head, (head, tail)


def test_lm_training_learns(tmp_path):
    """The synthetic mixture needs in-context induction — expect a steady
    ~20% drop in 100 steps (full convergence is the example's job)."""
    _, losses = train_loop(SMALL_LM, steps=100, batch=8, seq=64,
                           ckpt_dir=str(tmp_path), save_every=1000,
                           opt_cfg=AdamWConfig(moment_dtype="float32"),
                           base_lr=2e-3, log_every=1000)
    _learns(losses, 0.88)


def test_lm_training_int8_moments_learns(tmp_path):
    _, losses = train_loop(SMALL_LM, steps=100, batch=8, seq=64,
                           ckpt_dir=str(tmp_path), save_every=1000,
                           opt_cfg=AdamWConfig(moment_dtype="int8"),
                           base_lr=2e-3, log_every=1000)
    _learns(losses, 0.88)


def test_serving_generates_tokens():
    cfg = registry.get_smoke("musicgen-medium")
    generated, tps = serve(cfg, batch=2, prompt_len=8, gen=4, verbose=False)
    assert generated.shape[-1] == 4
    assert tps > 0


@pytest.fixture(scope="module")
def trained_cnn():
    topo = RUNNABLE_CNN1
    params = materialize(cnn_param_spec(topo), jax.random.PRNGKey(0))
    oc = AdamWConfig(moment_dtype="float32", weight_decay=0.0)
    opt = adamw_init(params, oc)

    @jax.jit
    def step(params, opt, batch):
        (l, m), g = jax.value_and_grad(cnn_loss, has_aux=True)(params, batch, topo)
        params, opt = adamw_update(g, params, opt, 1e-3, oc)
        return params, opt, m

    for i in range(120):
        params, opt, _ = step(params, opt, digits_batch(0, i, batch=64))
    return topo, params


def _acc(topo, params, odin, nb=2, bs=16):
    c = t = 0
    for i in range(nb):
        b = digits_batch(1, 10_000 + i, batch=bs)
        lg = cnn_forward(params, b["image"], topo, odin=odin)
        c += int((jnp.argmax(lg, -1) == b["label"]).sum())
        t += bs
    return c / t


def test_cnn_trains_and_int8_gap_minimal(trained_cnn):
    topo, params = trained_cnn
    acc_fp = _acc(topo, params, None, nb=4, bs=32)
    acc_i8 = _acc(topo, params, OdinConfig(mode="int8"), nb=4, bs=32)
    assert acc_fp > 0.8
    assert abs(acc_fp - acc_i8) < 0.05      # paper's 8-bit adjustment claim


def test_cnn_sc_hybrid_accuracy(trained_cnn):
    """Bit-faithful SC at the paper's 32-operand hybrid boundary works;
    the naive full-K MUX tree collapses (documented finding, DESIGN.md)."""
    topo, params = trained_cnn
    acc_fp = _acc(topo, params, None)
    acc_sc = _acc(topo, params,
                  OdinConfig(mode="sc", signed_activations=False, sc_block_k=8))
    acc_full = _acc(topo, params,
                    OdinConfig(mode="sc", signed_activations=False, sc_block_k=0),
                    nb=1)
    # The realized SC streams depend on the jax version's PRNG: on jax 0.4.37
    # the hybrid measures ~0.7 (vs fp 1.0; was within 0.15 of fp on the
    # authoring environment).  The load-bearing contrast is hybrid ≫ chance
    # (0.1 for 10 classes) while the naive full-K tree collapses to it.
    assert acc_sc > 0.55
    assert acc_full < 0.5                   # signal destroyed at K̂=1024


def test_data_digit_classes_learnable_and_balanced():
    b = digits_batch(0, 0, batch=512)
    counts = np.bincount(np.asarray(b["label"]), minlength=10)
    assert counts.min() > 20                # roughly balanced
    assert b["image"].shape == (512, 28, 28, 1)
    assert 0.0 <= float(b["image"].min()) and float(b["image"].max()) <= 1.0
