"""Checkpoint atomicity, integrity, resume-exactness, crash injection."""
import json
import os
import shutil
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.synthetic import lm_batch

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "m": jnp.zeros((8, 4), jnp.int8)},
            "step": jnp.int32(3)}


def _tpl(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    out, step = ckpt.restore(str(tmp_path), 3, _tpl(t), verify=True)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tmp_dirs_ignored_and_gced(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_000000002.tmp"))       # crash debris
    assert ckpt.latest_step(d) == 1                          # ignored
    ckpt.save(d, 3, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(d))  # collected


def test_keep_policy(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        ckpt.save(d, s, _tree(), keep=2)
    assert ckpt.all_steps(d) == [4, 5]


def test_corruption_detected(tmp_path):
    d = str(tmp_path)
    t = _tree()
    path = ckpt.save(d, 1, t)
    npz = os.path.join(path, "arrays.npz")
    raw = open(npz, "rb").read()
    # flip bytes inside the payload
    corrupted = raw[:-50] + bytes(b ^ 0xFF for b in raw[-50:])
    open(npz, "wb").write(corrupted)
    with pytest.raises(Exception):
        ckpt.restore(d, 1, _tpl(t), verify=True)


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    t = _tree()
    ckpt.save(d, 1, t)
    bad = dict(t)
    bad["params"] = {"w": jnp.zeros((9, 4)), "m": t["params"]["m"]}
    with pytest.raises(ckpt.CheckpointError):
        ckpt.restore(d, 1, _tpl(bad))


# ---------------------------------------------------------------------------
# resume exactness: 10 straight steps == 5 + restart + 5
# ---------------------------------------------------------------------------

def test_train_resume_exactness(tmp_path):
    from repro.launch.train import train_loop
    from repro.models import registry
    from repro.optim.adamw import AdamWConfig

    cfg = registry.get_smoke("xlstm-350m")
    kw = dict(batch=2, seq=32, save_every=5, seed=7,
              opt_cfg=AdamWConfig(moment_dtype="float32"))

    d1 = str(tmp_path / "a")
    _, losses_straight = train_loop(cfg, steps=10, ckpt_dir=d1, **kw)

    d2 = str(tmp_path / "b")
    train_loop(cfg, steps=5, ckpt_dir=d2, **kw)
    _, losses_resumed = train_loop(cfg, steps=10, ckpt_dir=d2, resume=True, **kw)

    np.testing.assert_allclose(losses_straight[5:], losses_resumed, rtol=1e-5)


def test_data_pipeline_step_indexed():
    a = lm_batch(0, 41, batch=2, seq=16, vocab=97)
    b = lm_batch(0, 41, batch=2, seq=16, vocab=97)
    c = lm_batch(0, 42, batch=2, seq=16, vocab=97)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert np.asarray(a["tokens"] != c["tokens"]).any()


# ---------------------------------------------------------------------------
# crash injection through the real driver (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_crash_and_relaunch(tmp_path):
    d = str(tmp_path / "run")
    env = dict(os.environ, PYTHONPATH=SRC)
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "xlstm-350m",
            "--smoke", "--batch", "2", "--seq", "32", "--ckpt-dir", d,
            "--save-every", "4", "--steps", "12"]
    # crash (no checkpoint!) at step 9 — last save was step 8
    p = subprocess.run(base + ["--simulate-crash-at", "9"], env=env,
                       capture_output=True, text=True, timeout=600)
    assert p.returncode == 137
    assert ckpt.latest_step(d) == 8
    # supervisor relaunches with --resume; run completes from step 8
    p2 = subprocess.run(base + ["--resume"], env=env, capture_output=True,
                        text=True, timeout=600)
    assert p2.returncode == 0, p2.stdout + p2.stderr
    assert "resumed from step 8" in p2.stdout
    assert ckpt.latest_step(d) == 12
