from repro.kernels.act_pool.ops import act_pool
from repro.kernels.act_pool.ref import act_pool_ref
