"""Fused 8-bit ReLU + p×p max-pool — ODIN's binary-domain add-on blocks.

The paper implements activation and pooling as CMOS logic *after* the
popcount (§IV-B.2): an 8-bit ReLU block and a 4:1 max-pool block, operating
in the binary domain (the hybrid boundary).  On TPU both are elementwise /
small-window VPU ops, so the natural mapping is one fused epilogue kernel
applied to the popcount (S_TO_B) output tile:

    y[b, i, j, c] = max_{2×2 window} clip(x, 0, 255)

Input is the int32 popcount-domain feature map NHWC; output is the pooled
uint8-range int32 map (values 0..255, the paper's 8-bit activations).  The
kernel blocks over (batch, channel) and keeps whole H×W planes in VMEM —
paper-scale planes (≤224×224) are ≤1.6 MB/block at bc=8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["act_pool_kernel", "act_pool_pallas_call"]


def _activate(x: jax.Array, act: str) -> jax.Array:
    """The paper's §IV-B.2 extensibility point: relu (clip) or 8-bit tanh —
    the 256-entry LUT a CMOS tanh block stores, in closed VPU form."""
    if act == "tanh":
        y = jnp.round(255.0 * jnp.tanh(x.astype(jnp.float32) / 64.0))
        return jnp.clip(y, 0, 255).astype(jnp.int32)
    return jnp.clip(x, 0, 255)                    # saturating 8-bit ReLU


def act_pool_kernel(x_ref, out_ref, *, pool: int, act: str = "relu",
                    pool_kind: str = "max"):
    """x int32 [1, H, W, bc] → out int32 [1, H/p, W/p, bc]."""
    x = x_ref[...]
    r = _activate(x, act)
    _, H, W, C = x.shape
    p = pool
    r = r.reshape(1, H // p, p, W // p, p, C)
    if pool_kind == "avg":                        # §IV-B.2 average pooling
        out_ref[...] = jnp.round(
            r.sum(axis=(2, 4)).astype(jnp.float32) / (p * p)
        ).astype(jnp.int32)
    else:
        out_ref[...] = r.max(axis=(2, 4))


def act_pool_pallas_call(
    x: jax.Array,            # int32 [B, H, W, C], H % pool == W % pool == 0
    *,
    pool: int = 2,
    block_c: int = 8,
    act: str = "relu",
    pool_kind: str = "max",
    interpret: bool = True,
) -> jax.Array:
    B, H, W, C = x.shape
    assert H % pool == 0 and W % pool == 0, (H, W, pool)
    assert C % block_c == 0, (C, block_c)
    kernel = functools.partial(act_pool_kernel, pool=pool, act=act,
                               pool_kind=pool_kind)
    return pl.pallas_call(
        kernel,
        grid=(B, C // block_c),
        in_specs=[pl.BlockSpec((1, H, W, block_c), lambda b, c: (b, 0, 0, c))],
        out_specs=pl.BlockSpec((1, H // pool, W // pool, block_c), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, H // pool, W // pool, C), jnp.int32),
        interpret=interpret,
    )(x)
