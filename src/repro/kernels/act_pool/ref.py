"""Pure-jnp oracle for the fused activation + pooling kernel."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["act_pool_ref"]


def act_pool_ref(x, pool: int = 2, act: str = "relu", pool_kind: str = "max"):
    """int32 NHWC → 8-bit activation then p×p pooling (stride p)."""
    B, H, W, C = x.shape
    if act == "tanh":
        r = jnp.clip(jnp.round(255.0 * jnp.tanh(x.astype(jnp.float32) / 64.0)),
                     0, 255).astype(jnp.int32)
    else:
        r = jnp.clip(x, 0, 255)
    r = r.reshape(B, H // pool, pool, W // pool, pool, C)
    if pool_kind == "avg":
        return jnp.round(r.sum(axis=(2, 4)).astype(jnp.float32) / (pool * pool)).astype(jnp.int32)
    return r.max(axis=(2, 4))
