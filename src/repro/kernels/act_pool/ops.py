"""jit'd wrapper for the fused activation+pool kernel (channel padding)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.act_pool.act_pool import act_pool_pallas_call

__all__ = ["act_pool"]


@functools.partial(jax.jit, static_argnames=("pool", "act", "pool_kind", "interpret"))
def act_pool(x: jax.Array, *, pool: int = 2, act: str = "relu",
             pool_kind: str = "max", interpret: bool = True) -> jax.Array:
    """int32 [B,H,W,C] → int32 [B,H/p,W/p,C]: 8-bit act then p×p pooling.

    ``act``: relu | tanh (8-bit LUT form); ``pool_kind``: max | avg — the
    paper's §IV-B.2 extensibility variants, same fused add-on block."""
    B, H, W, C = x.shape
    bc = 8 if C % 8 == 0 else 1
    return act_pool_pallas_call(x, pool=pool, block_c=bc, act=act,
                                pool_kind=pool_kind, interpret=interpret)
