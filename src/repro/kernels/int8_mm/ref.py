"""Pure-jnp oracle for the int8 GEMM + dequant kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["int8_mm_ref"]


def int8_mm_ref(a, w, scale_a, scale_w):
    """int8 [M,K] · int8 [K,N], exact int32 accumulate, fp32 dequant."""
    acc = jnp.matmul(a.astype(jnp.int32), w.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (scale_a.reshape(-1, 1) * scale_w.reshape(1, -1))
