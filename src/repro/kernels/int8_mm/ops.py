"""jit'd wrapper: quantize → int8 MXU GEMM → dequant.

``int8_matmul(x, w)`` is the end-to-end op: symmetric per-row quantization of
``x``, per-column of ``w`` (the paper's fixed-8-bit operand adjustment with
the finer granularity TPU int8 kernels conventionally use), then the fused
Pallas GEMM.  ``int8_mm_pallas`` is the raw quantized-operand entry point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.int8_mm.int8_mm import int8_mm_pallas_call

__all__ = ["int8_mm_pallas", "int8_matmul"]


def _pad(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def int8_mm_pallas(a, w, scale_a, scale_w, *, block_m=128, block_n=128,
                   block_k=128, interpret=True):
    """a int8 [M,K], w int8 [K,N], scales f32 [M]/[N] → f32 [M,N]."""
    M, K = a.shape
    _, N = w.shape
    bm, bn, bk = (min(block_m, M), min(block_n, N), min(block_k, K))
    a2 = _pad(_pad(a, 0, bm), 1, bk)
    w2 = _pad(_pad(w, 0, bk), 1, bn)
    sa = _pad(scale_a.reshape(-1, 1).astype(jnp.float32), 0, bm)
    sw = _pad(scale_w.reshape(1, -1).astype(jnp.float32), 1, bn)
    y = int8_mm_pallas_call(a2, w2, sa, sw, block_m=bm, block_n=bn, block_k=bk,
                            interpret=interpret)
    return y[:M, :N]


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_matmul(x: jax.Array, w: jax.Array, *, interpret: bool = True) -> jax.Array:
    """fp [M,K] @ fp [K,N] through symmetric int8 quantization (per-row/col)."""
    amax_x = jnp.maximum(jnp.abs(x).max(axis=1, keepdims=True), 1e-12)
    amax_w = jnp.maximum(jnp.abs(w).max(axis=0, keepdims=True), 1e-12)
    sx = (amax_x / 127.0).astype(jnp.float32)
    sw = (amax_w / 127.0).astype(jnp.float32)
    xq = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)
    wq = jnp.clip(jnp.round(w / sw), -127, 127).astype(jnp.int8)
    return int8_mm_pallas(xq, wq, sx[:, 0], sw[0, :], interpret=interpret)
