"""int8×int8→int32 MXU GEMM with fused dequant epilogue.

This is the *deployment* path for ODIN's expected-value surrogate
(DESIGN.md §2): the stochastic pipeline's expectation is an integer dot with
fixed scaling, and on TPU the right execution unit for an integer dot is the
MXU, not bit-ops.  The kernel:

* accumulates ``int8×int8`` partial products in an int32 VMEM scratch tile
  across the K grid axis (exact — no fp accumulation error),
* on the last K step applies the dequant epilogue
  ``y = acc · scale_a[m] · scale_w[n]`` and writes fp32.

Block sizes default to MXU-native 128×128×128 (multiples of the 128-lane /
128×128 systolic geometry); the interpret-mode tests sweep smaller blocks.

VMEM at defaults: a 16 KB + w 16 KB + acc 64 KB + out 64 KB ≪ budget; the
grid is (M/bm, N/bn, K/bk) with K innermost (sequential revisiting of the
same output tile — the standard Pallas accumulation pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["int8_mm_kernel", "int8_mm_pallas_call"]


def int8_mm_kernel(a_ref, w_ref, sa_ref, sw_ref, out_ref, acc_ref, *, n_k: int):
    """a int8 [bm,bk] · w int8 [bk,bn] → out f32 [bm,bn] (dequantized)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        scale = sa_ref[...] * sw_ref[...]                 # [bm,1]·[1,bn] → [bm,bn]
        out_ref[...] = acc_ref[...].astype(jnp.float32) * scale


def int8_mm_pallas_call(
    a: jax.Array,            # int8 [M, K]
    w: jax.Array,            # int8 [K, N]
    scale_a: jax.Array,      # f32 [M, 1] per-row activation scales
    scale_w: jax.Array,      # f32 [1, N] per-column weight scales
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    M, K = a.shape
    _, N = w.shape
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (M, N, K)
    n_k = K // block_k
    kernel = functools.partial(int8_mm_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(a, w, scale_a, scale_w)
