from repro.kernels.int8_mm.ops import int8_mm_pallas, int8_matmul
from repro.kernels.int8_mm.ref import int8_mm_ref
