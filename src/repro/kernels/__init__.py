# Pallas TPU kernels for the paper's compute hot-spots (validated with
# interpret=True on CPU; BlockSpecs sized for the TPU memory hierarchy):
#   sc_mac   — fused B→S → AND → MUX-tree → popcount stochastic GEMM (§IV-B.1)
#   int8_mm  — int8×int8→int32 MXU GEMM + dequant epilogue (expected surrogate)
#   act_pool — fused 8-bit ReLU + p×p max-pool (§IV-B.2 add-on logic blocks)
#   paged_attn — decode attention over the paged device KV block pool
from repro.kernels.sc_mac import sc_matmul_pallas
from repro.kernels.int8_mm import int8_mm_pallas, int8_matmul
from repro.kernels.act_pool import act_pool
from repro.kernels.paged_attn import paged_attention
