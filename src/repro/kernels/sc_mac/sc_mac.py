"""Fused stochastic-MAC Pallas kernel — ODIN's MAC array on the TPU VPU.

One kernel invocation performs, entirely in VMEM (DESIGN.md §2 "fused in
VMEM" — the headline beyond-paper optimization over ODIN's Compute-Partition
round trips):

    B→S (comparator SNG)  →  bit-parallel AND  →  MUX tree  →  popcount

for one ``[bm, bn]`` output tile against a ``[bk]`` slice of the contraction
axis.  The paper's PCRAM flow writes every intermediate stream back to the
Compute Partition (ANN_MUL: 1R+1W *per 256-bit product*); VMEM residency
removes all of that traffic.

TPU mapping notes
-----------------
* Streams are packed little-endian into ``W = stream_len/32`` uint32 words.
  The bit-parallel PCRAM row ops (PINATUBO double-row activation) become
  VPU bitwise AND/OR over vector registers.
* B→S is *comparator* SNG: bit ``i`` of the stream for value ``v`` is
  ``rank[i] < v``, where ``rank`` is the fixed permutation that defines the
  SRAM LUT contents.  Gathering LUT rows would be a dynamic gather (slow on
  TPU); the comparison form is a broadcast compare + weighted lane reduce,
  which is pure VPU work and produces *bit-identical* streams to the LUT
  (ops.py recovers the rank vector from the LUT so kernel == reference).
* The MUX tree runs ``log2(bk)`` levels of ``(S∧a)∨(S̄∧b)`` with one packed
  half-density select stream per level (the paper's pre-stored S/S' rows).
* Popcount is ``lax.population_count`` + lane sum — the paper's PISO+counter
  without the 256-cycle serialization (a PCRAM artifact, not ported).

Cross-tile accumulation over the K grid axis is *binary* (int32 adds of
per-tile popcounts) — ODIN's own hybrid binary/stochastic philosophy pushed
one level down.  With a single K tile (``bk == K̂``) the kernel is bit-exact
against ``repro.core.stochastic.sc_matmul``'s full MUX tree.

VMEM budget (defaults bm=bn=8, bk=256, W=8):
  sa 64 KB + sw 64 KB + prod 512 KB + cmp staging ≲ 2 MB  « 16 MB/core.
Production lane packing: the ``W=8`` minor axis underfills the 128-lane VPU;
Mosaic re-tiles ``(bn, W) → (8·16, 8)`` supertiles so lanes stay full — the
logical layout here is what the compiler relays out.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["sc_mac_kernel", "sc_mac_pallas_call"]


def _pack_last32(cmp_bits: jax.Array) -> jax.Array:
    """bool [..., 32] → uint32 [...]: little-endian bit packing via lane dot."""
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (cmp_bits.astype(jnp.uint32) * weights).sum(axis=-1, dtype=jnp.uint32)


def sc_mac_kernel(a_ref, w_ref, ranks_a_ref, ranks_w_ref, selects_ref, out_ref,
                  *, depth: int):
    """One grid step: out[bm, bn] (+)= popcount(MUXtree_bk(AND(SNG(a), SNG(w)))).

    a_ref: int32 [bm, bk]     — quantized activations (0..L-1; 0-padded)
    w_ref: int32 [bk, bn]     — quantized weights
    ranks_*_ref: int32 [W, 32] — SNG permutation ranks (decorrelated pair)
    selects_ref: uint32 [depth_max, W] — per-level MUX select streams
    out_ref: int32 [bm, bn]
    """
    k = pl.program_id(2)

    a = a_ref[...]                                        # [bm, bk]
    w = w_ref[...]                                        # [bk, bn]
    ranks_a = ranks_a_ref[...]                            # [W, 32]
    ranks_w = ranks_w_ref[...]

    # --- B→S: comparator SNG (bit-identical to the SRAM LUT rows) ----------
    # sa[m, kk, w] = pack_j( ranks_a[w, j] < a[m, kk] )
    sa = _pack_last32(a[:, :, None, None] > ranks_a[None, None])      # [bm, bk, W]
    sw = _pack_last32(w[:, :, None, None] > ranks_w[None, None])      # [bk, bn, W]

    # --- bit-parallel AND (ODIN ANN_MUL / PINATUBO double-row read) --------
    prod = sa[:, None, :, :] & jnp.transpose(sw, (1, 0, 2))[None, :, :, :]
    # prod: [bm, bn, bk, W]

    # --- MUX tree (ODIN ANN_ACC chain, balanced) ---------------------------
    x = prod
    for level in range(depth):
        sel = selects_ref[level]                                      # [W] uint32
        x = (sel & x[..., 0::2, :]) | (~sel & x[..., 1::2, :])
    # x: [bm, bn, 1, W]

    # --- popcount (ODIN S_TO_B, parallel) + hybrid binary accumulate -------
    pop = jax.lax.population_count(x[..., 0, :]).astype(jnp.int32).sum(axis=-1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += pop


def sc_mac_pallas_call(
    a: jax.Array,            # int32 [M, K̂]  (padded: M % bm == 0, K̂ % bk == 0)
    w: jax.Array,            # int32 [K̂, N]
    ranks_a: jax.Array,      # int32 [W, 32]
    ranks_w: jax.Array,      # int32 [W, 32]
    selects: jax.Array,      # uint32 [depth_max, W]
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    interpret: bool = True,
) -> jax.Array:
    """Launch the kernel over a (M/bm, N/bn, K̂/bk) grid.  Returns int32 [M, N].

    Semantics: ``out = Σ_ktiles popcount(MUXtree_bk(tile products))`` — pop
    units of per-tile ``K̂_t = block_k``.  Single K tile ⇒ exact full tree.
    """
    M, K = a.shape
    _, N = w.shape
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (M, N, K)
    depth = int(np.log2(block_k))
    assert 1 << depth == block_k, f"block_k must be a power of two, got {block_k}"
    assert selects.shape[0] >= depth, (selects.shape, depth)
    n_k = K // block_k

    kernel = functools.partial(sc_mac_kernel, depth=depth)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec(ranks_a.shape, lambda i, j, k: (0, 0)),
            pl.BlockSpec(ranks_w.shape, lambda i, j, k: (0, 0)),
            pl.BlockSpec(selects.shape, lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(a, w, ranks_a, ranks_w, selects)
