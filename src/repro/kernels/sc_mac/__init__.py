from repro.kernels.sc_mac.ops import sc_matmul_pallas
from repro.kernels.sc_mac.ref import sc_matmul_tree_ref, sc_matmul_hybrid_ref, ranks_from_lut
