"""jit'd public wrapper for the fused stochastic-MAC kernel.

``sc_matmul_pallas`` keeps the same operand signature as the jnp reference
(`core.stochastic.sc_matmul`): packed LUTs in, popcounts out.  It recovers
the comparator-SNG rank vectors from the LUTs (bit-exact round trip) and
dispatches:

* ``K̂ ≤ max_tree_k``   — single K tile, full MUX tree: output int32, equal
  bit-for-bit to ``sc_matmul``.
* ``K̂ > max_tree_k``   — tiled hybrid (per-tile tree + binary accumulate),
  rescaled to full-tree popcount units (× K̂_t/K̂) so callers see one scale;
  output float32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stochastic as sc
from repro.kernels.sc_mac.ref import ranks_from_lut
from repro.kernels.sc_mac.sc_mac import sc_mac_pallas_call

__all__ = ["sc_matmul_pallas"]


def _pad_axis(x, axis: int, mult: int):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "interpret", "block_m", "block_n", "max_tree_k"),
)
def sc_matmul_pallas(
    a_q: jax.Array,          # uint8/int32 [M, K]
    w_q: jax.Array,          # uint8/int32 [K, N]
    lut_a: jax.Array,
    lut_w: jax.Array,
    selects: jax.Array,
    spec: sc.StreamSpec = sc.StreamSpec(),
    *,
    interpret: bool = True,
    block_m: int = 8,
    block_n: int = 8,
    max_tree_k: int = 2048,
) -> jax.Array:
    """Fused ODIN MAC array.  See module docstring for the two regimes."""
    M, K = a_q.shape
    _, N = w_q.shape
    khat = 1 << sc.tree_depth(K)

    ra = ranks_from_lut(lut_a, spec.n_levels)
    rw = ranks_from_lut(lut_w, spec.n_levels)

    a = _pad_axis(a_q.astype(jnp.int32), 0, block_m)
    w = _pad_axis(w_q.astype(jnp.int32), 1, block_n)

    if khat <= max_tree_k:
        block_k = khat
        a = _pad_axis(a, 1, block_k)
        w = _pad_axis(w, 0, block_k)
        out = sc_mac_pallas_call(
            a, w, ra, rw, selects,
            block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret,
        )
        return out[:M, :N]

    block_k = max_tree_k
    a = _pad_axis(a, 1, block_k)
    w = _pad_axis(w, 0, block_k)
    out = sc_mac_pallas_call(
        a, w, ra, rw, selects,
        block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret,
    )
    # rescale per-tile popcount units (K̂_t) to full-tree units (K̂)
    return out[:M, :N].astype(jnp.float32) * (block_k / khat)
