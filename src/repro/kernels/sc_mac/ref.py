"""Pure-jnp oracles for the sc_mac kernel.

Two semantics, matching the kernel's two operating regimes:

* ``sc_matmul_tree_ref``   — single-K-tile full MUX tree.  Bit-identical to
  ``repro.core.stochastic.sc_matmul`` (re-derivation, used as the kernel
  oracle so the test does not compare a function with itself).
* ``sc_matmul_hybrid_ref`` — K tiled into ``block_k`` chunks; each chunk is
  reduced by its own depth-log2(block_k) MUX tree and popcounted; chunk
  popcounts accumulate in int32 (the kernel's cross-tile binary accumulate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stochastic as sc

__all__ = ["sc_matmul_tree_ref", "sc_matmul_hybrid_ref", "ranks_from_lut"]


def ranks_from_lut(lut: jax.Array, n_levels: int) -> jax.Array:
    """Recover the SNG permutation ranks from a packed LUT.

    Bit ``i`` is set in rows ``v > rank_i`` ⇒ column popcount over rows is
    ``(L-1) - min(rank_i, L-1)``.  Ranks ≥ L-1 are indistinguishable from
    L-1 for every comparison with v < L, so the capped recovery is exact for
    stream generation.  Returned as int32 ``[W, 32]`` (word, bit) layout.
    """
    bits = sc.unpack_bits(lut)                       # [L, stream_len]
    counts = bits.sum(axis=0).astype(jnp.int32)      # [stream_len]
    ranks = (n_levels - 1) - counts
    W = lut.shape[-1]
    return ranks.reshape(W, 32)


def _streams(values: jax.Array, ranks_w32: jax.Array) -> jax.Array:
    """Comparator SNG: int [..] → packed uint32 [.., W] (same math as kernel)."""
    cmp = values[..., None, None] > ranks_w32        # [.., W, 32]
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (cmp.astype(jnp.uint32) * weights).sum(axis=-1, dtype=jnp.uint32)


def sc_matmul_tree_ref(a_q, w_q, lut_a, lut_w, selects, spec: sc.StreamSpec):
    """Full-tree oracle (== core.stochastic.sc_matmul, independent derivation)."""
    ra = ranks_from_lut(lut_a, spec.n_levels)
    rw = ranks_from_lut(lut_w, spec.n_levels)
    sa = _streams(a_q.astype(jnp.int32), ra)                         # [M, K, W]
    sw = _streams(w_q.astype(jnp.int32), rw)                         # [K, N, W]
    prod = sa[:, None] & jnp.moveaxis(sw, 0, 1)[None]                # [M, N, K, W]
    acc = sc.sc_mac_tree(prod, selects)
    return sc.s_to_b(acc)


def sc_matmul_hybrid_ref(a_q, w_q, lut_a, lut_w, selects, spec: sc.StreamSpec,
                         block_k: int):
    """Tiled-hybrid oracle: per-K-tile MUX subtree + int32 popcount accumulate."""
    M, K = a_q.shape
    _, N = w_q.shape
    pad = (-K) % block_k
    a_p = jnp.pad(a_q.astype(jnp.int32), ((0, 0), (0, pad)))
    w_p = jnp.pad(w_q.astype(jnp.int32), ((0, pad), (0, 0)))
    Kp = K + pad
    out = jnp.zeros((M, N), jnp.int32)
    depth = int(np.log2(block_k))
    assert 1 << depth == block_k
    ra = ranks_from_lut(lut_a, spec.n_levels)
    rw = ranks_from_lut(lut_w, spec.n_levels)
    for t in range(Kp // block_k):
        a_t = a_p[:, t * block_k:(t + 1) * block_k]
        w_t = w_p[t * block_k:(t + 1) * block_k]
        sa = _streams(a_t, ra)
        sw = _streams(w_t, rw)
        prod = sa[:, None] & jnp.moveaxis(sw, 0, 1)[None]            # [M,N,bk,W]
        x = prod
        for level in range(depth):
            sel = selects[level]
            x = (sel & x[..., 0::2, :]) | (~sel & x[..., 1::2, :])
        out = out + jax.lax.population_count(x[..., 0, :]).astype(jnp.int32).sum(-1)
    return out
