"""Pure-jnp oracle for the paged decode-attention kernel.

Gathers every table page from the pool into a dense ``[B, n_pages·bs]``
view and runs a masked softmax — O(max_len) memory per call, which is
exactly what the kernel avoids; this exists to pin the kernel's semantics
(tests) and as a shape-checked fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["paged_attn_ref"]

NEG_INF = -1e30


def paged_attn_ref(q, k_pool, v_pool, tables, lengths, *, window: int = 0,
                   kv_scale=None, q_len: int = 1):
    """q [B,Hkv,Q·G,D], pools [N,bs,Hkv,D], tables [B,P], lengths [B]
    → [B,Hkv,Q·G,D].

    Row ``q·G + g`` of the query tile is query token ``q`` at absolute
    position ``lengths - q_len + q`` (causally masked per row); ``q_len=1``
    is plain decode.
    """
    B, Hkv, QG, D = q.shape
    bs = k_pool.shape[1]
    P = tables.shape[1]
    G = QG // q_len
    k = k_pool[tables].reshape(B, P * bs, Hkv, D).astype(jnp.float32)
    v = v_pool[tables].reshape(B, P * bs, Hkv, D).astype(jnp.float32)
    if kv_scale is not None:
        k = k * (1.0 / kv_scale)
        v = v * (1.0 / kv_scale)
    s = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32), k) / np.sqrt(D)
    pos = jnp.arange(P * bs, dtype=jnp.int32)[None, :]          # [1, P·bs]
    q_pos = (lengths[:, None] - q_len
             + jnp.arange(QG, dtype=jnp.int32)[None, :] // G)   # [B, Q·G]
    ok = pos[:, None, :] <= q_pos[..., None]                    # [B, Q·G, P·bs]
    if window:
        ok = ok & (pos[:, None, :] > q_pos[..., None] - window)
    okb = ok[:, None, :, :]                                     # [B,1,Q·G,P·bs]
    s = jnp.where(okb, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.where(okb, jnp.exp(s - m), 0.0)                     # exact 0 when empty
    l = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v) / jnp.maximum(l, 1e-30)
    return o.astype(q.dtype)
