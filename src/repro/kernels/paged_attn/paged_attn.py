"""Paged decode-attention Pallas kernel: attend over a device block pool.

The physical KV store is a single pool ``[n_blocks, block_size, H_kv, d_head]``
shared by every serving slot; each slot owns a *block table* mapping its
logical pages to pool blocks.  The kernel indexes the table **inside** the
compiled step, so decode reads K/V blocks in place — no dense
``[slots, max_len]`` live cache, no gather materialization; device KV memory
scales with ``n_blocks·block_size`` (≈ active tokens) instead of
``slots × max_len``.

Layout (the standard TPU paged-attention shape):

* grid ``(B, H_kv, n_pages)`` with the page axis innermost — the online
  softmax state (m, l, acc) lives in VMEM scratch carried across pages;
* ``lengths [B]`` and ``tables [B, n_pages]`` are **scalar-prefetched**: the
  K/V BlockSpec index maps read ``tables[b, i]`` to pull page ``i`` of
  sequence ``b`` from the pool, one ``[block_size, d_head]`` tile per step
  (the Pallas pipeline turns those into the HBM→VMEM block DMAs);
* pages past a sequence's length — and, under a sliding window, pages wholly
  below it — are skipped via ``pl.when``; partially-valid pages mask by
  absolute position, so stale rows from a block's previous owner are
  invisible;
* int8 pools (the ODIN fixed-8-bit KV working set) dequantize in-kernel:
  the kernel reads half the bytes per page and rescales after the load.

Multi-token queries (``q_len > 1``, speculative verify): the query tile packs
``Q`` in-flight tokens — query row ``q·G + g`` sits at absolute position
``length - Q + q`` and is causally masked against the page axis per row, so
one kernel pass scores a whole draft (each draft token sees the committed
prefix *and* the earlier draft rows, which its forward already wrote into the
slot's tail blocks).  ``q_len == 1`` reduces exactly to the decode case.

Per-tile VMEM at the ``block_size=16, d_head=128`` default: q Q·1 KB + k/v
2×4 KB (int8) + acc/m/l ≈ Q·1 KB ≪ budget; arithmetic is one
``[Q·G, bs]·[bs, D]`` MXU pass per page.  ``interpret=True`` runs the same
kernel on CPU (tier-1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attn_kernel", "paged_attn_pallas_call"]

NEG_INF = -1e30


def paged_attn_kernel(lengths_ref, tables_ref, q_ref, k_ref, v_ref, o_ref,
                      m_ref, l_ref, acc_ref, *, block_size: int, n_pages: int,
                      window: int, scale: float, kv_scale, q_len: int,
                      n_groups: int):
    """One (sequence b, kv-head h, page i) grid step of online-softmax GQA.

    q_ref [1,1,Q·G,D] · k_ref/v_ref [1,bs,1,D] (page ``tables[b, i]`` of the
    pool) → o_ref [1,1,Q·G,D]; m/l/acc scratch carry the softmax state over
    the page axis.  Query row ``q·G + g`` is query token ``q`` at absolute
    position ``length - Q + q`` (``Q = q_len``; Q == 1 is plain decode).
    """
    b, i = pl.program_id(0), pl.program_id(2)
    length = lengths_ref[b]

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Page overlaps the union of the rows' visible ranges?  The last query
    # sits at length-1; the first at length-Q, seeing back to length-Q-window.
    live = i * block_size < length
    if window:
        live = jnp.logical_and(
            live, (i + 1) * block_size > length - q_len - window + 1)

    @pl.when(live)
    def _page():
        q = q_ref[0, 0].astype(jnp.float32)                  # [Q·G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bs, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if kv_scale is not None:                             # int8 pool dequant
            k = k * (1.0 / kv_scale)
            v = v * (1.0 / kv_scale)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [Q·G, bs]
        pos = i * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1)
        # per-row causal limit: row q·G+g is the query at length - Q + q
        q_pos = length - q_len + jax.lax.broadcasted_iota(
            jnp.int32, (q_len * n_groups, 1), 0) // n_groups
        ok = pos <= q_pos
        if window:
            ok = jnp.logical_and(ok, pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        # mask p, not just s: a fully-masked row (q_pos < 0, a query tile
        # longer than the sequence) has m_new == NEG_INF and exp(s - m_new)
        # would resurrect every masked column as exp(0) = 1
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finish():
        # length == 0 (idle slot) leaves l at 0 → output 0, never NaN
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attn_pallas_call(
    q: jax.Array,            # [B, H_kv, Q·G, D] current-token queries
    k_pool: jax.Array,       # [n_blocks, block_size, H_kv, D] physical store
    v_pool: jax.Array,       # [n_blocks, block_size, H_kv, D]
    tables: jax.Array,       # int32 [B, n_pages] pool block ids per slot page
    lengths: jax.Array,      # int32 [B] visible tokens (incl. all Q current)
    *,
    window: int = 0,
    kv_scale=None,           # pool is int8 fixed-point with this scale
    q_len: int = 1,          # Q query tokens packed per sequence
    interpret: bool = True,
) -> jax.Array:
    B, Hkv, QG, D = q.shape
    if QG % q_len:
        raise ValueError(f"query tile {QG} not a multiple of q_len {q_len}")
    bs = k_pool.shape[1]
    n_pages = tables.shape[1]
    scale = 1.0 / np.sqrt(D)
    kernel = functools.partial(
        paged_attn_kernel, block_size=bs, n_pages=n_pages, window=window,
        scale=scale, kv_scale=kv_scale, q_len=q_len, n_groups=QG // q_len)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, QG, D), lambda b, h, i, lens, tabs: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, i, lens, tabs: (tabs[b, i], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, i, lens, tabs: (tabs[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, QG, D),
                               lambda b, h, i, lens, tabs: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((QG, 1), jnp.float32),     # m: running max
            pltpu.VMEM((QG, 1), jnp.float32),     # l: running denominator
            pltpu.VMEM((QG, D), jnp.float32),     # acc: running numerator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, QG, D), q.dtype),
        interpret=interpret,
    )(lengths, tables, q, k_pool, v_pool)
