"""jit'd entry point for paged decode attention.

``paged_attention(q, k_pool, v_pool, tables, lengths)`` is the op the serving
decode path calls per layer: GQA head grouping, kernel dispatch, and the
interpret-mode fallback so tier-1 tests run on CPU.  ``use_kernel=False``
routes to the pure-jnp oracle (ref.py) for debugging.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attn.paged_attn import paged_attn_pallas_call
from repro.kernels.paged_attn.ref import paged_attn_ref

__all__ = ["paged_attention"]


@functools.partial(jax.jit, static_argnames=("window", "kv_scale",
                                             "use_kernel", "interpret"))
def paged_attention(q, k_pool, v_pool, tables, lengths, *, window: int = 0,
                    kv_scale=None, use_kernel: bool = True,
                    interpret=None) -> jax.Array:
    """q [B, H, D] against pools [N, bs, H_kv, D] via tables [B, P] → [B, H, D].

    ``lengths [B]`` counts visible tokens per sequence (the current token's
    K/V must already be written at row ``lengths-1``).  ``kv_scale`` set ⇒
    pools hold fixed-point int8 (values/kv_scale).  ``interpret=None`` picks
    compiled on TPU, interpreter everywhere else.
    """
    B, H, D = q.shape
    Hkv = k_pool.shape[2]
    if H % Hkv:
        raise ValueError(f"n_heads {H} not a multiple of n_kv_heads {Hkv}")
    qg = q.reshape(B, Hkv, H // Hkv, D)
    tables = tables.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    if use_kernel:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        o = paged_attn_pallas_call(qg, k_pool, v_pool, tables, lengths,
                                   window=window, kv_scale=kv_scale,
                                   interpret=interpret)
    else:
        o = paged_attn_ref(qg, k_pool, v_pool, tables, lengths,
                           window=window, kv_scale=kv_scale)
    return o.reshape(B, H, D)
