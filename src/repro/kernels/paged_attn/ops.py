"""jit'd entry point for paged decode attention.

``paged_attention(q, k_pool, v_pool, tables, lengths)`` is the op the serving
decode path calls per layer: GQA head grouping, kernel dispatch, and the
interpret-mode fallback so tier-1 tests run on CPU.  ``use_kernel=False``
routes to the pure-jnp oracle (ref.py) for debugging.

``q`` may carry a small leading query axis (``[B, Q, H, D]``, the speculative
verify tile): the Q tokens are packed into the kernel's query tile and
causally masked per row — one dispatch scores a whole draft.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attn.paged_attn import paged_attn_pallas_call
from repro.kernels.paged_attn.ref import paged_attn_ref

__all__ = ["paged_attention"]


@functools.partial(jax.jit, static_argnames=("window", "kv_scale",
                                             "use_kernel", "interpret"))
def paged_attention(q, k_pool, v_pool, tables, lengths, *, window: int = 0,
                    kv_scale=None, use_kernel: bool = True,
                    interpret=None) -> jax.Array:
    """q [B, H, D] (decode) or [B, Q, H, D] (Q-token verify) against pools
    [N, bs, H_kv, D] via tables [B, P] → output of q's shape.

    ``lengths [B]`` counts visible tokens per sequence *including every query
    token* (each query's K/V must already be written; query ``j`` of Q sits
    at absolute position ``lengths - Q + j`` and attends causally).
    ``kv_scale`` set ⇒ pools hold fixed-point int8 (values/kv_scale).
    ``interpret=None`` picks compiled on TPU, interpreter everywhere else.
    """
    if q.ndim == 3:
        B, H, D = q.shape
        Q = 1
    else:
        B, Q, H, D = q.shape
    Hkv = k_pool.shape[2]
    if H % Hkv:
        raise ValueError(f"n_heads {H} not a multiple of n_kv_heads {Hkv}")
    G = H // Hkv
    if q.ndim == 3:
        qt = q.reshape(B, Hkv, G, D)
    else:
        # pack the Q tokens into the query tile: row q·G + g
        # [B, Q, Hkv, G, D] → [B, Hkv, Q, G, D] → [B, Hkv, Q·G, D]
        qt = q.reshape(B, Q, Hkv, G, D).transpose(0, 2, 1, 3, 4)
        qt = qt.reshape(B, Hkv, Q * G, D)
    tables = tables.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    if use_kernel:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        o = paged_attn_pallas_call(qt, k_pool, v_pool, tables, lengths,
                                   window=window, kv_scale=kv_scale,
                                   q_len=Q, interpret=interpret)
    else:
        o = paged_attn_ref(qt, k_pool, v_pool, tables, lengths,
                           window=window, kv_scale=kv_scale, q_len=Q)
    if q.ndim == 3:
        return o.reshape(B, H, D)
    return o.reshape(B, Hkv, Q, G, D).transpose(0, 2, 1, 3, 4).reshape(B, Q, H, D)
