from repro.kernels.paged_attn.ops import paged_attention
from repro.kernels.paged_attn.ref import paged_attn_ref
