"""Synthetic open-loop request generators for serving scenarios.

Arrivals follow a Poisson process (optionally bursty: ``burst`` requests per
arrival event); prompt and generation lengths draw from discrete buckets.
Bucketed lengths are deliberate: prefill chunk shapes stay bounded (each
distinct chunk length traces one executable) while still exercising the
mixed-length behavior that separates continuous batching from the static
loop — short-generation requests retire early and their slots re-admit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.scheduler import Request

__all__ = ["WorkloadSpec", "SCENARIOS", "poisson_arrivals", "make_requests"]


@dataclass(frozen=True)
class WorkloadSpec:
    n_requests: int = 16
    rate: float = 50.0                    # arrival events per second
    burst: int = 1                        # requests per arrival event
    prompt_buckets: Tuple[int, ...] = (16, 32)
    prompt_weights: Optional[Tuple[float, ...]] = None
    gen_buckets: Tuple[int, ...] = (8, 32)
    gen_weights: Optional[Tuple[float, ...]] = None
    # shared system prompt: every request's prompt starts with the same
    # ``shared_prefix`` tokens (drawn once per seed), split over
    # ``share_groups`` distinct system prompts round-robin — the workload
    # prefix sharing dedups.  ``prompt_buckets`` then sizes the unique tail.
    shared_prefix: int = 0
    share_groups: int = 1
    # repetition-heavy prompts: >0 tiles a per-request random pattern of this
    # period to the bucket length (structured/templated traffic — the
    # workload n-gram speculation feeds on; greedy continuations of periodic
    # prompts fall into cycles the draft match predicts)
    pattern_period: int = 0
    # failure-semantics schedules (all off by default — and drawn AFTER the
    # length/arrival draws, so enabling them never perturbs the token streams
    # an existing seed produces): per-request deadlines sampled from buckets
    # of seconds-after-arrival, a queue-admission timeout, and client
    # cancellations — each request cancels with prob ``cancel_rate`` at
    # ``arrival + cancel_after * deadline`` (or ``cancel_after`` seconds when
    # no deadline is set)
    deadline_buckets: Optional[Tuple[float, ...]] = None
    deadline_weights: Optional[Tuple[float, ...]] = None
    queue_timeout: Optional[float] = None
    cancel_rate: float = 0.0
    cancel_after: float = 0.5
    # multi-tenant traffic: >0 assigns tenant ids "t0".."t{n-1}" round-robin
    # by request index.  The assignment consumes NO rng draws, so enabling
    # tenants never perturbs the token streams an existing seed produces.
    n_tenants: int = 0


# Scenario presets (lengths are smoke-scale; scale up for full configs).
SCENARIOS: Dict[str, WorkloadSpec] = {
    # uniform lengths, steady arrivals — the static loop's best case
    "steady": WorkloadSpec(prompt_buckets=(32,), gen_buckets=(16,)),
    # mixed generation lengths — finished slots must re-admit to keep busy
    "mixed": WorkloadSpec(prompt_buckets=(16, 32), gen_buckets=(4, 16, 48),
                          gen_weights=(0.4, 0.35, 0.25)),
    # bursty arrivals of long-tail requests — exercises queueing + preemption
    "bursty": WorkloadSpec(burst=4, rate=10.0, prompt_buckets=(16, 48),
                           gen_buckets=(8, 64), gen_weights=(0.7, 0.3)),
    # shared system prompt + unique user tails — the prefix-sharing workload
    "shared": WorkloadSpec(shared_prefix=96, prompt_buckets=(8, 16),
                           gen_buckets=(8, 16)),
    # periodic prompts + long generations — repetition-heavy traffic where
    # greedy continuations cycle and n-gram speculation accepts deep drafts
    "repetitive": WorkloadSpec(pattern_period=8, prompt_buckets=(32,),
                               gen_buckets=(160,)),
    # impatient bursty clients: tight bursts, deadlines of the same order as
    # a request's service time, and a cancellation stream — the robustness
    # workload (queue expiry, mid-run aborts, degradation under pressure)
    "flaky": WorkloadSpec(burst=4, rate=20.0, prompt_buckets=(16, 48),
                          gen_buckets=(8, 64), gen_weights=(0.7, 0.3),
                          deadline_buckets=(0.5, 2.0, 8.0),
                          deadline_weights=(0.3, 0.4, 0.3),
                          queue_timeout=4.0, cancel_rate=0.15),
}


def poisson_arrivals(rng: np.random.Generator, n: int, rate: float,
                     burst: int = 1) -> np.ndarray:
    """[n] arrival offsets (seconds): Poisson events of ``burst`` requests."""
    n_events = -(-n // burst)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), n_events)
    times = np.cumsum(gaps)
    return np.repeat(times, burst)[:n]


def _draw(rng, buckets, weights, n):
    p = None if weights is None else np.asarray(weights) / np.sum(weights)
    return rng.choice(np.asarray(buckets), size=n, p=p)


def make_requests(cfg: ModelConfig, spec: WorkloadSpec, seed: int = 0,
                  start_rid: int = 0) -> List[Request]:
    """Build ``spec.n_requests`` synthetic requests for ``cfg``.

    With ``spec.shared_prefix > 0``, request ``i`` prepends system prompt
    ``i % spec.share_groups`` (each ``shared_prefix`` tokens, drawn once) to
    its unique ``prompt_buckets``-sized tail.
    """
    rng = np.random.default_rng(seed)
    arrivals = poisson_arrivals(rng, spec.n_requests, spec.rate, spec.burst)
    plens = _draw(rng, spec.prompt_buckets, spec.prompt_weights, spec.n_requests)
    gens = _draw(rng, spec.gen_buckets, spec.gen_weights, spec.n_requests)
    lead = lambda n: (cfg.n_codebooks, n) if cfg.n_codebooks > 1 else (n,)
    systems = [rng.integers(0, cfg.vocab, size=lead(spec.shared_prefix),
                            dtype=np.int32)
               for _ in range(spec.share_groups)] if spec.shared_prefix else []
    out = []
    for i in range(spec.n_requests):
        if spec.pattern_period:
            pat = rng.integers(0, cfg.vocab, size=lead(spec.pattern_period),
                               dtype=np.int32)
            reps = -(-int(plens[i]) // spec.pattern_period)
            tiles = (1,) * (pat.ndim - 1) + (reps,)
            prompt = np.tile(pat, tiles)[..., :int(plens[i])]
        else:
            prompt = rng.integers(0, cfg.vocab, size=lead(int(plens[i])),
                                  dtype=np.int32)
        if systems:
            prompt = np.concatenate(
                [systems[i % spec.share_groups], prompt], axis=-1)
        out.append(Request(rid=start_rid + i, prompt=prompt,
                           max_new=int(gens[i]), arrival=float(arrivals[i])))
    if spec.n_tenants:
        # round-robin by index, no rng: seeds stay byte-identical
        for i, req in enumerate(out):
            req.tenant = f"t{i % spec.n_tenants}"
    # failure-semantics draws come last: legacy seeds consume an identical
    # rng stream, so streams stay byte-identical with these features off
    if spec.deadline_buckets:
        dls = _draw(rng, spec.deadline_buckets, spec.deadline_weights,
                    spec.n_requests)
        for req, d in zip(out, dls):
            req.deadline = req.arrival + float(d)
    if spec.queue_timeout is not None:
        for req in out:
            req.queue_timeout = float(spec.queue_timeout)
    if spec.cancel_rate > 0.0:
        flips = rng.random(spec.n_requests) < spec.cancel_rate
        for req, flip in zip(out, flips):
            if flip:
                horizon = ((req.deadline - req.arrival) * spec.cancel_after
                           if req.deadline is not None else spec.cancel_after)
                req.cancel_at = req.arrival + float(horizon)
    return out
