"""Paged KV-cache block pool: fixed-size blocks, block tables, swap store.

Two tiers, mirroring the classic paged-KV serving design:

* :class:`BlockPool` — a pure-accounting, **refcounted** free-list allocator
  over fixed-size token blocks.  One pool instance budgets the *device* KV
  memory — for paged-capable attention families that budget IS the physical
  store (the ``k_pool/v_pool`` leaves the paged-attention kernel indexes);
  for the remaining dense families (MLA latents, sliding-window rings) it
  meters the ``[B_slots, S_max]`` live-cache rows.  A second instance inside
  :class:`PagedKVStore` budgets the swap tier.  Requests hold their blocks in
  a per-sequence block table (``Request.block_table``) and grow it one block
  at a time as decode crosses block boundaries; admission control and
  preemption both key off this pool.  Refcounts let tables *alias* blocks
  (prefix sharing: ``share`` attaches, ``fork`` is the copy-on-write
  primitive, release happens at refcount 0) and let the scheduler's prefix
  cache retain prompt chains past their request's lifetime, evicted through
  the ``reclaimer`` hook only under allocation pressure.

* :class:`PagedKVStore` — block-granular storage for *preempted* sequences.
  Two leaf families:

  - **pool leaves** (``k_pool/v_pool`` — the physical paged store): swap is a
    block-table handoff — ``swap_out`` copies the request's device blocks
    (by id) into swap blocks, O(cached_len) data and no slot-shaped
    reshuffle; ``swap_in`` copies them back into whatever device blocks the
    scheduler hands the resumed request.
  - **dense sequence leaves** (``k/v`` rings, MLA ``c_kv/k_rope``): the slot's
    cache rows scatter/gather through ``[n_blocks, L, bs, ...]`` buffers as
    before.

  Leaves without a sequence axis (SSM/xLSTM recurrent states, position
  vectors) are O(1) per request and ride along in the :class:`SwapTicket`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.attention import POOL_LEAVES
from repro.serving.faults import SwapCopyError
from repro.serving.trace import NULL_TRACER

__all__ = ["BlockPool", "PagedKVStore", "SwapTicket"]

# Dense cache leaves with a sequence axis (axis 2 of the stacked [L, B, S, ...]
# layout) — the same key-name convention launch/specs.py's cache_pspecs uses.
# POOL_LEAVES (k_pool/v_pool) are the paged physical store: [L, N+1, bs, ...],
# no slot axis.
SEQ_LEAVES = ("k", "v", "c_kv", "k_rope")


def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path[-1:]).strip("[]'\"")


def _leaf_key(path) -> str:
    return jax.tree_util.keystr(path)


class BlockPool:
    """Refcounted free-list allocator over ``n_blocks`` fixed-size token blocks.

    All-or-nothing ``alloc`` (returns None when the request cannot be met in
    full), double-free checked ``free``.  Pure bookkeeping — no arrays.

    Blocks carry a **refcount** so block tables may alias the same physical
    block (prefix sharing): ``alloc`` hands out blocks at refcount 1,
    ``share`` adds a claim, ``free`` drops one — the block returns to the
    free list only when its last claim is gone.  ``fork`` is the
    copy-on-write primitive: trading a claim on a shared block for a fresh
    exclusive block (the caller copies the contents before writing).

    A ``reclaimer`` (duck-typed: ``reclaimable() -> int`` and
    ``reclaim(n) -> int``) may be attached by a block cache that retains
    otherwise-unreferenced blocks (the scheduler's prefix cache); ``alloc``
    asks it to release blocks before failing, so cached prefixes are evicted
    lazily under allocation pressure instead of eagerly on request
    completion.

    **PCRAM reliability** (PR 10): the pool is the physical PCRAM, so it
    carries per-block *write-endurance* accounting — ``record_writes`` bumps
    a per-block wear counter (rows written) and a last-write wall clock, a
    host-side mirror of device writes derived from the scheduler/StepPlan
    bookkeeping.  ``policy="min_wear"`` orders the free list by a
    wear-then-age score so allocation always picks the least-worn block
    (ties: oldest-freed first), narrowing the wear distribution vs. the seed
    LIFO order.  Blocks may be *retired* (bad-block management):
    ``retire_free`` pulls a free block out of circulation, ``retire_used``
    swaps a referenced block for a fresh one (the caller copies contents and
    remaps tables).  Retired blocks shrink :attr:`usable_blocks`; the
    conservation law becomes free ∪ referenced ∪ retired == pool.
    """

    def __init__(self, n_blocks: int, block_size: int, *,
                 policy: str = "lifo", endurance_budget: Optional[int] = None):
        if n_blocks < 0 or block_size <= 0:
            raise ValueError((n_blocks, block_size))
        if policy not in ("lifo", "min_wear"):
            raise ValueError(f"unknown alloc policy {policy!r}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.policy = policy
        self.endurance_budget = endurance_budget
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._refs: Dict[int, int] = {}
        self.reclaimer = None
        # per-block endurance accounting (host mirror of device writes)
        self.wear = np.zeros(n_blocks, np.int64)        # cache rows written
        self.last_write = np.full(n_blocks, -1.0)       # wall clock, -1 ⇒ never
        self.total_writes = 0                           # monotone row counter
        self.retired: set = set()                       # bad blocks, out of play
        self._freed_seq = np.zeros(n_blocks, np.int64)  # age tiebreak for min_wear
        self._seq = 0
        self._free_dirty = False
        # armed fault injection: the next N non-empty allocs fail (None
        # return, pool untouched) regardless of headroom — exercises every
        # caller's exhaustion fallback at moments the headroom math says are
        # impossible
        self._forced_failures = 0
        # structured-event recorder (repro.serving.trace); the engine swaps
        # in its Tracer — the no-op default keeps every emit site free
        self.tracer = NULL_TRACER

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return len(self._refs)

    @property
    def available_blocks(self) -> int:
        """Blocks an ``alloc`` could obtain right now: free + reclaimable."""
        extra = self.reclaimer.reclaimable() if self.reclaimer is not None else 0
        return len(self._free) + extra

    @property
    def usable_blocks(self) -> int:
        """Total capacity net of retired bad blocks — what admission and
        horizon grants must size against once retirement shrinks the pool."""
        return self.n_blocks - len(self.retired)

    def refs(self, bid: int) -> int:
        """Current claim count on block ``bid`` (0 ⇒ free or out of range)."""
        return self._refs.get(bid, 0)

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` cache rows."""
        return -(-max(n_tokens, 0) // self.block_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` block ids at refcount 1, or None (no change) if
        unavailable even after asking the reclaimer to evict.  Eviction is
        only asked for when it can actually cover the shortfall — a doomed
        allocation must not wipe the resident prefix cache for nothing."""
        if n < 0:
            raise ValueError(n)
        if n > 0 and self._forced_failures:
            self._forced_failures -= 1
            if self.tracer.enabled:
                self.tracer.instant("alloc-fault", "pool", "pool",
                                    args={"n": n, "free": len(self._free)})
            return None
        if n > len(self._free) and self.reclaimer is not None \
                and n <= len(self._free) + self.reclaimer.reclaimable():
            self.reclaimer.reclaim(n - len(self._free))
        if n > len(self._free):
            return None
        if self.policy == "min_wear" and self._free_dirty:
            # lazy re-sort: pop() must yield the least-worn free block, ties
            # broken oldest-freed-first (the age half of the hybrid score)
            self._free.sort(key=lambda b: (self.wear[b], self._freed_seq[b]),
                            reverse=True)
            self._free_dirty = False
        ids = [self._free.pop() for _ in range(n)]
        for b in ids:
            self._refs[b] = 1
        if n and self.tracer.enabled:
            self.tracer.instant("alloc", "pool", "pool",
                                args={"n": n, "free_after": len(self._free)})
        return ids

    def share(self, ids: List[int]) -> None:
        """Add one claim to each allocated block (prefix-sharing attach)."""
        for b in ids:
            if b not in self._refs:
                raise ValueError(f"share of unallocated block {b}")
        for b in ids:
            self._refs[b] += 1

    def free(self, ids: List[int]) -> None:
        """Drop one claim per id; blocks are released at refcount 0."""
        released = 0
        for b in ids:
            if b not in self._refs:
                raise ValueError(f"double free of block {b}")
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)
                self._seq += 1
                self._freed_seq[b] = self._seq
                self._free_dirty = True
                released += 1
        if ids and self.tracer.enabled:
            self.tracer.instant("release", "pool", "pool",
                                args={"n": len(ids), "released": released,
                                      "free_after": len(self._free)})

    def fork(self, bid: int) -> Optional[int]:
        """Copy-on-write fork of one claim on ``bid``.

        Exclusive block (refcount 1): returned as-is — the caller may write
        in place.  Shared block: allocates a fresh block, releases the
        caller's claim on ``bid``, and returns the new id; the caller must
        copy the block contents before writing.  None ⇒ pool exhausted (the
        claim on ``bid`` is kept so the caller can roll back).
        """
        if bid not in self._refs:
            raise ValueError(f"fork of unallocated block {bid}")
        if self._refs[bid] == 1:
            return bid
        got = self.alloc(1)
        if got is None:
            return None
        self.free([bid])
        if self.tracer.enabled:
            self.tracer.instant("fork", "pool", "pool",
                                args={"src": bid, "dst": got[0]})
        return got[0]

    def extend_to(self, table: List[int], n_tokens: int) -> bool:
        """Grow a block table in place until it covers ``n_tokens`` cache rows.

        All-or-nothing like :meth:`alloc`: returns False (table unchanged)
        when the pool cannot supply every missing block.  Shared by the
        scheduler's per-step growth and the horizon pre-reservation.  A
        target beyond the pool's total capacity can never be satisfied — it
        raises instead of letting the caller retry (and preempt victims)
        forever on a grant the pool cannot honor.
        """
        need = self.blocks_for(n_tokens)
        if need > self.n_blocks:
            raise ValueError(
                f"block-table grant for {n_tokens} tokens needs {need} blocks "
                f"but the pool only has {self.n_blocks} — the grant exceeds "
                f"pool capacity and can never be satisfied")
        if need <= len(table):
            return True
        got = self.alloc(need - len(table))
        if got is None:
            return False
        table.extend(got)
        return True

    def record_writes(self, pairs: Iterable[Tuple[int, int]],
                      now: float = 0.0) -> int:
        """Bill device writes to the endurance accounting.

        ``pairs`` is ``(block_id, rows_written)`` — the host-side mirror of a
        dispatch's KV scatters / block copies.  Bumps per-block wear and the
        last-write clock; returns total rows billed.  Writes to retired
        blocks are a bookkeeping bug upstream — rejected loudly.
        """
        rows = 0
        for bid, n in pairs:
            if n <= 0:
                continue
            if bid in self.retired:
                raise ValueError(f"write billed to retired block {bid}")
            self.wear[bid] += n
            self.last_write[bid] = now
            rows += n
        self.total_writes += rows
        return rows

    def over_budget(self) -> List[int]:
        """Non-retired blocks whose wear has crossed the endurance budget."""
        if self.endurance_budget is None:
            return []
        worn = np.flatnonzero(self.wear >= self.endurance_budget)
        return [int(b) for b in worn if b not in self.retired]

    def retire_free(self, bid: int) -> None:
        """Retire a block that currently sits on the free list."""
        if bid in self.retired:
            return
        if bid in self._refs:
            raise ValueError(f"retire_free of referenced block {bid}")
        self._free.remove(bid)
        self.retired.add(bid)
        if self.tracer.enabled:
            self.tracer.instant("retire", "pool", "pool",
                                args={"block": bid, "wear": int(self.wear[bid]),
                                      "usable": self.usable_blocks})

    def retire_used(self, bid: int) -> Optional[int]:
        """Retire a *referenced* block: allocate a replacement, transfer the
        refcount claims to it, and retire ``bid``.  Returns the replacement
        id (the caller must copy contents and remap every table that held
        ``bid``), or None when no replacement block is available — ``bid``
        stays live and the caller retries later."""
        if bid in self.retired:
            return None
        if bid not in self._refs:
            raise ValueError(f"retire_used of unreferenced block {bid}")
        got = self.alloc(1)
        if got is None:
            return None
        new = got[0]
        self._refs[new] = self._refs.pop(bid)
        self.retired.add(bid)
        if self.tracer.enabled:
            self.tracer.instant("retire", "pool", "pool",
                                args={"block": bid, "remap_to": new,
                                      "wear": int(self.wear[bid]),
                                      "usable": self.usable_blocks})
        return new

    def arm_alloc_failures(self, n: int = 1) -> None:
        """Fault injection: make the next ``n`` non-empty allocations fail
        (return None, pool untouched) even with headroom available."""
        if n < 0:
            raise ValueError(n)
        self._forced_failures += n

    def snapshot(self) -> Tuple[List[int], Dict[int, int]]:
        """(free ids, refcounts) copies — for invariant-checking tests."""
        return list(self._free), dict(self._refs)


@dataclass
class SwapTicket:
    """Handle for one swapped-out sequence: swap-tier block ids plus the
    non-paged slot state (recurrent states, per-slot position vectors).

    ``skip_blocks`` leading device blocks were *retained* instead of copied
    (sharing-aware swap: the scheduler kept refcount claims on blocks other
    tables or the prefix cache still hold on-device) — the ticket covers only
    the exclusive suffix, and swap-in restores into table rows
    ``skip_blocks`` onward."""

    block_ids: List[int]
    n_tokens: int
    side: Dict[str, jax.Array] = field(default_factory=dict)
    skip_blocks: int = 0


class PagedKVStore:
    """Swap-tier paged storage matching one serving-cache layout.

    Built from a serving cache pytree (``init_serving_caches``); allocates a
    ``[n_blocks, L, block_size, *trailing]`` buffer per sequence-axis leaf.
    Sliding-window (ring buffer) leaves are handled by capacity-clamping: a
    ring of ``window`` rows only ever occupies its first ``window/block_size``
    blocks of the table, and restoring rows + ``pos`` restores ring semantics
    exactly.
    """

    def __init__(self, caches, n_blocks: int, block_size: int):
        self.block_size = block_size
        self.pool = BlockPool(n_blocks, block_size)
        # armed fault injection: the next N copies in the given direction
        # raise SwapCopyError *before* touching any state (both copies are
        # functional, so the caller's fallback sees untouched caches)
        self._fail_out = 0
        self._fail_in = 0
        self.bufs: Dict[str, jax.Array] = {}
        self.pool_keys: set = set()
        for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
            name = _leaf_name(path)
            if name in POOL_LEAVES:
                L, _, bs, *trail = leaf.shape
                if bs != block_size:
                    raise ValueError(
                        f"pool leaf {_leaf_key(path)} block size {bs} != "
                        f"store block size {block_size}")
                self.bufs[_leaf_key(path)] = jnp.zeros(
                    (n_blocks, L, block_size, *trail), leaf.dtype)
                self.pool_keys.add(_leaf_key(path))
            elif name in SEQ_LEAVES:
                L, _, size, *trail = leaf.shape
                if size % block_size:
                    raise ValueError(
                        f"cache seq axis {size} of {_leaf_key(path)} not divisible "
                        f"by block_size {block_size}")
                self.bufs[_leaf_key(path)] = jnp.zeros(
                    (n_blocks, L, block_size, *trail), leaf.dtype)

    def arm_swap_failures(self, direction: str, n: int = 1) -> None:
        """Fault injection: the next ``n`` copies in ``direction`` ("out" or
        "in") raise :class:`SwapCopyError` before touching any state."""
        if direction == "out":
            self._fail_out += n
        elif direction == "in":
            self._fail_in += n
        else:
            raise ValueError(f"direction must be 'out' or 'in', got {direction!r}")

    def _nb_leaf(self, leaf, nb: int) -> int:
        # ring-buffer leaves are smaller than the table they are filed under
        return min(nb, leaf.shape[2] // self.block_size)

    def swap_out(self, caches, slot: int, block_ids: List[int], n_tokens: int,
                 dev_ids: Optional[List[int]] = None,
                 skip: int = 0) -> SwapTicket:
        """Copy ``slot``'s cache state into swap blocks; returns the ticket.

        ``dev_ids`` is the request's device block table at preemption time —
        pool leaves copy those blocks directly (block-table handoff); dense
        sequence leaves scatter the slot's rows as before.  ``skip`` leading
        device blocks are retained on-device by the scheduler (sharing-aware
        swap) and excluded from the copy — the ticket covers device blocks
        ``skip`` onward.
        """
        if self._fail_out:
            self._fail_out -= 1
            raise SwapCopyError("injected swap-out copy fault")
        bs = self.block_size
        ids = jnp.asarray(block_ids, jnp.int32)
        ticket = SwapTicket(list(block_ids), n_tokens, skip_blocks=skip)
        for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
            key = _leaf_key(path)
            if key in self.pool_keys:
                if dev_ids is None:
                    raise ValueError(f"pool leaf {key} needs dev_ids to swap out")
                nbl = min(len(block_ids), len(dev_ids) - skip)
                src = jnp.asarray(dev_ids[skip:skip + nbl], jnp.int32)
                seg = leaf[:, src]                                 # [L,nbl,bs,..]
                self.bufs[key] = self.bufs[key].at[ids[:nbl]].set(seg.swapaxes(0, 1))
                continue
            sl = jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=1)
            if key in self.bufs:
                nbl = max(0, self._nb_leaf(leaf, skip + len(block_ids)) - skip)
                L, trail = leaf.shape[0], leaf.shape[3:]
                seg = sl[:, 0, skip * bs:(skip + nbl) * bs]
                seg = seg.reshape(L, nbl, bs, *trail).swapaxes(0, 1)
                self.bufs[key] = self.bufs[key].at[ids[:nbl]].set(seg)
            else:
                ticket.side[key] = sl
        return ticket

    def swap_in(self, caches, slot: int, ticket: SwapTicket,
                dev_ids: Optional[List[int]] = None):
        """Copy a ticket's state back into ``slot``; returns new caches.

        ``dev_ids``: the freshly allocated device block table of the resumed
        request — pool leaves restore into those blocks (the table handoff's
        other half).  A ticket with ``skip_blocks`` restores into table rows
        ``skip_blocks`` onward; the leading blocks were never copied out
        (they stayed resident under retained claims).
        """
        if self._fail_in:
            self._fail_in -= 1
            raise SwapCopyError("injected swap-in copy fault")
        bs = self.block_size
        skip = ticket.skip_blocks
        ids = jnp.asarray(ticket.block_ids, jnp.int32)
        flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
        out = []
        for path, leaf in flat:
            key = _leaf_key(path)
            if key in self.pool_keys:
                if dev_ids is None:
                    raise ValueError(f"pool leaf {key} needs dev_ids to swap in")
                nbl = min(len(ticket.block_ids), len(dev_ids) - skip)
                seg = self.bufs[key][ids[:nbl]].swapaxes(0, 1)     # [L,nbl,bs,..]
                dst = jnp.asarray(dev_ids[skip:skip + nbl], jnp.int32)
                out.append(leaf.at[:, dst].set(seg))
            elif key in self.bufs:
                nbl = max(0, self._nb_leaf(leaf, skip + len(ticket.block_ids))
                          - skip)
                L, trail = leaf.shape[0], leaf.shape[3:]
                seg = self.bufs[key][ids[:nbl]].swapaxes(0, 1).reshape(L, 1, nbl * bs, *trail)
                sl = jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=1)
                sl = jax.lax.dynamic_update_slice(
                    sl, seg, (0, 0, skip * bs) + (0,) * (sl.ndim - 3))
                out.append(jax.lax.dynamic_update_slice_in_dim(leaf, sl, slot, axis=1))
            elif key in ticket.side:
                out.append(jax.lax.dynamic_update_slice_in_dim(
                    leaf, ticket.side[key], slot, axis=1))
            else:  # pragma: no cover — layout mismatch
                raise KeyError(f"leaf {key} missing from swap ticket")
        return jax.tree_util.tree_unflatten(treedef, [l for l in out])
