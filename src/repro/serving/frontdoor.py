"""Async streaming front door: backpressure, tenant QoS, failure semantics.

:class:`FrontDoor` wraps a synchronous :class:`~repro.serving.engine.\
ServingEngine` in an asyncio driver task and exposes ``submit(request)`` as
an **async token stream**.  The contract it adds on top of the engine:

* **Bounded-queue backpressure** — when the scheduler's waiting queue holds
  ``max_queue`` requests, or the degradation ladder has reached
  ``admit_deny``, ``submit`` raises a typed :class:`Overloaded` carrying a
  ``retry_after`` hint in relative seconds (the HTTP-429 shape — the
  :func:`run_server` wrapper maps it to ``429`` + ``Retry-After``).
* **Per-tenant QoS** — each tenant gets a token bucket metered on *emitted*
  tokens (accept-aware: a speculative step that emits 4 accepted tokens
  debits 4), so quota reflects delivered service, not requested budgets.
  An exhausted bucket rejects new admissions with ``retry_after`` sized to
  the refill, and a preemption-victim hook ranks running requests of
  over-quota tenants ahead of everyone else regardless of age.
* **End-to-end failure semantics** — a consumer that abandons its stream
  (client disconnect) triggers ``engine.cancel(rid)`` from the generator's
  ``finally``; :meth:`shutdown` (the SIGTERM path) drains gracefully,
  flushing in-flight streams while late submissions get a typed
  :class:`ShuttingDown`; per-request deadlines propagate through the
  engine's watch list; idle streams emit heartbeats so slow queues are
  distinguishable from dead connections.

Single-threaded by construction: asyncio's cooperative scheduling means
``submit``/``cancel`` can call the synchronous engine *directly* — the
driver task only runs ``engine.step()`` between ``await`` points, so there
is no interleaving hazard and no command queue.  Token events are built
incrementally from the engine's ``on_token`` callback, which fires with
per-token interpolated timestamps even inside a fused decode horizon.
"""
from __future__ import annotations

import asyncio
import json
import signal
from dataclasses import dataclass, field
from typing import AsyncIterator, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.faults import Overloaded, ShuttingDown
from repro.serving.scheduler import Request, RequestState

__all__ = ["FrontDoor", "TokenBucket", "TokenEvent", "HeartbeatEvent",
           "DoneEvent", "run_server"]


# ---------------------------------------------------------------- events

@dataclass(frozen=True)
class TokenEvent:
    """One emitted token (tuple over codebooks) with its engine timestamp."""
    rid: int
    token: Tuple[int, ...]
    index: int                       # 0-based position in the generation
    t: float
    tenant: Optional[str] = None
    kind: str = field(default="token", init=False)


@dataclass(frozen=True)
class HeartbeatEvent:
    """Keep-alive for an idle stream (queued, swapped, or mid-horizon)."""
    rid: int
    t: float
    state: str
    kind: str = field(default="heartbeat", init=False)


@dataclass(frozen=True)
class DoneEvent:
    """Terminal event: exactly one per stream, always the last event."""
    rid: int
    t: float
    state: str                       # "done"/"timeout"/"cancelled"/"failed"
    finish_reason: Optional[str]
    n_tokens: int
    kind: str = field(default="done", init=False)


# ---------------------------------------------------------------- QoS

class TokenBucket:
    """Token-bucket quota metered on emitted tokens.

    ``debit`` may push the level negative: emission is billed *post hoc*
    (the engine already produced the token), so a deep speculative accept
    can overshoot.  The debt then delays re-admission — ``retry_after_s``
    sizes the wait to refill back past one token.
    """

    def __init__(self, rate: float, burst: float, now: float = 0.0):
        self.rate = float(rate)          # tokens/second refill
        self.burst = float(burst)        # level cap
        self.level = float(burst)
        self._t = float(now)

    def _refill(self, now: float) -> None:
        if now > self._t:
            self.level = min(self.burst, self.level + (now - self._t) * self.rate)
            self._t = now

    def debit(self, n: float, now: float) -> None:
        self._refill(now)
        self.level -= n

    def admit_ok(self, now: float) -> bool:
        self._refill(now)
        return self.level > 0.0

    def retry_after_s(self, now: float) -> float:
        self._refill(now)
        if self.level > 0.0:
            return 0.0
        return (1.0 - self.level) / max(self.rate, 1e-9)


class _Stream:
    """Per-request bridge between the driver and one consumer."""

    __slots__ = ("req", "queue", "emitted", "last_event_t")

    def __init__(self, req: Request):
        self.req = req
        # unbounded: depth is naturally capped by req.max_new + heartbeats
        self.queue: asyncio.Queue = asyncio.Queue()
        self.emitted = 0
        self.last_event_t = req.arrival


# ---------------------------------------------------------------- front door

class FrontDoor:
    """Asyncio serving layer over a synchronous :class:`ServingEngine`.

    Parameters
    ----------
    engine : ServingEngine
        The engine to drive.  The front door installs itself as the
        ``on_token`` callback (chaining any existing one) and as the
        scheduler's ``victim_key`` policy hook; :meth:`aclose` restores
        both, leaving the engine serviceable for direct use.
    max_queue : int
        Bound on the scheduler's waiting queue.  A submit that would
        exceed it raises :class:`Overloaded`.
    tenant_rate, tenant_burst : float, optional
        Token-bucket parameters applied per tenant id.  ``None`` disables
        quotas (untenanted deployments pay nothing).
    heartbeat_s : float, optional
        Emit a :class:`HeartbeatEvent` on any stream idle this long.
    """

    def __init__(self, engine, *, max_queue: int = 64,
                 tenant_rate: Optional[float] = None,
                 tenant_burst: Optional[float] = None,
                 heartbeat_s: Optional[float] = None):
        self.engine = engine
        self.max_queue = int(max_queue)
        self.tenant_rate = tenant_rate
        self.tenant_burst = tenant_burst if tenant_burst is not None else (
            tenant_rate if tenant_rate is not None else None)
        self.heartbeat_s = heartbeat_s
        self.buckets: Dict[str, TokenBucket] = {}
        self.stats = {"accepted": 0, "rejected_queue": 0,
                      "rejected_degrade": 0, "rejected_quota": 0,
                      "rejected_draining": 0, "disconnect_cancels": 0,
                      "heartbeats": 0}
        self._streams: Dict[int, _Stream] = {}
        self._done_mark = len(engine._done)
        self._draining = False
        self._wake = asyncio.Event()
        self._driver: Optional[asyncio.Task] = None
        self._closed = False
        # install hooks (chained / restored by aclose)
        self._prev_on_token = engine.on_token
        engine.on_token = self._on_token
        self._prev_victim_key = engine.sched.victim_key
        engine.sched.victim_key = self._victim_key

    # ---- engine hooks ---------------------------------------------------

    def _on_token(self, req: Request, tok, now: float) -> None:
        if self._prev_on_token is not None:
            self._prev_on_token(req, tok, now)
        if req.tenant is not None and self.tenant_rate is not None:
            self._bucket(req.tenant, now).debit(1.0, now)
        h = self._streams.get(req.rid)
        if h is None:
            return
        token = tuple(int(x) for x in np.asarray(tok).ravel().tolist())
        h.queue.put_nowait(TokenEvent(rid=req.rid, token=token,
                                      index=h.emitted, t=now,
                                      tenant=req.tenant))
        h.emitted += 1
        h.last_event_t = now

    def _victim_key(self, r: Request):
        # over-quota tenants preempt first, regardless of age; ties fall
        # back to the engine's default youngest-first policy
        return (1 if self._over_quota(r.tenant) else 0, r.arrival, r.rid)

    def _over_quota(self, tenant: Optional[str]) -> bool:
        if tenant is None or self.tenant_rate is None:
            return False
        b = self.buckets.get(tenant)
        return b is not None and b.level <= 0.0

    def _bucket(self, tenant: str, now: float) -> TokenBucket:
        b = self.buckets.get(tenant)
        if b is None:
            b = TokenBucket(self.tenant_rate, self.tenant_burst, now)
            self.buckets[tenant] = b
        return b

    # ---- admission ------------------------------------------------------

    def _reject(self, counter: str, exc: Exception, req: Request,
                now: float) -> Exception:
        self.stats[counter] += 1
        eng = self.engine
        if eng.tracer.enabled:
            args = {"rid": req.rid, "why": counter,
                    "retry_after": getattr(exc, "retry_after", None)}
            if req.tenant is not None:
                args["tenant"] = req.tenant
            eng.tracer.instant("reject", "lifecycle", "scheduler", ts=now,
                               args=args)
        return exc

    def submit(self, req: Request) -> AsyncIterator:
        """Admit ``req`` and return its async event stream.

        Raises :class:`Overloaded` (queue full / degradation denial /
        tenant over quota) or :class:`ShuttingDown` (draining) *at call
        time* — a rejected request never allocates engine state.  On
        success, ``req.arrival`` is stamped to the engine clock's *now*
        (front-door requests arrive when they are admitted; with greedy
        decoding the stream content depends only on the prompt, so this
        preserves bit-identical tokens vs. an offline run).
        """
        eng = self.engine
        now = eng._now()
        if self._draining or eng.draining or self._closed:
            raise self._reject(
                "rejected_draining",
                ShuttingDown(f"request {req.rid}: front door is draining"),
                req, now)
        if len(eng.sched.waiting) >= self.max_queue:
            # heuristic: one step per queued request ahead of this one
            step = max(eng._est_step_time(), 1e-3)
            raise self._reject(
                "rejected_queue",
                Overloaded(f"request {req.rid}: queue full "
                           f"({self.max_queue} waiting)",
                           retry_after=step * len(eng.sched.waiting),
                           tenant=req.tenant),
                req, now)
        ctl = eng.degrade
        if ctl is not None and ctl.deny_admission:
            raise self._reject(
                "rejected_degrade",
                Overloaded(f"request {req.rid}: degradation ladder at "
                           f"'{ctl.name}' denies admissions",
                           retry_after=max(0.0, ctl.retry_after(now) - now),
                           tenant=req.tenant),
                req, now)
        if req.tenant is not None and self.tenant_rate is not None:
            b = self._bucket(req.tenant, now)
            if not b.admit_ok(now):
                raise self._reject(
                    "rejected_quota",
                    Overloaded(f"request {req.rid}: tenant '{req.tenant}' "
                               f"over quota",
                               retry_after=b.retry_after_s(now),
                               tenant=req.tenant),
                    req, now)
        req.arrival = now
        h = _Stream(req)
        self._streams[req.rid] = h
        try:
            eng.submit(req)
        except Exception:
            self._streams.pop(req.rid, None)
            raise
        self.stats["accepted"] += 1
        self._wake.set()
        return self._consume(h)

    async def _consume(self, h: _Stream) -> AsyncIterator:
        req = h.req
        try:
            while True:
                ev = await h.queue.get()
                yield ev
                if ev.kind == "done":
                    return
        finally:
            # consumer abandoned the stream (disconnect, aclose, timeout
            # wrapper): cancel is idempotent, a no-op for terminal requests
            if not req.terminal:
                if self.engine.cancel(req.rid, reason="disconnect"):
                    self.stats["disconnect_cancels"] += 1
                self._wake.set()
            self._streams.pop(req.rid, None)

    # ---- driver ---------------------------------------------------------

    async def start(self) -> None:
        if self._driver is None:
            self._driver = asyncio.ensure_future(self._drive())

    async def _drive(self) -> None:
        eng = self.engine
        try:
            while not self._closed:
                if eng.sched.has_work:
                    eng.step()
                    self._route_done()
                    self._heartbeats()
                    # yield so consumers drain their queues between steps
                    await asyncio.sleep(0)
                else:
                    self._route_done()
                    self._wake.clear()
                    timeout = self.heartbeat_s if self.heartbeat_s else None
                    try:
                        await asyncio.wait_for(self._wake.wait(), timeout)
                    except asyncio.TimeoutError:
                        self._heartbeats(force_idle=True)
        except asyncio.CancelledError:
            pass

    def _route_done(self) -> None:
        """Push a DoneEvent for every newly-terminal request.

        Scans ``engine._done`` past a high-water mark, so requests that
        finished through *any* path — completion, deadline, queue timeout,
        client cancel, drain — all produce exactly one terminal event."""
        done = self.engine._done
        while self._done_mark < len(done):
            req = done[self._done_mark]
            self._done_mark += 1
            h = self._streams.get(req.rid)
            if h is None:
                continue
            t = req.t_done if req.t_done is not None else self.engine._now()
            h.queue.put_nowait(DoneEvent(
                rid=req.rid, t=t, state=req.state.value,
                finish_reason=req.finish_reason, n_tokens=req.n_generated))
            h.last_event_t = t

    def _heartbeats(self, force_idle: bool = False) -> None:
        if not self.heartbeat_s:
            return
        now = self.engine._now()
        for h in self._streams.values():
            if h.req.terminal:
                continue
            if h.queue.empty() and now - h.last_event_t >= self.heartbeat_s:
                h.queue.put_nowait(HeartbeatEvent(
                    rid=h.req.rid, t=now, state=h.req.state.value))
                h.last_event_t = now
                self.stats["heartbeats"] += 1

    # ---- shutdown -------------------------------------------------------

    async def shutdown(self) -> None:
        """Graceful SIGTERM semantics: stop admitting (late submits raise
        :class:`ShuttingDown`), cancel never-admitted queued requests with
        reason ``"drain"``, then step until every in-flight stream has
        flushed its terminal event."""
        eng = self.engine
        self._draining = True
        eng.draining = True
        now = eng._now()
        for _, _, req in list(eng.sched.waiting):
            if req.t_admit is None:
                eng.cancel(req.rid, reason="drain")
        self._route_done()
        await asyncio.sleep(0)
        while eng.sched.has_work:
            eng.step()
            self._route_done()
            await asyncio.sleep(0)
        self._route_done()
        # let consumers drain their final events before the driver stops
        for _ in range(3):
            await asyncio.sleep(0)
        await self.aclose()

    async def aclose(self) -> None:
        """Detach from the engine: stop the driver and restore the hooks.

        Unlike :meth:`shutdown` this does not drain — the engine stays
        serviceable for direct (synchronous) use afterwards."""
        if self._closed:
            return
        self._closed = True
        self._wake.set()
        if self._driver is not None:
            self._driver.cancel()
            try:
                await self._driver
            except asyncio.CancelledError:
                pass
            self._driver = None
        self.engine.on_token = self._prev_on_token
        self.engine.sched.victim_key = self._prev_victim_key

    def summary(self) -> Dict:
        out = dict(self.stats)
        out["live_streams"] = len(self._streams)
        if self.buckets:
            out["tenant_buckets"] = {
                t: round(b.level, 4) for t, b in sorted(self.buckets.items())}
        return out


# ---------------------------------------------------------------- HTTP/SSE

async def _read_request(reader) -> Tuple[str, str, Dict[str, str], bytes]:
    """Minimal HTTP/1.1 parse: request line, headers, Content-Length body."""
    line = await reader.readline()
    if not line:
        raise ConnectionError("empty request")
    parts = line.decode("latin-1").strip().split()
    if len(parts) < 2:
        raise ConnectionError(f"bad request line: {line!r}")
    method, path = parts[0], parts[1]
    headers: Dict[str, str] = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        k, _, v = line.decode("latin-1").partition(":")
        headers[k.strip().lower()] = v.strip()
    n = int(headers.get("content-length", "0") or "0")
    body = await reader.readexactly(n) if n else b""
    return method, path, headers, body


def _http_response(status: str, body: bytes,
                   extra_headers: Tuple[Tuple[str, str], ...] = ()) -> bytes:
    head = [f"HTTP/1.1 {status}", "Connection: close",
            f"Content-Length: {len(body)}",
            "Content-Type: application/json"]
    head += [f"{k}: {v}" for k, v in extra_headers]
    return ("\r\n".join(head) + "\r\n\r\n").encode("latin-1") + body


def _event_json(ev) -> dict:
    if ev.kind == "token":
        return {"kind": "token", "rid": ev.rid, "token": list(ev.token),
                "index": ev.index, "t": round(ev.t, 6)}
    if ev.kind == "heartbeat":
        return {"kind": "heartbeat", "rid": ev.rid, "state": ev.state,
                "t": round(ev.t, 6)}
    return {"kind": "done", "rid": ev.rid, "state": ev.state,
            "finish_reason": ev.finish_reason, "n_tokens": ev.n_tokens,
            "t": round(ev.t, 6)}


async def run_server(fd: FrontDoor, host: str = "127.0.0.1",
                     port: int = 8080, *, vocab: int = 32000,
                     install_signals: bool = True,
                     ready: Optional[asyncio.Event] = None) -> None:
    """Serve ``POST /generate`` as a server-sent-event token stream.

    Request body (JSON): ``{"prompt": [ids]}`` or ``{"prompt_len": n}``
    (random prompt), plus optional ``max_new``, ``tenant``, and
    ``deadline_ms``.  Responses: ``200`` SSE stream of token/heartbeat/done
    events; ``429`` + ``Retry-After`` on :class:`Overloaded`; ``503`` on
    :class:`ShuttingDown`.  SIGTERM/SIGINT trigger :meth:`FrontDoor.\
    shutdown` — in-flight streams flush, late submits get ``503``.
    """
    await fd.start()
    next_rid = [max(fd.engine._by_rid.keys(), default=-1) + 1]
    stop = asyncio.Event()

    async def handle(reader, writer):
        try:
            try:
                method, path, headers, body = await _read_request(reader)
            except (ConnectionError, asyncio.IncompleteReadError):
                return
            if method != "POST" or path != "/generate":
                writer.write(_http_response(
                    "404 Not Found", b'{"error": "POST /generate"}'))
                await writer.drain()
                return
            try:
                spec = json.loads(body or b"{}")
            except json.JSONDecodeError:
                writer.write(_http_response(
                    "400 Bad Request", b'{"error": "invalid JSON"}'))
                await writer.drain()
                return
            if "prompt" in spec:
                prompt = np.asarray(spec["prompt"], dtype=np.int32)
            else:
                n = int(spec.get("prompt_len", 16))
                rng = np.random.default_rng(next_rid[0])
                prompt = rng.integers(0, vocab, size=(n,), dtype=np.int32)
            req = Request(rid=next_rid[0], prompt=prompt,
                          max_new=int(spec.get("max_new", 16)),
                          arrival=0.0, tenant=spec.get("tenant"))
            next_rid[0] += 1
            if spec.get("deadline_ms") is not None:
                req.deadline = (fd.engine._now()
                                + float(spec["deadline_ms"]) / 1e3)
            try:
                stream = fd.submit(req)
            except ShuttingDown as e:
                writer.write(_http_response(
                    "503 Service Unavailable",
                    json.dumps({"error": str(e)}).encode()))
                await writer.drain()
                return
            except Overloaded as e:
                retry = e.retry_after if e.retry_after is not None else 1.0
                writer.write(_http_response(
                    "429 Too Many Requests",
                    json.dumps({"error": str(e),
                                "retry_after": retry}).encode(),
                    (("Retry-After", f"{max(0.0, retry):.3f}"),)))
                await writer.drain()
                return
            writer.write(b"HTTP/1.1 200 OK\r\n"
                         b"Content-Type: text/event-stream\r\n"
                         b"Cache-Control: no-cache\r\n"
                         b"Connection: close\r\n\r\n")
            await writer.drain()
            async for ev in stream:
                payload = json.dumps(_event_json(ev))
                writer.write(f"data: {payload}\n\n".encode())
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            pass                     # client went away; finally-cancel fires
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass

    server = await asyncio.start_server(handle, host, port)

    def _sigterm():
        stop.set()

    loop = asyncio.get_event_loop()
    if install_signals:
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                loop.add_signal_handler(sig, _sigterm)
            except (NotImplementedError, RuntimeError):
                pass                 # non-main thread / platform without it
    if ready is not None:
        ready.set()
    try:
        await stop.wait()
    finally:
        server.close()
        await server.wait_closed()
        await fd.shutdown()
