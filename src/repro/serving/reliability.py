"""PCRAM reliability layer: endurance budgets, wear stats, scrub policy.

ODIN computes in-situ in phase-change RAM, and PCRAM's defining reliability
constraints — finite write endurance (~1e6–1e9 SET/RESET cycles per cell),
resistance drift of the stored analog state over time, and stuck-at cell
faults — are properties of the *medium*, not of any one workload.  The
device block pool has been the "physical PCRAM" since PR 2, so this module
makes those constraints first-class for the serving stack:

* :class:`ReliabilityConfig` — the knob set threaded through
  ``ServingEngine(reliability=...)``: an optional per-block **endurance
  budget** (writes-in-rows before a block is retired), the **wear-leveling**
  allocator policy toggle (min-wear free-list ordering in
  :class:`~repro.serving.blocks.BlockPool`), and the **drift-refresh
  scrubber** rate/deadline (rewrite the oldest-written resident blocks at a
  bounded blocks-per-step rate before their analog state drifts past the
  read margin).

* :func:`wear_gini` — the Gini coefficient of the per-block write
  distribution, the summary statistic the bench uses to show wear-leveling
  *provably narrows* wear vs. the seed LIFO allocator (0 = perfectly even,
  →1 = all writes on one block).

Everything here is pure host-side policy: the accounting lives in
``BlockPool`` (a host mirror of device writes derived from the same
StepPlan/scheduler bookkeeping that already tracks table claims), the
retirement/scrub *mechanism* lives in the engine (block copies through the
existing pool-leaf machinery, billed as a ``scrub`` ODIN energy phase), and
capacity loss feeds the degradation ladder as a new pressure input.  The
stack's signature invariant is preserved by construction: retirement and
scrubbing copy identical bytes and only change *which physical block id*
holds them, so greedy streams are bit-identical with reliability on vs. off.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

__all__ = ["ReliabilityConfig", "wear_gini"]


@dataclass(frozen=True)
class ReliabilityConfig:
    """Knobs for the PCRAM reliability layer.

    endurance_budget
        Per-block write budget in *cache rows written*; a block whose wear
        counter crosses it is drained (contents copied to a fresh block, all
        live tables remapped) and retired from the free list.  None ⇒ blocks
        are immortal (accounting still runs).
    wear_leveling
        Order the pool free list by (wear, age-freed) so allocation always
        picks the least-worn block, ties broken oldest-freed-first.  Off ⇒
        the seed LIFO order.
    scrub_rate
        Drift-refresh bound: at most this many resident blocks rewritten in
        place per engine step.  0 disables the scrubber.
    drift_deadline_s
        A resident block whose last write is older than this is due for a
        drift refresh.  None disables the scrubber regardless of rate.
    """

    endurance_budget: Optional[int] = None
    wear_leveling: bool = True
    scrub_rate: int = 0
    drift_deadline_s: Optional[float] = None

    def __post_init__(self):
        if self.endurance_budget is not None and self.endurance_budget <= 0:
            raise ValueError(f"endurance_budget must be positive, "
                             f"got {self.endurance_budget}")
        if self.scrub_rate < 0:
            raise ValueError(f"scrub_rate must be >= 0, got {self.scrub_rate}")
        if self.drift_deadline_s is not None and self.drift_deadline_s <= 0:
            raise ValueError(f"drift_deadline_s must be positive, "
                             f"got {self.drift_deadline_s}")

    @property
    def scrub_enabled(self) -> bool:
        return self.scrub_rate > 0 and self.drift_deadline_s is not None


def wear_gini(wear) -> float:
    """Gini coefficient of a per-block write distribution.

    0.0 ⇒ perfectly even wear; → 1.0 ⇒ all writes concentrated on one
    block.  An all-zero distribution reads as perfectly even.
    """
    w = np.sort(np.asarray(wear, np.float64))
    n = w.size
    total = w.sum()
    if n == 0 or total <= 0:
        return 0.0
    # G = (2 * sum_i i*w_i) / (n * sum w) - (n + 1) / n  with i in 1..n
    idx = np.arange(1, n + 1, dtype=np.float64)
    return float((2.0 * (idx * w).sum()) / (n * total) - (n + 1.0) / n)
