"""Request lifecycle + slot-based continuous-batching scheduler.

State machine (per request)::

    QUEUED ──admit──▶ RUNNING ──complete──▶ DONE
       ▲                │  ▲
       │   recompute-   │  │ resume (swap-in)
       └── preempt ─────┤  │
                        └──┴── swap preempt ──▶ SWAPPED

    QUEUED / RUNNING / SWAPPED ──release──▶ TIMEOUT | CANCELLED | FAILED

Every request ends in exactly one terminal state: ``DONE`` (eos/length),
``TIMEOUT`` (deadline or queue timeout expired), ``CANCELLED`` (client
cancel or drain), or ``FAILED`` (quarantined by a fault guard).  The
typed reason lands in ``Request.finish_reason``.  :meth:`Scheduler.release`
tears a live request down from any non-terminal state — slot freed,
refcount claims dropped, swap tickets returned — reusing the PR 5
recompute-downgrade release discipline, so the pool/prefix-cache stay
coherent no matter where in the lifecycle the request dies.

``Scheduler.plan(now)`` is pure bookkeeping — it mutates only scheduler /
request accounting state and returns a :class:`StepPlan` of device actions
(swap-out scatters, swap-in gathers, chunked prefills) for the engine to
execute.  That split keeps the policy unit-testable without touching jax.

Per step, in order:

1. **Growth** — each running request whose next decode write crosses a block
   boundary allocates one more block.  On pool exhaustion the youngest
   running request is preempted (swap if the swap tier has room, else
   recompute-requeue) until the allocation succeeds; a request may preempt
   itself, in which case it stops growing.
2. **Resume** — swapped requests re-enter freed slots (FIFO), ahead of new
   admissions so preempted work cannot starve.
3. **Admission** — arrived queued requests fill the remaining free slots,
   each allocating blocks for its whole prompt (+ the first decode row).

Steps 2–3 are skipped on any step that preempted, so blocks freed under
memory pressure relieve the pressure instead of thrashing.

For horizon-batched decode the engine follows ``plan`` with
:meth:`Scheduler.grant_horizon`, which returns the largest safe number of
lockstep decode steps for one fused dispatch and pre-extends every running
block table to cover it (see the method docstring for the three caps).
``table_version`` increments on every block-table/slot mutation so the
engine's device mirror of the tables re-uploads only when something changed.

**Prefix sharing.**  With a :class:`PrefixCache` attached, admission matches
the incoming request's prompt against resident block chains at block
granularity: matched full blocks are *aliased* into the new table (refcount
bump, zero prefill work), a partially-matching block is COW-forked (the
engine copies it before the slot writes its tail into it), and only the
unmatched tail is prefilled — the admission allocates the **marginal** new
blocks, not the full prompt footprint.  The cache holds one claim per
registered block, so prompt blocks of completed/preempted requests stay
resident (system-prompt caching) until allocation pressure evicts them LRU
through the pool's reclaimer hook.  ``free``/preemption decrement refcounts,
so a block shared with another slot (or retained by the cache) is never
physically released while still read.
"""
from __future__ import annotations

import enum
import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.blocks import BlockPool
from repro.serving.trace import NULL_TRACER

__all__ = ["PrefixCache", "PrefixGrant", "Request", "RequestState",
           "Scheduler", "StepPlan", "TERMINAL_STATES"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    SWAPPED = "swapped"
    DONE = "done"
    TIMEOUT = "timeout"
    CANCELLED = "cancelled"
    FAILED = "failed"


#: states a request can never leave
TERMINAL_STATES = (RequestState.DONE, RequestState.TIMEOUT,
                   RequestState.CANCELLED, RequestState.FAILED)


@dataclass
class Request:
    """One serving request: a prompt and a generation budget.

    ``prompt`` is an int32 array of shape [S] (or [K, S] for multi-codebook
    models).  ``extras`` may carry ``patch_embeds``/``pos3d`` for vision-stub
    models (single-chunk prompts only).  All fields below ``arrival`` are
    runtime state owned by the scheduler/engine.
    """

    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: float = 0.0
    extras: Optional[dict] = None
    # multi-tenant QoS identity: threaded through lifecycle/decision trace
    # events, per-request ODIN bills and the windowed per-tenant TTFT/TPOT
    # metrics; None ⇒ untenanted (single-tenant deployments pay nothing)
    tenant: Optional[str] = None
    # absolute engine-clock instant after which the request times out (None
    # ⇒ no deadline); queue_timeout is relative to arrival and applies only
    # while the request has never been admitted (t_admit is None); cancel_at
    # is an absolute scripted client cancellation (workload schedules)
    deadline: Optional[float] = None
    queue_timeout: Optional[float] = None
    cancel_at: Optional[float] = None

    state: RequestState = RequestState.QUEUED
    finish_reason: Optional[str] = None   # "eos"/"length"/"deadline"/"queue"/
                                          # "client"/"drain"/"nan_logits"/...
    slot: int = -1
    generated: List = field(default_factory=list)
    block_table: List[int] = field(default_factory=list)
    # device blocks a swap preemption kept claims on (sharing-aware swap:
    # blocks other tables/the prefix cache also hold stay resident instead of
    # round-tripping through the swap tier; resume re-attaches them), and the
    # swap-tier blocks its ticket occupies (scheduler-side accounting so a
    # stuck resume can be downgraded to recompute without engine help)
    kept_blocks: List[int] = field(default_factory=list)
    swap_block_ids: List[int] = field(default_factory=list)
    eos: bool = False                     # emitted the engine's eos_id
    ticket: object = None                 # SwapTicket while SWAPPED
    # mixed-dispatch prefill progress: while ``prefilling`` the request's
    # prompt replay is being staged through fused mixed dispatches and
    # ``prefill_pos`` counts the replay rows already written (admission
    # starts it at the prefix grant's ``start``).  The separate prefill
    # path completes in one engine call and never sets ``prefilling``.
    prefilling: bool = False
    prefill_pos: int = 0
    n_prefill_tokens: int = 0             # includes recompute re-prefills
    spec_overhead_rows: int = 0           # verify rows beyond emitted tokens
    n_preempt_swap: int = 0
    n_preempt_recompute: int = 0
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[-1])

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def cached_len(self) -> int:
        """Cache rows this request occupies: prompt + all generated tokens
        except the pending one (the last generated token is the next decode
        *input*; its KV row is written by that decode step)."""
        return self.prompt_len + max(0, self.n_generated - 1)

    @property
    def remaining(self) -> int:
        """Decode budget left: tokens this request may still emit."""
        return max(0, self.max_new - self.n_generated)

    @property
    def done(self) -> bool:
        return self.eos or self.n_generated >= self.max_new

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def replay_tokens(self) -> np.ndarray:
        """Tokens a (re-)prefill of this request feeds the model: the prompt
        plus every generated token except the pending one (whose KV row is
        written by its own decode step).  Shape [.., cached_len]."""
        prompt = np.asarray(self.prompt)
        if self.n_generated <= 1:
            return prompt
        gen = np.stack(self.generated[:-1], axis=-1).astype(np.int32)
        return np.concatenate([prompt, gen.reshape(*prompt.shape[:-1], -1)],
                              axis=-1)


@dataclass
class PrefixGrant:
    """Shared-prefix admission grant for one request.

    ``start`` cache rows are already resident through the request's block
    table — the engine prefills only ``[start:]`` of the replay tokens.
    ``shared_blocks`` leading table entries are aliased (refcounted) blocks;
    ``fork`` is a ``(src, dst)`` pool-block copy the engine must execute
    *before* the tail prefill (the COW fork of a partially-matched block —
    rows below ``start % block_size`` of ``dst`` become the copied prefix
    rows, and the slot's own writes land at ``start`` onward).
    """

    start: int
    shared_blocks: int
    fork: Optional[Tuple[int, int]] = None


@dataclass
class StepPlan:
    """Device actions for one engine step.

    ``preempt`` entries are ``(request, mode, swap_block_ids, old_slot,
    dev_block_ids)`` with mode "swap" (engine copies the request's device KV
    blocks — ``dev_block_ids``, its block table at preemption time — into the
    listed swap blocks) or "recompute" (nothing device-side; the request
    re-prefills on readmission).  The device ids are snapshot *before* the
    pool frees them; the engine's swap-out copy runs before anything written
    this step (growth/prefill lands in the decode phase), so the handoff is
    race-free within the step.  ``resume``/``admit`` requests already have
    their new slot and device block table assigned.  ``grants`` maps an
    admitted request's rid to its :class:`PrefixGrant` (absent ⇒ full
    prefill from row 0).
    """

    preempt: List[Tuple[Request, str, Optional[List[int]], int, List[int]]] = field(default_factory=list)
    resume: List[Request] = field(default_factory=list)
    admit: List[Request] = field(default_factory=list)
    grants: Dict[int, PrefixGrant] = field(default_factory=dict)


class _PrefixNode:
    """One resident block of a registered prompt chain."""

    __slots__ = ("key", "parent", "block_id", "tokens", "stamp")

    def __init__(self, key: int, parent: int, block_id: int,
                 tokens: np.ndarray, stamp: int):
        self.key = key
        self.parent = parent
        self.block_id = block_id
        self.tokens = tokens          # [.., t] prompt tokens held by the block
        self.stamp = stamp            # LRU clock of the last match/registration


class PrefixCache:
    """Prompt-prefix trie over resident pool blocks (block granularity).

    Chain keys hash the *path* of block contents from the prompt start
    (``key_i = hash(key_{i-1}, tokens_i)``), so a lookup walks the incoming
    prompt block by block with O(1) dict probes; a final scan of the matched
    node's children finds the longest partial-block match (the COW-fork
    case), comparing actual tokens — never hashes — so a hash collision can
    at worst miss a share, not corrupt one.

    Every registered node holds **one pool claim** on its block
    (``pool.share``): prompt blocks survive their request's completion or
    preemption and are evicted LRU only when allocation pressure asks for
    them back through the pool's reclaimer hook (``reclaimable``/``reclaim``
    — only nodes whose block has no other claim are evictable, since
    releasing a block some table still reads would free nothing and lose the
    entry).  Node contents are immutable by construction: tables never write
    a row into a block another table aliases (prefill/decode writes always
    land at or beyond the grant's ``start``), and a node's ``tokens`` cover
    only the prompt rows its owner wrote before registration.
    """

    _ROOT = 0

    def __init__(self, pool: BlockPool, block_size: int):
        self.pool = pool
        self.block_size = block_size
        self._nodes: Dict[int, _PrefixNode] = {}     # chain key → node
        self._by_block: Dict[int, int] = {}          # block id → chain key
        self._children: Dict[int, List[int]] = {}    # parent key → child keys
        self._clock = 0
        self.hit_tokens = 0
        self.forks = 0
        pool.reclaimer = self

    # -- reclaimer protocol (BlockPool) -------------------------------------

    def reclaimable(self) -> int:
        return sum(1 for n in self._nodes.values()
                   if self.pool.refs(n.block_id) == 1)

    def reclaim(self, n: int) -> int:
        """Evict up to ``n`` LRU nodes whose block only the cache holds.

        Leaf-first: a chain's nodes share LRU stamps root-to-leaf, so a pure
        min-stamp pick would evict the *root* and strand every still-resident
        descendant unmatchable.  Preferring childless nodes shortens chains
        from the tail, keeping the surviving prefix usable.  (Both scans are
        O(cached nodes) — fine at serving scale; an evictability index is
        the lever if caches ever grow to many thousands of blocks.)
        """
        freed = 0
        while freed < n:
            victim = fallback = None
            for node in self._nodes.values():
                if self.pool.refs(node.block_id) != 1:
                    continue
                if self._children.get(node.key):
                    if fallback is None or node.stamp < fallback.stamp:
                        fallback = node
                elif victim is None or node.stamp < victim.stamp:
                    victim = node
            victim = victim or fallback
            if victim is None:
                break
            self._evict(victim)
            freed += 1
        return freed

    def _evict(self, node: _PrefixNode) -> None:
        tracer = self.pool.tracer
        if tracer.enabled:
            tracer.instant("prefix-evict", "pool", "pool",
                           args={"block": node.block_id,
                                 "tokens": int(node.tokens.shape[-1])})
        del self._nodes[node.key]
        del self._by_block[node.block_id]
        kids = self._children.get(node.parent)
        if kids is not None:
            kids.remove(node.key)
            if not kids:
                del self._children[node.parent]
        self.pool.free([node.block_id])

    # -- queries ------------------------------------------------------------

    def holds(self, bid: int) -> bool:
        return bid in self._by_block

    def held_blocks(self) -> List[int]:
        return list(self._by_block)

    def __len__(self) -> int:
        return len(self._nodes)

    # -- matching / registration --------------------------------------------

    @staticmethod
    def _key(parent: int, chunk: np.ndarray) -> int:
        return hash((parent, chunk.shape[-1], chunk.tobytes()))

    def _tick(self, node: _PrefixNode) -> None:
        self._clock += 1
        node.stamp = self._clock

    def match(self, toks: np.ndarray, limit: int
              ) -> Tuple[List[int], int, Optional[int]]:
        """Longest resident prefix of ``toks`` (int array, [.., S]).

        Returns ``(full_block_ids, partial_tokens, partial_src_block)``: the
        aliasable full blocks, then the longest common prefix (< block) with
        any resident continuation block — the COW-fork source.  At most
        ``limit`` tokens ever match, so the caller always keeps ≥ 1 tail
        token to prefill (the logits that mint the next token).
        """
        bs = self.block_size
        ids: List[int] = []
        parent = self._ROOT
        while (len(ids) + 1) * bs <= min(toks.shape[-1], limit):
            chunk = toks[..., len(ids) * bs:(len(ids) + 1) * bs]
            node = self._nodes.get(self._key(parent, chunk))
            if node is None or not np.array_equal(node.tokens, chunk):
                break
            self._tick(node)
            ids.append(node.block_id)
            parent = node.key
        off = len(ids) * bs
        best_p, best_node = 0, None
        cap = min(toks.shape[-1], limit) - off
        if cap > 0:
            for ck in self._children.get(parent, ()):
                node = self._nodes[ck]
                n = min(node.tokens.shape[-1], cap)
                if n <= best_p:
                    continue
                eq = (node.tokens[..., :n] == toks[..., off:off + n])
                col = eq.reshape(-1, n).all(axis=0)
                p = int(col.sum()) if col.all() else int(np.argmin(col))
                if p > best_p:
                    best_p, best_node = p, node
        if best_node is not None:
            self._tick(best_node)
        return ids, best_p, best_node.block_id if best_node else None

    def register(self, req: Request) -> None:
        """Index the request's *prompt* blocks (full chain + partial tail).

        Already-present chains are skipped (aliased blocks re-register as
        no-ops); each newly indexed block gains the cache's claim.
        """
        toks = np.asarray(req.prompt)
        bs = self.block_size
        S = toks.shape[-1]
        parent = self._ROOT
        for j in range(S // bs):
            chunk = toks[..., j * bs:(j + 1) * bs]
            key = self._key(parent, chunk)
            node = self._nodes.get(key)
            if node is None or not np.array_equal(node.tokens, chunk):
                if node is not None:       # hash collision: keep the old node
                    break
                node = self._insert(key, parent, req.block_table[j], chunk)
            parent = key
        p = S % bs
        if p:
            chunk = toks[..., S - p:]
            key = self._key(parent, chunk)
            node = self._nodes.get(key)
            if node is None:
                self._insert(key, parent, req.block_table[S // bs], chunk)

    def _insert(self, key: int, parent: int, bid: int,
                chunk: np.ndarray) -> _PrefixNode:
        if bid in self._by_block:          # block already indexed (aliased)
            return self._nodes[self._by_block[bid]]
        self.pool.share([bid])
        self._clock += 1
        node = _PrefixNode(key, parent, bid, np.array(chunk), self._clock)
        self._nodes[key] = node
        self._by_block[bid] = key
        self._children.setdefault(parent, []).append(key)
        return node


class Scheduler:
    def __init__(self, n_slots: int, pool: BlockPool, max_len: int,
                 swap_pool: Optional[BlockPool] = None,
                 prefix_cache: Optional[PrefixCache] = None,
                 write_span: int = 1):
        self.n_slots = n_slots
        self.pool = pool
        self.max_len = max_len
        self.swap_pool = swap_pool
        self.prefix_cache = prefix_cache
        # structured-event recorder (repro.serving.trace); the engine swaps
        # in its Tracer — the no-op default keeps every emit site free
        self.tracer = NULL_TRACER
        # rows one decode dispatch may write per slot before rollback:
        # 1 + the engine's speculative draft length (K)
        self.write_span = write_span
        self.waiting: List[Tuple[float, int, Request]] = []    # heap
        self.swapped: deque = deque()
        self.running: Dict[int, Request] = {}                  # slot → request
        self.free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        # bumped whenever any request's block table (or slot binding) changes;
        # the engine re-mirrors its device table array only when this moves
        self.table_version: int = 0
        # degradation knobs (set each step by the engine's controller):
        # admission_hold, when not None, pauses admissions and carries the
        # structured retry-after instant for denied clients; prefix_retain
        # False stops registering new prompt chains (retention released)
        self.admission_hold: Optional[float] = None
        self.prefix_retain: bool = True
        # mixed dispatch (engine-owned): defer prompt-chain registration to
        # finish_prefill — registering at admission would let a later arrival
        # alias blocks whose rows the staged prefill has not written yet
        self.defer_prefix_register: bool = False
        # round-robin cursor for decode rows under mixed-budget scarcity
        self._mixed_rr: int = 0
        # preemption-victim policy hook: a key function over running requests
        # (max wins).  None keeps the default youngest-first ``(arrival,
        # rid)`` order; the front door installs a QoS-aware key that ranks
        # over-quota tenants ahead of everyone regardless of age.
        self.victim_key: Optional[callable] = None

    # -- queries ------------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.swapped or self.running)

    def next_arrival(self) -> Optional[float]:
        return self.waiting[0][0] if self.waiting else None

    # -- lifecycle ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        total = req.prompt_len + req.max_new
        if total > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {total} exceeds max_len {self.max_len}")
        if self.pool.blocks_for(total) > self.pool.usable_blocks:
            raise ValueError(
                f"request {req.rid}: needs {self.pool.blocks_for(total)} blocks, "
                f"pool has {self.pool.usable_blocks} usable")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        heapq.heappush(self.waiting, (req.arrival, req.rid, req))

    def complete(self, req: Request, now: float) -> None:
        """Called by the engine when the request's last token was emitted."""
        self.pool.free(req.block_table)
        req.block_table = []
        self.running.pop(req.slot)
        self.free_slots.append(req.slot)
        req.slot = -1
        req.state = RequestState.DONE
        req.finish_reason = "eos" if req.eos else "length"
        req.t_done = now
        self.table_version += 1

    def release(self, req: Request, state: RequestState, now: float,
                reason: str) -> None:
        """Tear a live request down into terminal ``state`` from wherever it
        is in the lifecycle, dropping every resource claim it holds:

        * RUNNING — free the block table (refcount-aware: shared/cached
          blocks survive), free the slot;
        * SWAPPED — drop kept-prefix claims, return swap-tier blocks and the
          ticket (the recompute-downgrade release discipline);
        * QUEUED — remove from the waiting heap.

        The engine owns the trace emission; this is pure bookkeeping."""
        if req.terminal:
            return
        if req.state is RequestState.RUNNING:
            self.pool.free(req.block_table)
            req.block_table = []
            self.running.pop(req.slot)
            self.free_slots.append(req.slot)
            req.slot = -1
            self.table_version += 1
        elif req.state is RequestState.SWAPPED:
            self.swapped.remove(req)
            self.pool.free(req.kept_blocks)
            req.kept_blocks = []
            if self.swap_pool is not None and req.swap_block_ids:
                self.swap_pool.free(req.swap_block_ids)
            req.swap_block_ids = []
            req.ticket = None
        else:                               # QUEUED: drop the heap entry
            self.waiting = [e for e in self.waiting if e[2] is not req]
            heapq.heapify(self.waiting)
        req.state = state
        req.finish_reason = reason
        req.t_done = now

    # -- PCRAM bad-block retirement -----------------------------------------

    def retire_blocks(self, bad: List[int]) -> List[Tuple[int, int]]:
        """Retire bad device blocks, remapping every live claim.

        For each block: free → pulled straight off the free list; held only
        by the prefix cache → the cached chain node is evicted first (its
        content is reconstructible from tokens, no copy owed); referenced →
        a replacement block is allocated, the refcount claims transfer, and
        every holder (running block tables, swapped requests' kept-prefix
        claims, the prefix-cache node) is remapped to the replacement.

        Returns ``(old, new)`` pairs whose *contents the caller must copy*
        on the physical store before the next dispatch reads them — called
        by the engine's reliability sweep ahead of ``plan()``, so no
        dispatch is in flight while ids move.  A referenced block with no
        replacement available is left live (not retired); the caller retries
        on a later sweep once pressure clears.

        The returned pairs are safe to apply as ONE batched copy: free bad
        blocks are retired first (so they can never be handed out as a
        replacement), and a bad block that still ends up as a replacement
        destination (cache eviction inside ``retire_used``'s alloc can
        re-free one mid-loop) is deferred to a later call instead of being
        retired now — a chained ``a→b, b→c`` copy in a single scatter would
        hand ``c`` the *old* bytes of ``b``.
        """
        cache = self.prefix_cache
        copies: List[Tuple[int, int]] = []
        remapped = False
        # pass 1: unreferenced (and cache-only) bad blocks leave the free
        # list before any replacement allocation can pick them up
        deferred = []
        for bid in bad:
            if bid in self.pool.retired:
                continue
            refs = self.pool.refs(bid)
            if refs == 0:
                self.pool.retire_free(bid)
            elif cache is not None and cache.holds(bid) and refs == 1:
                # cache-only claim: evict (frees the block), then retire —
                # the chain rebuilds from tokens on the next matching prompt
                cache._evict(cache._nodes[cache._by_block[bid]])
                self.pool.retire_free(bid)
            else:
                deferred.append(bid)
        # pass 2: referenced bad blocks drain through a replacement
        dsts: set = set()
        for bid in deferred:
            if bid in dsts:
                continue                    # became a replacement: next sweep
            if self.pool.refs(bid) == 0:
                # lost its claims mid-loop (eviction re-freed it)
                self.pool.retire_free(bid)
                continue
            new = self.pool.retire_used(bid)
            if new is None:
                continue                    # no replacement yet: retry later
            dsts.add(new)
            for req in self.running.values():
                for i, b in enumerate(req.block_table):
                    if b == bid:
                        req.block_table[i] = new
                        remapped = True
            for req in self.swapped:
                for i, b in enumerate(req.kept_blocks):
                    if b == bid:
                        req.kept_blocks[i] = new
                        remapped = True
            if cache is not None and cache.holds(bid):
                key = cache._by_block.pop(bid)
                cache._by_block[new] = key
                cache._nodes[key].block_id = new
            copies.append((bid, new))
        if remapped or copies:
            self.table_version += 1
        return copies

    # -- planning -----------------------------------------------------------

    def _victim(self) -> Optional[Request]:
        """Preemption victim: youngest running request (latest arrival breaks
        toward higher rid), unless a ``victim_key`` policy hook reorders."""
        if not self.running:
            return None
        key = self.victim_key or (lambda r: (r.arrival, r.rid))
        return max(self.running.values(), key=key)

    def _kept_prefix(self, req: Request) -> int:
        """Leading device blocks a swap preemption may keep claims on: fully
        written blocks (strictly below the next write row) that some *other*
        claim also holds — another table's alias or the prefix cache.  Those
        blocks would not be physically freed by our release anyway, so
        keeping our claim costs nothing now and saves both the swap-tier copy
        and the swap-in restore; content stays valid because aliased blocks
        are never written (write-block exclusivity)."""
        if self.prefix_cache is None:
            return 0
        kept = 0
        limit = min(req.cached_len // self.pool.block_size,
                    len(req.block_table))
        while kept < limit and self.pool.refs(req.block_table[kept]) >= 2:
            kept += 1
        return kept

    def _preempt(self, req: Request, plan: StepPlan) -> None:
        old_slot = req.slot
        self.running.pop(old_slot)
        self.free_slots.append(old_slot)
        req.slot = -1
        dev_ids = list(req.block_table)     # snapshot for the swap-out copy
        swap_ids = None
        kept = 0
        # a mid-prefill request has written only ``prefill_pos`` of its
        # ``cached_len`` rows — a swap-out would copy (and a resume restore)
        # garbage for the unwritten tail, so force recompute instead
        if self.swap_pool is not None and not req.prefilling:
            kept = self._kept_prefix(req)
            swap_ids = self.swap_pool.alloc(
                self.swap_pool.blocks_for(req.cached_len) - kept)
        if swap_ids is not None:
            req.kept_blocks = dev_ids[:kept]
            req.swap_block_ids = list(swap_ids)
            self.pool.free(dev_ids[kept:])  # shared prefix claims stay held
            req.block_table = []
            self.table_version += 1
            req.state = RequestState.SWAPPED
            req.n_preempt_swap += 1
            self.swapped.append(req)
            plan.preempt.append((req, "swap", swap_ids, old_slot, dev_ids))
        else:
            self.pool.free(dev_ids)
            req.block_table = []
            self.table_version += 1
            req.state = RequestState.QUEUED
            req.n_preempt_recompute += 1
            req.prefilling = False
            req.prefill_pos = 0
            heapq.heappush(self.waiting, (req.arrival, req.rid, req))
            plan.preempt.append((req, "recompute", None, old_slot, dev_ids))
        if self.tracer.enabled:
            mode = "swap" if swap_ids is not None else "recompute"
            args = {"rid": req.rid, "slot": old_slot, "mode": mode,
                    "blocks": len(dev_ids), "kept_blocks": kept}
            if req.tenant is not None:
                args["tenant"] = req.tenant
            self.tracer.instant(f"preempt-{mode}", "scheduler", "scheduler",
                                args=args, flow=req.rid)

    def _downgrade_to_recompute(self, req: Request) -> None:
        """Convert a swapped request that can never resume (pool fragmented
        by retained claims, nothing running) into a recompute readmission:
        release its kept claims and swap-tier blocks, drop the ticket, and
        requeue — the re-prefill rebuilds the KV from tokens (and typically
        re-attaches whatever prefix chains survived)."""
        self.pool.free(req.kept_blocks)
        req.kept_blocks = []
        if self.swap_pool is not None and req.swap_block_ids:
            self.swap_pool.free(req.swap_block_ids)
        req.swap_block_ids = []
        req.ticket = None
        req.state = RequestState.QUEUED
        req.n_preempt_recompute += 1
        heapq.heappush(self.waiting, (req.arrival, req.rid, req))
        if self.tracer.enabled:
            self.tracer.instant("swap-downgrade", "scheduler", "scheduler",
                                args={"rid": req.rid}, flow=req.rid)

    def fail_swap_out(self, req: Request) -> None:
        """The swap-out copy failed after :meth:`_preempt` moved the request
        to SWAPPED (ticket never created).  Downgrade to recompute: kept
        claims and swap-tier blocks are released, the request re-prefills
        from tokens on readmission.  Nothing device-side was written, so the
        caches are untouched."""
        self.swapped.remove(req)
        self._downgrade_to_recompute(req)

    def fail_resume(self, req: Request) -> None:
        """The swap-in copy failed after :meth:`plan` placed the resumed
        request back in a slot (functional swap-in: the caches are
        untouched).  Tear the placement back down and requeue as recompute —
        the swap-tier copy may be suspect, so its blocks are returned rather
        than retried."""
        self.pool.free(req.block_table)
        req.block_table = []
        self.running.pop(req.slot)
        self.free_slots.append(req.slot)
        req.slot = -1
        self.table_version += 1
        if self.swap_pool is not None and req.ticket is not None:
            self.swap_pool.free(req.ticket.block_ids)
        req.ticket = None
        req.swap_block_ids = []
        req.state = RequestState.QUEUED
        req.n_preempt_recompute += 1
        heapq.heappush(self.waiting, (req.arrival, req.rid, req))
        if self.tracer.enabled:
            self.tracer.instant("resume-fail", "scheduler", "scheduler",
                                args={"rid": req.rid}, flow=req.rid)

    def _place(self, req: Request, blocks: List[int], now: float) -> None:
        req.block_table = blocks
        req.slot = self.free_slots.pop()
        req.state = RequestState.RUNNING
        self.running[req.slot] = req
        self.table_version += 1
        if req.t_admit is None:
            req.t_admit = now

    def _check_write_block(self, req: Request) -> None:
        """Every block the request's next decode dispatch may write — rows
        ``cached_len .. cached_len + write_span - 1`` (span > 1 under
        speculative verify, whose rejected rows roll back) — must be
        table-exclusive: aliased by no other table, at most retained by the
        prefix cache.  A violation means a COW fork was missed; fail loudly
        here instead of silently corrupting a shared prefix.  Blocks past the
        table's current length are skipped (horizon pre-extension allocates
        them fresh and exclusive before any multi-row dispatch runs)."""
        bs = self.pool.block_size
        first = req.cached_len // bs
        last = (req.cached_len + self.write_span - 1) // bs
        for idx in range(first, last + 1):
            if idx >= len(req.block_table):
                return                      # not allocated yet / preempted
            bid = req.block_table[idx]
            refs = self.pool.refs(bid)
            if self.prefix_cache is not None and self.prefix_cache.holds(bid):
                refs -= 1
            if refs != 1:
                raise RuntimeError(
                    f"request {req.rid}: decode write rows "
                    f"[{req.cached_len}, {req.cached_len + self.write_span}) "
                    f"land in block {bid} carrying {refs} table claims — "
                    f"missed COW fork would corrupt a shared prefix")

    def _admission_blocks(self, req: Request
                          ) -> Tuple[Optional[List[int]], Optional[PrefixGrant]]:
        """Block table for an admission: aliased shared-prefix blocks (+ one
        COW fork) plus freshly allocated *marginal* blocks.  None ⇒ the pool
        cannot cover the marginal need (claims rolled back, nothing leaked).
        """
        need = self.pool.blocks_for(req.cached_len + 1)
        if self.prefix_cache is not None and not req.extras:
            toks = req.replay_tokens()
            ids, p, src = self.prefix_cache.match(toks, limit=toks.shape[-1] - 1)
            if (ids or p) and self.pool.available_blocks < need - len(ids):
                # cannot cover the marginal need even with eviction: bail
                # before touching any claims, so a stalled head-of-queue
                # request retried every step neither churns fork blocks nor
                # evicts resident chains for nothing
                return None, None
            if ids or p:
                self.pool.share(ids)
                table = list(ids)
                fork = None
                if p:
                    self.pool.share([src])
                    dst = self.pool.fork(src)
                    if dst is None:        # exhausted mid-fork: roll back
                        self.pool.free([src])
                        self.pool.free(ids)
                        return None, None
                    table.append(dst)
                    fork = (src, dst)
                got = self.pool.alloc(need - len(table))
                if got is None:            # marginal blocks unavailable
                    self.pool.free(table[len(ids):])   # the fork block
                    self.pool.free(ids)
                    return None, None
                table += got
                # cache hit/fork accounting only on *placed* admissions
                self.prefix_cache.hit_tokens += len(ids) * self.pool.block_size + p
                if fork is not None:
                    self.prefix_cache.forks += 1
                grant = PrefixGrant(start=len(ids) * self.pool.block_size + p,
                                    shared_blocks=len(ids), fork=fork)
                return table, grant
        got = self.pool.alloc(need)
        return (got, None) if got is not None else (None, None)

    def plan(self, now: float) -> StepPlan:
        plan = StepPlan()

        # 1. growth, oldest first: the next decode step writes KV row
        # ``cached_len``, which may need a fresh block.
        for req in sorted(self.running.values(), key=lambda r: (r.arrival, r.rid)):
            if req.slot < 0:               # already preempted this step
                continue
            grew = len(req.block_table)
            while not self.pool.extend_to(req.block_table, req.cached_len + 1):
                victim = self._victim()
                self._preempt(victim, plan)
                if victim is req:
                    break
            if len(req.block_table) != grew:
                self.table_version += 1
            if req.slot >= 0:
                self._check_write_block(req)

        if plan.preempt:
            return plan                    # let freed blocks settle one step

        # 2. resume swapped requests into free slots (FIFO).  Blocks the
        # preemption kept claims on (sharing-aware swap) re-attach in place;
        # only the exclusive suffix needs fresh blocks + the swap-in copy.
        resume_starved = False
        while self.swapped and self.free_slots:
            req = self.swapped[0]
            got = self.pool.alloc(self.pool.blocks_for(req.cached_len + 1)
                                  - len(req.kept_blocks))
            if got is None:
                if not self.running:
                    # nothing running can ever free more capacity, so a
                    # starved resume would deadlock: retained claims (ours
                    # and other swapped requests') have fragmented the pool.
                    # Downgrade the head to recompute-readmission — releasing
                    # its kept claims and swap blocks is sound because a
                    # re-prefill rebuilds everything from tokens.
                    self.swapped.popleft()
                    self._downgrade_to_recompute(req)
                    continue
                resume_starved = True       # kept claims stay held: content
                break                       # must survive until the resume
            self.swapped.popleft()
            kept = len(req.kept_blocks)
            table, req.kept_blocks = req.kept_blocks + got, []
            req.swap_block_ids = []         # engine/driver frees the ticket
            self._place(req, table, now)
            plan.resume.append(req)
            if self.tracer.enabled:
                self.tracer.instant(
                    "resume", "scheduler", "scheduler", ts=now,
                    args={"rid": req.rid, "slot": req.slot,
                          "reattached_blocks": kept,
                          "restored_blocks": len(got)},
                    flow=req.rid)

        # 3. admit arrived requests into the remaining free slots.  Not while
        # a swapped request is starved for blocks: a new admission would eat
        # the very blocks it is waiting for (resume priority must hold for
        # blocks, not just slots).  Admission allocates only the *marginal*
        # blocks beyond the resident shared prefix, and registers the new
        # prompt chain so later arrivals can share it.
        if self.admission_hold is not None:
            # degradation ladder top: admissions denied with a structured
            # retry-after; queued requests keep waiting (their queue_timeout
            # bounds the wait) and resume priority still drains the swapped
            if (self.tracer.enabled and self.waiting
                    and self.waiting[0][0] <= now):
                self.tracer.instant(
                    "admit-hold", "scheduler", "scheduler", ts=now,
                    args={"queued": len(self.waiting),
                          "retry_after_s": self.admission_hold})
            return plan
        while self.waiting and self.free_slots and not resume_starved:
            arrival, _, req = self.waiting[0]
            if arrival > now:
                break
            table, grant = self._admission_blocks(req)
            if table is None:
                if self.tracer.enabled:
                    self.tracer.instant(
                        "admit-deny", "scheduler", "scheduler", ts=now,
                        args={"rid": req.rid,
                              "need_blocks": self.pool.blocks_for(req.cached_len + 1),
                              "available_blocks": self.pool.available_blocks},
                        flow=req.rid)
                break
            heapq.heappop(self.waiting)
            self._place(req, table, now)
            if grant is not None:
                plan.grants[req.rid] = grant
            if (self.prefix_cache is not None and not req.extras
                    and self.prefix_retain and not self.defer_prefix_register):
                self.prefix_cache.register(req)
            self._check_write_block(req)
            plan.admit.append(req)
            if self.tracer.enabled:
                shared = grant.shared_blocks if grant is not None else 0
                args = {"rid": req.rid, "slot": req.slot,
                        "blocks": len(table),
                        "marginal_blocks": len(table) - shared
                        - (1 if grant is not None and grant.fork else 0),
                        "shared_blocks": shared,
                        "prefix_hit_tokens": grant.start if grant else 0}
                if req.tenant is not None:
                    args["tenant"] = req.tenant
                self.tracer.instant("admit", "scheduler", "scheduler",
                                    ts=now, args=args, flow=req.rid)

        return plan

    # -- mixed prefill+decode packing ---------------------------------------

    def pack_mixed(self, budget: int, chunk: int
                   ) -> Tuple[List[Request], List[Tuple[Request, int, int]]]:
        """Pack one fused dispatch under a total query-row ``budget``.

        Returns ``(decode, parts)``: running slots that ride at q_len = 1
        (their pending token decodes), and prefill assignments
        ``(request, start, rows)`` — ``rows`` replay tokens starting at
        replay offset ``start`` for each mid-prefill slot, capped at
        ``chunk`` rows per slot per dispatch.

        Fairness: decode rows are packed FIRST (Sarathi-style decode-
        priority — steady-state TPOT never waits on a prompt), so with
        ``budget ≥ running slots + 1`` no decode slot is ever skipped.
        Under pathological scarcity (budget < decode population + 1) a
        persistent round-robin cursor rotates which decode slots ride, so
        no slot waits more than one rotation.  When any slot is
        mid-prefill, one row is reserved for the oldest prefilling slot so
        prefill always progresses ≥ 1 row per dispatch (TTFT cannot starve
        behind decode either).

        Pure bookkeeping — no allocation happens here: admission already
        allocated the full replay footprint (``cached_len + 1`` rows), so
        every prefill write row is table-covered.
        """
        running = sorted(self.running.values(),
                         key=lambda r: (r.arrival, r.rid))
        prefilling = [r for r in running if r.prefilling]
        decoding = [r for r in running if not r.prefilling and not r.done]
        rows_left = max(1, budget)
        reserve = 1 if prefilling else 0
        decode: List[Request] = []
        if decoding:
            cap = max(0, rows_left - reserve)
            if len(decoding) <= cap:
                decode = list(decoding)
            elif cap:
                order = sorted(decoding, key=lambda r: r.slot)
                i0 = self._mixed_rr % len(order)
                decode = [order[(i0 + i) % len(order)] for i in range(cap)]
                self._mixed_rr = (i0 + cap) % len(order)
            rows_left -= len(decode)
        parts: List[Tuple[Request, int, int]] = []
        for r in prefilling:
            if rows_left <= 0:
                break
            c = min(chunk, r.cached_len - r.prefill_pos, rows_left)
            if c <= 0:
                continue
            parts.append((r, r.prefill_pos, c))
            rows_left -= c
        return decode, parts

    def finish_prefill(self, req: Request) -> None:
        """A staged (mixed-dispatch) prefill wrote its last replay row.

        Deferred prompt-chain registration happens here — the rows are now
        physically resident, so later arrivals may alias them safely."""
        req.prefilling = False
        req.prefill_pos = req.cached_len
        if (self.prefix_cache is not None and not req.extras
                and self.prefix_retain):
            self.prefix_cache.register(req)

    # -- horizon granting ---------------------------------------------------

    def grant_horizon(self, max_h: int, now: float,
                      est_step_time: float = 0.0, spec_k: int = 0) -> int:
        """Largest safe number of lockstep decode steps for one dispatch.

        Called after :meth:`plan` (so single-step growth is already settled)
        and before the engine launches its fused multi-step decode.  The
        grant is the min of three caps, snapped DOWN to a power of two so the
        engine compiles at most ``log2(max_h)+1`` horizon executables:

        1. **Completion events.**  While admissions or resumes are blocked on
           capacity (a swapped request, or an arrived request still queued),
           the horizon ends at the earliest running completion — min over
           running slots of remaining budget — so freed slots/blocks turn
           into admitted work at the boundary instead of idling frozen.
           (An early EOS can still freeze a slot mid-horizon; that waste is
           bounded by this same cap.)  With speculation an inner step emits
           up to ``spec_k + 1`` tokens, so the earliest completion is
           ``ceil(remaining / (spec_k+1))`` steps out.
        2. **Arrival events.**  With a free slot and a future arrival, the
           horizon stops roughly at the admission time (``est_step_time`` is
           the engine's measured per-token decode time; 0 disables the cap).
        3. **Block headroom.**  Every granted step must be able to write its
           KV rows: each running request's table is pre-extended *before*
           the dispatch so the paged kernel never indexes an unallocated
           page mid-horizon.  Speculative dispatches budget the worst case —
           every inner step writes ``spec_k + 1`` rows even when rejection
           rolls most of them back, and a slot that freezes on budget still
           wrote ``spec_k`` rows past its last accepted token — capped at
           ``max_len`` (the attention write path parks rows beyond the table
           span on the pool's write-off block).  If the pool cannot cover
           ``h`` steps the grant halves (never preempts); with speculation,
           an uncoverable ``h == 1`` returns 0 and the engine falls back to
           one plain decode step (plan()'s growth already covered one row).
        """
        running = sorted(self.running.values(), key=lambda r: (r.arrival, r.rid))
        if not running:
            return 0
        per = spec_k + 1
        h = max(1, max_h)
        if self.swapped or (self.waiting and self.waiting[0][0] <= now):
            h = min(h, max(1, min(-(-r.remaining // per) for r in running)))
        elif self.waiting and self.free_slots and est_step_time > 0:
            until = self.waiting[0][0] - now
            h = min(h, max(1, int(until / est_step_time) + 1))
        # deadline events: a past-deadline running request must be aborted at
        # the next step boundary, so cap the horizon roughly at the earliest
        # running deadline — a mid-horizon abort otherwise burns up to a full
        # grant of dead work before the engine's expiry sweep sees it
        deadlines = [r.deadline - now for r in running if r.deadline is not None]
        if deadlines and est_step_time > 0:
            h = min(h, max(1, int(min(deadlines) / (est_step_time * per)) + 1))
        h = 1 << (max(1, h).bit_length() - 1)          # snap down to 2^k

        def rows_for(r: Request, hh: int) -> int:
            return min(self.max_len,
                       r.cached_len + min(hh * per, r.remaining + spec_k))

        def extra_blocks(hh: int) -> int:
            return sum(
                max(0, self.pool.blocks_for(rows_for(r, hh))
                    - len(r.block_table))
                for r in running)

        while h > 1 and extra_blocks(h) > self.pool.available_blocks:
            h //= 2
        if spec_k and (extra_blocks(h) > self.pool.available_blocks or any(
                self.pool.blocks_for(rows_for(r, h)) > self.pool.usable_blocks
                for r in running)):
            h = 0                           # this step cannot verify a draft
        grew = False
        while h and (h > 1 or spec_k):
            ok = True
            for r in running:
                before = len(r.block_table)
                ok = self.pool.extend_to(r.block_table, rows_for(r, h))
                grew |= len(r.block_table) != before
                if not ok:
                    break
            if ok:
                break
            # headroom vanished between the check and the extension (an
            # injected allocation fault, or a reclaimer that reported blocks
            # it could not deliver): halve the grant and retry with whatever
            # partial extension already landed — never crash, never preempt.
            # With speculation an uncoverable h == 1 degrades to 0 and the
            # engine falls back to one plain decode step (plan()'s growth
            # already covered that row).
            h = h // 2 if h > 1 else 0
        if grew:
            self.table_version += 1
        if self.tracer.enabled:
            self.tracer.instant(
                "grant_horizon", "scheduler", "scheduler", ts=now,
                args={"max_h": max_h, "granted": h, "spec_k": spec_k,
                      "running": len(running), "swapped": len(self.swapped),
                      "queued": len(self.waiting),
                      "free_slots": len(self.free_slots),
                      "available_blocks": self.pool.available_blocks,
                      "est_step_time_s": est_step_time})
        return h
