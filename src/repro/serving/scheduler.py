"""Request lifecycle + slot-based continuous-batching scheduler.

State machine (per request)::

    QUEUED ──admit──▶ RUNNING ──complete──▶ DONE
       ▲                │  ▲
       │   recompute-   │  │ resume (swap-in)
       └── preempt ─────┤  │
                        └──┴── swap preempt ──▶ SWAPPED

``Scheduler.plan(now)`` is pure bookkeeping — it mutates only scheduler /
request accounting state and returns a :class:`StepPlan` of device actions
(swap-out scatters, swap-in gathers, chunked prefills) for the engine to
execute.  That split keeps the policy unit-testable without touching jax.

Per step, in order:

1. **Growth** — each running request whose next decode write crosses a block
   boundary allocates one more block.  On pool exhaustion the youngest
   running request is preempted (swap if the swap tier has room, else
   recompute-requeue) until the allocation succeeds; a request may preempt
   itself, in which case it stops growing.
2. **Resume** — swapped requests re-enter freed slots (FIFO), ahead of new
   admissions so preempted work cannot starve.
3. **Admission** — arrived queued requests fill the remaining free slots,
   each allocating blocks for its whole prompt (+ the first decode row).

Steps 2–3 are skipped on any step that preempted, so blocks freed under
memory pressure relieve the pressure instead of thrashing.

For horizon-batched decode the engine follows ``plan`` with
:meth:`Scheduler.grant_horizon`, which returns the largest safe number of
lockstep decode steps for one fused dispatch and pre-extends every running
block table to cover it (see the method docstring for the three caps).
``table_version`` increments on every block-table/slot mutation so the
engine's device mirror of the tables re-uploads only when something changed.
"""
from __future__ import annotations

import enum
import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.blocks import BlockPool

__all__ = ["Request", "RequestState", "Scheduler", "StepPlan"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    SWAPPED = "swapped"
    DONE = "done"


@dataclass
class Request:
    """One serving request: a prompt and a generation budget.

    ``prompt`` is an int32 array of shape [S] (or [K, S] for multi-codebook
    models).  ``extras`` may carry ``patch_embeds``/``pos3d`` for vision-stub
    models (single-chunk prompts only).  All fields below ``arrival`` are
    runtime state owned by the scheduler/engine.
    """

    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: float = 0.0
    extras: Optional[dict] = None

    state: RequestState = RequestState.QUEUED
    slot: int = -1
    generated: List = field(default_factory=list)
    block_table: List[int] = field(default_factory=list)
    eos: bool = False                     # emitted the engine's eos_id
    ticket: object = None                 # SwapTicket while SWAPPED
    n_prefill_tokens: int = 0             # includes recompute re-prefills
    n_preempt_swap: int = 0
    n_preempt_recompute: int = 0
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[-1])

    @property
    def n_generated(self) -> int:
        return len(self.generated)

    @property
    def cached_len(self) -> int:
        """Cache rows this request occupies: prompt + all generated tokens
        except the pending one (the last generated token is the next decode
        *input*; its KV row is written by that decode step)."""
        return self.prompt_len + max(0, self.n_generated - 1)

    @property
    def remaining(self) -> int:
        """Decode budget left: tokens this request may still emit."""
        return max(0, self.max_new - self.n_generated)

    @property
    def done(self) -> bool:
        return self.eos or self.n_generated >= self.max_new


@dataclass
class StepPlan:
    """Device actions for one engine step.

    ``preempt`` entries are ``(request, mode, swap_block_ids, old_slot,
    dev_block_ids)`` with mode "swap" (engine copies the request's device KV
    blocks — ``dev_block_ids``, its block table at preemption time — into the
    listed swap blocks) or "recompute" (nothing device-side; the request
    re-prefills on readmission).  The device ids are snapshot *before* the
    pool frees them; the engine's swap-out copy runs before anything written
    this step (growth/prefill lands in the decode phase), so the handoff is
    race-free within the step.  ``resume``/``admit`` requests already have
    their new slot and device block table assigned.
    """

    preempt: List[Tuple[Request, str, Optional[List[int]], int, List[int]]] = field(default_factory=list)
    resume: List[Request] = field(default_factory=list)
    admit: List[Request] = field(default_factory=list)


class Scheduler:
    def __init__(self, n_slots: int, pool: BlockPool, max_len: int,
                 swap_pool: Optional[BlockPool] = None):
        self.n_slots = n_slots
        self.pool = pool
        self.max_len = max_len
        self.swap_pool = swap_pool
        self.waiting: List[Tuple[float, int, Request]] = []    # heap
        self.swapped: deque = deque()
        self.running: Dict[int, Request] = {}                  # slot → request
        self.free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        # bumped whenever any request's block table (or slot binding) changes;
        # the engine re-mirrors its device table array only when this moves
        self.table_version: int = 0

    # -- queries ------------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.swapped or self.running)

    def next_arrival(self) -> Optional[float]:
        return self.waiting[0][0] if self.waiting else None

    # -- lifecycle ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        total = req.prompt_len + req.max_new
        if total > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {total} exceeds max_len {self.max_len}")
        if self.pool.blocks_for(total) > self.pool.n_blocks:
            raise ValueError(
                f"request {req.rid}: needs {self.pool.blocks_for(total)} blocks, "
                f"pool has {self.pool.n_blocks}")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        heapq.heappush(self.waiting, (req.arrival, req.rid, req))

    def complete(self, req: Request, now: float) -> None:
        """Called by the engine when the request's last token was emitted."""
        self.pool.free(req.block_table)
        req.block_table = []
        self.running.pop(req.slot)
        self.free_slots.append(req.slot)
        req.slot = -1
        req.state = RequestState.DONE
        req.t_done = now
        self.table_version += 1

    # -- planning -----------------------------------------------------------

    def _victim(self) -> Optional[Request]:
        """Youngest running request (latest arrival breaks toward higher rid)."""
        if not self.running:
            return None
        return max(self.running.values(), key=lambda r: (r.arrival, r.rid))

    def _preempt(self, req: Request, plan: StepPlan) -> None:
        old_slot = req.slot
        self.running.pop(old_slot)
        self.free_slots.append(old_slot)
        req.slot = -1
        dev_ids = list(req.block_table)     # snapshot for the swap-out copy
        self.pool.free(req.block_table)
        req.block_table = []
        self.table_version += 1
        swap_ids = None
        if self.swap_pool is not None:
            swap_ids = self.swap_pool.alloc(self.swap_pool.blocks_for(req.cached_len))
        if swap_ids is not None:
            req.state = RequestState.SWAPPED
            req.n_preempt_swap += 1
            self.swapped.append(req)
            plan.preempt.append((req, "swap", swap_ids, old_slot, dev_ids))
        else:
            req.state = RequestState.QUEUED
            req.n_preempt_recompute += 1
            heapq.heappush(self.waiting, (req.arrival, req.rid, req))
            plan.preempt.append((req, "recompute", None, old_slot, dev_ids))

    def _place(self, req: Request, blocks: List[int], now: float) -> None:
        req.block_table = blocks
        req.slot = self.free_slots.pop()
        req.state = RequestState.RUNNING
        self.running[req.slot] = req
        self.table_version += 1
        if req.t_admit is None:
            req.t_admit = now

    def plan(self, now: float) -> StepPlan:
        plan = StepPlan()

        # 1. growth, oldest first: the next decode step writes KV row
        # ``cached_len``, which may need a fresh block.
        for req in sorted(self.running.values(), key=lambda r: (r.arrival, r.rid)):
            if req.slot < 0:               # already preempted this step
                continue
            grew = len(req.block_table)
            while not self.pool.extend_to(req.block_table, req.cached_len + 1):
                victim = self._victim()
                self._preempt(victim, plan)
                if victim is req:
                    break
            if len(req.block_table) != grew:
                self.table_version += 1

        if plan.preempt:
            return plan                    # let freed blocks settle one step

        # 2. resume swapped requests into free slots (FIFO)
        resume_starved = False
        while self.swapped and self.free_slots:
            req = self.swapped[0]
            got = self.pool.alloc(self.pool.blocks_for(req.cached_len + 1))
            if got is None:
                resume_starved = True
                break
            self.swapped.popleft()
            self._place(req, got, now)
            plan.resume.append(req)

        # 3. admit arrived requests into the remaining free slots.  Not while
        # a swapped request is starved for blocks: a new admission would eat
        # the very blocks it is waiting for (resume priority must hold for
        # blocks, not just slots).
        while self.waiting and self.free_slots and not resume_starved:
            arrival, _, req = self.waiting[0]
            if arrival > now:
                break
            got = self.pool.alloc(self.pool.blocks_for(req.cached_len + 1))
            if got is None:
                break
            heapq.heappop(self.waiting)
            self._place(req, got, now)
            plan.admit.append(req)

        return plan

    # -- horizon granting ---------------------------------------------------

    def grant_horizon(self, max_h: int, now: float,
                      est_step_time: float = 0.0) -> int:
        """Largest safe number of lockstep decode steps for one dispatch.

        Called after :meth:`plan` (so single-step growth is already settled)
        and before the engine launches its fused multi-step decode.  The
        grant is the min of three caps, snapped DOWN to a power of two so the
        engine compiles at most ``log2(max_h)+1`` horizon executables:

        1. **Completion events.**  While admissions or resumes are blocked on
           capacity (a swapped request, or an arrived request still queued),
           the horizon ends at the earliest running completion — min over
           running slots of remaining budget — so freed slots/blocks turn
           into admitted work at the boundary instead of idling frozen.
           (An early EOS can still freeze a slot mid-horizon; that waste is
           bounded by this same cap.)
        2. **Arrival events.**  With a free slot and a future arrival, the
           horizon stops roughly at the admission time (``est_step_time`` is
           the engine's measured per-token decode time; 0 disables the cap).
        3. **Block headroom.**  Every granted step must be able to write its
           KV row: each running request's table is pre-extended to cover
           ``cached_len + min(h, remaining)`` rows *before* the dispatch, so
           the paged kernel never indexes an unallocated page mid-horizon.
           If the pool cannot cover ``h`` steps the grant halves (never
           preempts — ``h == 1`` falls back to plan()'s growth/preemption).
        """
        running = sorted(self.running.values(), key=lambda r: (r.arrival, r.rid))
        if not running:
            return 0
        h = max(1, max_h)
        if self.swapped or (self.waiting and self.waiting[0][0] <= now):
            h = min(h, min(r.remaining for r in running))
        elif self.waiting and self.free_slots and est_step_time > 0:
            until = self.waiting[0][0] - now
            h = min(h, max(1, int(until / est_step_time) + 1))
        h = 1 << (max(1, h).bit_length() - 1)          # snap down to 2^k

        def extra_blocks(hh: int) -> int:
            return sum(
                max(0, self.pool.blocks_for(r.cached_len + min(hh, r.remaining))
                    - len(r.block_table))
                for r in running)

        while h > 1 and extra_blocks(h) > self.pool.free_blocks:
            h //= 2
        if h > 1:
            grew = False
            for r in running:
                rows = r.cached_len + min(h, r.remaining)
                before = len(r.block_table)
                ok = self.pool.extend_to(r.block_table, rows)
                assert ok, "grant_horizon headroom check missed"
                grew |= len(r.block_table) != before
            if grew:
                self.table_version += 1
        return h
