"""Graceful degradation: shed load along a traced ladder, restore in reverse.

The :class:`DegradationController` watches the engine's live observables
each step — pool occupancy, arrived queue depth, preemption churn, and the
speculative ``accept_rate`` — and walks a five-level ladder:

====  ================  ====================================================
lvl   name              effect
====  ================  ====================================================
0     ``normal``        full service
1     ``spec_off``      speculation disabled (K→0): verify rows are the
                        first ballast overboard — they buy latency with
                        extra KV rows and pool pressure
2     ``horizon_min``   horizon grants shrunk to ``min_horizon`` so slots
                        re-plan (and free) at a finer grain
3     ``prefix_release``  prefix-cache retention released: resident chains
                        no longer pin blocks, reclaimable blocks are freed
4     ``admit_deny``    admissions denied with a structured retry-after
                        (queued requests wait; their queue_timeout bounds
                        the wait)
====  ================  ====================================================

Escalation needs ``up_steps`` consecutive unhealthy observations; recovery
needs ``down_steps`` consecutive healthy ones (hysteresis, so the ladder
does not thrash on the boundary).  One level per transition, each traced
as a ``degrade``/``restore`` instant on the scheduler track.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from .trace import NULL_TRACER

__all__ = ["DegradeConfig", "DegradationController", "DEGRADE_LEVELS"]

DEGRADE_LEVELS = ("normal", "spec_off", "horizon_min", "prefix_release",
                  "admit_deny")


@dataclass(frozen=True)
class DegradeConfig:
    """Thresholds and hysteresis for the degradation ladder.

    ``pool_hi``/``pool_lo`` bound pool occupancy (used/total blocks);
    ``queue_hi``/``queue_lo`` bound the *arrived* waiting-queue depth;
    ``churn_hi`` is preemptions-per-observation that count as pressure;
    ``accept_lo`` treats a draining speculative accept rate under mild
    pool pressure as pressure too (verify rows are pure overhead then);
    ``retired_hi`` is the PCRAM bad-block fraction (retired/total) above
    which sustained capacity loss counts as pressure while the surviving
    pool is actually loaded — a retirement storm walks the ladder to
    admission denial instead of crashing into exhaustion.
    """
    pool_hi: float = 0.85
    pool_lo: float = 0.55
    queue_hi: int = 3
    queue_lo: int = 0
    churn_hi: int = 1
    accept_lo: float = 0.25
    retired_hi: float = 0.25
    up_steps: int = 2
    down_steps: int = 6
    min_horizon: int = 2
    retry_after_steps: float = 8.0


class DegradationController:
    def __init__(self, cfg: Optional[DegradeConfig] = None, tracer=None):
        self.cfg = cfg or DegradeConfig()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.level = 0
        self.transitions = 0
        self._hot = 0
        self._cool = 0
        self._est_step_time = 0.0
        # transition log for snapshot(): bounded so a thrash-prone config
        # cannot grow the summary without limit (oldest entries drop)
        self.history: list = []
        self._history_cap = 64

    @property
    def name(self) -> str:
        return DEGRADE_LEVELS[self.level]

    def _log_transition(self, now: float, direction: str) -> None:
        self.history.append({"t": now, "level": self.level,
                             "name": self.name, "dir": direction})
        if len(self.history) > self._history_cap:
            del self.history[0]

    def observe(self, now: float, *, pool_frac: float, queue_depth: int,
                churn: int, accept_rate: Optional[float] = None,
                est_step_time: float = 0.0, active: int = 0,
                retired_frac: float = 0.0) -> int:
        """Feed one step's observables; returns the (possibly new) level.

        ``accept_rate`` is None when no drafting happened this window.
        ``active`` is the running-slot count: queue depth only counts as
        pressure while slots are actually busy, and an *idle* engine always
        reads as calm no matter how deep its queue — otherwise admission
        denial would deadlock (deny ⇒ nothing runs ⇒ queue never drains ⇒
        deny forever).  The restore path is the liveness guarantee.
        ``retired_frac`` is the PCRAM bad-block fraction — sustained
        retirement counts as pressure only while the surviving pool carries
        real load (``pool_frac >= pool_lo``), so a mostly-idle engine with
        old scars stays calm and can still restore.
        """
        c = self.cfg
        self._est_step_time = est_step_time
        pressure = (pool_frac >= c.pool_hi
                    or (queue_depth >= c.queue_hi and active > 0)
                    or churn > c.churn_hi
                    or (accept_rate is not None and accept_rate < c.accept_lo
                        and pool_frac >= c.pool_lo)
                    or (retired_frac >= c.retired_hi
                        and pool_frac >= c.pool_lo))
        calm = (pool_frac <= c.pool_lo and churn == 0
                and (queue_depth <= c.queue_lo or active == 0))
        if pressure:
            self._hot += 1
            self._cool = 0
            if self._hot >= c.up_steps and self.level < len(DEGRADE_LEVELS) - 1:
                self.level += 1
                self.transitions += 1
                self._hot = 0
                self._log_transition(now, "up")
                if self.tracer.enabled:
                    self.tracer.instant(
                        "degrade", "scheduler", "scheduler", ts=now,
                        args={"level": self.level, "name": self.name,
                              "pool_frac": round(pool_frac, 4),
                              "queue_depth": queue_depth, "churn": churn})
        elif calm:
            self._cool += 1
            self._hot = 0
            if self._cool >= c.down_steps and self.level > 0:
                self.level -= 1
                self.transitions += 1
                self._cool = 0
                self._log_transition(now, "down")
                if self.tracer.enabled:
                    self.tracer.instant(
                        "restore", "scheduler", "scheduler", ts=now,
                        args={"level": self.level, "name": self.name})
        else:
            # neither hot nor cool: decay both streaks (require consecutive)
            self._hot = 0
            self._cool = 0
        return self.level

    # ---- engine-facing knobs -------------------------------------------
    def spec_k(self, k: int) -> int:
        return 0 if self.level >= 1 else k

    def horizon_cap(self, h: int) -> int:
        return min(h, self.cfg.min_horizon) if self.level >= 2 else h

    @property
    def release_prefix(self) -> bool:
        return self.level >= 3

    @property
    def deny_admission(self) -> bool:
        return self.level >= 4

    def retry_after(self, now: float) -> float:
        """Structured backoff hint: when a denied client should retry."""
        step = max(self._est_step_time, 1e-3)
        return now + self.cfg.retry_after_steps * step

    def snapshot(self, now: Optional[float] = None) -> dict:
        """Operator-facing state: level, transition history, and — when
        admissions are currently denied and ``now`` is given — the live
        ``retry_after_s`` hint in *relative* seconds (the same number the
        front door returns to rejected clients), else None."""
        retry = None
        if now is not None and self.deny_admission:
            retry = max(0.0, self.retry_after(now) - now)
        return {"level": self.level, "name": self.name,
                "transitions": self.transitions,
                "history": list(self.history),
                "retry_after_s": retry}
