"""Continuous-batching serving engine: the step-loop driver.

One :class:`ServingEngine` owns the compiled step functions (slot-sliced
chunked prefill + activity-masked decode, launch/steps.py), the serving
caches, the device block pool, the swap-tier paged store, and the scheduler.
Each ``step()``:

1. asks the scheduler for a :class:`StepPlan` at the current clock,
2. executes preemptions (swap-out copy / recompute requeue), resumes
   (swap-in copy) and admissions (chunked prefill; the prefill's last
   logits yield the request's **first generated token**, so TTFT is stamped
   here),
3. runs a fixed-shape decode over every slot with the activity mask —
   either one ``[B_slots, 1]`` step (``horizon=1``, the parity baseline) or
   a **horizon-batched** dispatch (``horizon>1``): the scheduler grants the
   largest safe number of lockstep steps (``grant_horizon``), pre-extends
   block tables for all of them, and one compiled ``lax.scan`` generates up
   to ``h`` tokens per slot on-device, feeding each sampled token back as
   the next input and freezing slots mid-horizon at EOS or budget
   exhaustion.  The host pays ONE dispatch and ONE sync per horizon instead
   of per token — emitted tokens get interpolated timestamps — then appends
   tokens, retires finished requests, and frees their slots/blocks for the
   next step's admissions.

For paged-capable attention families (non-windowed GQA) the device block
pool IS the physical KV store: the caches hold ``k_pool/v_pool`` block
arrays, the engine mirrors every running request's block table into a
``[slots, n_pages]`` device array each step, prefill writes blocks directly,
decode attends through the Pallas paged kernel, and swap-preemption is a
block-to-block copy keyed by table ids instead of an O(max_len) slot-row
scatter.  MLA and sliding-window families keep their dense/ring live caches
behind the same block accounting.

Everything runs at fixed ``[B_slots, S_max]`` / ``[B_slots, 1]`` shapes, so
one compiled executable serves every request mix; only distinct prefill
chunk lengths trace separately (bounded by the workload's length buckets).

Sampling: ``temperature > 0`` switches the decode step (and the prefill's
first token) from greedy argmax to temperature + top-k sampling with
per-slot PRNG keys folded from ``sample_seed`` and the decode step counter.
Greedy (the default) keeps the preemption-parity guarantee; sampled streams
are deterministic for a fixed seed and schedule.

Execution modes follow ``OdinConfig``: ``odin_mode="exact"`` runs the exact
matmuls, ``"int8"`` the ODIN fixed-8-bit expected-value surrogate, ``"sc"``
the bit-parallel stochastic kernels (slow; reference).
"""
from __future__ import annotations

import time
from collections import OrderedDict
from contextlib import nullcontext
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.steps import (init_serving_caches,
                                make_serving_decode_guarded,
                                make_serving_decode_horizon,
                                make_serving_decode_step,
                                make_serving_mixed_step,
                                make_serving_spec_horizon,
                                make_slot_prefill_step, pageable_block,
                                speculable)
from repro.models import lm
from repro.nn import module as nnmod
from repro.nn.attention import POOL_LEAVES
from repro.serving.blocks import (SEQ_LEAVES, BlockPool, PagedKVStore,
                                  _leaf_name)
from repro.serving.degrade import DegradationController, DegradeConfig
from repro.serving.faults import (EngineStallError, FaultPlan, ShuttingDown,
                                  SwapCopyError)
from repro.serving.metrics import EngineStats, OdinCostModel, summarize
from repro.serving.reliability import ReliabilityConfig
from repro.serving.scheduler import (PrefixCache, PrefixGrant, Request,
                                     RequestState, Scheduler)
from repro.serving.trace import NULL_TRACER, MetricsRegistry

__all__ = ["ServingEngine"]


class ServingEngine:
    """Drives continuous-batching inference over ``slots`` cache slots.

    Parameters
    ----------
    cfg : ModelConfig (smoke or full).
    slots : decode batch width B (one compiled ``[B, 1]`` decode step).
    max_len : per-slot cache depth; every request needs prompt+max_new ≤ max_len.
    block_size : KV block granularity (max_len must divide evenly).
    n_blocks : device KV budget in blocks.  Default ``slots·max_len/block_size``
        (never preempts); set lower to exercise preemption under load.
    swap_blocks : swap-tier capacity in blocks (0 disables swap — preemption
        falls back to recompute).
    prefill_chunk : chunked-prefill granularity (default: max_len, i.e. one
        chunk).  Smaller chunks bound the prefill executable's shape.
    paged : use the paged physical KV store for paged-capable attention
        families (non-windowed GQA).  ``False`` keeps the PR-1 dense
        ``[slots, max_len]`` live caches everywhere (the benchmark baseline).
    prefix_sharing : dedup identical prompt prefixes across requests via
        refcounted block aliasing + copy-on-write forks (scheduler
        PrefixCache): admissions alias resident prefix blocks and prefill
        only the unmatched tail.  ``None`` (default) enables it exactly when
        the whole model state is paged — every cache leaf lives in the block
        pool (non-windowed GQA stacks); MLA / sliding-window / recurrent
        families keep per-slot dense state a shared block cannot cover, so
        sharing silently stays off.  ``True`` raises if the model is not
        fully paged; requests carrying ``extras`` (vision patch embeddings —
        KV not token-determined) always bypass matching and registration.
    horizon : max decode steps fused into one dispatch.  1 (default) is the
        single-step parity baseline; >1 asks ``Scheduler.grant_horizon`` for
        the largest safe power-of-two grant each step and runs the fused
        on-device loop.  Greedy token streams are identical for every
        horizon; sampled streams match whenever the slot schedule does (the
        per-step key folds the *global* decode-step counter either way).
    spec_ngram : draft length K for n-gram self-speculative decode (0
        disables).  Each horizon inner step drafts K tokens by prompt-lookup
        over the slot's on-device token history, verifies all K+1 logits in
        ONE forward through the multi-token-query paged kernel, emits the
        longest accepted prefix plus the bonus token (1..K+1 tokens per
        inner step — every one a greedy argmax, so spec-on streams are
        token-identical to spec-off by construction), and rolls rejected KV
        rows back by not advancing the slot's length.  Greedy only
        (temperature must be 0); requires every cache leaf to be
        position-addressed (no SSM/xLSTM recurrent state) and a
        single-codebook vocabulary — ``speculable(cfg)``.
    spec_hist : token-history window for the n-gram draft match (per slot,
        device-resident; seeded from the prompt tail at admission).
    mixed : fused mixed prefill+decode dispatch (chunked-prefill
        piggybacking, à la Sarathi/vLLM).  While any slot is mid-prompt, ONE
        dispatch carries [decode slots at q_len = 1] + [prefill slots at
        q_len = chunk-or-less], packed by ``Scheduler.pack_mixed`` under
        ``mixed_budget`` total query rows — running streams keep emitting
        every dispatch instead of stalling behind an admission's prefill
        loop, which is what makes steady-state TPOT independent of arrival
        bursts.  ``None`` (default) enables it exactly when the whole model
        state is paged (same gate as prefix sharing: the mixed tile writes
        KV through the block tables, so per-slot dense/recurrent state
        cannot ride along); ``True`` raises if the model is not fully
        paged; ``False`` keeps the separate alternating prefill/decode
        paths (the ``--no-mixed`` baseline).  Greedy mixed-on streams are
        token-identical to mixed-off: each emitted token is still the
        argmax at the same position over the same KV (requests carrying
        ``extras`` always take the separate single-chunk prefill).
    mixed_budget : total query rows per mixed dispatch (default
        ``prefill_chunk + slots``: every decode slot rides along at full
        chunk-rate prefill progress).  Decode rows are packed first; one
        row is always reserved for the oldest mid-prefill slot.
    jit_cache : max fused decode executables kept compiled (LRU over
        (horizon, spec) grants; evictions counted in ``EngineStats``).
    jit_cache : max fused decode executables kept compiled (LRU over
        (horizon, spec) grants; evictions counted in ``EngineStats``).
    eos_id : token id that ends a request early (None disables; multi-
        codebook models match on the first codebook).  Checked on-device
        inside horizons and host-side everywhere else.
    temperature / top_k / sample_seed : decode sampling (0 ⇒ greedy argmax).
        Sampled streams are deterministic for a fixed seed and schedule, but
        NOT preemption-invariant (a resume re-enters the per-step key
        stream); greedy keeps the token-stream parity guarantee.
    odin_mode : override cfg.odin_mode ("exact" | "int8" | "sc").
    on_token : streaming callback ``(request, token, t_now)`` per emitted
        token.  Inside a horizon, per-token timestamps are interpolated
        across the dispatch's wall time (TTFT from prefill stays exact).
    clock : monotonic seconds callable (injectable for deterministic tests).
    tracer : a :class:`repro.serving.trace.Tracer` to record dispatch spans,
        request lifecycle flows and scheduler/pool decision events into
        (exportable as Perfetto-loadable Chrome trace JSON).  Default None ⇒
        the no-op recorder: every emit site is guarded by ``tracer.enabled``,
        so the trace-off hot path allocates nothing per dispatch.
    metrics_window : window length (engine-clock seconds) for the windowed
        metrics registry — TTFT/TPOT/dispatch-wall-time histograms and
        counter deltas are snapshotted per window so long runs report
        p50/p99 over time (``summary()["metrics"]["windows"]``).
    xla_annotations : wrap each compiled dispatch in a
        ``jax.profiler.TraceAnnotation`` named ``serving/<kind>`` so XLA
        profiler timelines line up with the engine's own dispatch spans.
    deadline_s / queue_timeout_s : engine-wide defaults stamped onto every
        submitted request that does not carry its own ``deadline`` /
        ``queue_timeout``.  A past-deadline request is released as
        ``TIMEOUT`` at the next step boundary from ANY live state (queued,
        swapped, or running mid-horizon — ``grant_horizon`` additionally
        caps horizons at the earliest running deadline so a fused dispatch
        never burns a full grant of dead work); ``queue_timeout`` is
        relative to arrival and applies only while the request has never
        been admitted.  Requests without lifecycle fields are never
        scanned — the guards-off hot path pays nothing.
    fault_plan : a :class:`repro.serving.faults.FaultPlan` to replay —
        deterministic fault events consumed at the top of each step
        (allocation failures, swap-copy faults, NaN-poisoned logits, clock
        skew).  The engine *contains* every injected fault: no event may
        escape ``step()`` as an exception.  Test/bench-only.
    nan_guard : route fault-step decodes through the guarded executable
        that flags non-finite per-slot logits; a flagged slot's request is
        quarantined as ``FAILED`` ("nan_logits") while co-batched slots
        keep bit-identical streams.  Default None ⇒ enabled exactly when a
        ``fault_plan`` is attached.
    degrade : graceful-degradation controller — True (default thresholds),
        a :class:`~repro.serving.degrade.DegradeConfig`, or a ready
        :class:`~repro.serving.degrade.DegradationController`.  Watches
        pool occupancy / arrived queue depth / preemption churn /
        ``accept_rate`` each step and sheds load along the traced ladder
        (speculation off → horizon shrunk → prefix retention released →
        admission denial with structured retry-after), restoring in
        reverse under hysteresis.  None disables (no per-step cost).
    reliability : PCRAM reliability layer — ``True`` for defaults
        (wear-leveled allocation, no endurance budget, no scrub), a
        :class:`~repro.serving.reliability.ReliabilityConfig` for full
        control, or None/False (off).  Per-block write-endurance accounting
        in the pool is always on (host-side bookkeeping); with a config
        attached the engine additionally wear-levels allocation, drains and
        retires blocks that cross the endurance budget (or are hit by a
        ``stuck_at`` fault), and runs the drift-refresh scrubber — all via
        block copies of identical bytes, so greedy streams stay
        bit-identical with reliability on vs. off.
    """

    def __init__(self, cfg: ModelConfig, *, slots: int, max_len: int,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 swap_blocks: int = 0, prefill_chunk: Optional[int] = None,
                 paged: bool = True, prefix_sharing: Optional[bool] = None,
                 mixed: Optional[bool] = None,
                 mixed_budget: Optional[int] = None,
                 horizon: int = 1, spec_ngram: int = 0, spec_hist: int = 64,
                 jit_cache: int = 8,
                 eos_id: Optional[int] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0,
                 params=None, seed: int = 0, odin_mode: Optional[str] = None,
                 on_token: Optional[Callable] = None,
                 clock: Optional[Callable[[], float]] = None,
                 attribution_cfg: Optional[ModelConfig] = None,
                 tracer=None, metrics_window: float = 1.0,
                 xla_annotations: bool = False,
                 deadline_s: Optional[float] = None,
                 queue_timeout_s: Optional[float] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 nan_guard: Optional[bool] = None,
                 degrade=None,
                 reliability=None):
        if odin_mode is not None:
            cfg = cfg.with_overrides(odin_mode=odin_mode)
        if max_len % block_size:
            raise ValueError(f"max_len {max_len} not divisible by block_size {block_size}")
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.block_size = block_size
        self.n_pages = max_len // block_size
        # Default chunk is bounded: serving prefill routes MoE drop-free, so
        # its expert dispatch buffer scales with the chunk's token count —
        # an unbounded max_len default would pay [E, max_len, d] per layer on
        # full configs.  Drop-free routing is chunk-invariant, so chunking
        # never changes results.
        self.chunk = prefill_chunk or min(max_len, 512)
        if params is None:
            params = nnmod.materialize(lm.param_spec(cfg), jax.random.PRNGKey(seed))
        self.params = params
        self.on_token = on_token
        self._clock = clock or time.monotonic
        self._t0: Optional[float] = None
        # clock-skew fault state: an injected offset plus a monotone clamp
        # (a negative skew must never run the engine clock backwards —
        # timestamps, windows and deadlines all assume monotonicity)
        self._skew = 0.0
        self._last_now = 0.0
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.sample_seed = int(sample_seed)
        self._sample_key = jax.random.PRNGKey(sample_seed)
        self.horizon = int(horizon)
        self.eos_id = None if eos_id is None else int(eos_id)
        self.spec_ngram = int(spec_ngram)
        self.spec_hist = int(spec_hist)
        self._spec_n = 2                    # n-gram match length (bigram)
        self.jit_cache = int(jit_cache)
        if self.jit_cache < 1:
            raise ValueError(f"jit_cache must be >= 1, got {self.jit_cache}")

        if n_blocks is None:
            n_blocks = slots * (max_len // block_size)
        self.paged = paged and any(pageable_block(b) for b in cfg.blocks)

        # ring buffers get `chunk` rows of headroom so chunked prefill is
        # exact for sliding-window attention (steps.init_serving_caches);
        # paged-capable segments get the physical block pool instead of a
        # dense live cache — their device KV bytes are n_blocks·block_size
        # rows, not slots·max_len.
        self.caches = init_serving_caches(
            cfg, slots, max_len, window_headroom=self.chunk,
            round_to=block_size, block_size=block_size,
            n_blocks=n_blocks if self.paged else 0)
        self._prefill = jax.jit(make_slot_prefill_step(
            cfg, max_len, window_headroom=self.chunk, round_to=block_size,
            block_size=block_size, paged=self.paged))
        self._decode = jax.jit(
            make_serving_decode_step(cfg, top_k=self.top_k,
                                     sample=self.temperature > 0),
            donate_argnums=(1,))
        # fused decode executables, one per granted (power-of-two h, spec K)
        # pair — built lazily, bounded LRU (horizon × spec grant combinations
        # must not grow the jit cache without bound)
        self._fused: "OrderedDict[Tuple[int, int], Callable]" = OrderedDict()

        if self.spec_ngram:
            if not speculable(cfg):
                raise ValueError(
                    "spec_ngram needs a single-codebook model whose decode "
                    "state is entirely position-addressed (no SSM/xLSTM "
                    "recurrent segments) — rollback of rejected draft rows "
                    "is a length decrement, which recurrent state and "
                    "codebook frames cannot honor")
            if self.temperature > 0:
                raise ValueError(
                    "spec_ngram is greedy-only (the accept rule compares "
                    "argmaxes); set temperature=0")
            if self.spec_hist < self.spec_ngram + self._spec_n + 1:
                raise ValueError(
                    f"spec_hist {self.spec_hist} too short for K="
                    f"{self.spec_ngram} drafts with {self._spec_n}-gram match")
            if any(b.attn is not None and b.attn.window
                   for b in cfg.blocks) and self.chunk <= self.spec_ngram:
                raise ValueError(
                    "sliding-window ring headroom (prefill_chunk = "
                    f"{self.chunk}) must exceed spec_ngram {self.spec_ngram}: "
                    "a verify tile may overwrite ring rows up to K past the "
                    "committed length")

        # ---- PCRAM reliability layer --------------------------------------
        # True → defaults (wear-leveled allocation, no budget, no scrub);
        # ReliabilityConfig → as given; None/False → off.  The wear
        # *accounting* in the pool is always on (pure host bookkeeping) so
        # the bench can compare allocator policies; budget-driven retirement
        # and the drift scrubber only run with a config attached.
        if reliability is None or reliability is False:
            self.reliability: Optional[ReliabilityConfig] = None
        elif reliability is True:
            self.reliability = ReliabilityConfig()
        else:
            self.reliability = reliability
        rel = self.reliability
        # blocks flagged bad (stuck-at faults, failed retirements) awaiting
        # drain+retire by the sweep — processed even with reliability off so
        # an injected stuck_at fault is always contained
        self._pending_bad: List[int] = []
        self._gauge_tick = 0
        self.pool = BlockPool(
            n_blocks, block_size,
            policy=("min_wear" if rel is not None and rel.wear_leveling
                    else "lifo"),
            endurance_budget=rel.endurance_budget if rel is not None else None)
        # prefix sharing needs the block pool to BE the whole model state:
        # every cache leaf either lives in the pool or is the per-slot `pos`
        # counter the tail prefill re-derives.  Any dense KV row or recurrent
        # state would be skipped by a shared-prefix (tail-only) prefill.
        fully_paged = self.paged and all(
            _leaf_name(p) in POOL_LEAVES + ("pos",)
            for p, _ in jax.tree_util.tree_flatten_with_path(self.caches)[0])
        if prefix_sharing is None:
            prefix_sharing = fully_paged
        elif prefix_sharing and not fully_paged:
            raise ValueError(
                "prefix_sharing=True needs a fully paged cache layout "
                "(non-windowed GQA families with paged=True); this model "
                "keeps per-slot dense/recurrent state a shared block cannot "
                "cover")
        self.prefix_sharing = bool(prefix_sharing)
        # mixed dispatch shares prefix sharing's gate: the fused tile writes
        # prompt KV through the block tables, so every cache leaf must be the
        # pool (or the `pos` counter the mixed step re-derives).  Dense ring
        # or recurrent state would need per-slot multi-row advances the
        # [slots, Q] tile cannot express for heterogeneous q_lens.
        if mixed is None:
            mixed = fully_paged
        elif mixed and not fully_paged:
            raise ValueError(
                "mixed=True needs a fully paged cache layout (non-windowed "
                "GQA families with paged=True); this model keeps per-slot "
                "dense/recurrent state a mixed prefill+decode tile cannot "
                "advance by heterogeneous per-slot row counts")
        self.mixed = bool(mixed)
        self.mixed_budget = int(mixed_budget if mixed_budget is not None
                                else self.chunk + slots)
        if self.mixed and self.mixed_budget < 2:
            raise ValueError(
                f"mixed_budget must be >= 2 (one decode row plus one prefill "
                f"row), got {self.mixed_budget}")
        self._mixed: Optional[Callable] = None      # lazily jitted
        prefix_cache = (PrefixCache(self.pool, block_size)
                        if self.prefix_sharing else None)
        self.store = (PagedKVStore(self.caches, swap_blocks, block_size)
                      if swap_blocks else None)
        self.sched = Scheduler(slots, self.pool, max_len,
                               swap_pool=self.store.pool if self.store else None,
                               prefix_cache=prefix_cache,
                               write_span=self.spec_ngram + 1)
        # under mixed dispatch a prompt chain is registered only once its
        # staged replay finishes (Scheduler.finish_prefill) — registering at
        # admission would let a later arrival share blocks whose rows the
        # staged prefill has not written yet
        self.sched.defer_prefix_register = self.mixed
        self.stats = EngineStats()
        self.stats.kv_cache_bytes = self._kv_bytes()
        self.cost_model = OdinCostModel(attribution_cfg or cfg)
        # observability: structured tracer (no-op by default — every emit
        # site is guarded on tracer.enabled so trace-off costs nothing) and
        # the always-on windowed metrics registry
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.tracer.set_clock(self._now)
        self.sched.tracer = self.tracer
        self.pool.tracer = self.tracer
        if self.store is not None:
            self.store.pool.tracer = self.tracer
        self.metrics = MetricsRegistry(window_s=metrics_window)
        # open the first window at t≈0 so no counter movement predates the
        # baseline (maybe_roll's first call only initializes)
        self.metrics.maybe_roll(self._now(), self._counter_snapshot())
        self.xla_annotations = bool(xla_annotations)

        # ---- robustness substrate ----------------------------------------
        self.deadline_s = deadline_s
        self.queue_timeout_s = queue_timeout_s
        self.fault_plan = fault_plan
        self._nan_guard = (bool(nan_guard) if nan_guard is not None
                           else fault_plan is not None)
        self._guarded: Optional[Callable] = None    # lazily jitted
        if degrade is None or degrade is False:
            self.degrade = None
        elif degrade is True:
            self.degrade = DegradationController(tracer=self.tracer)
        elif isinstance(degrade, DegradeConfig):
            self.degrade = DegradationController(degrade, tracer=self.tracer)
        else:
            self.degrade = degrade
        # shutdown latch: drain() (or the front door's SIGTERM handler) sets
        # it, after which late submits get a typed ShuttingDown rejection
        # instead of queueing behind a loop that will never admit them
        self.draining = False
        # only requests carrying lifecycle fields are scanned per step, so
        # a workload without deadlines/cancellations pays nothing here
        self._watched: List[Request] = []
        self._by_rid: Dict[int, Request] = {}
        # observe() deltas for the degradation controller
        self._churn_mark = 0
        self._spec_mark = (0, 0)

        K = cfg.n_codebooks
        tok_shape = (slots, K, 1) if K > 1 else (slots, 1)
        self._last_tok = jnp.zeros(tok_shape, jnp.int32)
        # per-slot token-history ring for the on-device n-gram draft match
        # (right-aligned, -1 padded; shifted on-device inside the spec scan)
        self._hist = (jnp.full((slots, self.spec_hist), -1, jnp.int32)
                      if self.spec_ngram else None)
        self._slot_len = np.zeros(slots, np.int32)
        self._tables = np.zeros((slots, self.n_pages), np.int32)
        self._tables_dev = jnp.asarray(self._tables)
        self._synced_version = self.sched.table_version
        self._done: List[Request] = []

    # ------------------------------------------------------------------ util

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = self._clock()
        t = self._clock() - self._t0 + self._skew
        if t < self._last_now:          # monotone clamp (clock-skew faults)
            t = self._last_now
        else:
            self._last_now = t
        return t

    def _kv_bytes(self) -> int:
        """Device bytes held by KV-bearing cache leaves (the paged-vs-dense
        memory observable the serving benchmark records)."""
        names = SEQ_LEAVES + POOL_LEAVES
        return int(sum(
            l.nbytes for p, l in jax.tree_util.tree_flatten_with_path(self.caches)[0]
            if _leaf_name(p) in names))

    @staticmethod
    def _slot_track(slot: int) -> str:
        return f"slot {slot}"

    def _annotate(self, kind: str):
        """Optional XLA-profiler annotation around a compiled dispatch, so
        device timelines line up with the engine's own spans."""
        if self.xla_annotations:
            return jax.profiler.TraceAnnotation(f"serving/{kind}")
        return nullcontext()

    def _counter_snapshot(self) -> Dict[str, float]:
        """Cumulative counters the metrics registry turns into window deltas."""
        st = self.stats
        return {"generated_tokens": st.generated_tokens,
                "decode_tokens": st.decode_tokens,
                "prefill_tokens": st.prefill_tokens,
                "dispatches": st.dispatches,
                "decode_dispatches": st.decode_dispatches,
                "mixed_dispatches": st.mixed_dispatches,
                "host_syncs": st.host_syncs,
                "preempt_swap": st.preempt_swap,
                "preempt_recompute": st.preempt_recompute,
                "spec_drafted": st.spec_drafted,
                "spec_accepted": st.spec_accepted,
                "spec_overhead_rows": st.spec_overhead_rows,
                "decode_time_s": st.decode_time,
                "prefill_time_s": st.prefill_time,
                "pool_writes": st.pool_writes,
                "retired_blocks": st.retired_blocks,
                "scrub_copies": st.scrub_copies,
                "scrub_rows": st.scrub_rows}

    def _set_last_tok(self, slot: int, tok) -> None:
        tok = jnp.asarray(tok, jnp.int32).reshape(self._last_tok.shape[1:])
        self._last_tok = self._last_tok.at[slot].set(tok)

    def _seed_hist(self, req: Request) -> None:
        """(Re)build the slot's draft-match history from the request's full
        token context (prompt + every generated token, pending included) —
        host-side only at admission/resume; the spec scan shifts emitted
        tokens in on-device."""
        ctx = np.concatenate([np.asarray(req.replay_tokens(), np.int32).ravel(),
                              np.ravel(req.generated[-1]).astype(np.int32)])
        row = np.full(self.spec_hist, -1, np.int32)
        tail = ctx[-self.spec_hist:]
        row[self.spec_hist - len(tail):] = tail
        self._hist = self._hist.at[req.slot].set(jnp.asarray(row))

    def _refresh_tables(self) -> jax.Array:
        """Device mirror of running requests' block tables ([slots, P] int32).

        Dirty-tracked against ``Scheduler.table_version``: the host loop and
        the host→device upload only run on steps where some table actually
        changed (growth, admission, preemption, resume, completion, horizon
        pre-extension) — steady-state decode reuses the cached device array.
        Entries past a table's length are stale ids — harmless, the kernel
        masks pages at or beyond the slot's length."""
        if self._synced_version != self.sched.table_version:
            for slot, req in self.sched.running.items():
                bt = req.block_table
                self._tables[slot, :len(bt)] = bt
            self._tables_dev = jnp.asarray(self._tables)
            self._synced_version = self.sched.table_version
        return self._tables_dev

    def _first_token(self, last_logits, req: Request) -> np.ndarray:
        """The request's first generated token from its prefill logits:
        greedy, or the engine's temperature/top-k sampling with a per-request
        key (host-side — prefill logits are already on the host path)."""
        logits = np.asarray(last_logits, np.float32)[0]        # [V] or [K, V]
        if self.temperature <= 0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        if self.top_k:
            kth = np.sort(logits, axis=-1)[..., -self.top_k, None]
            logits = np.where(logits >= kth, logits, -np.inf)
        rng = np.random.default_rng((self.sample_seed, req.rid))
        z = logits / max(self.temperature, 1e-6) + rng.gumbel(size=logits.shape)
        z = np.where(np.isfinite(logits), z, -np.inf)
        return np.argmax(z, axis=-1).astype(np.int32)

    def _emit(self, req: Request, tok: np.ndarray, now: float) -> None:
        req.generated.append(tok)
        self.stats.generated_tokens += 1
        if self.eos_id is not None and int(np.ravel(tok)[0]) == self.eos_id:
            req.eos = True                 # first codebook, same as on-device
        if req.t_first_token is None:
            req.t_first_token = now
            ttft = max(0.0, now - req.arrival)
            self.metrics.observe("ttft_s", ttft)
            if req.tenant is not None:
                self.metrics.observe(f"ttft_s/{req.tenant}", ttft)
        if self.on_token is not None:
            self.on_token(req, tok, now)

    # ------------------------------------------------------------- lifecycle

    @staticmethod
    def _extras_worst_replay(req: Request) -> int:
        """Worst-case rows a (re-)prefill of this request can ever replay:
        the prompt plus every generated token except the pending one (a
        recompute preemption at max_new-1 generated tokens replays exactly
        this many)."""
        return req.prompt_len + req.max_new - 1

    def _check_extras_fit(self, req: Request) -> None:
        """THE extras/chunk guard — shared by submit() and the prefill path
        so the two can never disagree.  The extras overlay (patch_embeds /
        pos3d) only works when the whole replay lands in a single prefill
        chunk; checking the worst-case replay length here means a request
        that passes submit() can never be rejected mid-run at readmission."""
        worst = self._extras_worst_replay(req)
        if req.extras and worst > self.chunk:
            raise ValueError(
                f"request {req.rid}: extras (patch_embeds/pos3d) need the "
                f"worst-case replay (prompt+max_new-1 = {worst}) to fit one "
                f"prefill chunk ({self.chunk})")

    def submit(self, req: Request) -> None:
        if self.draining:
            raise ShuttingDown(
                f"request {req.rid}: engine is draining — submissions after "
                f"drain() begin get a typed rejection, never a silent hang")
        self._check_extras_fit(req)
        if req.deadline is None and self.deadline_s is not None:
            req.deadline = req.arrival + self.deadline_s
        if req.queue_timeout is None and self.queue_timeout_s is not None:
            req.queue_timeout = self.queue_timeout_s
        self.sched.submit(req)
        self._by_rid[req.rid] = req
        if (req.deadline is not None or req.queue_timeout is not None
                or req.cancel_at is not None):
            self._watched.append(req)
        if self.tracer.enabled:
            t = self._now()
            # the flow "s" anchor: every later lifecycle event for this rid
            # hangs off this arrow chain (admit → prefill → … → complete)
            self.tracer.flow_event("s", "request", "scheduler", req.rid, ts=t)
            args = {"rid": req.rid, "prompt_tokens": req.prompt_len,
                    "max_new": req.max_new}
            if req.tenant is not None:
                args["tenant"] = req.tenant
            self.tracer.instant("queued", "lifecycle", "scheduler", ts=t,
                                args=args, flow=req.rid)

    def _complete(self, req: Request, now: float) -> None:
        slot = req.slot
        self.sched.complete(req, now)
        self._done.append(req)
        if req.t_first_token is not None and req.n_generated > 1:
            tpot = max(0.0, (now - req.t_first_token) / (req.n_generated - 1))
            self.metrics.observe("tpot_s", tpot)
            if req.tenant is not None:
                self.metrics.observe(f"tpot_s/{req.tenant}", tpot)
        if self.tracer.enabled:
            track = self._slot_track(slot) if slot >= 0 else "scheduler"
            args = {"rid": req.rid, "generated_tokens": req.n_generated,
                    "eos": bool(req.eos)}
            if req.tenant is not None:
                args["tenant"] = req.tenant
            self.tracer.instant("complete", "lifecycle", track, ts=now,
                                args=args, flow=req.rid)
            self.tracer.flow_event("f", "request", track, req.rid, ts=now)

    _TERMINAL_EVENT = {RequestState.TIMEOUT: "timeout",
                       RequestState.CANCELLED: "cancel",
                       RequestState.FAILED: "failed"}

    def _finalize(self, req: Request, state: RequestState, reason: str,
                  now: float) -> None:
        """Release a live request into a non-DONE terminal state (the DONE
        path stays :meth:`_complete`): scheduler teardown from wherever it
        is in the lifecycle, terminal bookkeeping, lifecycle trace events."""
        slot = req.slot
        self.sched.release(req, state, now, reason)
        self._done.append(req)
        if state is RequestState.TIMEOUT:
            self.stats.timeouts += 1
        elif state is RequestState.CANCELLED:
            self.stats.cancelled += 1
        else:
            self.stats.failed += 1
        if self.tracer.enabled:
            track = self._slot_track(slot) if slot >= 0 else "scheduler"
            args = {"rid": req.rid, "reason": reason,
                    "generated_tokens": req.n_generated}
            if req.tenant is not None:
                args["tenant"] = req.tenant
            self.tracer.instant(
                self._TERMINAL_EVENT[state], "lifecycle", track, ts=now,
                args=args, flow=req.rid)
            self.tracer.flow_event("f", "request", track, req.rid, ts=now)

    def cancel(self, rid: int, reason: str = "client") -> bool:
        """Client-side cancellation: release request ``rid`` from any live
        state (slot freed, refcount claims dropped, swap ticket returned).
        Returns False when the rid is unknown or already terminal — cancel
        is idempotent and never raises."""
        req = self._by_rid.get(rid)
        if req is None or req.terminal:
            return False
        self._finalize(req, RequestState.CANCELLED, reason, self._now())
        return True

    def _expire(self, now: float) -> None:
        """Sweep watched requests for scripted cancellations, deadlines and
        queue timeouts.  Runs at the top of each step, so a mid-horizon
        deadline is enforced at the next step boundary (grant_horizon's
        deadline cap keeps that boundary close)."""
        alive: List[Request] = []
        for req in self._watched:
            if req.terminal:
                continue
            if req.cancel_at is not None and now >= req.cancel_at:
                self._finalize(req, RequestState.CANCELLED, "client", now)
            elif req.deadline is not None and now >= req.deadline:
                self._finalize(req, RequestState.TIMEOUT, "deadline", now)
            elif (req.queue_timeout is not None and req.t_admit is None
                    and now >= req.arrival + req.queue_timeout):
                self._finalize(req, RequestState.TIMEOUT, "queue", now)
            else:
                alive.append(req)
        self._watched = alive

    def _apply_faults(self, now: float):
        """Consume this step's fault events from the plan.  Arming faults
        (alloc/swap/clock) mutate the seams directly; a ``nan_logits`` event
        is returned for the decode phase to inject through the guarded
        executable."""
        nan_ev = None
        for ev in self.fault_plan.events_at(self.stats.steps):
            self.stats.faults_injected += 1
            if ev.site == "alloc":
                self.pool.arm_alloc_failures(ev.count)
                self.stats.alloc_faults += ev.count
                self.fault_plan.record(ev, "armed", count=ev.count)
            elif ev.site in ("swap_out", "swap_in"):
                if self.store is None:
                    self.fault_plan.record(ev, "skipped-no-swap-tier")
                else:
                    self.store.arm_swap_failures(ev.site[5:], ev.count)
                    self.fault_plan.record(ev, "armed", count=ev.count)
            elif ev.site == "clock_skew":
                self._skew += ev.skew_s
                self.fault_plan.record(ev, "applied", skew_s=ev.skew_s)
            elif ev.site == "stuck_at":
                # one PCRAM block develops a stuck-at cell: flag it for the
                # reliability sweep to drain+retire before the next dispatch
                if self.pool.n_blocks == 0:
                    self.fault_plan.record(ev, "skipped-empty-pool")
                else:
                    bid = ev.slot % self.pool.n_blocks
                    if bid in self.pool.retired:
                        self.fault_plan.record(ev, "already-retired", block=bid)
                    else:
                        self._pending_bad.append(bid)
                        self.fault_plan.record(ev, "flagged", block=bid)
            elif ev.site == "wear_exhaustion":
                # the count most-worn live blocks burn through their
                # remaining endurance at once — a retirement storm
                order = np.argsort(self.pool.wear, kind="stable")[::-1]
                picked = [int(b) for b in order
                          if int(b) not in self.pool.retired][:ev.count]
                self._pending_bad.extend(picked)
                self.fault_plan.record(ev, "flagged", blocks=picked)
            elif ev.site == "nan_logits":
                if self._nan_guard:
                    nan_ev = ev
                else:
                    self.fault_plan.record(ev, "skipped-guard-off")
            if self.tracer.enabled:
                self.tracer.instant("fault-inject", "faults", "scheduler",
                                    ts=now, args={"site": ev.site,
                                                  "step": ev.step,
                                                  "count": ev.count})
        return nan_ev

    def _observe_degrade(self, now: float) -> None:
        """Feed the controller this step's observables and push its knobs
        into the scheduler (admission hold, prefix retention) — decode-side
        knobs (spec K, horizon cap) are read in the decode routing."""
        ctl = self.degrade
        churn_now = self.stats.preempt_swap + self.stats.preempt_recompute
        churn = churn_now - self._churn_mark
        self._churn_mark = churn_now
        d_draft = self.stats.spec_drafted - self._spec_mark[0]
        d_acc = self.stats.spec_accepted - self._spec_mark[1]
        self._spec_mark = (self.stats.spec_drafted, self.stats.spec_accepted)
        ctl.observe(
            now,
            # occupancy over the SURVIVING capacity: retirement shrinks the
            # denominator, so sustained bad-block loss reads as pressure
            # through the same pool_frac trigger load always has
            pool_frac=self.pool.used_blocks / max(1, self.pool.usable_blocks),
            queue_depth=sum(1 for a, _, _ in self.sched.waiting if a <= now),
            churn=churn,
            accept_rate=(d_acc / d_draft) if d_draft else None,
            est_step_time=self._est_step_time(),
            active=len(self.sched.running),
            retired_frac=len(self.pool.retired) / max(1, self.pool.n_blocks))
        self.sched.admission_hold = (ctl.retry_after(now)
                                     if ctl.deny_admission else None)
        self.sched.prefix_retain = not ctl.release_prefix
        cache = self.sched.prefix_cache
        if ctl.release_prefix and cache is not None:
            n = cache.reclaimable()
            if n:
                cache.reclaim(n)
        self.stats.degrade_level = ctl.level
        self.stats.degrade_transitions = ctl.transitions

    def drain(self, max_steps: int = 100_000) -> Dict:
        """Graceful shutdown: cancel every request that never started
        (reason "drain"), then drive the loop until all in-flight work —
        running, swapped, and preempted-but-admitted requests — finishes.
        Once draining, late :meth:`submit` calls raise :class:`ShuttingDown`.
        Returns the final summary."""
        self.draining = True
        now = self._now()
        for _, _, req in list(self.sched.waiting):
            if req.t_admit is None:
                self._finalize(req, RequestState.CANCELLED, "drain", now)
        steps = 0
        while self.sched.has_work:
            self.step()
            steps += 1
            if steps > max_steps:
                raise EngineStallError(
                    f"drain exceeded {max_steps} steps",
                    summary=self.summary())
        return self.summary()

    def _cow_fork(self, src: int, dst: int) -> None:
        """Execute a COW fork: copy pool block ``src`` into ``dst`` on every
        pool leaf, before the forking slot writes its tail rows into ``dst``."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.caches)
        t0 = self._now() if self.tracer.enabled else 0.0
        out = []
        for path, leaf in flat:
            if _leaf_name(path) in POOL_LEAVES:
                leaf = leaf.at[:, dst].set(leaf[:, src])
            out.append(leaf)
        self.caches = jax.tree_util.tree_unflatten(treedef, out)
        self.stats.cow_forks += 1
        # endurance: the fork physically programs a full block at dst
        self.pool.record_writes([(dst, self.block_size)], self._now())
        self.stats.pool_writes = self.pool.total_writes
        if self.tracer.enabled:
            self.tracer.span("cow-copy", "dispatch", "pool", t0,
                             self._now() - t0,
                             args={"kind": "cow-copy", "src": src, "dst": dst})

    # ------------------------------------------------- PCRAM reliability

    def _record_writes(self, req: Request, start: int, rows: int,
                       now: float) -> None:
        """Host-side endurance mirror of one dispatch's KV writes: bill rows
        ``[start, start+rows)`` of the request's sequence to the pool blocks
        its table maps them to.  Rows past the table's span are parked on
        the kernel's write-off block (never a real pool block) — skipped."""
        if rows <= 0:
            return
        bs = self.block_size
        table = req.block_table
        pairs = []
        row = start
        end = min(start + rows, self.max_len)
        while row < end:
            bi = row // bs
            if bi >= len(table):
                break                       # write-off parking, not PCRAM
            n = min(end, (bi + 1) * bs) - row
            pairs.append((table[bi], n))
            row += n
        if pairs:
            self.pool.record_writes(pairs, now)
            self.stats.pool_writes = self.pool.total_writes

    def _update_wear_gauges(self) -> None:
        if self.pool.n_blocks:
            self.stats.wear_p99 = float(np.percentile(self.pool.wear, 99))
            self.stats.wear_max = int(self.pool.wear.max())

    def _maybe_update_wear_gauges(self) -> None:
        """Per-step gauge refresh, throttled: wear moves by at most one
        block's worth of rows per dispatch, but the percentile scan costs
        more than the rest of the sweep — every 16th sweep tracks it
        closely enough, and ``summary()`` recomputes exactly at read time."""
        self._gauge_tick = (self._gauge_tick + 1) % 16
        if self._gauge_tick == 0:
            self._update_wear_gauges()

    def _block_rewrite(self, pairs: List[Tuple[int, int]], kind: str,
                       now: float) -> None:
        """Execute block copies on the physical store and bill them: each
        ``(src, dst)`` pair copies identical bytes (``src == dst`` for a
        drift refresh in place), costs one block of PCRAM writes at the
        destination, and is traced as a ``scrub`` span carrying its ODIN
        energy — the rows land in the ``scrub`` phase of ``odin_phases``,
        so span energies still sum exactly to ``odin_total``."""
        if not pairs:
            return
        t0 = self._now()
        # identity pairs (drift refresh in place) are byte no-ops on the
        # functional cache arrays — executing them would copy whole pool
        # leaves per sweep, an O(pool) simulation artifact with no modeled
        # counterpart.  The physical PCRAM rewrite they represent is billed
        # below (wear, energy, trace) exactly as if the scatter had run.
        moves = [(s, d) for s, d in pairs if s != d]
        if self.paged and moves:
            src = jnp.asarray([s for s, _ in moves], jnp.int32)
            dst = jnp.asarray([d for _, d in moves], jnp.int32)
            flat, treedef = jax.tree_util.tree_flatten_with_path(self.caches)
            out = []
            for path, leaf in flat:
                if _leaf_name(path) in POOL_LEAVES:
                    leaf = leaf.at[:, dst].set(leaf[:, src])
                out.append(leaf)
            self.caches = jax.tree_util.tree_unflatten(treedef, out)
        rows = len(pairs) * self.block_size
        self.pool.record_writes([(d, self.block_size) for _, d in pairs], now)
        self.stats.pool_writes = self.pool.total_writes
        self.stats.scrub_copies += len(pairs)
        self.stats.scrub_rows += rows
        if self.tracer.enabled:
            self.tracer.span(
                "scrub", "dispatch", "pool", t0, self._now() - t0,
                args={"kind": kind, "blocks": len(pairs), "rows": rows,
                      "odin_energy_mj": self.cost_model.energy_mj(rows)})

    def _reliability_sweep(self, now: float) -> None:
        """Bad-block retirement + drift-refresh scrubbing, run between the
        fault sweep and ``plan()`` so no dispatch is in flight while block
        ids move.  Retirement drains each bad block through a block copy,
        remaps every live claim (tables, kept prefixes, prefix cache) and
        shrinks the usable pool; requests the surviving capacity can never
        hold again are failed typed (``capacity``) instead of livelocking
        admission.  Copies move identical bytes, so greedy streams stay
        bit-identical with reliability on vs. off."""
        rel = self.reliability
        bad = list(self._pending_bad)
        if rel is not None and rel.endurance_budget is not None:
            bad.extend(self.pool.over_budget())
        if bad:
            bad = sorted(set(bad))
            copies = self.sched.retire_blocks(bad)
            self._pending_bad = [b for b in bad if b not in self.pool.retired]
            self._block_rewrite(copies, "retire-drain", now)
            self.stats.retired_blocks = len(self.pool.retired)
            if self.tracer.enabled and copies:
                self.tracer.counter(
                    "retired blocks", "pool",
                    {"retired": len(self.pool.retired),
                     "usable": self.pool.usable_blocks})
            # capacity containment: a request whose full footprint no longer
            # fits the surviving pool can never finish — one typed terminal
            # state now beats an admission livelock forever
            usable = self.pool.usable_blocks
            for req in self._all_live():
                if self.pool.blocks_for(req.prompt_len + req.max_new) > usable:
                    self._finalize(req, RequestState.FAILED, "capacity", now)
        if rel is not None and rel.scrub_enabled:
            self._scrub(now, rel)
        self._maybe_update_wear_gauges()

    def _scrub(self, now: float, rel: ReliabilityConfig) -> None:
        """Drift refresh: rewrite the oldest-written resident blocks in
        place (identical bytes — PCRAM re-SET/RESET restores the analog
        level before drift crosses the read margin), at most ``scrub_rate``
        blocks per step, once their last write is older than the drift
        deadline."""
        lw = self.pool.last_write
        cand = np.flatnonzero((lw >= 0) & (now - lw >= rel.drift_deadline_s))
        due = [int(b) for b in cand
               if self.pool.refs(int(b)) > 0 and int(b) not in self.pool.retired]
        if not due:
            return
        due.sort(key=lambda b: lw[b])
        batch = due[:rel.scrub_rate]
        self._block_rewrite([(b, b) for b in batch], "drift-refresh", now)

    def _all_live(self) -> List[Request]:
        live = [r for _, _, r in self.sched.waiting]
        live += list(self.sched.swapped)
        live += list(self.sched.running.values())
        return [r for r in live if not r.terminal]

    def _prefill_request(self, req: Request, now: float,
                         grant: Optional[PrefixGrant] = None) -> None:
        """Chunked prefill into the request's slot; emits the first token for
        fresh admissions (readmitted requests already hold their pending
        token — re-prefill only rebuilds the KV they lost).  A shared-prefix
        ``grant`` skips the resident rows: after the COW fork copy (if any),
        only ``[grant.start:]`` of the replay tokens run through the model —
        their queries read the shared prefix through the slot's block table.
        """
        fresh = req.n_generated == 0
        toks = req.replay_tokens()
        ntok = toks.shape[-1]
        extras = req.extras or {}
        if extras:
            self._check_extras_fit(req)     # same bound submit() enforced
        pos3d = extras.get("pos3d") if extras else None
        if pos3d is not None:
            pos3d = np.asarray(pos3d)
            if ntok > pos3d.shape[0]:
                # recompute replay covers generated tokens too: extend with
                # the degenerate (t, t, t) text positions decode would use
                tail = np.repeat(np.arange(pos3d.shape[0], ntok,
                                           dtype=pos3d.dtype)[:, None], 3, axis=1)
                pos3d = np.concatenate([pos3d, tail], axis=0)
        start0 = 0
        if grant is not None:
            if grant.fork is not None:
                self._cow_fork(*grant.fork)
            start0 = grant.start
            self.stats.prefix_hit_tokens += start0
            self.stats.shared_prefix_blocks += grant.shared_blocks
        trace = self.tracer.enabled
        # one clock domain for everything this dispatch records: metrics
        # walls, stats time accounting and trace spans all read the engine
        # clock (injectable / skew-clamped), never time.perf_counter —
        # a deterministic test clock must see them agree exactly
        t0 = self._now()
        chunk_sizes: List[int] = []
        # prefill writes K/V blocks straight into the pool via this row
        # (admission bumped table_version, so the mirror refreshes here)
        tables = self._refresh_tables()
        start = start0
        ll = None
        with self._annotate("prefill"):
            while start < ntok:
                c = min(self.chunk, ntok - start)
                chunk_toks = jnp.asarray(toks[..., start:start + c][None])
                kw = {}
                if extras:
                    if extras.get("patch_embeds") is not None:
                        kw["patch_embeds"] = jnp.asarray(extras["patch_embeds"])[None]
                    if pos3d is not None:
                        kw["pos3d"] = jnp.asarray(pos3d)[None][:, start:start + c]
                ll, self.caches = self._prefill(
                    self.params, self.caches, chunk_toks,
                    jnp.int32(req.slot), jnp.int32(start), jnp.bool_(start == start0),
                    tables, **kw)
                self.stats.dispatches += 1
                chunk_sizes.append(c)
                start += c
            jax.block_until_ready(ll)
        wall = self._now() - t0
        self.stats.host_syncs += 1
        self.stats.prefill_time += wall
        self.stats.prefill_tokens += ntok - start0
        req.n_prefill_tokens += ntok - start0
        self.metrics.observe("dispatch_prefill_s", wall)
        if trace:
            # chunks are not individually synced, so the dispatch's engine-
            # clock span is split across chunks proportionally to their rows
            # (same interpolation philosophy as horizon token timestamps)
            span = wall
            track = self._slot_track(req.slot)
            total = max(1, ntok - start0)
            self.tracer.flow_event("t", "request", track, req.rid, ts=t0)
            off, pos = t0, start0
            for i, c in enumerate(chunk_sizes):
                dur = span * c / total
                self.tracer.span(
                    "prefill-chunk", "dispatch", track, off, dur,
                    args={"kind": "prefill-chunk", "rid": req.rid,
                          "slot": req.slot, "start": pos, "rows": c,
                          "prefix_hit_tokens": start0 if i == 0 else 0,
                          "host_syncs": 1 if i == len(chunk_sizes) - 1 else 0,
                          "interpolated": len(chunk_sizes) > 1,
                          "odin_energy_mj": self.cost_model.energy_mj(c)},
                    flow=req.rid)
                off += dur
                pos += c
        self._slot_len[req.slot] = ntok
        # endurance mirror: the replay scattered rows [start0, ntok) into
        # the request's blocks (shared prefix rows were read, not written)
        self._record_writes(req, start0, ntok - start0, self._now())
        if fresh:
            tok = self._first_token(ll, req)                   # [] or [K]
            self._emit(req, tok, self._now())
            pending = tok
        else:
            pending = req.generated[-1]
        self._set_last_tok(req.slot, pending)
        if self.spec_ngram:
            self._seed_hist(req)

    # -------------------------------------------------- mixed dispatch path

    def _reset_slot_pos(self, slot: int, value: int) -> None:
        """Set every cache ``pos`` leaf for ``slot`` (fully paged layouts
        keep no other per-slot state, so this is the whole slot reset a
        staged admission needs before its first mixed dispatch)."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.caches)
        out = []
        for path, leaf in flat:
            if _leaf_name(path) == "pos":
                leaf = leaf.at[..., slot].set(jnp.int32(value))
            out.append(leaf)
        self.caches = jax.tree_util.tree_unflatten(treedef, out)

    def _stage_mixed_admission(self, req: Request,
                               grant: Optional[PrefixGrant] = None) -> None:
        """Admission under mixed dispatch: run the COW fork and shared-prefix
        accounting now, then mark the request mid-prefill — its replay is
        staged through fused mixed dispatches (``_dispatch_mixed``), chunk
        rows at a time, instead of the separate prefill loop."""
        start0 = 0
        if grant is not None:
            if grant.fork is not None:
                self._cow_fork(*grant.fork)
            start0 = grant.start
            self.stats.prefix_hit_tokens += start0
            self.stats.shared_prefix_blocks += grant.shared_blocks
        req.prefilling = True
        req.prefill_pos = start0
        self._slot_len[req.slot] = start0
        self._reset_slot_pos(req.slot, start0)
        if self.tracer.enabled:
            self.tracer.flow_event("t", "request",
                                   self._slot_track(req.slot), req.rid)

    def _mixed_fn(self) -> Callable:
        """Lazily-jitted mixed prefill+decode step.  One jit object; XLA
        retraces per tile width Q, and the engine snaps Q to the next power
        of two so the executable count is bounded by log2(chunk)+1."""
        if self._mixed is None:
            self._mixed = jax.jit(
                make_serving_mixed_step(self.cfg, top_k=self.top_k,
                                        sample=self.temperature > 0),
                donate_argnums=(1,))
        return self._mixed

    def _dispatch_mixed(self) -> None:
        """ONE fused dispatch over both populations: decode slots at
        ``q_len = 1`` plus mid-prefill slots at ``q_len ≤ chunk``, packed by
        ``Scheduler.pack_mixed`` under the ``mixed_budget`` row budget.

        Decode rows emit exactly what the single-step path would have
        emitted (the kernel's per-row online softmax makes each query row
        independent, and right alignment puts every slot's last real token
        at column Q-1); a prefill slot whose replay completes here gets its
        first token from ``last_logits`` through the same host-side
        ``_first_token`` path as the separate prefill — greedy mixed-on
        streams are bit-identical to mixed-off."""
        decode, parts = self.sched.pack_mixed(self.mixed_budget, self.chunk)
        if not decode and not parts:
            return
        q_max = max([1] + [c for _, _, c in parts])
        Q = 1 << (q_max - 1).bit_length()       # pow-2 tile widths, bounded
        K = self.cfg.n_codebooks
        tok = np.zeros((self.slots, K, Q) if K > 1 else (self.slots, Q),
                       np.int32)
        q_lens = np.zeros(self.slots, np.int32)
        active = np.zeros(self.slots, bool)
        dm = np.zeros(self.slots, bool)
        for r in decode:
            active[r.slot] = True
            dm[r.slot] = True
            q_lens[r.slot] = 1
            # the pending token is host-resident in the stream — no device
            # readback of _last_tok needed to build the tile
            tok[r.slot, ..., -1] = np.asarray(r.generated[-1], np.int32)
        for r, start, c in parts:
            active[r.slot] = True
            q_lens[r.slot] = c
            tok[r.slot, ..., Q - c:] = np.asarray(
                r.replay_tokens(), np.int32)[..., start:start + c]
        t0 = self._now()            # engine clock: metrics ≡ stats ≡ trace
        tables = self._refresh_tables()
        key = jax.random.fold_in(self._sample_key, self.stats.decode_steps)
        with self._annotate("mixed"):
            nxt, last_logits, self.caches = self._mixed_fn()(
                self.params, self.caches, jnp.asarray(tok),
                jnp.asarray(self._slot_len), jnp.asarray(q_lens),
                jnp.asarray(dm), jnp.asarray(active), tables, key,
                jnp.float32(self.temperature))
            host = np.asarray(nxt)                   # syncs the step
            ll_host = np.asarray(last_logits) if parts else None
        wall = self._now() - t0
        dec_rows = len(decode)
        pre_rows = sum(c for _, _, c in parts)
        rows = dec_rows + pre_rows
        # phase-attributed time: the dispatch is one wall, split across the
        # decode/prefill ledgers proportionally to the rows each contributed
        self.stats.decode_time += wall * dec_rows / rows
        self.stats.prefill_time += wall * pre_rows / rows
        self.metrics.observe("dispatch_mixed_s", wall)
        self.stats.dispatches += 1
        self.stats.host_syncs += 1
        self.stats.mixed_dispatches += 1
        self.stats.mixed_decode_rows += dec_rows
        self.stats.mixed_prefill_rows += pre_rows
        if self.tracer.enabled:
            self.tracer.span(
                "mixed", "dispatch", "dispatch", t0, wall,
                args={"kind": "mixed", "q_tile": Q,
                      "slots_active": int(active.sum()),
                      "decode_rows": dec_rows, "prefill_rows": pre_rows,
                      "tokens": dec_rows, "rows": rows, "host_syncs": 1,
                      "odin_energy_mj": self.cost_model.energy_mj(rows)})
        now = self._now()
        if decode:
            # the decode sampling-key schedule only advances when decode
            # rows actually rode along (pure-prefill dispatches don't burn
            # a fold_in index the separate path never would have)
            self.stats.decode_steps += 1
            self.stats.decode_dispatches += 1
            self.stats.active_slot_steps += dec_rows
            self.stats.slot_steps += self.slots
            dmj = jnp.asarray(dm).reshape(
                (self.slots,) + (1,) * (self._last_tok.ndim - 1))
            self._last_tok = jnp.where(dmj, nxt, self._last_tok)
            if self.spec_ngram:
                # speculable ⇒ single codebook, so nxt is [slots, 1]
                shifted = jnp.concatenate([self._hist[:, 1:], nxt], axis=1)
                self._hist = jnp.where(jnp.asarray(dm)[:, None], shifted,
                                       self._hist)
        for r in decode:
            self._record_writes(r, int(self._slot_len[r.slot]), 1, now)
            self._slot_len[r.slot] += 1
            self.stats.decode_tokens += 1
            self._emit(r, host[r.slot, ..., 0], now)
            if r.done:
                self._complete(r, now)
        for r, start, c in parts:
            self._record_writes(r, start, c, now)
            r.prefill_pos = start + c
            self._slot_len[r.slot] = r.prefill_pos
            self.stats.prefill_tokens += c
            r.n_prefill_tokens += c
            if r.prefill_pos < r.cached_len:
                continue                            # more chunks to stage
            self.sched.finish_prefill(r)
            if r.n_generated == 0:
                tok1 = self._first_token(ll_host[r.slot:r.slot + 1], r)
                self._emit(r, tok1, now)
                pending = tok1
            else:
                # readmitted after a recompute preemption: the pending token
                # survived host-side, the replay only rebuilt the KV
                pending = r.generated[-1]
            self._set_last_tok(r.slot, pending)
            if self.spec_ngram:
                self._seed_hist(r)
            if r.done:
                self._complete(r, now)

    def step(self) -> bool:
        """One engine iteration; returns True while work remains.

        Injected faults are *contained* here: an armed allocation failure
        surfaces as preemption/denial through the planner's normal fallback
        paths, a swap-copy fault downgrades the victim to recompute, a
        NaN-poisoned slot is quarantined by the guarded decode, and clock
        skew is clamped monotone — no fault event ever escapes ``step()``
        as an exception."""
        now = self._now()
        if self._watched:
            self._expire(now)
        nan_ev = None
        if self.fault_plan is not None:
            nan_ev = self._apply_faults(now)
            now = self._now()              # clock skew may have moved it
        # PCRAM reliability sweep: retire flagged/over-budget blocks and run
        # the drift scrubber BEFORE planning, so block ids never move under
        # an in-flight dispatch.  Pending stuck-at blocks are processed even
        # with reliability off — fault containment is not optional.
        if self._pending_bad or self.reliability is not None:
            self._reliability_sweep(now)
        plan = self.sched.plan(now)

        trace = self.tracer.enabled
        for req, mode, swap_ids, old_slot, dev_ids in plan.preempt:
            if mode == "swap":
                t0 = self._now() if trace else 0.0
                try:
                    req.ticket = self.store.swap_out(
                        self.caches, old_slot, swap_ids, req.cached_len,
                        dev_ids, skip=len(req.kept_blocks))
                except SwapCopyError:
                    # the copy raised before touching device state: downgrade
                    # to recompute (kept claims + swap blocks released, the
                    # re-prefill rebuilds the KV from tokens)
                    self.stats.swap_faults += 1
                    self.sched.fail_swap_out(req)
                    if trace:
                        self.tracer.instant(
                            "swap-fault", "faults", self._slot_track(old_slot),
                            args={"rid": req.rid, "direction": "out"},
                            flow=req.rid)
                    continue
                self.stats.preempt_swap += 1
                self.stats.swap_skipped_blocks += len(req.kept_blocks)
                if trace:
                    track = self._slot_track(old_slot)
                    self.tracer.span(
                        "swap-copy", "dispatch", track, t0, self._now() - t0,
                        args={"kind": "swap-copy", "direction": "out",
                              "rid": req.rid,
                              "blocks": len(swap_ids) - len(req.kept_blocks),
                              "skipped_blocks": len(req.kept_blocks)},
                        flow=req.rid)
                    self.tracer.flow_event("t", "request", track, req.rid, ts=t0)
            else:
                self.stats.preempt_recompute += 1
                if trace:
                    self.tracer.flow_event("t", "request",
                                           self._slot_track(old_slot), req.rid)
        for req in plan.resume:
            t0 = self._now() if trace else 0.0
            n_swap = len(req.ticket.block_ids)
            try:
                self.caches = self.store.swap_in(self.caches, req.slot,
                                                 req.ticket, req.block_table)
            except SwapCopyError:
                # functional swap-in: the caches are untouched.  Tear the
                # placement back down and requeue as recompute.
                self.stats.swap_faults += 1
                slot = req.slot
                self.sched.fail_resume(req)
                if trace:
                    self.tracer.instant(
                        "swap-fault", "faults", self._slot_track(slot),
                        args={"rid": req.rid, "direction": "in"},
                        flow=req.rid)
                continue
            # endurance mirror: the restore programmed one full block per
            # copied-in device block (retained kept-prefix blocks were never
            # copied — no wear there)
            skip = req.ticket.skip_blocks
            nbl = min(len(req.ticket.block_ids), len(req.block_table) - skip)
            if nbl > 0:
                self.pool.record_writes(
                    [(b, self.block_size)
                     for b in req.block_table[skip:skip + nbl]], self._now())
                self.stats.pool_writes = self.pool.total_writes
            self.store.pool.free(req.ticket.block_ids)
            req.ticket = None
            self._slot_len[req.slot] = req.cached_len
            self._set_last_tok(req.slot, req.generated[-1])
            if self.spec_ngram:
                self._seed_hist(req)
            if trace:
                track = self._slot_track(req.slot)
                self.tracer.span(
                    "swap-copy", "dispatch", track, t0, self._now() - t0,
                    args={"kind": "swap-copy", "direction": "in",
                          "rid": req.rid, "blocks": n_swap},
                    flow=req.rid)
                self.tracer.flow_event("t", "request", track, req.rid, ts=t0)
        for req in plan.admit:
            if self.mixed and not req.extras:
                # mixed dispatch: admission only stages the replay; the
                # prompt runs through fused mixed dispatches below, chunk
                # rows at a time, with decode slots riding along.  Requests
                # carrying extras keep the separate path — the patch-embed
                # overlay needs the whole replay in one dispatch.
                self._stage_mixed_admission(req, plan.grants.get(req.rid))
            else:
                self._prefill_request(req, now, plan.grants.get(req.rid))

        # requests may finish straight out of prefill (max_new == 1)
        for req in list(self.sched.running.values()):
            if req.done:
                self._complete(req, self._now())

        # steady-state pool occupancy sample: distinct device blocks the
        # running tables reference (shared blocks count once)
        held = set()
        for r in self.sched.running.values():
            held.update(r.block_table)
        self.stats.table_block_steps += len(held)
        self.stats.pool_steps += 1
        if trace:
            self.tracer.counter("kv blocks", "pool",
                                {"referenced": len(held),
                                 "used": self.pool.used_blocks,
                                 "free": self.pool.free_blocks})

        # mid-prefill (staged) slots are excluded from every decode path —
        # their cache holds only a replay prefix, so a decode row there
        # would attend over unwritten KV
        active_slots = sorted(
            s for s, r in self.sched.running.items() if not r.prefilling)
        mixed_pending = self.mixed and any(
            r.prefilling for r in self.sched.running.values())
        spec_k = self.spec_ngram
        max_h = self.horizon
        if self.degrade is not None:
            spec_k = self.degrade.spec_k(spec_k)
            max_h = self.degrade.horizon_cap(max_h)
        if nan_ev is not None and not active_slots:
            self.fault_plan.record(nan_ev, "skipped-idle")
        if active_slots and nan_ev is not None:
            # a poisoned step runs the guarded single-step kernel so the
            # NaN is quarantined per-slot; greedy streams are horizon-
            # invariant, so unfaulted co-batched slots stay bit-identical.
            # Mid-prefill slots sit this one step out (the guard has no
            # mixed tile) and resume staging next step.
            self._decode_guarded_step(active_slots, nan_ev)
        elif mixed_pending:
            # ONE dispatch carries decode rows and prefill-chunk rows; the
            # horizon/spec fused paths resume once the prefill burst drains
            self._dispatch_mixed()
        elif active_slots:
            if spec_k:
                # speculation always rides the fused scan (h == 1 is one
                # draft→verify→accept step); grant 0 ⇒ the pool cannot cover
                # the worst-case K+1-row write span — plain single step
                h = self.sched.grant_horizon(max_h, now,
                                             self._est_step_time(),
                                             spec_k=spec_k)
                if h >= 1:
                    self._decode_spec_steps(active_slots, h)
                else:
                    self._decode_single_step(active_slots)
            elif self.spec_ngram:
                # speculation shed by the degradation ladder: plain single
                # steps keep the n-gram history aligned for the restore
                self._decode_single_step(active_slots)
            else:
                h = 1
                if max_h > 1:
                    h = self.sched.grant_horizon(max_h, now,
                                                 self._est_step_time())
                if h > 1:
                    self._decode_horizon_steps(active_slots, h)
                else:
                    self._decode_single_step(active_slots)
        self.stats.steps += 1
        if self.degrade is not None:
            self._observe_degrade(self._now())
        self.metrics.maybe_roll(self._now(), self._counter_snapshot())
        return self.sched.has_work

    def _decode_single_step(self, active_slots: List[int]) -> None:
        """One ``[slots, 1]`` decode dispatch (the horizon=1 parity baseline)."""
        trace = self.tracer.enabled
        t0 = self._now()            # engine clock: metrics ≡ stats ≡ trace
        active = np.zeros(self.slots, bool)
        active[active_slots] = True
        tables = self._refresh_tables()  # growth may have extended tables
        key = jax.random.fold_in(self._sample_key, self.stats.decode_steps)
        with self._annotate("decode"):
            nxt, self.caches = self._decode(
                self.params, self.caches, self._last_tok,
                jnp.asarray(self._slot_len), jnp.asarray(active),
                tables, key, jnp.float32(self.temperature))
            host = np.asarray(nxt)                   # syncs the step
        wall = self._now() - t0
        self.stats.decode_time += wall
        self.metrics.observe("dispatch_decode_s", wall)
        if trace:
            rows = len(active_slots)
            self.tracer.span(
                "decode", "dispatch", "dispatch", t0, wall,
                args={"kind": "decode", "h": 1, "spec_k": 0,
                      "slots_active": rows, "tokens": rows, "rows": rows,
                      "host_syncs": 1,
                      "odin_energy_mj": self.cost_model.energy_mj(rows)})
        self.stats.decode_steps += 1
        self.stats.dispatches += 1
        self.stats.decode_dispatches += 1
        self.stats.host_syncs += 1
        self.stats.active_slot_steps += len(active_slots)
        self.stats.slot_steps += self.slots
        self._last_tok = nxt
        if self.spec_ngram:
            # keep the draft history aligned when speculation fell back to a
            # plain step (pool too tight for a verify tile this iteration)
            shifted = jnp.concatenate([self._hist[:, 1:], nxt], axis=1)
            self._hist = jnp.where(jnp.asarray(active)[:, None], shifted,
                                   self._hist)
        now = self._now()
        for s in active_slots:
            req = self.sched.running[s]
            self._record_writes(req, int(self._slot_len[s]), 1, now)
            self._slot_len[s] += 1
            self.stats.decode_tokens += 1
            self._emit(req, host[s, ..., 0], now)
            if req.done:
                self._complete(req, now)

    def _guarded_fn(self):
        """Lazily-compiled guarded decode step: same math as the plain step
        plus a per-slot finiteness verdict on the last-position logits."""
        if self._guarded is None:
            self._guarded = jax.jit(
                make_serving_decode_guarded(self.cfg, top_k=self.top_k,
                                            sample=self.temperature > 0),
                donate_argnums=(1,))
        return self._guarded

    def _decode_guarded_step(self, active_slots: List[int], ev) -> None:
        """One guarded ``[slots, 1]`` dispatch with an injected NaN poison.

        The poison mask corrupts exactly one slot's logits *post-forward*
        (the PCRAM-drift analog: a resistance excursion flips the readout,
        not the programmed weights).  The guard quarantines that slot as
        FAILED; every other slot samples from untouched logits with the
        same key schedule as the plain step, so unfaulted co-batched greedy
        streams stay bit-identical to a fault-free run."""
        trace = self.tracer.enabled
        t0 = self._now()            # engine clock: metrics ≡ stats ≡ trace
        active = np.zeros(self.slots, bool)
        active[active_slots] = True
        poison = np.zeros(self.slots, bool)
        target = active_slots[ev.slot % len(active_slots)]
        poison[target] = True
        self.fault_plan.record(ev, "poisoned", slot=target,
                               rid=self.sched.running[target].rid)
        tables = self._refresh_tables()
        key = jax.random.fold_in(self._sample_key, self.stats.decode_steps)
        with self._annotate("decode"):
            nxt, bad, self.caches = self._guarded_fn()(
                self.params, self.caches, self._last_tok,
                jnp.asarray(self._slot_len), jnp.asarray(active),
                tables, key, jnp.float32(self.temperature),
                jnp.asarray(poison))
            host = np.asarray(nxt)                   # syncs the step
            badh = np.asarray(bad)
        wall = self._now() - t0
        self.stats.decode_time += wall
        self.metrics.observe("dispatch_decode_s", wall)
        if trace:
            rows = len(active_slots)
            self.tracer.span(
                "decode", "dispatch", "dispatch", t0, wall,
                args={"kind": "decode", "h": 1, "spec_k": 0, "guarded": True,
                      "slots_active": rows, "tokens": rows, "rows": rows,
                      "host_syncs": 1,
                      "odin_energy_mj": self.cost_model.energy_mj(rows)})
        self.stats.decode_steps += 1
        self.stats.dispatches += 1
        self.stats.decode_dispatches += 1
        self.stats.host_syncs += 1
        self.stats.active_slot_steps += len(active_slots)
        self.stats.slot_steps += self.slots
        self._last_tok = nxt
        if self.spec_ngram:
            shifted = jnp.concatenate([self._hist[:, 1:], nxt], axis=1)
            self._hist = jnp.where(jnp.asarray(active)[:, None], shifted,
                                   self._hist)
        now = self._now()
        for s in active_slots:
            req = self.sched.running[s]
            # the forward wrote this slot's KV row whether or not the logit
            # readout was poisoned — wear is physical, bill it either way
            self._record_writes(req, int(self._slot_len[s]), 1, now)
            if badh[s]:
                # quarantine: only the poisoned request fails; its garbage
                # token never enters a stream and the slot is re-admittable
                self.stats.nan_quarantined += 1
                self._finalize(req, RequestState.FAILED, "nan_logits", now)
                continue
            self._slot_len[s] += 1
            self.stats.decode_tokens += 1
            self._emit(req, host[s, ..., 0], now)
            if req.done:
                self._complete(req, now)

    def _decode_horizon_steps(self, active_slots: List[int], h: int) -> None:
        """One fused dispatch generating up to ``h`` tokens per slot.

        The scheduler has already pre-extended every running table for ``h``
        rows (``grant_horizon``); slots freeze on-device at EOS / budget
        exhaustion, so the returned per-slot ``counts`` tell the host which
        prefix of each slot's ``[h]`` token row is real.  Per-token
        timestamps are linearly interpolated over the dispatch's span *of the
        engine clock* (the host cannot observe inner-step boundaries — that
        is the point; an injected test clock stays self-consistent)."""
        t_before = self._now()      # engine clock: metrics ≡ stats ≡ trace
        active = np.zeros(self.slots, bool)
        active[active_slots] = True
        rem = np.zeros(self.slots, np.int32)
        for s in active_slots:
            rem[s] = self.sched.running[s].remaining
        tables = self._refresh_tables()
        with self._annotate("horizon"):
            block, counts, last, self.caches = self._horizon_fn(h)(
                self.params, self.caches, self._last_tok,
                jnp.asarray(self._slot_len), jnp.asarray(active),
                jnp.asarray(rem), tables, self._sample_key,
                jnp.float32(self.temperature),
                jnp.int32(self.stats.decode_steps),
                jnp.int32(-1 if self.eos_id is None else self.eos_id))
            block, counts = jax.device_get((block, counts))  # ONE sync for h steps
        wall = self._now() - t_before
        self.stats.decode_time += wall
        self.metrics.observe("dispatch_decode_s", wall)
        if self.tracer.enabled:
            emitted = int(counts.sum())
            self.tracer.span(
                "horizon", "dispatch", "dispatch", t_before, wall,
                args={"kind": "horizon", "h": h, "spec_k": 0,
                      "slots_active": len(active_slots), "tokens": emitted,
                      "rows": emitted, "host_syncs": 1,
                      "odin_energy_mj": self.cost_model.energy_mj(emitted)})
        self.stats.decode_steps += h
        self.stats.dispatches += 1
        self.stats.decode_dispatches += 1
        self.stats.host_syncs += 1
        self.stats.active_slot_steps += int(counts.sum())
        self.stats.slot_steps += self.slots * h
        self._last_tok = last
        now_w = self._now()
        for s in active_slots:
            # endurance mirror: the scan wrote counts[s] KV rows for this
            # slot starting at its pre-dispatch length
            self._record_writes(self.sched.running[s],
                                int(self._slot_len[s]), int(counts[s]), now_w)
        span = wall                              # engine-clock dispatch span
        for hh in range(h):                      # step-major: matches h=1 order
            t_h = t_before + (hh + 1) * span / h
            for s in active_slots:
                if hh < counts[s]:
                    self._slot_len[s] += 1
                    self.stats.decode_tokens += 1
                    self._emit(self.sched.running[s], block[s, ..., hh], t_h)
        for s in active_slots:
            req = self.sched.running[s]
            if req.done:
                self._complete(req, t_before + int(counts[s]) * span / h)

    def _decode_spec_steps(self, active_slots: List[int], h: int) -> None:
        """One fused dispatch of ``h`` draft→verify→accept inner steps.

        Each inner step emits 1..K+1 tokens per live slot (the accepted
        draft prefix plus the bonus token); ``counts[s, hh]`` tells the host
        which prefix of ``block[s, hh]`` is real.  Timestamps interpolate
        over the dispatch's engine-clock span per inner step, and within a
        step across its accepted run."""
        K = self.spec_ngram
        t_before = self._now()      # engine clock: metrics ≡ stats ≡ trace
        active = np.zeros(self.slots, bool)
        active[active_slots] = True
        rem = np.zeros(self.slots, np.int32)
        for s in active_slots:
            rem[s] = self.sched.running[s].remaining
        tables = self._refresh_tables()
        with self._annotate("spec-horizon"):
            block, counts, last, hist, self.caches = self._fused_fn(h, K)(
                self.params, self.caches, self._last_tok,
                jnp.asarray(self._slot_len), jnp.asarray(active),
                jnp.asarray(rem), self._hist, tables,
                jnp.int32(-1 if self.eos_id is None else self.eos_id))
            block, counts = jax.device_get((block, counts))   # ONE sync
        self._last_tok = last
        self._hist = hist
        wall = self._now() - t_before
        self.stats.decode_time += wall
        self.metrics.observe("dispatch_decode_s", wall)
        self.stats.decode_steps += h
        self.stats.dispatches += 1
        self.stats.decode_dispatches += 1
        self.stats.host_syncs += 1
        live = counts > 0                                  # [slots, h]
        self.stats.active_slot_steps += int(live.sum())
        self.stats.slot_steps += self.slots * h
        self.stats.spec_drafted += K * int(live.sum())
        self.stats.spec_accepted += int((counts - live).sum())
        # every live inner step verified a K+1-row forward; rows beyond the
        # emitted run are rejected drafts — real PIMC energy, billed as
        # verify overhead (satellite 2: spec_overhead_rows) both fleet-wide
        # and on the request that incurred them
        emitted = int(counts.sum())
        rows = (K + 1) * int(live.sum())
        self.stats.spec_overhead_rows += rows - emitted
        for s in active_slots:
            s_over = int(((K + 1) * live[s] - counts[s]).sum())
            if s_over:
                self.sched.running[s].spec_overhead_rows += s_over
        if self.tracer.enabled:
            self.tracer.span(
                "spec-horizon", "dispatch", "dispatch", t_before, wall,
                args={"kind": "spec-horizon", "h": h, "spec_k": K,
                      "slots_active": len(active_slots), "tokens": emitted,
                      "drafted": K * int(live.sum()),
                      "accepted": int((counts - live).sum()),
                      "rows": rows, "overhead_rows": rows - emitted,
                      "host_syncs": 1,
                      "odin_energy_mj": self.cost_model.energy_mj(rows)})
        now_w = self._now()
        for s in active_slots:
            # endurance mirror: every live inner step wrote a K+1-row verify
            # tile at the slot's running position (rejected rows were
            # physically written before rollback — their wear is real), and
            # the position advanced by the accepted count
            pos = int(self._slot_len[s])
            for hh in range(h):
                if live[s, hh]:
                    self._record_writes(self.sched.running[s], pos, K + 1,
                                        now_w)
                    pos += int(counts[s, hh])
        span = wall
        last_t = {}
        for hh in range(h):                      # step-major: matches h=1 order
            for s in active_slots:
                m = int(counts[s, hh])
                for j in range(m):
                    t_tok = t_before + (hh + (j + 1) / m) * span / h
                    self._slot_len[s] += 1
                    self.stats.decode_tokens += 1
                    self._emit(self.sched.running[s], block[s, hh, j], t_tok)
                    last_t[s] = t_tok
        for s in active_slots:
            req = self.sched.running[s]
            if req.done:
                self._complete(req, last_t.get(s, t_before + span))

    def _horizon_fn(self, h: int) -> Callable:
        return self._fused_fn(h, 0)

    def _fused_fn(self, h: int, k: int) -> Callable:
        """LRU cache of compiled fused decode executables, keyed (h, k)."""
        key = (h, k)
        fn = self._fused.get(key)
        if fn is None:
            if k:
                fn = jax.jit(
                    make_serving_spec_horizon(self.cfg, h, k, n=self._spec_n),
                    donate_argnums=(1,))
            else:
                fn = jax.jit(
                    make_serving_decode_horizon(self.cfg, h, top_k=self.top_k,
                                                sample=self.temperature > 0),
                    donate_argnums=(1,))
            self._fused[key] = fn
            if len(self._fused) > self.jit_cache:
                self._fused.popitem(last=False)
                self.stats.jit_evictions += 1
        else:
            self._fused.move_to_end(key)
        return fn

    def _est_step_time(self) -> float:
        """Measured seconds per decode token step (0 until the first step)."""
        return (self.stats.decode_time / self.stats.decode_steps
                if self.stats.decode_steps else 0.0)

    def run(self, requests: Sequence[Request] = (), max_steps: int = 100_000) -> Dict:
        """Submit ``requests``, drive the loop until drained, return the
        metrics summary (per-request records + aggregates)."""
        for req in requests:
            self.submit(req)
        self._now()                                       # start the clock
        steps = idle = 0
        while self.sched.has_work:
            busy = bool(self.sched.running)
            self.step()
            if busy or self.sched.running:
                steps += 1
                idle = 0
                if steps > max_steps:
                    raise EngineStallError(
                        f"engine exceeded {max_steps} steps",
                        summary=self.summary())
            else:
                # idle: nothing running, next arrival in the future.  Idle
                # waits don't count against the runaway-loop bound (a
                # low-rate open-loop workload may idle for minutes), but
                # they are bounded too in case an injected clock never
                # advances past the next arrival.
                idle += 1
                if idle > max_steps:
                    raise EngineStallError(
                        f"engine idle for {max_steps} iterations — is the "
                        "clock advancing toward the next arrival?",
                        summary=self.summary())
                nxt = self.sched.next_arrival()
                if nxt is not None and nxt > self._now():
                    # an injected ticking clock can advance between the
                    # check above and this read — never sleep negative
                    time.sleep(max(0.0, min(0.05, nxt - self._now())))
        return self.summary()

    def summary(self) -> Dict:
        done = self._all_requests()
        self._update_wear_gauges()
        self.stats.retired_blocks = len(self.pool.retired)
        self.metrics.flush(self._now(), self._counter_snapshot())
        out = summarize(done, self.stats, self.cost_model,
                        registry=self.metrics)
        if self.degrade is not None:
            # full controller state: transition history plus the live
            # retry_after_s hint (None unless admissions are denied now)
            out["degradation"].update(self.degrade.snapshot(self._now()))
        if self.fault_plan is not None:
            out["fault_plan"] = self.fault_plan.snapshot()
        return out

    def _all_requests(self) -> List[Request]:
        seen = {r.rid: r for _, _, r in self.sched.waiting}
        for r in list(self.sched.swapped) + list(self.sched.running.values()):
            seen[r.rid] = r
        for r in self._done:
            seen[r.rid] = r
        return list(seen.values())
