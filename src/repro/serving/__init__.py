"""repro.serving — continuous-batching serving engine with a paged KV-cache.

The serving substrate over the repo's compiled prefill/decode steps:

* :mod:`repro.serving.blocks`    — KV block pool + swap-tier paged store
* :mod:`repro.serving.scheduler` — request lifecycle / admission / preemption
* :mod:`repro.serving.engine`    — the step-loop driver (ServingEngine)
* :mod:`repro.serving.metrics`   — TTFT/TPOT/occupancy + ODIN PIMC attribution
* :mod:`repro.serving.trace`     — ring-buffered tracer, Perfetto export,
  windowed metrics registry
* :mod:`repro.serving.workload`  — synthetic open-loop arrival generators
* :mod:`repro.serving.faults`    — seeded fault-injection plans + typed errors
* :mod:`repro.serving.degrade`   — load-shedding ladder (graceful degradation)
* :mod:`repro.serving.reliability` — PCRAM endurance/wear/scrub policy knobs
* :mod:`repro.serving.frontdoor` — asyncio streaming front door (backpressure,
  per-tenant QoS, typed rejections, SSE server)

Quick start::

    from repro.models import registry
    from repro.serving import ServingEngine, SCENARIOS, make_requests

    cfg = registry.get_smoke("phi4-mini-3.8b")
    eng = ServingEngine(cfg, slots=4, max_len=96, block_size=16)
    summary = eng.run(make_requests(cfg, SCENARIOS["mixed"], seed=0))
    print(summary["decode_tokens_per_s"], summary["ttft_s"]["p50"])

See src/repro/serving/README.md for the full walkthrough.
"""
from repro.serving.blocks import BlockPool, PagedKVStore, SwapTicket
from repro.serving.degrade import (DEGRADE_LEVELS, DegradationController,
                                   DegradeConfig)
from repro.serving.engine import ServingEngine
from repro.serving.faults import (FAULT_SITES, EngineStallError, FaultEvent,
                                  FaultPlan, Overloaded, ShuttingDown,
                                  SwapCopyError)
from repro.serving.frontdoor import (DoneEvent, FrontDoor, HeartbeatEvent,
                                     TokenBucket, TokenEvent, run_server)
from repro.serving.metrics import EngineStats, OdinCostModel, summarize
from repro.serving.reliability import ReliabilityConfig, wear_gini
from repro.serving.scheduler import (TERMINAL_STATES, PrefixCache, PrefixGrant,
                                     Request, RequestState, Scheduler,
                                     StepPlan)
from repro.serving.trace import (NULL_TRACER, LogHistogram, MetricsRegistry,
                                 NullTracer, Tracer, chrome_trace,
                                 validate_chrome_trace)
from repro.serving.workload import SCENARIOS, WorkloadSpec, make_requests, poisson_arrivals

__all__ = [
    "BlockPool", "PagedKVStore", "SwapTicket",
    "ServingEngine",
    "EngineStats", "OdinCostModel", "summarize",
    "PrefixCache", "PrefixGrant",
    "Request", "RequestState", "Scheduler", "StepPlan", "TERMINAL_STATES",
    "FaultPlan", "FaultEvent", "FAULT_SITES",
    "EngineStallError", "SwapCopyError", "Overloaded", "ShuttingDown",
    "FrontDoor", "TokenBucket", "TokenEvent", "HeartbeatEvent", "DoneEvent",
    "run_server",
    "DegradationController", "DegradeConfig", "DEGRADE_LEVELS",
    "ReliabilityConfig", "wear_gini",
    "Tracer", "NullTracer", "NULL_TRACER", "LogHistogram", "MetricsRegistry",
    "chrome_trace", "validate_chrome_trace",
    "SCENARIOS", "WorkloadSpec", "make_requests", "poisson_arrivals",
]
