"""Seeded fault injection for the serving engine.

ODIN computes inside an imperfect medium — PCRAM drifts, SC rails are
approximate by construction — so the serving stack must treat failure as
an input, not an exception.  A :class:`FaultPlan` is a deterministic,
seeded schedule of fault events at the engine's real seams:

=============  ==============================================================
site           what fires
=============  ==============================================================
``alloc``      the next ``count`` :meth:`BlockPool.alloc` calls return None
               (pool exhaustion between headroom check and extension)
``swap_out``   the next swap-out copy raises :class:`SwapCopyError` before
               touching device state (the ticket is never created)
``swap_in``    the next swap-in copy raises :class:`SwapCopyError` (the
               resumed slot is torn back down to a recompute re-queue)
``nan_logits`` one decode step poisons one slot's logits with NaN — the
               per-slot guard must quarantine exactly that request as
               FAILED while co-batched slots keep bit-identical streams
``clock_skew`` the engine clock jumps by ``skew_s`` (negative jumps are
               clamped by the engine's monotone guard)
``stuck_at``   one PCRAM block (``slot`` modulo the pool size) develops a
               stuck-at cell fault — the reliability sweep must drain and
               retire it before the next dispatch touches it
``wear_exhaustion``
               the ``count`` most-worn live blocks burn through their
               remaining endurance at once — a retirement storm that must
               walk the degradation ladder, never crash the pool
=============  ==============================================================

The plan is pure data (numpy only, no serving imports) so it can be
serialized as a CI artifact (``to_json``/``from_json``) and replayed to
reproduce a falsifying chaos run exactly.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FAULT_SITES",
    "FaultEvent",
    "FaultPlan",
    "SwapCopyError",
    "EngineStallError",
    "Overloaded",
    "ShuttingDown",
]

FAULT_SITES = ("alloc", "swap_out", "swap_in", "nan_logits", "clock_skew",
               "stuck_at", "wear_exhaustion")


class SwapCopyError(RuntimeError):
    """Injected swap-ticket copy failure (device↔host block copy lost).

    Raised by :class:`~repro.serving.blocks.PagedKVStore` before any cache
    mutation, so the engine can fall back to the recompute path with the
    caches untouched.
    """


class EngineStallError(RuntimeError):
    """The engine exceeded its step/idle budget without draining.

    Carries the partial :meth:`ServingEngine.summary` as ``.summary`` so a
    wedged run still yields its metrics and trace.
    """

    def __init__(self, message: str, summary: Optional[dict] = None):
        super().__init__(message)
        self.summary = summary


class Overloaded(RuntimeError):
    """Typed admission rejection (the HTTP-429 shape).

    Raised by the front door instead of buffering unboundedly: the request
    queue is full, the degradation ladder reached ``admit_deny``, or the
    tenant's token bucket is exhausted.  ``retry_after`` is the structured
    backoff hint in *relative seconds* (None when no estimate exists) and
    ``tenant`` names the quota that rejected, when one did.
    """

    def __init__(self, message: str, retry_after: Optional[float] = None,
                 tenant: Optional[str] = None):
        super().__init__(message)
        self.retry_after = retry_after
        self.tenant = tenant


class ShuttingDown(Overloaded):
    """Typed late-submit rejection while the engine drains (HTTP-503 shape).

    A subclass of :class:`Overloaded` so one except-clause covers both
    rejection shapes; ``retry_after`` is usually None — the process is going
    away, not backing off.
    """


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``site`` fires at engine step ``step``.

    ``count`` arms multi-shot sites (alloc/swap counters) and picks how many
    worn blocks ``wear_exhaustion`` burns out; ``slot`` picks the poisoned
    slot for ``nan_logits`` (taken modulo the live slot count at fire time)
    and doubles as the bad-block selector for ``stuck_at`` (modulo the pool
    size); ``skew_s`` is the clock jump for ``clock_skew``.
    """
    site: str
    step: int
    count: int = 1
    slot: int = 0
    skew_s: float = 0.0

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"expected one of {FAULT_SITES}")
        if self.step < 0 or self.count < 1:
            raise ValueError("FaultEvent needs step >= 0 and count >= 1")


class FaultPlan:
    """A deterministic schedule of :class:`FaultEvent`s keyed by step.

    The engine consumes events via :meth:`events_at` at the top of each
    ``step()`` and records what actually happened with :meth:`record`
    (armed / poisoned rid / skipped), so a replayed plan can be diffed
    against its original firing log.
    """

    def __init__(self, events: Sequence[FaultEvent] = (), seed: int = 0):
        self.events: Tuple[FaultEvent, ...] = tuple(events)
        self.seed = seed
        self.fired: List[dict] = []
        self._by_step: Dict[int, List[FaultEvent]] = {}
        for ev in self.events:
            self._by_step.setdefault(ev.step, []).append(ev)

    def __len__(self) -> int:
        return len(self.events)

    def events_at(self, step: int) -> List[FaultEvent]:
        return self._by_step.get(step, [])

    def record(self, event: FaultEvent, outcome: str, **detail) -> None:
        self.fired.append({"site": event.site, "step": event.step,
                           "outcome": outcome, **detail})

    @classmethod
    def generate(cls, seed: int, n_steps: int = 64, rate: float = 0.15,
                 sites: Sequence[str] = FAULT_SITES,
                 max_skew_s: float = 0.05) -> "FaultPlan":
        """Draw a random plan: each step fires one fault with prob ``rate``,
        site chosen uniformly from ``sites``.  Same seed → same plan."""
        rng = np.random.default_rng(seed)
        events = []
        for step in range(n_steps):
            if rng.random() >= rate:
                continue
            site = sites[int(rng.integers(len(sites)))]
            events.append(FaultEvent(
                site=site, step=step,
                count=int(rng.integers(1, 4)),
                slot=int(rng.integers(0, 64)),
                skew_s=float(rng.uniform(-max_skew_s, max_skew_s))
                if site == "clock_skew" else 0.0))
        return cls(events, seed=seed)

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "events": [dataclasses.asdict(ev) for ev in self.events],
            "fired": self.fired,
        }, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        obj = json.loads(text)
        events = [FaultEvent(**{k: v for k, v in ev.items()
                                if k in {f.name for f in
                                         dataclasses.fields(FaultEvent)}})
                  for ev in obj.get("events", [])]
        return cls(events, seed=obj.get("seed", 0))

    def snapshot(self) -> dict:
        """Summary-friendly view: schedule size + what actually fired."""
        return {"seed": self.seed, "n_events": len(self.events),
                "fired": list(self.fired)}
