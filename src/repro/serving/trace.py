"""Serving-engine structured tracing + windowed metrics.

Two instruments, both cheap enough to leave compiled-in:

* :class:`Tracer` — a ring-buffered structured event recorder.  The engine
  emits **dispatch spans** at every compiled-step launch site (prefill-chunk,
  decode, horizon, spec-horizon, swap-copy, cow-copy — each carrying slot
  occupancy, granted horizon, draft length, emitted/accepted token counts and
  its ODIN PIMC energy bill), **request lifecycle events** (queued → admitted
  → prefill → decode → preempt/resume → complete) linked by per-request
  **flow ids** that survive preemption, and **decision events** from the
  scheduler (admission grant/deny with marginal-block accounting,
  ``grant_horizon`` inputs/outputs) and the block pool (alloc/free/fork,
  prefix-cache eviction).  The buffer drops-oldest at capacity and counts the
  drops, so a long run can always be traced at bounded memory.

  Tracing is **off by default**: the module-level :data:`NULL_TRACER` is a
  no-op recorder whose ``enabled`` flag lets every call site skip even the
  argument-dict construction, so the trace-off hot path allocates nothing.

* :class:`MetricsRegistry` — windowed serving metrics.  Log-bucketed
  streaming histograms (TTFT / TPOT / per-dispatch wall time) plus counter
  deltas are snapshotted every ``window_s`` seconds of engine clock, so a
  long run reports p50/p99 *over time* instead of one end-of-run number.

Export is Chrome trace-event JSON (the ``traceEvents`` array format), loadable
in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``: one track per
engine slot plus scheduler/pool tracks, ``X`` complete events for spans,
``C`` counter series for pool occupancy, and ``s``/``t``/``f`` flow events
following a request across preemptions.  :func:`validate_chrome_trace` is the
schema check CI runs over the benchmark's trace artifact.

Usage::

    from repro.serving import ServingEngine, Tracer

    tracer = Tracer()
    eng = ServingEngine(cfg, slots=4, max_len=96, tracer=tracer)
    eng.run(requests)
    tracer.export("trace.json")          # load in https://ui.perfetto.dev
"""
from __future__ import annotations

import json
import math
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["TraceEvent", "Tracer", "NullTracer", "NULL_TRACER",
           "LogHistogram", "MetricsRegistry",
           "chrome_trace", "validate_chrome_trace"]


# --------------------------------------------------------------------- events

_PID = 1                                  # single engine process per trace


class TraceEvent:
    """One recorded event.  ``ph`` follows the Chrome trace-event phase
    alphabet: "X" complete span, "i" instant, "C" counter, "s"/"t"/"f" flow
    start/step/finish.  ``ts``/``dur`` are engine-clock **seconds** (exported
    as microseconds); ``track`` is a human-readable lane name interned to a
    ``tid`` at export time; ``flow`` is the request id tying lifecycle events
    into one arrow chain across slots."""

    __slots__ = ("name", "cat", "ph", "track", "ts", "dur", "args", "flow")

    def __init__(self, name: str, cat: str, ph: str, track: str, ts: float,
                 dur: float = 0.0, args: Optional[dict] = None,
                 flow: Optional[int] = None):
        self.name = name
        self.cat = cat
        self.ph = ph
        self.track = track
        self.ts = ts
        self.dur = dur
        self.args = args
        self.flow = flow


class NullTracer:
    """No-op recorder — the trace-off default.

    ``enabled`` is False so call sites guard the *argument construction*,
    not just the call::

        if tracer.enabled:
            tracer.span("decode", "dispatch", track, t0, dur, args={...})

    Every method is still safe to call (does nothing), so forgetting a guard
    costs a no-op call, never a crash.
    """

    enabled = False
    dropped_events = 0

    def set_clock(self, clock: Callable[[], float]) -> None:
        pass

    def span(self, name, cat, track, ts, dur, args=None, flow=None) -> None:
        pass

    def instant(self, name, cat, track, ts=None, args=None, flow=None) -> None:
        pass

    def counter(self, name, track, values, ts=None) -> None:
        pass

    def flow_event(self, phase, name, track, fid, ts=None) -> None:
        pass

    def events(self) -> Tuple:
        return ()


NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Ring-buffered structured event recorder.

    ``capacity`` bounds memory: at overflow the **oldest** events are dropped
    and ``dropped_events`` counts them, so the tail of a long run — usually
    what you are debugging — always survives.  Timestamps default to the
    attached clock (the engine injects its own run clock via ``set_clock``);
    span emit sites pass explicit ``ts``/``dur`` measured around the
    dispatch.
    """

    enabled = True

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._events: deque = deque()
        self.dropped_events = 0
        self._clock: Callable[[], float] = lambda: 0.0
        self._tracks: Dict[str, int] = {}

    def set_clock(self, clock: Callable[[], float]) -> None:
        """Attach the timestamp source (the engine's run clock, seconds)."""
        self._clock = clock

    # -- recording ----------------------------------------------------------

    def _push(self, ev: TraceEvent) -> None:
        if len(self._events) >= self.capacity:
            self._events.popleft()
            self.dropped_events += 1
        self._events.append(ev)

    def span(self, name: str, cat: str, track: str, ts: float, dur: float,
             args: Optional[dict] = None, flow: Optional[int] = None) -> None:
        """A completed span (``X``): one dispatch / copy / prefill chunk."""
        self._push(TraceEvent(name, cat, "X", track, ts, dur, args, flow))

    def instant(self, name: str, cat: str, track: str,
                ts: Optional[float] = None, args: Optional[dict] = None,
                flow: Optional[int] = None) -> None:
        """A point event (``i``): lifecycle transitions, scheduler decisions."""
        ts = self._clock() if ts is None else ts
        self._push(TraceEvent(name, cat, "i", track, ts, 0.0, args, flow))

    def counter(self, name: str, track: str, values: Dict[str, float],
                ts: Optional[float] = None) -> None:
        """A counter sample (``C``): pool occupancy, free blocks, …"""
        ts = self._clock() if ts is None else ts
        self._push(TraceEvent(name, "counter", "C", track, ts, 0.0,
                              dict(values)))

    def flow_event(self, phase: str, name: str, track: str, fid: int,
                   ts: Optional[float] = None) -> None:
        """A flow-arrow anchor: ``phase`` ∈ {"s", "t", "f"} (start / step /
        finish).  One chain per request id follows it across slot moves."""
        if phase not in ("s", "t", "f"):
            raise ValueError(f"flow phase must be s/t/f, got {phase!r}")
        ts = self._clock() if ts is None else ts
        self._push(TraceEvent(name, "request", phase, track, ts, 0.0,
                              None, fid))

    # -- access / export ----------------------------------------------------

    def events(self) -> Tuple[TraceEvent, ...]:
        return tuple(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON object (``{"traceEvents": [...]}``)."""
        return chrome_trace(self.events(), dropped_events=self.dropped_events)

    def export(self, path: str) -> dict:
        """Validate + write the Chrome trace JSON; returns the object."""
        obj = self.to_chrome()
        errors = validate_chrome_trace(obj)
        if errors:                         # pragma: no cover — exporter bug
            raise ValueError("invalid chrome trace: " + "; ".join(errors[:5]))
        with open(path, "w") as f:
            json.dump(obj, f, allow_nan=False)
        return obj


# ------------------------------------------------------------ chrome export

def _track_order(track: str) -> Tuple[int, str]:
    """Slots first (numeric order), then scheduler/pool/other lanes."""
    if track.startswith("slot "):
        try:
            return (0, f"{int(track.split()[1]):06d}")
        except ValueError:
            pass
    return (1, track)


def chrome_trace(events, dropped_events: int = 0) -> dict:
    """Render recorded events as a Chrome trace-event JSON object.

    One process (`pid` 1, "serving-engine") with one thread per distinct
    track, named and sorted slots-first.  Timestamps convert seconds →
    microseconds.  ``otherData.dropped_events`` records ring-buffer drops so
    a truncated trace is detectable from the artifact alone.
    """
    tracks: Dict[str, int] = {}
    for ev in events:
        if ev.track not in tracks:
            tracks[ev.track] = 0
    for i, name in enumerate(sorted(tracks, key=_track_order)):
        tracks[name] = i

    out: List[dict] = [{"name": "process_name", "ph": "M", "pid": _PID,
                        "tid": 0, "args": {"name": "serving-engine"}}]
    for name, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
        out.append({"name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
                    "args": {"name": name}})
        out.append({"name": "thread_sort_index", "ph": "M", "pid": _PID,
                    "tid": tid, "args": {"sort_index": tid}})

    for ev in events:
        rec = {"name": ev.name, "cat": ev.cat, "ph": ev.ph, "pid": _PID,
               "tid": tracks[ev.track], "ts": ev.ts * 1e6}
        if ev.ph == "X":
            rec["dur"] = max(ev.dur, 0.0) * 1e6
        if ev.ph == "i":
            rec["s"] = "t"                 # thread-scoped instant
        if ev.ph in ("s", "t", "f"):
            rec["id"] = ev.flow
            if ev.ph == "f":
                rec["bp"] = "e"            # bind to enclosing slice
        elif ev.flow is not None:
            args = dict(ev.args or {})
            args["flow_id"] = ev.flow
            rec["args"] = args
        if "args" not in rec and ev.args is not None:
            rec["args"] = ev.args
        out.append(rec)
    return {"traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": dropped_events}}


_REQUIRED_KEYS = ("name", "ph", "pid", "tid")
_KNOWN_PHASES = ("X", "B", "E", "i", "I", "C", "M", "s", "t", "f")


def validate_chrome_trace(obj) -> List[str]:
    """Schema check for a Chrome trace-event JSON object (Perfetto-loadable).

    Returns a list of error strings (empty ⇒ valid).  Checks the structural
    contract Perfetto's legacy-JSON importer relies on: a ``traceEvents``
    array of objects each carrying name/ph/pid/tid, numeric non-negative
    ``ts`` (and ``dur`` for "X"), known phase letters, ids on flow events
    with every chain starting at an "s", and strict-JSON serializability
    (``NaN``/``Infinity`` tokens would make the file unloadable).
    """
    errors: List[str] = []
    if not isinstance(obj, dict) or not isinstance(obj.get("traceEvents"), list):
        return ["top level must be an object with a 'traceEvents' array"]
    # a ring-buffer overflow may have dropped a chain's "s" anchor — orphan
    # "t"/"f" events are then expected (Perfetto just skips the arrow), so
    # the ordering check only applies to complete traces
    dropped = (obj.get("otherData") or {}).get("dropped_events", 0)
    check_flow_order = not dropped
    flows_started = set()
    for i, ev in enumerate(obj["traceEvents"]):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        for k in _REQUIRED_KEYS:
            if k not in ev:
                errors.append(f"{where}: missing key {k!r}")
        ph = ev.get("ph")
        if ph not in _KNOWN_PHASES:
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or not math.isfinite(ts) or ts < 0:
                errors.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or not math.isfinite(dur) or dur < 0:
                errors.append(f"{where}: bad dur {dur!r}")
        if ph == "M":
            if not isinstance(ev.get("args"), dict):
                errors.append(f"{where}: metadata event needs args")
        if ph in ("s", "t", "f"):
            fid = ev.get("id")
            if fid is None:
                errors.append(f"{where}: flow event missing id")
            elif ph == "s":
                flows_started.add(fid)
            elif check_flow_order and fid not in flows_started:
                errors.append(f"{where}: flow {ph!r} id {fid!r} before its 's'")
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"{where}: args must be an object")
    try:
        json.dumps(obj, allow_nan=False)
    except (TypeError, ValueError) as e:
        errors.append(f"not strict-JSON serializable: {e}")
    return errors


# ----------------------------------------------------------- windowed metrics

class LogHistogram:
    """Log-bucketed streaming histogram over positive values.

    ``bins_per_decade`` geometric buckets between ``lo`` and ``hi`` plus
    underflow/overflow buckets — O(1) memory per metric regardless of run
    length, with percentile error bounded by one bucket's ratio
    (``10^(1/bins_per_decade)``, ~47% at the default 3/decade; serving
    latencies span decades, so ratio resolution is the right trade).
    Percentiles interpolate at the geometric midpoint of the containing
    bucket.  ``marks()``/``delta_summary`` support windowed snapshots: the
    registry records the cumulative counts at each window open and summarizes
    the difference at close.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 bins_per_decade: int = 6):
        if not (0 < lo < hi):
            raise ValueError((lo, hi))
        self.lo = lo
        self.hi = hi
        self.bins_per_decade = bins_per_decade
        n = int(math.ceil(bins_per_decade * math.log10(hi / lo)))
        self._n = n
        self.counts = [0] * (n + 2)        # [under, b0..b{n-1}, over]
        self.total = 0
        self.sum = 0.0

    def _bucket(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return self._n + 1
        return 1 + min(self._n - 1, int(self.bins_per_decade
                                        * math.log10(v / self.lo)))

    def _edges(self, b: int) -> Tuple[float, float]:
        """(low, high) value edges of bucket index ``b`` (clamped ends)."""
        if b == 0:
            return (0.0, self.lo)
        if b == self._n + 1:
            return (self.hi, self.hi)
        lo = self.lo * 10 ** ((b - 1) / self.bins_per_decade)
        return (lo, lo * 10 ** (1 / self.bins_per_decade))

    def observe(self, v: float) -> None:
        self.counts[self._bucket(v)] += 1
        self.total += 1
        self.sum += v

    def marks(self) -> Tuple[List[int], int, float]:
        return (list(self.counts), self.total, self.sum)

    def _percentile_from(self, counts: List[int], total: int,
                         q: float) -> Optional[float]:
        if total == 0:
            return None
        target = q / 100.0 * total
        acc = 0
        for b, c in enumerate(counts):
            acc += c
            if acc >= target and c:
                lo, hi = self._edges(b)
                return math.sqrt(lo * hi) if lo > 0 else 0.0
        return self._edges(len(counts) - 1)[1]   # pragma: no cover

    def percentile(self, q: float) -> Optional[float]:
        return self._percentile_from(self.counts, self.total, q)

    def summary(self, qs=(50, 90, 99)) -> dict:
        return self.delta_summary(([0] * len(self.counts), 0, 0.0), qs)

    def delta_summary(self, marks: Tuple[List[int], int, float],
                      qs=(50, 90, 99)) -> dict:
        """Summary of observations since ``marks`` (a window's worth)."""
        counts0, total0, sum0 = marks
        counts = [a - b for a, b in zip(self.counts, counts0)]
        total = self.total - total0
        out = {"count": total,
               "mean": (self.sum - sum0) / total if total else None}
        for q in qs:
            out[f"p{q}"] = self._percentile_from(counts, total, q)
        return out


class MetricsRegistry:
    """Counters, gauges and log-bucketed histograms with periodic windows.

    The engine feeds observations (``observe``) and counter values as it
    runs; every ``window_s`` seconds of engine clock ``maybe_roll`` closes a
    window — a dict of counter **deltas** and per-histogram delta summaries —
    appended to ``windows``.  Long runs therefore report p50/p99 *over time*
    (TTFT during the arrival burst vs steady state) instead of one
    end-of-run number.  Empty windows (no observations, no counter movement)
    are elided, keeping idle gaps cheap; window boundaries stay aligned to
    ``k·window_s`` so gaps are visible as missing ``t0`` values.
    """

    def __init__(self, window_s: float = 1.0, hist_lo: float = 1e-6,
                 hist_hi: float = 1e4, bins_per_decade: int = 6):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.window_s = window_s
        self._hist_kw = dict(lo=hist_lo, hi=hist_hi,
                             bins_per_decade=bins_per_decade)
        self.hists: Dict[str, LogHistogram] = {}
        self.gauges: Dict[str, float] = {}
        self.windows: List[dict] = []
        self._next: Optional[float] = None
        self._marks: Dict[str, Tuple[List[int], int, float]] = {}
        self._counters0: Dict[str, float] = {}

    # -- feeding ------------------------------------------------------------

    def observe(self, name: str, v: float) -> None:
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = LogHistogram(**self._hist_kw)
            self._marks[name] = ([0] * len(h.counts), 0, 0.0)
        h.observe(v)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauges[name] = float(v)

    # -- windowing ----------------------------------------------------------

    def maybe_roll(self, now: float,
                   counters: Optional[Dict[str, float]] = None) -> None:
        """Close every window boundary passed by ``now``.  ``counters`` is
        the current cumulative counter snapshot (e.g. off ``EngineStats``);
        each window records the delta since the previous close."""
        if self._next is None:
            self._next = (math.floor(now / self.window_s) + 1) * self.window_s
            self._counters0 = dict(counters or {})
            return
        while now >= self._next:
            self._close(self._next - self.window_s, self._next, counters)
            self._next += self.window_s

    def flush(self, now: float,
              counters: Optional[Dict[str, float]] = None) -> None:
        """Close the in-progress partial window (end of run / snapshot)."""
        if self._next is None:
            return
        self.maybe_roll(now, counters)
        if now > self._next - self.window_s:
            self._close(self._next - self.window_s, now, counters)
            self._next = (math.floor(now / self.window_s) + 1) * self.window_s

    def _close(self, t0: float, t1: float,
               counters: Optional[Dict[str, float]]) -> None:
        hist_deltas = {}
        n_obs = 0
        for name, h in self.hists.items():
            d = h.delta_summary(self._marks[name])
            self._marks[name] = h.marks()
            if d["count"]:
                hist_deltas[name] = d
                n_obs += d["count"]
        counter_deltas = {}
        if counters is not None:
            for k, v in counters.items():
                dv = v - self._counters0.get(k, 0)
                if dv:
                    counter_deltas[k] = dv
            self._counters0 = dict(counters)
        if not n_obs and not counter_deltas:
            return                          # elide empty windows
        self.windows.append({"t0": t0, "t1": t1,
                             "counters": counter_deltas,
                             "histograms": hist_deltas})

    # -- reporting ----------------------------------------------------------

    def summary(self) -> dict:
        return {
            "window_s": self.window_s,
            "windows": self.windows,
            "histograms": {k: h.summary() for k, h in self.hists.items()},
            "gauges": dict(self.gauges),
        }


# -------------------------------------------------------------- validator CLI

def main(argv=None):                       # pragma: no cover — CI entry point
    import argparse
    ap = argparse.ArgumentParser(
        description="Validate a Chrome trace-event JSON file (Perfetto schema)")
    ap.add_argument("path", help="trace JSON file to validate")
    args = ap.parse_args(argv)
    with open(args.path) as f:
        obj = json.load(f)
    errors = validate_chrome_trace(obj)
    n = len(obj.get("traceEvents", [])) if isinstance(obj, dict) else 0
    if errors:
        for e in errors[:20]:
            print(f"INVALID: {e}")
        raise SystemExit(1)
    print(f"OK: {args.path} — {n} events, schema valid")


if __name__ == "__main__":                 # pragma: no cover
    main()
