"""Serving observables: latency percentiles, throughput, occupancy, and
per-request ODIN PIMC cost attribution.

The ODIN attribution turns the paper's evaluation instrument (pim/trace's
transaction-level simulator) into a serving-time observable: every token a
request moves through the model — prefill and decode alike — costs one pass
of the active-parameter matmul stack, which maps to a fixed bundle of PIMC
commands (ANN_MUL/ANN_ACC plus the B_TO_S/S_TO_B conversion flows).  A
request's bill is therefore ``per-token command bundle × tokens processed``,
the same workload→command-trace framing RAPIDNN uses, applied per request.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.models import lm
from repro.pim.geometry import OdinModule
from repro.pim.trace import FC, Topology, trace_topology

__all__ = ["EngineStats", "OdinCostModel", "percentiles", "summarize"]


@dataclass
class EngineStats:
    """Counters the engine accumulates across its step loop."""

    steps: int = 0
    decode_steps: int = 0                 # decode *token* steps (horizon inner)
    decode_time: float = 0.0
    prefill_time: float = 0.0
    prefill_tokens: int = 0
    generated_tokens: int = 0             # all emitted tokens (incl. prefill's)
    decode_tokens: int = 0                # tokens emitted by decode steps only
    active_slot_steps: int = 0            # Σ per decode step of active slots
    slot_steps: int = 0                   # Σ per decode step of total slots
    dispatches: int = 0                   # compiled-step launches (prefill+decode)
    decode_dispatches: int = 0            # decode launches only (horizon = 1)
    host_syncs: int = 0                   # blocking device→host syncs
    preempt_swap: int = 0
    preempt_recompute: int = 0
    kv_cache_bytes: int = 0               # device bytes of KV-bearing leaves
    prefix_hit_tokens: int = 0            # prefill rows served from shared blocks
    shared_prefix_blocks: int = 0         # Σ aliased blocks over admissions
    cow_forks: int = 0                    # partial-block copy-on-write forks
    table_block_steps: int = 0            # Σ per step of distinct table blocks
    pool_steps: int = 0                   # steps the occupancy sample covers
    spec_drafted: int = 0                 # n-gram draft tokens verified
    spec_accepted: int = 0                # draft tokens accepted into streams
    spec_overhead_rows: int = 0           # verify rows computed beyond emitted
    mixed_dispatches: int = 0             # fused prefill+decode launches
    mixed_decode_rows: int = 0            # decode rows carried by mixed tiles
    mixed_prefill_rows: int = 0           # prefill rows carried by mixed tiles
    swap_skipped_blocks: int = 0          # swap-out copies skipped (re-attach)
    jit_evictions: int = 0                # fused executables dropped (LRU)
    timeouts: int = 0                     # requests expired (deadline/queue)
    cancelled: int = 0                    # client cancellations (incl. drain)
    failed: int = 0                       # requests quarantined as FAILED
    nan_quarantined: int = 0              # slots isolated by the logit guard
    alloc_faults: int = 0                 # injected pool-allocation failures
    swap_faults: int = 0                  # injected swap copies contained
    faults_injected: int = 0              # fault events applied from the plan
    degrade_level: int = 0                # ladder level at last observation
    degrade_transitions: int = 0          # ladder moves (escalate + restore)
    pool_writes: int = 0                  # cache rows written to PCRAM blocks
    retired_blocks: int = 0               # bad blocks retired from the pool
    scrub_copies: int = 0                 # blocks rewritten (scrub + retire drain)
    scrub_rows: int = 0                   # cache rows those rewrites moved
    wear_p99: float = 0.0                 # p99 of the per-block wear counters
    wear_max: int = 0                     # most-worn block's write count

    @property
    def occupancy(self) -> float:
        return self.active_slot_steps / max(1, self.slot_steps)

    @property
    def mean_referenced_blocks(self) -> float:
        """Steady-state pool occupancy: mean distinct device blocks referenced
        by running block tables per engine step (shared blocks count once —
        the observable prefix sharing shrinks)."""
        return self.table_block_steps / max(1, self.pool_steps)

    @property
    def decode_tps(self) -> float:
        """Decode-phase throughput: decode-emitted tokens over decode time.
        The first token of each request comes out of *prefill* and must not
        inflate this number (its cost sits in prefill_time)."""
        return self.decode_tokens / max(1e-9, self.decode_time)

    @property
    def tokens_per_dispatch(self) -> float:
        """Decode tokens amortized per compiled decode launch — the horizon
        amortization as a first-class observable (1.0 ⇒ no amortization;
        approaches the granted horizon as slots stay busy)."""
        return self.decode_tokens / max(1, self.decode_dispatches)

    @property
    def accept_rate(self) -> float:
        """Fraction of speculative draft tokens the verify step accepted —
        the knob the n-gram speedup rides on (0.0 with speculation off)."""
        return self.spec_accepted / max(1, self.spec_drafted)


class OdinCostModel:
    """Per-token PIMC command/energy bundle for one model config.

    One decoded (or prefilled) token activates ``N_active`` MACs (the
    active-parameter stack, lm.model_flops/2); modeled as an FC layer and
    traced through the five-command set exactly like the paper topologies.
    Pass a *full* arch config to attribute realistic energies even when the
    engine itself runs the smoke config.
    """

    def __init__(self, cfg, module: Optional[OdinModule] = None):
        module = module or OdinModule()
        self.macs_per_token = max(1, int(lm.model_flops(cfg, 1, train=False) / 2))
        topo = Topology(cfg.name, [FC(cfg.d_model, max(1, self.macs_per_token // cfg.d_model))])
        cost = trace_topology(topo, module, accounting="full")
        self.energy_pj_per_token = cost.total_energy_pj
        self.latency_ns_per_token = cost.total_latency_ns
        self.commands_per_token: Dict[str, int] = {}
        for layer in cost.layers:
            for name, n in layer.commands.items():
                self.commands_per_token[name] = self.commands_per_token.get(name, 0) + n

    def attribute(self, n_tokens: int) -> Dict:
        """Cost bill for one request that moved ``n_tokens`` through the model."""
        return {
            "tokens": n_tokens,
            "macs": n_tokens * self.macs_per_token,
            "energy_mj": n_tokens * self.energy_pj_per_token / 1e9,
            "module_latency_ms": n_tokens * self.latency_ns_per_token / 1e6,
            "commands": {k: n_tokens * v for k, v in self.commands_per_token.items()},
        }

    def energy_mj(self, n_rows: int) -> float:
        """Energy bill (mJ) for ``n_rows`` forward rows — the per-dispatch
        quantity trace spans carry, so summing span bills reproduces the
        run's ``odin_total`` exactly."""
        return n_rows * self.energy_pj_per_token / 1e9


def percentiles(xs: List[float], qs=(50, 90, 99)) -> Dict[str, Optional[float]]:
    """Exact percentiles of ``xs``; an empty sample yields ``None`` values —
    NOT ``float("nan")``, which ``json.dumps`` would emit as a bare ``NaN``
    token no strict JSON parser (or Perfetto) accepts."""
    if not xs:
        return {f"p{q}": None for q in qs}
    return {f"p{q}": float(np.percentile(np.asarray(xs, np.float64), q)) for q in qs}


def summarize(requests, stats: EngineStats, cost: Optional[OdinCostModel] = None,
              registry=None) -> Dict:
    """JSON-able roll-up: per-request records + fleet aggregates.

    ``registry`` (a :class:`repro.serving.trace.MetricsRegistry`) adds the
    windowed view — per-window counter deltas and streaming-histogram
    percentiles — under ``"metrics"``; the flat end-of-run aggregates remain
    exact and schema-stable (every field is a superset of the previous PRs').
    ``"engine_stats"`` mirrors every raw :class:`EngineStats` counter so a
    field added to the dataclass can never silently go unreported (CI pins
    the key set to the dataclass fields).
    """
    requests = list(requests)
    per_request = []
    ttfts, tpots = [], []
    for r in sorted(requests, key=lambda r: r.rid):
        ttft = None if r.t_first_token is None else r.t_first_token - r.arrival
        tpot = None
        if r.t_done is not None and r.t_first_token is not None and r.n_generated > 1:
            tpot = (r.t_done - r.t_first_token) / (r.n_generated - 1)
        if ttft is not None:
            ttfts.append(ttft)
        if tpot is not None:
            tpots.append(tpot)
        rec = {
            "rid": r.rid,
            "tenant": r.tenant,
            "arrival_s": r.arrival,
            "prompt_tokens": r.prompt_len,
            "generated_tokens": r.n_generated,
            "prefill_tokens": r.n_prefill_tokens,
            "state": r.state.value,
            "finish_reason": r.finish_reason,
            "ttft_s": ttft,
            "tpot_s": tpot,
            "preemptions": {"swap": r.n_preempt_swap, "recompute": r.n_preempt_recompute},
        }
        if cost is not None:
            # forward rows actually computed: prefill tokens (the request's
            # first generated token falls out of the last prefill pass), one
            # decode row per subsequent emitted token (the final token is
            # emitted without ever being passed back through the model), PLUS
            # the speculative verify rows whose drafts were rejected — each
            # spec inner step runs a K+1-row forward regardless of how many
            # tokens it ends up emitting, so rejected rows are real energy,
            # billed here as ``spec_overhead`` instead of silently vanishing.
            useful = r.n_prefill_tokens + max(0, r.n_generated - 1)
            overhead = getattr(r, "spec_overhead_rows", 0)
            rec["odin"] = cost.attribute(useful + overhead)
            rec["odin"]["spec_overhead"] = {
                "rows": overhead,
                "energy_mj": cost.energy_mj(overhead),
            }
        per_request.append(rec)
    out = {
        "requests": per_request,
        "ttft_s": percentiles(ttfts),
        "tpot_s": percentiles(tpots),
        "decode_tokens_per_s": stats.decode_tps,
        "generated_tokens": stats.generated_tokens,
        "decode_tokens": stats.decode_tokens,
        "prefill_tokens": stats.prefill_tokens,
        "steps": stats.steps,
        "decode_steps": stats.decode_steps,
        "dispatches": stats.dispatches,
        "decode_dispatches": stats.decode_dispatches,
        "host_syncs": stats.host_syncs,
        "tokens_per_dispatch": stats.tokens_per_dispatch,
        "decode_time_s": stats.decode_time,
        "prefill_time_s": stats.prefill_time,
        "slot_occupancy": stats.occupancy,
        "preemptions": {"swap": stats.preempt_swap, "recompute": stats.preempt_recompute},
        "kv_cache_bytes": stats.kv_cache_bytes,
        "prefix": {
            "hit_tokens": stats.prefix_hit_tokens,
            "shared_blocks": stats.shared_prefix_blocks,
            "cow_forks": stats.cow_forks,
            "mean_referenced_blocks": stats.mean_referenced_blocks,
            "swap_skipped_blocks": stats.swap_skipped_blocks,
        },
        "speculation": {
            "drafted": stats.spec_drafted,
            "accepted": stats.spec_accepted,
            "accept_rate": stats.accept_rate,
            "overhead_rows": stats.spec_overhead_rows,
        },
        "mixed": {
            "dispatches": stats.mixed_dispatches,
            "decode_rows": stats.mixed_decode_rows,
            "prefill_rows": stats.mixed_prefill_rows,
        },
        "jit_evictions": stats.jit_evictions,
        # terminal-state matrix: every request ends in exactly one of these
        "terminal": {
            "done": sum(1 for r in requests if r.state.value == "done"),
            "timeout": stats.timeouts,
            "cancelled": stats.cancelled,
            "failed": stats.failed,
        },
        "faults": {
            "injected": stats.faults_injected,
            "alloc": stats.alloc_faults,
            "swap": stats.swap_faults,
            "nan_quarantined": stats.nan_quarantined,
        },
        "degradation": {
            "level": stats.degrade_level,
            "transitions": stats.degrade_transitions,
        },
        # PCRAM reliability: endurance accounting, bad-block retirement, and
        # the drift-refresh scrubber's copy traffic
        "reliability": {
            "pool_writes": stats.pool_writes,
            "retired_blocks": stats.retired_blocks,
            "scrub_copies": stats.scrub_copies,
            "scrub_rows": stats.scrub_rows,
            "wear_p99": stats.wear_p99,
            "wear_max": stats.wear_max,
        },
        # raw counter mirror: keys pinned to the EngineStats dataclass fields
        # (tests/test_trace.py), so new counters surface here automatically
        "engine_stats": dataclasses.asdict(stats),
    }
    if any(r.tenant is not None for r in requests):
        # per-tenant QoS view: the accept-aware bill (emitted tokens), the
        # terminal matrix, latency percentiles and — when a cost model is
        # attached — the ODIN energy split per tenant.  Only materialized on
        # tenanted workloads, so untenanted summaries keep their old schema.
        tenants: Dict[str, Dict] = {}
        for r in sorted(requests, key=lambda r: r.rid):
            key = r.tenant if r.tenant is not None else "_untenanted"
            t = tenants.setdefault(key, {
                "requests": 0, "generated_tokens": 0, "prefill_tokens": 0,
                "terminal": {"done": 0, "timeout": 0, "cancelled": 0,
                             "failed": 0, "live": 0},
                "_ttfts": [], "_tpots": [], "energy_mj": 0.0})
            t["requests"] += 1
            t["generated_tokens"] += r.n_generated
            t["prefill_tokens"] += r.n_prefill_tokens
            state = r.state.value
            t["terminal"][state if state in t["terminal"] else "live"] += 1
            if r.t_first_token is not None:
                t["_ttfts"].append(r.t_first_token - r.arrival)
                if r.t_done is not None and r.n_generated > 1:
                    t["_tpots"].append(
                        (r.t_done - r.t_first_token) / (r.n_generated - 1))
            if cost is not None:
                rows = (r.n_prefill_tokens + max(0, r.n_generated - 1)
                        + getattr(r, "spec_overhead_rows", 0))
                t["energy_mj"] += cost.energy_mj(rows)
        for t in tenants.values():
            t["ttft_s"] = percentiles(t.pop("_ttfts"))
            t["tpot_s"] = percentiles(t.pop("_tpots"))
        out["tenants"] = tenants
    if registry is not None:
        out["metrics"] = registry.summary()
    if cost is not None:
        # phase-attributed energy: rejected speculative rows are verify
        # overhead, not free — and neither are the reliability layer's block
        # rewrites (drift-refresh scrub + retirement drains), which SET/RESET
        # real PCRAM rows.  odin_total is the sum of the phases and (by
        # construction) of every dispatch span's energy bill in a trace.
        phases = {
            "prefill": stats.prefill_tokens,
            "decode": stats.decode_tokens,
            "spec_verify_overhead": stats.spec_overhead_rows,
            "scrub": stats.scrub_rows,
        }
        out["odin_phases"] = {
            name: {"rows": rows, "energy_mj": cost.energy_mj(rows)}
            for name, rows in phases.items()
        }
        out["odin_total"] = cost.attribute(sum(phases.values()))
    return out
