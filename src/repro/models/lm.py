"""Decoder LM over heterogeneous scanned layer segments.

Supports every assigned architecture: dense/MoE GQA or MLA transformers,
Hymba hybrids, xLSTM stacks, MusicGen multi-codebook decoding, Qwen2-VL
vision-stub inputs, and DeepSeek MTP.  Params for each segment are stacked
``[n_layers, ...]`` and the stack runs under ``lax.scan`` so HLO size is
O(1 segment) — the 126-layer dry-run cells compile in seconds.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import BlockConfig, ModelConfig
from repro.core.odin_linear import OdinConfig
from repro.nn.blocks import block_apply, block_cache, block_spec
from repro.nn.layers import embed, embed_spec, linear, norm_spec, rmsnorm
from repro.nn.module import ParamSpec, count_params
from repro.nn.pcontext import constrain

__all__ = ["param_spec", "forward", "init_caches", "loss_fn", "model_flops"]

_is_spec = lambda x: isinstance(x, ParamSpec)


def _stack(tree, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("layers", *s.logical_axes), s.dtype, s.init, s.scale),
        tree, is_leaf=_is_spec,
    )


def _odin(cfg: ModelConfig) -> Optional[OdinConfig]:
    return None if cfg.odin_mode == "exact" else OdinConfig(mode=cfg.odin_mode)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig) -> Dict:
    spec: Dict = {
        "embed": embed_spec(cfg.vocab, cfg.d_model)
        if cfg.n_codebooks == 1
        else ParamSpec((cfg.n_codebooks, cfg.vocab, cfg.d_model), (None, "vocab", "embed")),
        "final_norm": norm_spec(cfg.d_model),
        "segments": [
            _stack(block_spec(b, cfg.d_model), b.n_layers) for b in cfg.blocks
        ],
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = (
            ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"), init="fan_in")
            if cfg.n_codebooks == 1
            else ParamSpec((cfg.n_codebooks, cfg.d_model, cfg.vocab), (None, "embed", "vocab"), init="fan_in")
        )
    if cfg.mtp:
        spec["mtp"] = {
            "proj": ParamSpec((2 * cfg.d_model, cfg.d_model), ("embed", "embed2"), init="fan_in"),
            "norm": norm_spec(cfg.d_model),
            "block": block_spec(cfg.blocks[0], cfg.d_model),
        }
    return spec


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _segment_apply(params_stacked, x, bcfg: BlockConfig, caches, positions, pos3d,
                   odin, remat: str, norm_eps: float, moe_no_drop: bool = False,
                   tables=None, spec_decode: bool = False, q_lens=None,
                   q_decode=None):
    """Scan one homogeneous segment of layers over the sequence activations."""
    spec1 = block_spec(bcfg, x.shape[-1])

    def layer(x, inp):
        p, c = inp
        # pin each per-layer param slice to its logical sharding: the scan
        # backward accumulates param cotangents into a stacked [L, ...]
        # buffer whose layout the partitioner copies from these slices —
        # unpinned, it replicates them (1.6 TB/device at the 405B cell).
        p = jax.tree.map(
            lambda w, s: constrain(w, s.logical_axes), p, spec1,
            is_leaf=lambda n: isinstance(n, ParamSpec),
        )
        y, c2 = block_apply(p, x, bcfg, cache=c, positions=positions, pos3d=pos3d,
                            odin=odin, norm_eps=norm_eps, moe_no_drop=moe_no_drop,
                            tables=tables, spec_decode=spec_decode, q_lens=q_lens,
                            q_decode=q_decode)
        # pin the scanned activation sharding so carry propagation never
        # settles on "replicated" (no-op outside a logical_sharding context)
        y = constrain(y, ("batch", "act_seq", None))
        return y, c2

    if remat == "full":
        layer = jax.checkpoint(layer, prevent_cse=False)
    elif remat == "dots":
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False,
        )
    x, new_caches = jax.lax.scan(layer, x, (params_stacked, caches))
    return x, new_caches


def forward(params, tokens, cfg: ModelConfig, caches=None, patch_embeds=None,
            pos3d=None, start_pos=None, moe_no_drop: bool = False, tables=None,
            spec_decode: bool = False, q_lens=None, q_decode=None):
    """tokens: [B,S] (or [B,K,S] multi-codebook) → (logits, new_caches).

    logits: [B,S,V] (or [B,S,K,V]).  ``caches``: list of per-segment stacked
    caches (or None for teacher-forced training).  ``start_pos``: absolute
    position of tokens[:, 0] (decode); defaults to 0.  ``moe_no_drop``:
    route without capacity dropping (serving paths — exact, per-token
    deterministic routing; training keeps the capped capacity).  ``tables``:
    per-slot KV block tables [B, n_pages] when the caches carry the paged
    block pool (one table serves every layer; scan-invariant).
    ``spec_decode``: the S tokens are an in-flight speculative draft —
    paged attention runs the multi-token-query decode kernel instead of the
    prefill gather path.  ``q_lens``: int32 [B] real-row counts of a mixed
    prefill+decode tile, right-aligned in the S rows (paged GQA caches
    only); ``start_pos`` should then be the per-slot position of row 0
    (pad rows get earlier — possibly negative — positions, which is fine:
    their output is discarded and their KV writes go to the write-off
    block); ``q_decode`` [B] bool flags the slots whose single real row is
    a decode step and must take the decode kernel's numerics.
    """
    odin = _odin(cfg)
    if cfg.n_codebooks > 1:
        # MusicGen: sum the K codebook embeddings per frame
        per = jax.vmap(lambda t, e: jnp.take(e, t, axis=0), in_axes=(1, 0), out_axes=1)(
            tokens, params["embed"]
        )                                                        # [B,K,S,d]
        x = per.sum(axis=1)
    else:
        x = embed(tokens, params["embed"])
    if cfg.vision_stub and patch_embeds is not None:
        # overlay precomputed patch embeddings on the image-token positions
        x = jax.lax.dynamic_update_slice(x, patch_embeds.astype(x.dtype), (0, 0, 0))

    start = jnp.int32(0) if start_pos is None else start_pos
    B, S = x.shape[0], x.shape[1]
    positions = start + jnp.arange(S, dtype=jnp.int32)[None, :] + jnp.zeros((B, 1), jnp.int32)

    new_caches = []
    for i, bcfg in enumerate(cfg.blocks):
        c = caches[i] if caches is not None else None
        if c is None:
            x, _ = _segment_apply(params["segments"][i], x, bcfg, None, positions, pos3d,
                                  odin, cfg.remat, cfg.norm_eps, moe_no_drop)
            new_caches.append(None)
        else:
            x, c2 = _segment_apply(params["segments"][i], x, bcfg, c, positions, pos3d,
                                   odin, cfg.remat, cfg.norm_eps, moe_no_drop,
                                   tables=tables, spec_decode=spec_decode,
                                   q_lens=q_lens, q_decode=q_decode)
            new_caches.append(c2)

    hidden = x
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if cfg.n_codebooks > 1:
        logits = jnp.einsum("bsd,kdv->bskv", x, head.astype(x.dtype))
    else:
        logits = jnp.matmul(x, head.astype(x.dtype))
    return logits, (new_caches if caches is not None else None), hidden


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
                attn_override=None):
    """Stacked per-segment decode caches (dtype defaults to cfg.kv_dtype).

    ``attn_override(block_cfg) -> dict | None`` substitutes a segment's
    attention cache before stacking (the serving layer swaps in the paged
    block pool this way without materializing the dense layout first).
    """
    if dtype is None:
        dtype = jnp.dtype(cfg.kv_dtype)
    out = []
    for b in cfg.blocks:
        one = block_cache(b, cfg.d_model, batch, max_len, dtype)
        if attn_override is not None:
            sub = attn_override(b)
            if sub is not None:
                one["attn"] = sub
        stacked = jax.tree.map(lambda a: jnp.broadcast_to(a, (b.n_layers, *a.shape)).copy()
                               if hasattr(a, "shape") else a, one)
        out.append(stacked)
    return out


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def _xent(logits, labels, vocab: int):
    """Cross-entropy in the vocab-sharded-friendly form.

    ``take_along_axis`` on a vocab-sharded logits tensor makes GSPMD gather
    the full vocab axis (3.3 GB fp32 per microbatch at phi4's 200k vocab);
    the masked-reduce form keeps every op vocab-local (the label pick and
    the logsumexp both reduce over vocab, which shards as a psum), and its
    gradient (softmax − onehot) stays elementwise-sharded too.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.sum(jnp.where(iota == labels[..., None], shifted, 0.0), axis=-1)
    return lse - picked


def loss_fn(params, batch: Dict, cfg: ModelConfig):
    """batch: tokens [B,S]/[B,K,S], labels same shape, optional stubs."""
    tokens, labels = batch["tokens"], batch["labels"]
    logits, _, h = forward(params, tokens, cfg,
                           patch_embeds=batch.get("patch_embeds"), pos3d=batch.get("pos3d"))
    if cfg.n_codebooks > 1:
        loss = _xent(logits, labels.swapaxes(1, 2), cfg.vocab).mean()
    else:
        loss = _xent(logits, labels, cfg.vocab).mean()
    metrics = {"loss": loss}
    if cfg.mtp:
        # Multi-token prediction (DeepSeek-V3): predict t+2 from h_t ++ emb(t+1)
        odin = _odin(cfg)
        x = embed(tokens, params["embed"])
        hm = rmsnorm(h[:, :-1], params["mtp"]["norm"], cfg.norm_eps)
        comb = jnp.concatenate([hm, x[:, 1:]], axis=-1)
        z = jnp.matmul(comb, params["mtp"]["proj"].astype(comb.dtype))
        B, S1 = z.shape[0], z.shape[1]
        pos = jnp.arange(S1, dtype=jnp.int32)[None, :] + jnp.zeros((B, 1), jnp.int32)
        z, _ = block_apply(params["mtp"]["block"], z, cfg.blocks[0], positions=pos,
                           odin=odin, norm_eps=cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        mtp_logits = jnp.matmul(z, head.astype(z.dtype))[:, :-1]   # predicts t+2
        mtp_loss = _xent(mtp_logits, labels[:, 2:] if labels.shape[1] > 2 else labels[:, :0], cfg.vocab).mean()
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.1 * mtp_loss
    metrics["loss_total"] = loss
    return loss, metrics


def model_flops(cfg: ModelConfig, n_tokens: int, train: bool = True) -> float:
    """MODEL_FLOPS = 6·N_active·D (roofline §g): params actually touched/token."""
    spec = param_spec(cfg)
    total = count_params(spec)
    # subtract non-active expert params for MoE
    inactive = 0
    for b in cfg.blocks:
        if b.kind == "moe" and b.moe is not None:
            per_expert = 3 * cfg.d_model * b.moe.d_ff
            inactive += b.n_layers * per_expert * (b.moe.n_experts - b.moe.top_k)
    active = total - inactive
    mult = 6.0 if train else 2.0
    return mult * active * n_tokens
