"""Architecture registry: ``--arch <id>`` → ModelConfig (full or smoke).

Shape-cell skips (DESIGN.md §5): ``long_500k`` requires sub-quadratic
attention and runs only for the SSM/hybrid archs; every arch is a decoder so
no other decode skips exist.
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import LM_SHAPES, ModelConfig, ShapeConfig

__all__ = ["ARCH_IDS", "get_config", "get_smoke", "cells", "skip_reason"]

_MODULES = {
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "llama3-405b": "repro.configs.llama3_405b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "xlstm-350m": "repro.configs.xlstm_350m",
}

ARCH_IDS: List[str] = list(_MODULES)

# Archs with sub-quadratic sequence mixing (run the long_500k cell).
SUBQUADRATIC = {"hymba-1.5b", "xlstm-350m"}


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).SMOKE


def skip_reason(arch: str, shape: str) -> str | None:
    """None ⇒ the (arch × shape) cell runs; else why it is skipped."""
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return "long_500k needs sub-quadratic attention (full-attention arch; DESIGN.md §5)"
    return None


def cells() -> List[Tuple[str, ShapeConfig]]:
    """All runnable (arch, shape) dry-run cells (40 assigned minus skips)."""
    out = []
    for arch in ARCH_IDS:
        for shape in LM_SHAPES.values():
            if skip_reason(arch, shape.name) is None:
                out.append((arch, shape))
    return out
