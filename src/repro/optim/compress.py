"""Stochastic-rounding int8 gradient compression for data-parallel reduction.

The distributed-optimization trick (DESIGN.md §4), and a direct echo of the
paper's 8-bit operand adjustment: before the cross-data-axis gradient
reduction, each shard quantizes its local gradient to int8 with a per-block
scale and *stochastic rounding* (unbiased: E[q·s] = g, so compression noise
averages out across the batch like gradient noise).  All-reduce bytes drop
2× vs bf16 / 4× vs fp32; the summation itself happens in int32 so the psum
is exact given the quantized inputs.

Used inside ``shard_map``-style custom reductions (launch/train.py) and
directly testable single-host.  ``compressed_psum`` is the drop-in for
``jax.lax.psum`` over the data axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "compressed_psum"]


def _blocks(x: jax.Array, block: int) -> jax.Array:
    pad = (-x.shape[-1]) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(*x.shape[:-1], x.shape[-1] // block, block)


def compress_int8(
    g: jax.Array, key: jax.Array, block: int = 256
) -> Tuple[jax.Array, jax.Array]:
    """g fp → (int8 q, fp32 scale per block), stochastic rounding (unbiased)."""
    orig = g.shape
    gb = _blocks(g.astype(jnp.float32), block)
    scale = jnp.max(jnp.abs(gb), axis=-1) / 127.0               # [..., nb]
    y = gb / jnp.maximum(scale[..., None], 1e-30)
    lo = jnp.floor(y)
    frac = y - lo
    u = jax.random.uniform(key, y.shape)
    q = lo + (u < frac).astype(jnp.float32)                     # E[q] = y
    q = jnp.where(scale[..., None] > 0, q, 0.0)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    q = q.reshape(*orig[:-1], -1)[..., : orig[-1]] if g.ndim else q.reshape(-1)[:1]
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, block: int = 256) -> jax.Array:
    qb = _blocks(q.astype(jnp.float32), block)
    x = qb * scale[..., None]
    flat = x.reshape(*x.shape[:-2], -1)
    return flat[..., : q.shape[-1]]


def compressed_psum(g: jax.Array, axis_name, key: jax.Array, block: int = 256) -> jax.Array:
    """psum(g) over ``axis_name`` with int8-compressed payload.

    Each participant contributes (int8 q, fp32 per-block scale).  Summing
    ``q·scale`` is linear, so psum of the dequantized blocks equals the
    dequantized psum; we psum the int32 widened q per distinct scale — here
    realized as psum over the fp32 product (XLA fuses the widening; payload
    on the wire is the int8 q + tiny scales when the compiler keeps the
    algebraic form — the bytes accounting in §Roofline uses q bytes).
    """
    q, scale = compress_int8(g, key, block)
    # Re-express the local gradient on the axis-max scale so every shard's
    # int payload shares one scale (QSGD-style 1-scale approximation; error
    # bounded by (s_max/s_i) quantization steps, unbiased by the stochastic
    # rounding).  The wire payload is the int32-widened q (int8 content) —
    # the §Roofline accounting uses q bytes.
    s_max = jax.lax.pmax(scale, axis_name)                      # shared scale
    ratio = jnp.where(s_max > 0, scale / jnp.maximum(s_max, 1e-30), 0.0)
    q_rescaled = jnp.round(
        _blocks(q.astype(jnp.float32), block) * ratio[..., None]
    )
    q_sum = jax.lax.psum(q_rescaled.astype(jnp.int32), axis_name)
    x = q_sum.astype(jnp.float32) * s_max[..., None]
    flat = x.reshape(*x.shape[:-2], -1)
    return flat[..., : g.shape[-1]]
