"""AdamW with int8 block-quantized moments.

Ties the paper's 8-bit theme to the training substrate: both Adam moments are
stored as int8 with one fp32 scale per ``block`` elements of the trailing
axis (bitsandbytes-style blockwise dynamic quantization).  At 1 byte/moment +
1/32 scale overhead this is what lets the 405B/671B cells hold the full
optimizer state on a 256-chip v5e pod (DESIGN.md §4): 6.1 bytes/param total
(bf16 param + 2 int8 moments + scales) vs 14 for canonical mixed precision.

Moment-quantization noise behaves like a small multiplicative perturbation on
the moment EMA (≤ 1/254 of the per-block max) — empirically loss-neutral
(tests/test_optim.py checks convergence parity against fp32 moments).

``moment_dtype="float32"`` switches to exact fp32 moments (small models,
parity tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "int8"    # int8 | float32
    block: int = 128              # int8 quantization block (trailing axis)


# ---------------------------------------------------------------------------
# blockwise int8 moment codec
# ---------------------------------------------------------------------------

def _pad_to_block(x: jax.Array, block: int) -> Tuple[jax.Array, int]:
    last = x.shape[-1]
    pad = (-last) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, pad


def _quant_block(x: jax.Array, block: int, kind: str = "m") -> Tuple[jax.Array, jax.Array]:
    """fp32 [..., L] → (int8 [..., L], fp32 scales [..., ceil(L/block)]).

    ``kind='m'`` — symmetric round-to-nearest (signed first moment).
    ``kind='v'`` — the second moment is quantized on the √v scale with
    *ceil* rounding: round-to-nearest maps small-but-nonzero v entries in a
    block to 0, and ``m/(√0+ε)`` then explodes (measured: LM loss → 10⁶).
    Ceil guarantees v̂ ≥ v, so quantization only ever *shrinks* updates —
    the numerically safe direction; √-space also halves the dynamic range
    the 8 bits must cover.
    """
    orig_last = x.shape[-1]
    if kind == "v":
        x = jnp.sqrt(jnp.maximum(x, 0.0))
    xp, _ = _pad_to_block(x, block)
    xb = xp.reshape(*xp.shape[:-1], xp.shape[-1] // block, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0                # [..., nb]
    y = xb / jnp.maximum(scale[..., None], 1e-30)
    q = jnp.where(scale[..., None] > 0.0,
                  jnp.ceil(y) if kind == "v" else jnp.round(y), 0.0)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    q = q.reshape(*xp.shape)[..., :orig_last]
    return q, scale.astype(jnp.float32)


def _dequant_block(q: jax.Array, scale: jax.Array, block: int,
                   kind: str = "m") -> jax.Array:
    qp, _ = _pad_to_block(q.astype(jnp.float32), block)
    xb = qp.reshape(*qp.shape[:-1], qp.shape[-1] // block, block)
    x = xb * scale[..., None]
    x = x.reshape(*qp.shape)[..., : q.shape[-1]]
    if kind == "v":
        x = x * x
    return x


# ---------------------------------------------------------------------------
# init / update
# ---------------------------------------------------------------------------

def _moment_zero(p: jax.Array, cfg: AdamWConfig):
    if cfg.moment_dtype == "float32":
        return {"q": jnp.zeros(p.shape, jnp.float32)}
    nb = -(-p.shape[-1] // cfg.block) if p.ndim else 1
    shape = p.shape if p.ndim else (1,)
    sshape = (*shape[:-1], nb)
    return {
        "q": jnp.zeros(shape, jnp.int8),
        "s": jnp.zeros(sshape, jnp.float32),
    }


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> Dict[str, Any]:
    return {
        "mu": jax.tree.map(lambda p: _moment_zero(p, cfg), params),
        "nu": jax.tree.map(lambda p: _moment_zero(p, cfg), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _load(m, cfg: AdamWConfig, kind: str = "m") -> jax.Array:
    if cfg.moment_dtype == "float32":
        return m["q"]
    return _dequant_block(m["q"], m["s"], cfg.block, kind)


def _store(x: jax.Array, cfg: AdamWConfig, kind: str = "m"):
    if cfg.moment_dtype == "float32":
        return {"q": x}
    q, s = _quant_block(x, cfg.block, kind)
    return {"q": q, "s": s}


def adamw_update(grads, params, state, lr, cfg: AdamWConfig = AdamWConfig()):
    """One AdamW step.  Returns (new_params, new_state).

    Decoupled weight decay; bias correction via step count.  Norm/bias params
    (ndim ≤ 1) are exempt from weight decay, the standard rule.
    """
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def leaf(g, p, mu, nu):
        g32 = g.astype(jnp.float32) if g.ndim else g.astype(jnp.float32).reshape(1)
        p32 = p.astype(jnp.float32) if p.ndim else p.astype(jnp.float32).reshape(1)
        m = cfg.b1 * _load(mu, cfg, "m") + (1.0 - cfg.b1) * g32
        v = cfg.b2 * _load(nu, cfg, "v") + (1.0 - cfg.b2) * g32 * g32
        upd = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:
            upd = upd + cfg.weight_decay * p32
        newp = (p32 - lr * upd).reshape(p.shape).astype(p.dtype)
        return newp, _store(m, cfg, "m"), _store(v, cfg, "v")

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [leaf(g, p, mu, nu) for g, p, mu, nu in zip(flat_g, flat_p, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}
