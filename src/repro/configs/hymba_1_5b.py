"""hymba-1.5b — 32L d_model=1600 25H (GQA kv=5) d_ff=5504, parallel attn+mamba.

[arXiv:2411.13676; hf]  Hybrid-head blocks: attention and a selective SSM run
in parallel on the same input, fused via per-branch output norms and learned
per-channel mixing (nn/blocks.py "hymba").  Sliding-window attention
(window=1024) everywhere except three global-attention layers (first, middle,
last) — the SWA + O(1) SSM state makes this a sub-quadratic arch, so it RUNS
the long_500k cell.  ssm_state=16, d_head = 1600/25 = 64.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig, SSMConfig

_SSM = SSMConfig(state_dim=16, expand=2, conv_dim=4)


def _attn(window: int) -> AttnConfig:
    return AttnConfig(kind="gqa", n_heads=25, n_kv_heads=5, d_head=64, window=window)


CONFIG = ModelConfig(
    name="hymba-1.5b",
    d_model=1_600,
    vocab=32_001,
    blocks=(
        BlockConfig(kind="hymba", n_layers=1, attn=_attn(0), ssm=_SSM, d_ff=5_504),
        BlockConfig(kind="hymba", n_layers=14, attn=_attn(1_024), ssm=_SSM, d_ff=5_504),
        BlockConfig(kind="hymba", n_layers=1, attn=_attn(0), ssm=_SSM, d_ff=5_504),
        BlockConfig(kind="hymba", n_layers=15, attn=_attn(1_024), ssm=_SSM, d_ff=5_504),
        BlockConfig(kind="hymba", n_layers=1, attn=_attn(0), ssm=_SSM, d_ff=5_504),
    ),
    remat="full",
)

_SMOKE_SSM = SSMConfig(state_dim=4, expand=2, conv_dim=4)

SMOKE = ModelConfig(
    name="hymba-smoke",
    d_model=64,
    vocab=256,
    blocks=(
        BlockConfig(
            kind="hymba", n_layers=2,
            attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16, window=8),
            ssm=_SMOKE_SSM, d_ff=128,
        ),
        BlockConfig(
            kind="hymba", n_layers=1,
            attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16),
            ssm=_SMOKE_SSM, d_ff=128,
        ),
    ),
)
