"""xlstm-350m — 24L d_model=1024, sLSTM + mLSTM blocks, vocab 50304, d_ff=0.

[arXiv:2405.04517]  xLSTM[7:1]-style stack: ratio 7 mLSTM (matrix memory,
parallel-friendly) to 1 sLSTM (scalar memory, strictly recurrent), repeated
three times.  4 heads.  O(1) recurrent state ⇒ RUNS the long_500k cell.
d_ff=0 per the assignment — the cells carry their own up/down projections,
there is no separate MLP.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

_HEADS = AttnConfig(kind="gqa", n_heads=4, n_kv_heads=4, d_head=256)


def _seg(kind: str, n: int) -> BlockConfig:
    return BlockConfig(kind=kind, n_layers=n, attn=_HEADS, d_ff=0)


CONFIG = ModelConfig(
    name="xlstm-350m",
    d_model=1_024,
    vocab=50_304,
    blocks=(
        _seg("mlstm", 7),
        _seg("slstm", 1),
        _seg("mlstm", 7),
        _seg("slstm", 1),
        _seg("mlstm", 7),
        _seg("slstm", 1),
    ),
    remat="full",
)

_SMOKE_HEADS = AttnConfig(kind="gqa", n_heads=2, n_kv_heads=2, d_head=32)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    d_model=64,
    vocab=256,
    blocks=(
        BlockConfig(kind="mlstm", n_layers=2, attn=_SMOKE_HEADS, d_ff=0),
        BlockConfig(kind="slstm", n_layers=1, attn=_SMOKE_HEADS, d_ff=0),
    ),
)
