"""musicgen-medium — 48L d_model=1536 24H (MHA kv=24) d_ff=6144, vocab 2048.

[arXiv:2306.05284; hf]  Decoder-only transformer over EnCodec tokens, 4
codebooks with the delay interleaving pattern handled by the data layer.  The
EnCodec frontend is a STUB (assignment): ``input_specs()`` provides the 4
parallel codebook token streams; the model sums 4 codebook embeddings per
frame and predicts 4 codebook heads (models/lm.py ``n_codebooks=4``).
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    d_model=1_536,
    vocab=2_048,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=48,
            attn=AttnConfig(kind="gqa", n_heads=24, n_kv_heads=24, d_head=64),
            d_ff=6_144,
            activation="gelu",
        ),
    ),
    n_codebooks=4,
    remat="full",
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    d_model=64,
    vocab=64,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=2,
            attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=4, d_head=16),
            d_ff=128,
            activation="gelu",
        ),
    ),
    n_codebooks=4,
)
