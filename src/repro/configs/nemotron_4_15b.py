"""nemotron-4-15b — 32L d_model=6144 48H (GQA kv=8) d_ff=24576, squared-ReLU.

[arXiv:2402.16819]  vocab 256000, no gated MLP (relu² activation), RoPE GQA.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    d_model=6_144,
    vocab=256_000,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=32,
            attn=AttnConfig(kind="gqa", n_heads=48, n_kv_heads=8, d_head=128),
            d_ff=24_576,
            activation="relu2",
        ),
    ),
    remat="full",
)

SMOKE = ModelConfig(
    name="nemotron-4-smoke",
    d_model=64,
    vocab=256,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=2,
            attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16),
            d_ff=128,
            activation="relu2",
        ),
    ),
)
