"""phi3-medium-14b — 40L d_model=5120 40H (GQA kv=10) d_ff=17920, RoPE SwiGLU.

[arXiv:2404.14219]  vocab 100352.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    d_model=5_120,
    vocab=100_352,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=40,
            attn=AttnConfig(kind="gqa", n_heads=40, n_kv_heads=10, d_head=128),
            d_ff=17_920,
            activation="swiglu",
        ),
    ),
    remat="full",
)

SMOKE = ModelConfig(
    name="phi3-medium-smoke",
    d_model=64,
    vocab=256,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=2,
            attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16),
            d_ff=128,
        ),
    ),
)
