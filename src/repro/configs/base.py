"""Model/run configuration schema.

One :class:`ModelConfig` describes any architecture in the assigned pool:
dense/MoE transformers (GQA or MLA attention), SSM hybrids (Hymba), xLSTM
stacks, multi-codebook audio LMs (MusicGen), and VLM backbones (Qwen2-VL).
The layer stack is a *pattern* of segments so heterogeneous stacks (DeepSeek's
dense-then-MoE, xLSTM's mLSTM/sLSTM alternation) still scan (DESIGN.md §3).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

__all__ = ["AttnConfig", "MoEConfig", "SSMConfig", "BlockConfig", "ModelConfig", "ShapeConfig", "LM_SHAPES"]


@dataclass(frozen=True)
class AttnConfig:
    kind: str = "gqa"             # gqa | mla
    n_heads: int = 8
    n_kv_heads: int = 8
    d_head: int = 64
    rope: str = "rope"            # rope | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # t/h/w splits of d_head/2
    window: int = 0               # >0 ⇒ sliding-window attention
    # MLA (DeepSeek-V3) dims
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff: int = 1024              # per-expert hidden
    n_shared: int = 0             # shared (always-on) experts
    capacity_factor: float = 1.25
    aux_free_bias: bool = True    # DeepSeek-V3 aux-loss-free load balancing
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    expand: int = 2
    conv_dim: int = 4
    dt_rank: int = 0              # 0 ⇒ ceil(d_model/16)


@dataclass(frozen=True)
class BlockConfig:
    """One layer-stack segment: ``n_layers`` identical blocks, scanned."""

    kind: str                     # dense | moe | hymba | mlstm | slstm
    n_layers: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    d_ff: int = 0                 # dense-MLP hidden (0 ⇒ no MLP, e.g. xLSTM)
    activation: str = "swiglu"    # swiglu | relu2 | gelu
    mlstm_impl: str = "chunkwise" # chunkwise (prod) | scan (reference/baseline)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab: int
    blocks: Tuple[BlockConfig, ...]
    norm: str = "rmsnorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    n_codebooks: int = 1          # >1 ⇒ MusicGen-style multi-codebook LM
    vision_stub: bool = False     # Qwen2-VL: frontend provides patch embeds
    mtp: bool = False             # DeepSeek multi-token-prediction head
    logical_rules: Dict[str, object] = field(default_factory=dict)
    # ODIN integration: execution mode for Linear layers (paper's technique)
    odin_mode: str = "exact"      # exact | int8 | sc
    # decode-cache element type: "int8" stores KV (or MLA latents) as 8-bit
    # fixed-point — ODIN's fixed-8-bit-operand adjustment applied to the
    # decode working set (halves cache capacity AND per-token HBM traffic,
    # §Perf-3); "bfloat16" is the exact baseline.
    kv_dtype: str = "bfloat16"
    remat: str = "none"           # none | full | dots
    dtype: str = "bfloat16"

    @property
    def n_layers(self) -> int:
        return sum(b.n_layers for b in self.blocks)

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


LM_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
