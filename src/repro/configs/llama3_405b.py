"""llama3-405b — 126L d_model=16384 128H (GQA kv=8) d_ff=53248, vocab 128256.

[arXiv:2407.21783]  The FSDP + int8-optimizer memory path exists for this
arch (DESIGN.md §4): 405B bf16 params shard over the full mesh.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    d_model=16_384,
    vocab=128_256,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=126,
            attn=AttnConfig(kind="gqa", n_heads=128, n_kv_heads=8, d_head=128),
            d_ff=53_248,
            activation="swiglu",
        ),
    ),
    remat="full",
)

SMOKE = ModelConfig(
    name="llama3-smoke",
    d_model=64,
    vocab=256,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=2,
            attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16),
            d_ff=128,
        ),
    ),
)
