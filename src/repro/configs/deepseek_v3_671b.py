"""deepseek-v3-671b — 61L d_model=7168, MLA, MoE 256e top-8 (+1 shared), MTP.

[arXiv:2412.19437; hf]  Exact paper dims: 3 dense layers then 58 MoE layers;
MLA with q_lora_rank=1536, kv_lora_rank=512, qk_nope=128, qk_rope=64,
v_head=128; routed experts d_ff=2048, dense/shared d_ff=18432 / 2048·1;
vocab 129280; aux-loss-free routing bias; multi-token prediction head.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig, MoEConfig

_MLA = AttnConfig(
    kind="mla",
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    d_model=7168,
    vocab=129_280,
    blocks=(
        BlockConfig(kind="dense", n_layers=3, attn=_MLA, d_ff=18_432),
        BlockConfig(
            kind="moe",
            n_layers=58,
            attn=_MLA,
            moe=MoEConfig(
                n_experts=256,
                top_k=8,
                d_ff=2_048,
                n_shared=1,
                capacity_factor=1.25,
                aux_free_bias=True,
            ),
        ),
    ),
    mtp=True,
    remat="full",
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke",
    d_model=64,
    vocab=256,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=1,
            attn=AttnConfig(
                kind="mla", n_heads=4, n_kv_heads=4, d_head=16, q_lora_rank=32,
                kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
            ),
            d_ff=128,
        ),
        BlockConfig(
            kind="moe",
            n_layers=2,
            attn=AttnConfig(
                kind="mla", n_heads=4, n_kv_heads=4, d_head=16, q_lora_rank=32,
                kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
            ),
            moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=1),
        ),
    ),
    mtp=True,
)
