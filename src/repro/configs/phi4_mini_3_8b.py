"""phi4-mini-3.8b — 32L d_model=3072 24H (GQA kv=8) d_ff=8192, RoPE SwiGLU.

[arXiv:2412.08905; hf]  vocab 200064.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    d_model=3_072,
    vocab=200_064,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=32,
            attn=AttnConfig(kind="gqa", n_heads=24, n_kv_heads=8, d_head=128),
            d_ff=8_192,
            activation="swiglu",
        ),
    ),
    remat="full",
)

SMOKE = ModelConfig(
    name="phi4-mini-smoke",
    d_model=64,
    vocab=256,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=2,
            attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16),
            d_ff=128,
        ),
    ),
)
