"""qwen2-vl-2b — 28L d_model=1536 12H (GQA kv=2) d_ff=8960, M-RoPE, vocab 151936.

[arXiv:2409.12191; hf]  VLM backbone only (assignment): the dynamic-resolution
vision frontend is a STUB — ``input_specs()`` supplies precomputed patch
embeddings which overlay the leading token positions (models/lm.py), plus the
3-D (t, h, w) M-RoPE position ids.  d_head = 1536/12 = 128.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    d_model=1_536,
    vocab=151_936,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=28,
            attn=AttnConfig(
                kind="gqa", n_heads=12, n_kv_heads=2, d_head=128,
                rope="mrope", mrope_sections=(16, 24, 24),
            ),
            d_ff=8_960,
            activation="swiglu",
        ),
    ),
    vision_stub=True,
    remat="full",
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke",
    d_model=64,
    vocab=256,
    blocks=(
        BlockConfig(
            kind="dense",
            n_layers=2,
            attn=AttnConfig(
                kind="gqa", n_heads=4, n_kv_heads=2, d_head=16,
                rope="mrope", mrope_sections=(2, 3, 3),
            ),
            d_ff=128,
        ),
    ),
    vision_stub=True,
)
