"""qwen3-moe-235b-a22b — 94L d_model=4096 64H (GQA kv=4), MoE 128e top-8.

[hf:Qwen/Qwen3-30B-A3B family; assignment dims]  d_ff=1536 per routed expert,
vocab 151936, no shared experts, RoPE GQA.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    d_model=4_096,
    vocab=151_936,
    blocks=(
        BlockConfig(
            kind="moe",
            n_layers=94,
            attn=AttnConfig(kind="gqa", n_heads=64, n_kv_heads=4, d_head=128),
            moe=MoEConfig(
                n_experts=128, top_k=8, d_ff=1_536, n_shared=0,
                capacity_factor=1.25, aux_free_bias=False,
            ),
        ),
    ),
    remat="full",
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    d_model=64,
    vocab=256,
    blocks=(
        BlockConfig(
            kind="moe",
            n_layers=2,
            attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=2, d_head=16),
            moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, aux_free_bias=False),
        ),
    ),
)
