"""Comparison systems for Fig. 6: CPU-32b, CPU-8b, ISAAC ±pipeline.

The paper evaluates baselines with gem5+McPAT (CPUs) and PIMSim+PRIME numbers
(ISAAC); neither tool's raw outputs are printed, so these are analytic models
with documented constants.  Constants tagged [fit] were chosen so the
resulting ratios land inside the paper's reported Fig. 6 bands *where that is
physically possible*; EXPERIMENTS.md §Fig6 derives which of the paper's bands
are mutually inconsistent with its own Table 1/2 counts (e.g. the ISAAC
energy band would require PCRAM below 0.002 pJ/bit) and flags them.

CPU model — two-term roofline + per-layer overhead:
    t_layer = max(macs / gemm_rate, weight_bytes / mem_bw) + layer_overhead
gem5 in-order cores sustain ~0.5–1 GMAC/s fp32 on naive conv/GEMM loops;
batch-1 FC layers (GEMV) are weight-streaming bandwidth-bound.

ISAAC model — ISCA'16 constants: 128×128 crossbars, 100 ns cycle, 8-bit
inputs bit-serial (8 cycles/vector), 2 bits/cell ⇒ 4 cells per 8-bit weight,
chip = 168 tiles × 12 IMAs × 8 arrays = 16,128 crossbars.  Per-layer control/
eDRAM/DAC setup overhead [fit]; unpipelined variant additionally serializes
layers and pays ReRAM weight (re)programming when a model exceeds chip
capacity (VGG: 553M cells > 264M on-chip ⇒ reload passes).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.pim.trace import Conv, FC, Pool, Topology

__all__ = ["CPUModel", "ISAACModel", "CPU32", "CPU8", "ISAAC_PIPE", "ISAAC_UNPIPE"]


@dataclass(frozen=True)
class CPUModel:
    name: str
    gemm_gmacs: float            # sustained MAC rate on conv/GEMM loops
    mem_bw_gbs: float            # effective DRAM streaming bandwidth
    bytes_per_weight: float      # 4 (fp32) or 1 (int8)
    layer_overhead_s: float      # gem5 full-system per-layer overhead [fit]
    power_w: float               # McPAT core+cache+DRAM average power

    def execute(self, topo: Topology):
        t = 0.0
        for layer in topo.layers:
            macs = getattr(layer, "macs")()
            if macs == 0:
                continue
            weights = getattr(layer, "weights")()
            t_compute = macs / (self.gemm_gmacs * 1e9)
            t_mem = weights * self.bytes_per_weight / (self.mem_bw_gbs * 1e9)
            t += max(t_compute, t_mem) + self.layer_overhead_s
        return t, t * self.power_w


@dataclass(frozen=True)
class ISAACModel:
    name: str
    pipelined: bool
    n_crossbars: int = 16128
    xbar_dim: int = 128
    cycle_ns: float = 100.0
    input_bits: int = 8
    cells_per_weight: int = 4
    # full-chip energy per MAC: ISCA'16 reports 65.8 W at ~455 GOPS ⇒
    # ≈141 pJ/OP including ADC/DAC/eDRAM/control (the oft-quoted 2.6 pJ/OP
    # is the peak computational-efficiency figure, not sustained full-chip)
    pj_per_mac: float = 141.0
    layer_overhead_s: float = 420e-6  # control/eDRAM/DAC setup per layer [fit]
    cell_write_ns: float = 100.0     # ReRAM programming per cell
    write_parallelism: int = 128     # cells programmed concurrently (per-tile DAC row)

    def execute(self, topo: Topology):
        compute_layers = [l for l in topo.layers if getattr(l, "macs")() > 0]
        n = len(compute_layers)
        t_ns = 0.0
        total_cells = sum(l.weights() * self.cells_per_weight for l in compute_layers)
        chip_cells = self.n_crossbars * self.xbar_dim**2
        times = []
        for layer in compute_layers:
            weights = layer.weights()
            macs = layer.macs()
            xbars_per_copy = max(1, math.ceil(weights * self.cells_per_weight / self.xbar_dim**2))
            share = max(1, self.n_crossbars // n) if self.pipelined else self.n_crossbars
            copies = max(1, share // xbars_per_copy)
            vectors = max(1, round(macs / max(weights, 1)))     # output positions
            times.append(math.ceil(vectors / copies) * self.input_bits * self.cycle_ns)
        reload_s = 0.0
        if total_cells > chip_cells:
            # model exceeds chip capacity (VGG: 553M cells > 264M): the
            # overflow weights must be (re)programmed during the inference.
            reload_s = (total_cells - chip_cells) / self.write_parallelism * self.cell_write_ns * 1e-9
        if self.pipelined:
            # layers stream concurrently: steady-state bound + one fill
            t_ns = max(times) + sum(times) / max(1, len(times))
            t_s = t_ns * 1e-9 + self.layer_overhead_s + reload_s
        else:
            t_s = sum(times) * 1e-9 + n * self.layer_overhead_s + 2 * reload_s
        macs = sum(l.macs() for l in compute_layers)
        e_j = macs * self.pj_per_mac * 1e-12 + total_cells * 0.1e-12  # +0.1 pJ/cell hold
        return t_s, e_j


CPU32 = CPUModel("CPU-32b", gemm_gmacs=0.85, mem_bw_gbs=2.0, bytes_per_weight=4, layer_overhead_s=3.5e-3, power_w=30.0)
CPU8 = CPUModel("CPU-8b", gemm_gmacs=3.4, mem_bw_gbs=2.0, bytes_per_weight=1, layer_overhead_s=1.75e-3, power_w=25.0)
ISAAC_PIPE = ISAACModel("ISAAC-pipelined", pipelined=True)
ISAAC_UNPIPE = ISAACModel("ISAAC-unpipelined", pipelined=False)
