"""PCRAM organization and primitive timing/energy model (paper §III-B, §VI-A).

Hierarchy (paper's example 16 GB part): 2 channels × 8 ranks × 16 banks;
each bank has 16 partitions of 4096 wordlines × 8 Kb bitlines; 256 peripheral
S/A + W/D structures ⇒ read/write granularity is one 256-bit block, and a full
8 Kb row holds 32 such blocks (= 32 packed 8-bit operands per block read,
32 stochastic operands per row).

Primitive timing is *derived from the paper's own Table 1* by solving the
linear system over the five commands:

    ANN_MUL  = 1·t_R + 1·t_W = 108 ns
    B_TO_S   = 33·t_R + 32·t_W = 3504 ns
    ⇒ t_R = 48 ns, t_W = 60 ns            (S_TO_B/ANN_POOL check: 3456 ns ✓)

Energy constants are *model inputs* (the paper extracts them from the K.-J.
Lee PRAM datasheet [29] scaled to 14 nm via [30] but does not print them);
defaults below follow that literature and are exposed for sensitivity runs.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["PCRAMGeometry", "PCRAMTiming", "PCRAMEnergy", "OdinModule"]


@dataclass(frozen=True)
class PCRAMGeometry:
    channels: int = 1            # the ODIN accelerator occupies one channel
    ranks_per_channel: int = 8
    banks_per_rank: int = 16
    partitions_per_bank: int = 16
    rows_per_partition: int = 4096
    row_bits: int = 8192         # 8 Kb row = 32 blocks
    block_bits: int = 256        # S/A + W/D granularity

    @property
    def blocks_per_row(self) -> int:
        return self.row_bits // self.block_bits          # 32

    @property
    def operands_per_block(self) -> int:
        return self.block_bits // 8                      # 32 8-bit operands

    @property
    def banks(self) -> int:
        return self.channels * self.ranks_per_channel * self.banks_per_rank  # 128

    @property
    def compute_rows_per_bank(self) -> int:
        # one whole partition per bank is the Compute Partition (paper §IV-B)
        return self.rows_per_partition

    def bank_bits(self) -> int:
        return self.partitions_per_bank * self.rows_per_partition * self.row_bits

    def module_bits(self) -> int:
        return self.banks * self.bank_bits()


@dataclass(frozen=True)
class PCRAMTiming:
    t_read_ns: float = 48.0      # per 256-bit block read  (derived from Table 1)
    t_write_ns: float = 60.0     # per 256-bit block write (derived from Table 1)


@dataclass(frozen=True)
class PCRAMEnergy:
    """Per-block (256-bit) access energies, pJ — 14 nm-scaled PCRAM literature values."""

    e_read_pj: float = 128.0     # 0.5 pJ/bit read
    e_write_pj: float = 1280.0   # 5.0 pJ/bit write (SET/RESET average)


@dataclass(frozen=True)
class OdinModule:
    """One ODIN accelerator channel: geometry + primitive costs + parallelism.

    ``partition_pairs`` — PALP-style [22] partition-level parallelism inside a
    bank: pairs of partitions can serve simultaneous row activations.  The
    paper adopts PALP for its conv mapping (its VGG conv read counts imply a
    combined row-packing × partition factor of ≈256; see trace.py).
    """

    geom: PCRAMGeometry = PCRAMGeometry()
    timing: PCRAMTiming = PCRAMTiming()
    energy: PCRAMEnergy = PCRAMEnergy()
    partition_pairs: int = 8     # concurrent row-pair activations per bank

    @property
    def parallel_units(self) -> int:
        """Independent command streams across the module (banks × partition pairs)."""
        return self.geom.banks * self.partition_pairs
