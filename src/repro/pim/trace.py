"""Transaction-level execution model: ANN topology → PIMC command trace → cost.

This is the paper's evaluation instrument (§VI-A "in-house transaction-level
simulator").  A topology is a list of layer specs; each layer maps to counts
of the five PIMC commands per inference, which roll up to latency (with
bank/partition parallelism) and energy (no parallelism discount).

Command-count model (validated against the parseable cells of paper Table 2):

* FC(n_in → n_out):  MUL = n_in·n_out, ACC = (n_in−1)·n_out (balanced MUX
  tree), so FC reads ≈ writes ≈ 2·MACs — for VGG1's FC stack (123.63M MACs)
  this gives 247.3M reads / 248.3M writes vs the paper's 247M / 248M.  ✓
* Activations are converted per layer (B_TO_S per 32 operands); weights are
  converted *once at upload* (offline, amortized) — required to match the
  paper's write counts (per-inference weight conversion would add ~123M
  writes to VGG1 FC, contradicting Table 2).
* CONV: weight-stationary mapping with full-row operand packing (32 operand
  pairs per PINATUBO row activation) × all 16 partitions activated per bank ⇒
  a fused MUL→ACC covers ``conv_pack = 512`` MACs with 2 reads + 1 write (the
  AND result stays latched in the sense amps and feeds the ACC directly —
  PINATUBO cascading).  Fitting the paper's own Table 2: VGG1 conv reads
  2·15.35G/512 = 60.0M vs printed 58.8M (+2%; the residual −2% is consistent
  with valid-padding output dims), writes 30.0M vs 30.3M (−1%).  ``accounting``
  selects "paper" (MUL/ACC only — what Table 2 prints; conversions excluded)
  or "full" (first-principles: + B_TO_S/S_TO_B flows, the default).
* POOL(p:1): one ANN_POOL per 32 outputs per pooling window group.
* Memory: two-rail 8-bit weights (16 bits/weight) + activation scratch.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.pim.commands import command_set
from repro.pim.geometry import OdinModule

__all__ = [
    "FC",
    "Conv",
    "Pool",
    "Topology",
    "LayerCost",
    "TopologyCost",
    "trace_topology",
    "CNN1",
    "CNN2",
    "VGG1",
    "VGG2",
    "PAPER_TOPOLOGIES",
]


@dataclass(frozen=True)
class FC:
    n_in: int
    n_out: int

    def macs(self) -> int:
        return self.n_in * self.n_out

    def weights(self) -> int:
        return self.n_in * self.n_out

    def out_units(self) -> int:
        return self.n_out


@dataclass(frozen=True)
class Conv:
    h: int
    w: int
    c_in: int
    k: int
    c_out: int
    stride: int = 1
    pad: int = 1

    @property
    def out_hw(self) -> Tuple[int, int]:
        oh = (self.h + 2 * self.pad - self.k) // self.stride + 1
        ow = (self.w + 2 * self.pad - self.k) // self.stride + 1
        return oh, ow

    def macs(self) -> int:
        oh, ow = self.out_hw
        return oh * ow * self.c_out * self.k * self.k * self.c_in

    def weights(self) -> int:
        return self.c_out * self.c_in * self.k * self.k

    def out_units(self) -> int:
        oh, ow = self.out_hw
        return oh * ow * self.c_out


@dataclass(frozen=True)
class Pool:
    h: int
    w: int
    c: int
    size: int = 2            # size×size window → size² : 1 pooling

    def outputs(self) -> int:
        return (self.h // self.size) * (self.w // self.size) * self.c

    def macs(self) -> int:
        return 0

    def weights(self) -> int:
        return 0


@dataclass(frozen=True)
class Topology:
    name: str
    layers: List[object]
    dataset: str = ""

    def fc_layers(self):
        return [l for l in self.layers if isinstance(l, FC)]

    def conv_layers(self):
        return [l for l in self.layers if isinstance(l, Conv)]


@dataclass
class LayerCost:
    kind: str
    commands: Dict[str, int]
    reads: int
    writes: int
    latency_ns: float
    energy_pj: float
    macs: int


@dataclass
class TopologyCost:
    name: str
    layers: List[LayerCost]
    fc_reads: int = 0
    fc_writes: int = 0
    conv_reads: int = 0
    conv_writes: int = 0
    fc_mem_gbit: float = 0.0
    conv_mem_gbit: float = 0.0
    total_latency_ns: float = 0.0
    total_energy_pj: float = 0.0

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _layer_commands(
    layer, module: OdinModule, conv_pack: int, accounting: str
) -> Tuple[str, Dict[str, int], int]:
    """Return (kind, command counts, parallel units available for this layer).

    Conv uses the *fused* MUL→ACC flow (``ANN_MUL_F``: 1 read, 0 writes — the
    AND result stays latched in the S/As and feeds the following ANN_ACC), the
    accounting that reproduces the paper's own Table 2 read:write = 2:1 ratio.
    ``accounting == "paper"`` drops the conversion commands (what Table 2
    prints); ``"full"`` is the first-principles flow.
    """
    ops_per_cmd = 32
    conversions = accounting != "paper"
    if isinstance(layer, FC):
        muls = layer.macs()
        accs = (layer.n_in - 1) * layer.n_out
        cmds = {"ANN_MUL": muls, "ANN_ACC": accs}
        if conversions:
            cmds["B_TO_S"] = _ceil(layer.n_in, ops_per_cmd)
            cmds["S_TO_B"] = _ceil(layer.n_out, ops_per_cmd)
        return "fc", cmds, layer.out_units()
    if isinstance(layer, Conv):
        macs = layer.macs()
        oh, ow = layer.out_hw
        cmds = {"ANN_MUL_F": _ceil(macs, conv_pack), "ANN_ACC": _ceil(macs, conv_pack)}
        if conversions:
            cmds["B_TO_S"] = _ceil(layer.h * layer.w * layer.c_in, ops_per_cmd)
            cmds["S_TO_B"] = _ceil(oh * ow * layer.c_out, ops_per_cmd)
        return "conv", cmds, layer.out_units()
    if isinstance(layer, Pool):
        cmds = {"ANN_POOL": _ceil(layer.outputs(), ops_per_cmd)}
        return "pool", cmds, max(1, layer.outputs() // 32)
    raise TypeError(layer)


def trace_topology(
    topo: Topology,
    module: OdinModule = OdinModule(),
    conv_pack: int = 512,
    accounting: str = "full",
) -> TopologyCost:
    cs = command_set()
    out = TopologyCost(topo.name, [])
    for layer in topo.layers:
        kind, cmds, units = _layer_commands(layer, module, conv_pack, accounting)
        reads = sum(cs[c].reads * n for c, n in cmds.items())
        writes = sum(cs[c].writes * n for c, n in cmds.items())
        serial_ns = sum(cs[c].latency_ns(module) * n for c, n in cmds.items())
        energy_pj = sum(cs[c].energy_pj(module) * n for c, n in cmds.items())
        # Commands for independent MAC trees spread across banks × partition
        # pairs; trees wider than 32 are split into 32-input subtrees so even
        # few-output layers (e.g. CNN1's 784→70 FC) use the full module.
        macs = getattr(layer, "macs")()
        par = max(1, min(module.parallel_units, max(units, _ceil(macs, 32))))
        lat = serial_ns / par
        lc = LayerCost(kind, cmds, reads, writes, lat, energy_pj, getattr(layer, "macs")())
        out.layers.append(lc)
        if kind == "fc":
            out.fc_reads += reads
            out.fc_writes += writes
            out.fc_mem_gbit += layer.weights() * 16 / 1e9      # two-rail 8-bit
        elif kind == "conv":
            out.conv_reads += reads
            out.conv_writes += writes
            out.conv_mem_gbit += layer.weights() * 16 / 1e9
        out.total_latency_ns += lat                            # layer-serial (paper §V-A)
        out.total_energy_pj += energy_pj
    return out


# ---------------------------------------------------------------------------
# Paper benchmark topologies (Table 4).  CNN strings are read as
# conv<k>x<filters>; VGG1/2 are the standard VGG-16/19 stacks on 224×224×3.
# ---------------------------------------------------------------------------

def _vgg(name: str, cfg: List, dataset="ImageNet") -> Topology:
    layers: List[object] = []
    h = w = 224
    c = 3
    for item in cfg:
        if item == "pool":
            layers.append(Pool(h, w, c, 2))
            h //= 2
            w //= 2
        else:
            k, c_out = item
            layers.append(Conv(h, w, c, k, c_out, 1, k // 2))
            c = c_out
    for n_in, n_out in [(25088, 4096), (4096, 4096), (4096, 1000)]:
        layers.append(FC(n_in, n_out))
    return Topology(name, layers, dataset)


# CNN1: conv5x5-pool-784-70-10 (MNIST).  Input 28×28×1, 5×5 conv ("5x5" read
# as kernel 5, 5 output maps — the string is ambiguous; documented choice),
# 2×2 pool, then the FC stack as printed.
CNN1 = Topology(
    "CNN1",
    [Conv(28, 28, 1, 5, 5, 1, 2), Pool(28, 28, 5, 2), FC(784, 70), FC(70, 10)],
    "MNIST",
)
# CNN2: conv7x10-pool-1210-120-10 (kernel 7, 10 maps).
CNN2 = Topology(
    "CNN2",
    [Conv(28, 28, 1, 7, 10, 1, 3), Pool(28, 28, 10, 2), FC(1210, 120), FC(120, 10)],
    "MNIST",
)
VGG1 = _vgg(
    "VGG1",
    [(3, 64), (3, 64), "pool", (3, 128), (3, 128), "pool",
     (3, 256), (3, 256), (3, 256), "pool", (3, 512), (3, 512), (3, 512), "pool",
     (3, 512), (3, 512), (3, 512), "pool"],
)
VGG2 = _vgg(
    "VGG2",
    [(3, 64), (3, 64), "pool", (3, 128), (3, 128), "pool",
     (3, 256), (3, 256), (3, 256), (1, 512), "pool", (3, 512), (3, 512), (3, 512),
     (1, 512), "pool", (3, 512), (3, 512), (3, 512), (1, 512), "pool"],
)

PAPER_TOPOLOGIES = {t.name: t for t in (CNN1, CNN2, VGG1, VGG2)}
