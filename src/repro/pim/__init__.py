from repro.pim.geometry import PCRAMGeometry, PCRAMTiming, PCRAMEnergy, OdinModule
from repro.pim.commands import Command, command_set, TABLE1_EXPECTED, TABLE3_PJ
from repro.pim.trace import (
    FC, Conv, Pool, Topology, trace_topology,
    CNN1, CNN2, VGG1, VGG2, PAPER_TOPOLOGIES,
)
from repro.pim.baselines import CPUModel, ISAACModel, CPU32, CPU8, ISAAC_PIPE, ISAAC_UNPIPE
