"""The five ODIN PIM-controller (PIMC) commands — paper §IV-C, Table 1.

Each command is a fixed activity flow of PCRAM block READs/WRITEs plus add-on
logic work.  Latency is ``reads·t_R + writes·t_W``; with the paper-derived
(t_R, t_W) = (48, 60) ns this reproduces Table 1 *exactly* (asserted in
tests/benchmarks).  Energy adds the add-on logic components of Table 3.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.pim.geometry import OdinModule

__all__ = ["AddOnEnergy", "Command", "command_set", "TABLE1_EXPECTED"]


# Table 3 (paper, 14 nm CMOS) — per-use energies of add-on circuits, pJ.
TABLE3_PJ = {
    "sram_lut": 0.297,
    "mux_16_8": 4.662,
    "mux_256_8": 4.72,
    "mux_256_32": 18.6,
    "demux_8_32": 18.64,
    "demux_8_256": 149.19,
    "demux_256_1024": 902.8,
    "relu": 185.0,
    "pool": 2140.0,
}

# Table 1 (paper) — ground truth used by tests.
TABLE1_EXPECTED = {
    "B_TO_S": dict(reads=33, writes=32, latency_ns=3504),
    "S_TO_B": dict(reads=32, writes=32, latency_ns=3456),
    "ANN_POOL": dict(reads=32, writes=32, latency_ns=3456),
    "ANN_MUL": dict(reads=1, writes=1, latency_ns=108),
    "ANN_ACC": dict(reads=1, writes=1, latency_ns=108),
}


@dataclass(frozen=True)
class AddOnEnergy:
    """Add-on logic energy per command invocation, composed from Table 3."""

    pj: float


@dataclass(frozen=True)
class Command:
    name: str
    reads: int               # 256-bit PCRAM block reads per invocation
    writes: int              # 256-bit PCRAM block writes per invocation
    addon_pj: float          # CMOS add-on energy per invocation

    def latency_ns(self, m: OdinModule) -> float:
        return self.reads * m.timing.t_read_ns + self.writes * m.timing.t_write_ns

    def energy_pj(self, m: OdinModule) -> float:
        return (
            self.reads * m.energy.e_read_pj
            + self.writes * m.energy.e_write_pj
            + self.addon_pj
        )


def command_set() -> Dict[str, Command]:
    """Activity flows per paper Fig. 5, add-on energy compositions per §IV-B.

    * B_TO_S  — 1 operand-block read + 32 stream-row writes (+32 LUT-iteration
      reads per Table 1's 33): per operand an SRAM-LUT access and an 8:256
      demux into the stream row.
    * S_TO_B  — 32 stream reads; per operand a 256:8 mux (popcount readout
      path) and the 8-bit ReLU block; 32 writes assemble results (Fig. 5d).
    * ANN_POOL— 32 reads / 32 writes; 4:1 pooling block per group of four
      operands (32/4 = 8 uses) plus a 256:32 mux staging.
    * ANN_MUL — one PINATUBO double-row activation read (bit-parallel AND) +
      one result-row write.  Sense-amp modification energy is folded into the
      block read energy (as in PINATUBO [3]).
    * ANN_ACC — one MUX step = AND/AND/OR over pre-stored S, S' rows; the
      paper's Table 1 counts it as 1R + 1W (the three logical ops share one
      multi-row activation), which we follow.
    """
    ops = 32  # operands per command invocation
    return {
        "B_TO_S": Command(
            "B_TO_S", 33, 32, ops * (TABLE3_PJ["sram_lut"] + TABLE3_PJ["demux_8_256"])
        ),
        "S_TO_B": Command(
            "S_TO_B", 32, 32, ops * (TABLE3_PJ["mux_256_8"] + TABLE3_PJ["relu"])
        ),
        "ANN_POOL": Command(
            "ANN_POOL", 32, 32, (ops // 4) * TABLE3_PJ["pool"] + TABLE3_PJ["mux_256_32"]
        ),
        "ANN_MUL": Command("ANN_MUL", 1, 1, 0.0),
        "ANN_ACC": Command("ANN_ACC", 1, 1, 0.0),
        # Fused conv variant: the AND result stays latched in the sense amps
        # and feeds the subsequent ANN_ACC directly (PINATUBO cascading) —
        # 1 read, 0 writes.  Not a PIMC command of its own (Table 1 lists
        # five); it is ANN_MUL issued with write-back suppressed, which is
        # the accounting the paper's own Table 2 conv read:write = 2:1
        # ratio implies.
        "ANN_MUL_F": Command("ANN_MUL_F", 1, 0, 0.0),
    }
