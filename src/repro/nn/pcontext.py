"""Logical-axis sharding-constraint context.

Layers are mesh-agnostic; drivers that *do* know the mesh activate
``logical_sharding(mesh, rules)`` and layer code can then pin critical
intermediates (the MoE dispatch buffer, scanned activations) with
``constrain(x, logical_axes)``.  Outside the context ``constrain`` is the
identity, so tests and single-device code never touch sharding machinery.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.nn.module import logical_to_pspec

__all__ = ["logical_sharding", "constrain"]

_ACTIVE: list = []


@contextlib.contextmanager
def logical_sharding(mesh: Mesh, rules: Dict[str, object]):
    _ACTIVE.append((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.pop()


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    spec = logical_to_pspec(tuple(logical_axes), rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
