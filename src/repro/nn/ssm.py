"""Selective state-space (Mamba-style) block — used by Hymba's SSM heads.

Recurrence (per channel c, state n):
    h_t = exp(Δ_t · A) ⊙ h_{t-1} + Δ_t · B_t · x_t
    y_t = C_t · h_t + D ⊙ x_t
with input-dependent Δ, B, C (selectivity).  Prefill/train runs a chunked
``lax.scan`` (small HLO, compile-friendly — the dry-run constraint); decode
is the natural single-step update carrying ``h [B, d_inner, N]``.  Constant
O(d_inner·N) state makes this the sub-quadratic path for the 500k cell.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.core.odin_linear import OdinConfig
from repro.nn.layers import linear, linear_spec
from repro.nn.module import ParamSpec
from repro.nn.pcontext import constrain
from repro.nn.scan_utils import chunked_scan

__all__ = ["ssm_spec", "ssm_block", "init_ssm_state"]


def ssm_spec(cfg: SSMConfig, d_model: int) -> Dict[str, ParamSpec]:
    d_inner = cfg.expand * d_model
    dt_rank = cfg.dt_rank or max(1, d_model // 16)
    N = cfg.state_dim
    return {
        "in_proj": linear_spec(d_model, 2 * d_inner, ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.conv_dim, d_inner), (None, "mlp"), init="fan_in"),
        "x_proj": linear_spec(d_inner, dt_rank + 2 * N, ("mlp", None)),
        "dt_proj": ParamSpec((dt_rank, d_inner), (None, "mlp"), init="fan_in"),
        "dt_bias": ParamSpec((d_inner,), ("mlp",), jnp.float32, init="zeros"),
        "A_log": ParamSpec((d_inner, N), ("mlp", None), jnp.float32, init="zeros"),
        "D": ParamSpec((d_inner,), ("mlp",), jnp.float32, init="ones"),
        "out_proj": linear_spec(d_inner, d_model, ("mlp", "embed")),
    }


def init_ssm_state(cfg: SSMConfig, d_model: int, batch: int, dtype=jnp.float32):
    d_inner = cfg.expand * d_model
    return {
        "h": jnp.zeros((batch, d_inner, cfg.state_dim), dtype),
        "conv": jnp.zeros((batch, cfg.conv_dim - 1, d_inner), dtype),
    }


def _selective_scan(u, dt, A, Bc, Cc, D, h0):
    """u: [B,S,di]  dt: [B,S,di]  A: [di,N]  Bc/Cc: [B,S,N]  h0: [B,di,N].

    The [B,S,di,N] discretized tensors are never materialized: per-step outer
    products live inside a chunked, rematerializing scan (scan_utils).
    """

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp                                # [B,di],[B,di],[B,N],[B,N]
        dA_t = jnp.exp(dt_t[..., None] * A[None])                # [B,di,N]
        h = dA_t * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h, ys = chunked_scan(
        step, h0,
        (u.swapaxes(0, 1), dt.swapaxes(0, 1), Bc.swapaxes(0, 1), Cc.swapaxes(0, 1)),
    )
    y = ys.swapaxes(0, 1) + u * D[None, None]
    return y, h


def ssm_block(p, x: jax.Array, cfg: SSMConfig, state=None,
              odin: Optional[OdinConfig] = None):
    """x: [B,S,d] → (y [B,S,d], new_state).  ``state`` enables decode."""
    B, S, d = x.shape
    d_inner = cfg.expand * d
    N = cfg.state_dim
    dt_rank = cfg.dt_rank or max(1, d // 16)

    xz = linear(x, p["in_proj"], odin)
    u, z = jnp.split(xz, 2, axis=-1)                             # [B,S,di] each

    # depthwise causal conv over time
    K = cfg.conv_dim
    if state is not None:
        ctx = jnp.concatenate([state["conv"].astype(u.dtype), u], axis=1)
    else:
        ctx = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    windows = jnp.stack([ctx[:, i : i + S] for i in range(K)], axis=-1)  # [B,S,di,K]
    u = jax.nn.silu(jnp.einsum("bsdk,kd->bsd", windows, p["conv_w"].astype(u.dtype)))

    proj = linear(u, p["x_proj"], odin).astype(jnp.float32)
    dt_in, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])    # [B,S,di]
    A = -jnp.exp(p["A_log"])                                     # [di,N], negative

    h0 = state["h"] if state is not None else jnp.zeros((B, d_inner, N), jnp.float32)
    h0 = constrain(h0, ("batch", "mlp", None))   # pin batch/TP sharding of the carry
    y, h = _selective_scan(u.astype(jnp.float32), dt, A, Bc, Cc, p["D"], h0)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = linear(y, p["out_proj"], odin)
    new_state = None
    if state is not None:
        new_state = {"h": h, "conv": ctx[:, -(K - 1):].astype(jnp.float32)}
    return out, new_state
