"""Mixture-of-Experts: top-k router + capacity-based scatter dispatch.

Dispatch is the sort-based MegaBlocks/MaxText-style static-shape algorithm:
flatten (token, k) assignments, rank each within its expert via a stable sort,
drop overflow beyond ``capacity``, scatter into a dense ``[E, C, d]`` buffer,
run per-expert matmuls (one grouped einsum — experts axis shards on "model"
for expert parallelism), gather back, and gate-weight the combine.  No
``[T, E, C]`` one-hot tensors are ever materialized (they would be ~TB-scale
at the assigned shapes).

Router: softmax over fp32 logits, top-k.  DeepSeek-style extensions: shared
(always-on) experts and the aux-loss-free bias (a non-learned, per-expert
bias added to routing scores only for *selection*, not for the gate weight).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.odin_linear import OdinConfig
from repro.nn.layers import activation, linear, linear_spec
from repro.nn.module import ParamSpec
from repro.nn.pcontext import constrain

__all__ = ["moe_spec", "moe_block", "dispatch_indices"]


def moe_spec(cfg: MoEConfig, d_model: int) -> Dict[str, ParamSpec]:
    E, F = cfg.n_experts, cfg.d_ff
    spec = {
        "router": ParamSpec((d_model, E), ("embed", None), jnp.float32, init="fan_in"),
        "w_gate": ParamSpec((E, d_model, F), ("experts", "embed", "mlp"), init="fan_in"),
        "w_up": ParamSpec((E, d_model, F), ("experts", "embed", "mlp"), init="fan_in"),
        "w_down": ParamSpec((E, F, d_model), ("experts", "mlp", "embed"), init="fan_in"),
    }
    if cfg.aux_free_bias:
        spec["route_bias"] = ParamSpec((E,), (None,), jnp.float32, init="zeros")
    if cfg.n_shared:
        S = cfg.n_shared * cfg.d_ff
        spec["shared_gate"] = linear_spec(d_model, S, ("embed", "mlp"))
        spec["shared_up"] = linear_spec(d_model, S, ("embed", "mlp"))
        spec["shared_down"] = linear_spec(S, d_model, ("mlp", "embed"))
    return spec


def dispatch_indices(expert_ids: jax.Array, n_experts: int, capacity: int):
    """Rank each (token·k) assignment within its expert; static shapes.

    expert_ids: int32 [A].  Returns (slot [A], keep [A]) where
    ``slot = expert·C + rank`` for kept assignments (rank < capacity) and
    the out-of-bounds sentinel ``E·C`` for dropped ones — scatters must use
    ``mode="drop"`` (a 0 sentinel would clobber expert 0's first slot).
    """
    A = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)               # assignments grouped by expert
    sorted_ids = expert_ids[order]
    # rank within group = index - start index of that expert's run
    starts = jnp.searchsorted(sorted_ids, jnp.arange(n_experts), side="left")
    rank_sorted = jnp.arange(A, dtype=jnp.int32) - starts[sorted_ids]
    rank = jnp.zeros((A,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < capacity
    slot = jnp.where(keep, expert_ids * capacity + rank, n_experts * capacity)
    return slot, keep


def moe_block(p, x: jax.Array, cfg: MoEConfig, activation_kind: str = "swiglu",
              no_drop: bool = False,
              odin: Optional[OdinConfig] = None) -> jax.Array:
    """x: [B, S, d] → [B, S, d]."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    select_scores = probs + (p["route_bias"][None, :] if "route_bias" in p else 0.0)
    _, top_idx = jax.lax.top_k(select_scores, cfg.top_k)       # [T, k]
    gates = jnp.take_along_axis(probs, top_idx, axis=-1)       # gate from *unbiased* probs
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    A = T * cfg.top_k
    expert_ids = top_idx.reshape(A).astype(jnp.int32)
    if no_drop or S == 1:
        # Inference-exact routing: capacity overflow resolves in batch order,
        # so a dropped assignment couples one token's output to what else
        # shares the batch — and makes prefill outputs depend on the total
        # token count.  Both break serving invariants: decode slots must be
        # isolated from co-batched (even garbage) slots, and chunked or
        # recompute-replay prefill must route each token exactly like the
        # original pass did.  Decode (S == 1) is therefore ALWAYS drop-free —
        # including the dry-run decode cells, whose cost artifacts now
        # reflect what a serving-correct decode actually pays ([E, B, d]
        # dispatch buffer instead of the capped [E, B·k·cf/E, d]).  Prefill
        # and training keep the capped capacity unless ``no_drop`` is set;
        # the serving prefill path sets it and bounds T by the chunk length.
        capacity = T
    else:
        capacity = max(1, int(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    slot, keep = dispatch_indices(expert_ids, cfg.n_experts, capacity)

    token_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), cfg.top_k)
    # Dispatch in GATHER form: scatter only scalar assignment ids into the
    # slot table, then gather token rows.  A row-wise ``buf.at[slot].set(x)``
    # scatter lowers to index matrices materialized at [E·C, d] (≈240 GB u32
    # at the 671B train cell); the scalar scatter is [E·C] ints.
    slot_to_assign = jnp.full((cfg.n_experts * capacity,), A, jnp.int32)
    slot_to_assign = slot_to_assign.at[slot].set(
        jnp.where(keep, jnp.arange(A, dtype=jnp.int32), A), mode="drop")
    token_for_slot = jnp.concatenate([token_idx, jnp.zeros((1,), jnp.int32)])[slot_to_assign]
    filled = (slot_to_assign < A)[:, None]
    buf = jnp.where(filled, xt[token_for_slot], 0)
    buf = buf.reshape(cfg.n_experts, capacity, d)
    # EP sharding hint: experts on "model", capacity on data — keeps the
    # [E, C, d] buffer (≈150 GB at the 671B train cell) distributed instead
    # of replicated (no-op outside a logical_sharding context).
    buf = constrain(buf, ("experts", "capacity", None))

    # per-expert FFN — grouped einsums; 'experts' axis shards (EP)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    if activation_kind == "swiglu":
        h = jax.nn.silu(g) * u
    elif activation_kind == "relu2":
        r = jax.nn.relu(g)
        h = r * r * u
    else:
        h = jax.nn.gelu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    y = y.reshape(cfg.n_experts * capacity, d)

    # combine: gather each assignment's expert output, weight by gate, sum over k
    out_per_assign = jnp.where(keep[:, None], y[slot], 0)      # [A, d]
    out_per_assign = constrain(out_per_assign, ("capacity", None))
    weighted = out_per_assign * gates.reshape(A, 1).astype(x.dtype)
    out = jax.ops.segment_sum(weighted, token_idx, num_segments=T)
    out = constrain(out, ("capacity", None))

    if "shared_gate" in p:
        sg = jax.nn.silu(linear(xt, p["shared_gate"], odin)) * linear(xt, p["shared_up"], odin)
        out = out + linear(sg, p["shared_down"], odin)
    return out.reshape(B, S, d)
