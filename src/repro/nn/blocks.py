"""Transformer-family blocks: dense, MoE, Hymba (parallel attn ∥ SSM), xLSTM.

Each block kind provides ``*_spec`` (ParamSpec tree) and an apply function
``(params, x, cache) → (x', cache')``.  Blocks are homogeneous within a
segment so the layer stack scans (models/lm.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import BlockConfig
from repro.core.odin_linear import OdinConfig
from repro.nn.attention import attention, attn_spec, init_cache
from repro.nn.layers import linear, linear_spec, norm_spec, rmsnorm
from repro.nn.module import ParamSpec
from repro.nn.moe import moe_block, moe_spec
from repro.nn.ssm import init_ssm_state, ssm_block, ssm_spec
from repro.nn.xlstm import (
    init_mlstm_state, init_slstm_state, mlstm_block, mlstm_spec, slstm_block, slstm_spec,
)

__all__ = ["block_spec", "block_apply", "block_cache"]


def _mlp_spec(d_model: int, d_ff: int, activation: str) -> Dict[str, ParamSpec]:
    if activation == "swiglu":
        return {
            "w_gate": linear_spec(d_model, d_ff, ("embed", "mlp")),
            "w_up": linear_spec(d_model, d_ff, ("embed", "mlp")),
            "w_down": linear_spec(d_ff, d_model, ("mlp", "embed")),
        }
    return {
        "w_up": linear_spec(d_model, d_ff, ("embed", "mlp")),
        "w_down": linear_spec(d_ff, d_model, ("mlp", "embed")),
    }


def _mlp(p, x, activation: str, odin):
    if activation == "swiglu":
        h = jax.nn.silu(linear(x, p["w_gate"], odin)) * linear(x, p["w_up"], odin)
    elif activation == "relu2":
        r = jax.nn.relu(linear(x, p["w_up"], odin))
        h = r * r
    else:
        h = jax.nn.gelu(linear(x, p["w_up"], odin))
    return linear(h, p["w_down"], odin)


def block_spec(cfg: BlockConfig, d_model: int) -> Dict:
    if cfg.kind in ("dense", "moe"):
        spec = {
            "ln1": norm_spec(d_model),
            "ln2": norm_spec(d_model),
            "attn": attn_spec(cfg.attn, d_model),
        }
        if cfg.kind == "dense":
            spec["mlp"] = _mlp_spec(d_model, cfg.d_ff, cfg.activation)
        else:
            spec["moe"] = moe_spec(cfg.moe, d_model)
        return spec
    if cfg.kind == "hymba":
        return {
            "ln1": norm_spec(d_model),
            "ln2": norm_spec(d_model),
            "attn": attn_spec(cfg.attn, d_model),
            "ssm": ssm_spec(cfg.ssm, d_model),
            "attn_out_norm": norm_spec(d_model),
            "ssm_out_norm": norm_spec(d_model),
            "mix_beta": ParamSpec((2, d_model), (None, "embed"), jnp.float32, init="ones"),
            "mlp": _mlp_spec(d_model, cfg.d_ff, cfg.activation),
        }
    if cfg.kind == "mlstm":
        return {"ln1": norm_spec(d_model), "cell": mlstm_spec(cfg.attn.n_heads, d_model)}
    if cfg.kind == "slstm":
        return {"ln1": norm_spec(d_model), "cell": slstm_spec(cfg.attn.n_heads, d_model)}
    raise ValueError(cfg.kind)


def block_cache(cfg: BlockConfig, d_model: int, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-layer decode state for one block."""
    if cfg.kind in ("dense", "moe"):
        return {"attn": init_cache(cfg.attn, batch, max_len, dtype)}
    if cfg.kind == "hymba":
        return {
            "attn": init_cache(cfg.attn, batch, max_len, dtype),
            "ssm": init_ssm_state(cfg.ssm, d_model, batch),
        }
    if cfg.kind == "mlstm":
        return {"cell": init_mlstm_state(cfg.attn.n_heads, d_model, batch)}
    if cfg.kind == "slstm":
        return {"cell": init_slstm_state(d_model, batch)}
    raise ValueError(cfg.kind)


def block_apply(p, x, cfg: BlockConfig, cache=None, positions=None, pos3d=None,
                odin: Optional[OdinConfig] = None, norm_eps: float = 1e-5,
                moe_no_drop: bool = False, tables=None,
                spec_decode: bool = False, q_lens=None, q_decode=None):
    """(params, x [B,S,d], cache) → (x', cache').  ``tables``: per-slot block
    tables when the attention cache is the paged block pool (serving);
    ``spec_decode``: the S tokens are a speculative draft tile (paged
    attention takes the multi-token-query kernel path); ``q_lens``: per-slot
    real-row counts of a mixed prefill+decode tile (paged GQA only), with
    ``q_decode`` flagging the slots that need decode-kernel numerics."""
    new_cache = dict(cache) if cache is not None else None
    if q_lens is not None and cfg.kind not in ("dense", "moe"):
        raise ValueError("mixed dispatch (q_lens) supports paged GQA blocks only")
    if cfg.kind in ("dense", "moe"):
        a, ac = attention(p["attn"], rmsnorm(x, p["ln1"], norm_eps), cfg.attn,
                          positions=positions, pos3d=pos3d,
                          cache=None if cache is None else cache["attn"], odin=odin,
                          tables=tables, spec_decode=spec_decode, q_lens=q_lens,
                          q_decode=q_decode)
        x = x + a
        h = rmsnorm(x, p["ln2"], norm_eps)
        if cfg.kind == "dense":
            x = x + _mlp(p["mlp"], h, cfg.activation, odin)
        else:
            x = x + moe_block(p["moe"], h, cfg.moe, cfg.activation,
                              no_drop=moe_no_drop, odin=odin)
        if new_cache is not None:
            new_cache["attn"] = ac
        return x, new_cache

    if cfg.kind == "hymba":
        h = rmsnorm(x, p["ln1"], norm_eps)
        a, ac = attention(p["attn"], h, cfg.attn, positions=positions, pos3d=pos3d,
                          cache=None if cache is None else cache["attn"], odin=odin,
                          tables=tables)
        s, sc = ssm_block(p["ssm"], h, cfg.ssm,
                          state=None if cache is None else cache["ssm"], odin=odin)
        # Hymba fusion: per-branch output norm, learnable per-channel mix
        fused = 0.5 * (
            p["mix_beta"][0] * rmsnorm(a, p["attn_out_norm"], norm_eps).astype(jnp.float32)
            + p["mix_beta"][1] * rmsnorm(s, p["ssm_out_norm"], norm_eps).astype(jnp.float32)
        )
        x = x + fused.astype(x.dtype)
        x = x + _mlp(p["mlp"], rmsnorm(x, p["ln2"], norm_eps), cfg.activation, odin)
        if new_cache is not None:
            new_cache["attn"], new_cache["ssm"] = ac, sc
        return x, new_cache

    if cfg.kind == "mlstm":
        y, st = mlstm_block(p["cell"], rmsnorm(x, p["ln1"], norm_eps), cfg.attn.n_heads,
                            state=None if cache is None else cache["cell"], odin=odin,
                            impl=cfg.mlstm_impl)
        x = x + y
        if new_cache is not None:
            new_cache["cell"] = st
        return x, new_cache

    if cfg.kind == "slstm":
        y, st = slstm_block(p["cell"], rmsnorm(x, p["ln1"], norm_eps),
                            state=None if cache is None else cache["cell"], odin=odin)
        x = x + y
        if new_cache is not None:
            new_cache["cell"] = st
        return x, new_cache
    raise ValueError(cfg.kind)
