"""Chunked, rematerializing time scans for recurrent blocks.

A naive ``lax.scan`` over S=4096 steps saves every carry for the backward
pass — for Hymba's SSM that is ``[S, B, d_inner, N]`` ≈ 13 GB/device at the
train_4k cell.  ``chunked_scan`` nests two scans and checkpoints the inner
one, so only chunk-boundary carries are saved (S/chunk × state) and the inner
steps recompute during backprop.  This is the standard memory/compute trade
for recurrent training and is required for the dry-run memory budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["chunked_scan"]


def chunked_scan(step, init, xs, chunk: int = 128, checkpoint: bool = True):
    """Like ``lax.scan(step, init, xs)`` but with chunk-boundary remat.

    ``xs`` leaves have leading time axis S; S need not divide ``chunk`` —
    the tail runs as a second scan.
    """
    S = jax.tree.leaves(xs)[0].shape[0]
    chunk = min(chunk, S)
    n_chunks, tail = divmod(S, chunk)

    def inner(carry, x):
        return step(carry, x)

    def outer(carry, xc):
        return jax.lax.scan(inner, carry, xc)

    if checkpoint:
        outer = jax.checkpoint(outer, prevent_cse=False)

    head = jax.tree.map(lambda a: a[: n_chunks * chunk].reshape(n_chunks, chunk, *a.shape[1:]), xs)
    carry, ys = jax.lax.scan(outer, init, head)
    ys = jax.tree.map(lambda a: a.reshape(n_chunks * chunk, *a.shape[2:]), ys)
    if tail:
        carry, ys_tail = jax.lax.scan(inner, carry, jax.tree.map(lambda a: a[n_chunks * chunk:], xs))
        ys = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0), ys, ys_tail)
    return carry, ys
