"""Attention: GQA and MLA (DeepSeek), RoPE/M-RoPE, sliding window, KV caches.

Long sequences use a blockwise flash-style scan (online softmax over KV
chunks, O(S·C) live memory instead of O(S²)) — required for the 32k-prefill
cells to fit the dry-run memory budget; short sequences use one einsum.
Decode (S_q = 1) takes a direct GEMV-shaped path against the cache.

Caches:
* GQA: full ``k/v [B, S_max, H_kv, D]`` or, when ``window > 0``, a ring
  buffer of ``window`` entries (Hymba's sliding-window heads ⇒ O(window)
  state for the 500k-context cell).
* Paged GQA (serving): the **physical block pool**
  ``k_pool/v_pool [n_blocks+1, block_size, H_kv, D]`` shared by every slot;
  per-slot block ``tables`` (passed alongside the cache — they are engine
  state, one table for all layers) map logical pages to pool blocks.  Decode
  attends in place via the Pallas paged kernel; device KV memory scales with
  the pool, not ``slots × max_len``.  The last pool block is the write-off
  target for inactive slots (``init_paged_cache``).
* MLA: *compressed* latent ``c_kv [B, S_max, r]`` + shared ``k_rope`` — the
  paper-exact DeepSeek-V3 cache; decompression happens per KV chunk.

The cache ``pos`` is a scalar (static batch: every row advances in lockstep)
or an int32 [B] vector (serving continuous batching: per-slot write offsets
and visibility masks, so one fixed-shape decode serves mixed-length slots).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig
from repro.core.odin_linear import OdinConfig
from repro.kernels.paged_attn import paged_attention
from repro.nn.layers import apply_mrope, apply_rope, linear, linear_spec, norm_spec, rmsnorm
from repro.nn.module import ParamSpec

__all__ = ["attn_spec", "attention", "init_cache", "init_paged_cache",
           "DEFAULT_CHUNK", "KV_SCALE", "POOL_LEAVES"]

# Cache-leaf names of the paged physical KV store (block-pool layout); shared
# by the serving step/swap machinery to tell pool leaves (no slot axis) from
# per-slot leaves.
POOL_LEAVES = ("k_pool", "v_pool")

DEFAULT_CHUNK = 512
NEG_INF = -1e30
# int8 KV-cache fixed-point scale: values quantize as round(x·16) ∈ [-127,127]
# (range ±7.94, step 1/16) — the ODIN 8-bit-operand adjustment applied to the
# decode working set.  Post-RoPE K and V magnitudes of trained LMs sit well
# inside ±8 (they are norm-bounded projections); parity tests bound the error.
KV_SCALE = 16.0


def _cache_write(x: jax.Array, cache_dtype) -> jax.Array:
    if cache_dtype == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * KV_SCALE), -127, 127).astype(jnp.int8)
    return x.astype(cache_dtype)


def _cache_read(x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    if x.dtype == jnp.int8:
        return (x.astype(jnp.float32) * (1.0 / KV_SCALE)).astype(compute_dtype)
    return x


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def attn_spec(cfg: AttnConfig, d_model: int) -> Dict[str, ParamSpec]:
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if cfg.kind == "mla":
        qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
        spec = {
            "kv_down": linear_spec(d_model, cfg.kv_lora_rank + cfg.qk_rope_dim, ("embed", None)),
            "kv_norm": ParamSpec((cfg.kv_lora_rank,), (None,), jnp.float32, init="ones"),
            "k_up": linear_spec(cfg.kv_lora_rank, H * cfg.qk_nope_dim, (None, "heads_flat")),
            "v_up": linear_spec(cfg.kv_lora_rank, H * cfg.v_head_dim, (None, "heads_flat")),
            "o": linear_spec(H * cfg.v_head_dim, d_model, ("heads_flat", "embed")),
        }
        if cfg.q_lora_rank:
            spec["q_down"] = linear_spec(d_model, cfg.q_lora_rank, ("embed", None))
            spec["q_norm"] = ParamSpec((cfg.q_lora_rank,), (None,), jnp.float32, init="ones")
            spec["q_up"] = linear_spec(cfg.q_lora_rank, H * qk_dim, (None, "heads_flat"))
        else:
            spec["q"] = linear_spec(d_model, H * qk_dim, ("embed", "heads_flat"))
        return spec
    return {
        "q": linear_spec(d_model, H * D, ("embed", "heads_flat")),
        "k": linear_spec(d_model, Hkv * D, ("embed", "heads_flat")),
        "v": linear_spec(d_model, Hkv * D, ("embed", "heads_flat")),
        "o": linear_spec(H * D, d_model, ("heads_flat", "embed")),
    }


def init_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Abstract-safe cache pytree (works with ShapeDtypeStruct under jit)."""
    if cfg.kind == "mla":
        return {
            "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    size = cfg.window if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.d_head), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def init_paged_cache(cfg: AttnConfig, n_blocks: int, block_size: int,
                     dtype=jnp.bfloat16):
    """Paged physical KV store for one GQA layer (serving continuous batching).

    One device-resident block pool ``[n_blocks+1, block_size, H_kv, D]`` per
    K and V, shared by every serving slot; per-slot block tables (engine
    state, threaded through the compiled steps) map logical pages to pool
    blocks.  Block ``n_blocks`` is the *write-off block*: the decode step
    points inactive slots' tables at it so their writes land somewhere
    harmless without a per-slot select over the (slot-axis-free) pool.
    Batch-independent — slot count is a property of the tables, not the pool.
    """
    if cfg.kind != "gqa" or cfg.window:
        raise ValueError("paged cache supports non-windowed GQA only")
    shape = (n_blocks + 1, block_size, cfg.n_kv_heads, cfg.d_head)
    return {
        "k_pool": jnp.zeros(shape, dtype),
        "v_pool": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# softmax attention cores
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, window: int):
    """[.., Sq, Sk] additive bias: causal + optional sliding window."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        ok &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def _sdpa(q, k, v, bias, scale):
    """q: [B,Sq,H,D] k/v: [B,Sk,Hkv,Dk/Dv] bias: [B,1,Sq,Sk] or [1,1,Sq,Sk]."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    s = s + bias[:, :, None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def _blockwise(q, k, v, q_pos, k_pos, window: int, scale: float, chunk: int):
    """Flash-style double loop: outer over Q chunks, inner scan over KV chunks.

    ``q_pos``/``k_pos`` are normalized to [B, S] so training (shared causal
    positions), prefill-into-cache and ring-buffer decode all take this path.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    Hkv = k.shape[2]
    G = H // Hkv
    cq = min(chunk, Sq)
    ck = min(chunk, Sk)
    nq, nk = Sq // cq, Sk // ck
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, Sk, chunk)

    q_pos = jnp.broadcast_to(q_pos, (B, Sq)) if q_pos.ndim < 2 else q_pos
    k_pos = jnp.broadcast_to(k_pos, (B, Sk)) if k_pos.ndim < 2 else k_pos

    qc = q.reshape(B, nq, cq, Hkv, G, D)
    qpc = q_pos.reshape(B, nq, cq)
    kc = k.reshape(B, nk, ck, Hkv, D)
    vc = v.reshape(B, nk, ck, Hkv, Dv)
    kpc = k_pos.reshape(B, nk, ck)

    def q_block(qi, qp):
        # qi: [B, cq, Hkv, G, D]; qp: [B, cq]; online softmax over kv chunks
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, vi, kp = inp                           # [B,ck,Hkv,D], [B,ck]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32), ki.astype(jnp.float32)) * scale
            bias = _mask_bias(qp, kp, window)          # [B, cq, ck]
            s = s + bias[:, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, cq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), kpc.swapaxes(0, 1)),
        )
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(o, 3, 1).astype(q.dtype)   # [B, cq, Hkv, G, Dv]

    out = jax.lax.map(lambda t: q_block(t[0], t[1]), (qc.swapaxes(0, 1), qpc.swapaxes(0, 1)))
    out = out.swapaxes(0, 1).reshape(B, Sq, H, Dv)
    return out


def sdpa(q, k, v, q_pos, k_pos, window: int = 0, chunk: int = DEFAULT_CHUNK,
         blockwise_threshold: int = 4096):
    """Dispatch between direct and blockwise attention. Shapes as in _sdpa."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    B = q.shape[0]
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq == 1 or (Sq * Sk) <= blockwise_threshold ** 2:
        qp = q_pos if q_pos.ndim == 2 else jnp.broadcast_to(q_pos, (B, Sq))
        kp = jnp.broadcast_to(k_pos, (B, Sk)) if k_pos.ndim == 1 else k_pos
        bias = _mask_bias(qp, kp, window)[:, None]     # [B,1,Sq,Sk]
        return _sdpa(q, k, v, bias, scale)
    # blockwise: pad both sequence axes to the chunk size.  Padded K rows get
    # position 2^30 (causally invisible to every real query); padded Q rows
    # get 2^29 (see everything real, row results are sliced away).
    pq = (-Sq) % min(chunk, max(Sq, 1))
    pk = (-Sk) % min(chunk, max(Sk, 1))
    if pq or pk:
        qp = q_pos if q_pos.ndim == 2 else jnp.broadcast_to(q_pos, (B, Sq))
        kp = k_pos if k_pos.ndim == 2 else jnp.broadcast_to(k_pos, (B, Sk))
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        qp = jnp.pad(qp, ((0, 0), (0, pq)), constant_values=2**29)
        kp = jnp.pad(kp, ((0, 0), (0, pk)), constant_values=2**30)
        out = _blockwise(q, k, v, qp, kp, window, scale, chunk)
        return out[:, :Sq]
    return _blockwise(q, k, v, q_pos, k_pos, window, scale, chunk)


# ---------------------------------------------------------------------------
# full attention blocks (projection + rope + cache + core + output)
# ---------------------------------------------------------------------------

def _positions(batch: int, start, seq: int):
    return start + jnp.arange(seq, dtype=jnp.int32)[None, :] + jnp.zeros((batch, 1), jnp.int32)


def _paged_gqa_core(q, k, v, cfg: AttnConfig, positions, cache, tables,
                    spec_decode: bool = False, q_lens=None, q_decode=None):
    """Write the new K/V rows into the block pool and attend through it.

    ``pos`` must be a per-slot [B] vector (paged caches exist only in the
    serving layout); ``tables [B, P]`` maps each slot's logical pages to pool
    blocks.  Decode (S == 1) runs the Pallas paged kernel — K/V blocks are
    read in place from the pool; chunked prefill (S > 1) gathers the table's
    pages once and reuses the blockwise/direct sdpa core (prefill is not the
    per-token hot path, and its cost is O(max_len) regardless).  A
    speculative verify (``spec_decode``, small S = draft+1) keeps the kernel
    path with an S-row query tile instead — per-token decode semantics, no
    O(max_len) gather in the per-dispatch hot loop.

    ``q_lens`` (mixed prefill+decode dispatch): int32 [B] of real query rows
    per slot, right-aligned in the S-row tile — slot b's q_lens[b] real
    tokens occupy rows S-q_lens[b]..S-1 so ``logits[:, -1]`` is the last
    real token for every slot regardless of its q_len.  Pad rows write to
    the pool's write-off block and their (lower, possibly negative) query
    positions make every key invisible to them, so no real row ever reads a
    pad row and pad-row outputs are discarded by the caller.  ``pos``
    advances by ``q_lens``.

    Bit-identity is the contract, so the mixed tile runs BOTH attention
    implementations and selects per slot: prefill slots take the same
    gather+sdpa core the dedicated chunked-prefill path uses (per-row
    results are chunk- and batch-shape-invariant there), while slots flagged
    in ``q_decode`` [B] take a single-row Pallas kernel call on the tile's
    last column — exactly the dedicated decode dispatch's call.  One
    implementation for both populations would be cheaper but would flip
    greedy argmaxes on logit ties (the two cores round differently), and
    mixed-on streams must equal mixed-off streams token for token.

    Writes for rows at or past the table's page span (a verify tile near a
    slot's ``max_len``, where rejected draft rows may overhang the budget)
    are redirected to the pool's write-off block — reading a stale table
    entry there could alias another slot's live block.
    """
    if tables is None:
        raise ValueError("paged attention cache requires block tables")
    B, S = q.shape[0], q.shape[1]
    P = tables.shape[1]
    pos = cache["pos"]
    kp, vp = cache["k_pool"], cache["v_pool"]
    cdt = kp.dtype
    bs = kp.shape[1]
    if q_lens is not None:
        idx = jnp.arange(S, dtype=jnp.int32)[None, :]
        off = (S - q_lens)[:, None]                                # pad rows
        rows = pos[:, None] + idx - off                            # [B, S]
        page = jnp.where(idx >= off, rows // bs, jnp.int32(P))
        bids = jnp.take_along_axis(tables, jnp.minimum(page, P - 1), axis=1)
        bids = jnp.where(page >= P, jnp.int32(kp.shape[0] - 1), bids)
        slot = jnp.where(idx >= off, rows % bs, 0)
        kp = kp.at[bids, slot].set(_cache_write(k, cdt))
        vp = vp.at[bids, slot].set(_cache_write(v, cdt))
        new_cache = {"k_pool": kp, "v_pool": vp, "pos": pos + q_lens}
        kv_scale = KV_SCALE if cdt == jnp.int8 else None
        # prefill rows: the dedicated chunked-prefill numerics (gather the
        # table's pages once, mask keys at the slot's new length, sdpa)
        Hkv, D = kp.shape[2], kp.shape[3]
        ck = _cache_read(kp[tables].reshape(B, P * bs, Hkv, D), q.dtype)
        cv = _cache_read(vp[tables].reshape(B, P * bs, Hkv, D), q.dtype)
        slot_rows = jnp.arange(P * bs, dtype=jnp.int32)[None, :]
        k_pos = jnp.where(slot_rows < (pos + q_lens)[:, None], slot_rows,
                          jnp.int32(2**30))
        o = sdpa(q, ck, cv, positions, k_pos, cfg.window)
        if q_decode is not None:
            # decode rows: the dedicated decode dispatch's kernel call on
            # the tile's last column (their only real row)
            od = paged_attention(q[:, -1], kp, vp, tables, pos + q_lens,
                                 window=cfg.window, kv_scale=kv_scale)
            last = jnp.where(q_decode[:, None, None], od, o[:, -1])
            o = jnp.concatenate([o[:, :-1], last[:, None]], axis=1)
        return o, new_cache
    rows = pos[:, None] + jnp.arange(S, dtype=jnp.int32)           # [B, S]
    page = rows // bs
    bids = jnp.take_along_axis(tables, jnp.minimum(page, P - 1), axis=1)
    bids = jnp.where(page >= P, jnp.int32(kp.shape[0] - 1), bids)  # [B, S]
    kp = kp.at[bids, rows % bs].set(_cache_write(k, cdt))
    vp = vp.at[bids, rows % bs].set(_cache_write(v, cdt))
    new_cache = {"k_pool": kp, "v_pool": vp, "pos": pos + S}
    kv_scale = KV_SCALE if cdt == jnp.int8 else None
    if S == 1:
        o = paged_attention(q[:, 0], kp, vp, tables, pos + 1,
                            window=cfg.window, kv_scale=kv_scale)[:, None]
    elif spec_decode:
        o = paged_attention(q, kp, vp, tables, pos + S,
                            window=cfg.window, kv_scale=kv_scale)
    else:
        P = tables.shape[1]
        Hkv, D = kp.shape[2], kp.shape[3]
        ck = _cache_read(kp[tables].reshape(B, P * bs, Hkv, D), q.dtype)
        cv = _cache_read(vp[tables].reshape(B, P * bs, Hkv, D), q.dtype)
        slot_rows = jnp.arange(P * bs, dtype=jnp.int32)[None, :]
        k_pos = jnp.where(slot_rows < (pos + S)[:, None], slot_rows,
                          jnp.int32(2**30))
        o = sdpa(q, ck, cv, positions, k_pos, cfg.window)
    return o, new_cache


def _gqa_attention(p, x, cfg: AttnConfig, positions, pos3d, cache, odin,
                   tables=None, spec_decode: bool = False, q_lens=None,
                   q_decode=None):
    B, S, _ = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = linear(x, p["q"], odin).reshape(B, S, H, D)
    k = linear(x, p["k"], odin).reshape(B, S, Hkv, D)
    v = linear(x, p["v"], odin).reshape(B, S, Hkv, D)
    if cfg.rope == "mrope":
        if pos3d is None:
            # text-only / decode steps: M-RoPE degenerates to (t, t, t)
            pos3d = jnp.broadcast_to(positions[..., None], (B, S, 3))
        q = apply_mrope(q, pos3d, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos3d, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        k_pos = positions
        o = sdpa(q, k, v, positions, k_pos, cfg.window)
        new_cache = None
    elif "k_pool" in cache:
        o, new_cache = _paged_gqa_core(q, k, v, cfg, positions, cache, tables,
                                       spec_decode=spec_decode, q_lens=q_lens,
                                       q_decode=q_decode)
    else:
        pos = cache["pos"]
        size = cache["k"].shape[1]
        cdt = cache["k"].dtype
        if pos.ndim:
            # per-slot positions (serving continuous batching): pos [B].
            # Batched scatter replaces the scalar dynamic_update_slice; the
            # visibility mask is per-slot so stale rows from a previous slot
            # occupant are invisible to the new request.
            bidx = jnp.arange(B)[:, None]
            rows = pos[:, None] + jnp.arange(S, dtype=jnp.int32)       # [B, S]
            if cfg.window:
                idx = rows % size
                ck = cache["k"].at[bidx, idx].set(_cache_write(k, cdt))
                cv = cache["v"].at[bidx, idx].set(_cache_write(v, cdt))
                k_pos = _ring_positions((pos + S)[:, None], size)       # [B, size]
            else:
                ck = cache["k"].at[bidx, rows].set(_cache_write(k, cdt))
                cv = cache["v"].at[bidx, rows].set(_cache_write(v, cdt))
                slot_rows = jnp.arange(size, dtype=jnp.int32)[None, :]
                k_pos = jnp.where(slot_rows < (pos + S)[:, None], slot_rows, jnp.int32(2**30))
            new_cache = {"k": ck, "v": cv, "pos": pos + S}
            o = sdpa(q, _cache_read(ck, q.dtype), _cache_read(cv, q.dtype),
                     positions, k_pos, cfg.window)
        elif cfg.window:
            idx = (pos + jnp.arange(S)) % size
            ck = cache["k"].at[:, idx].set(_cache_write(k, cdt))
            cv = cache["v"].at[:, idx].set(_cache_write(v, cdt))
            k_pos = _ring_positions(pos + S, size)
            new_cache = {"k": ck, "v": cv, "pos": pos + S}
            o = sdpa(q, _cache_read(ck, q.dtype), _cache_read(cv, q.dtype),
                     positions, jnp.broadcast_to(k_pos, (B, size)), cfg.window)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], _cache_write(k, cdt), pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], _cache_write(v, cdt), pos, axis=1)
            size = ck.shape[1]
            k_pos = jnp.arange(size, dtype=jnp.int32)
            # entries beyond pos+S are zeros — mask them via position > current
            k_pos = jnp.where(k_pos < pos + S, k_pos, jnp.int32(2**30))
            new_cache = {"k": ck, "v": cv, "pos": pos + S}
            o = sdpa(q, _cache_read(ck, q.dtype), _cache_read(cv, q.dtype),
                     positions, jnp.broadcast_to(k_pos, (B, size)), cfg.window)
    o = o.reshape(B, S, H * D)
    return linear(o, p["o"], odin), new_cache


def _ring_positions(next_pos, size: int):
    """Absolute position of each ring-buffer slot given ``next_pos`` total written."""
    slots = jnp.arange(size, dtype=jnp.int32)
    wrapped = next_pos - 1 - (next_pos - 1 - slots) % size
    return jnp.where(slots < next_pos, wrapped, jnp.int32(2**30))


def _mla_attention(p, x, cfg: AttnConfig, positions, cache, odin):
    """DeepSeek-V3 multi-head latent attention with compressed KV cache."""
    B, S, _ = x.shape
    H = cfg.n_heads
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim

    if "q_down" in p:
        cq = rmsnorm(linear(x, p["q_down"], odin), p["q_norm"])
        q = linear(cq, p["q_up"], odin).reshape(B, S, H, qk_dim)
    else:
        q = linear(x, p["q"], odin).reshape(B, S, H, qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = linear(x, p["kv_down"], odin)
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if cache is not None:
        pos = cache["pos"]
        cdt = cache["c_kv"].dtype
        if pos.ndim:
            # per-slot positions (serving continuous batching): pos [B]
            bidx = jnp.arange(B)[:, None]
            rows = pos[:, None] + jnp.arange(S, dtype=jnp.int32)
            c_kv_q = cache["c_kv"].at[bidx, rows].set(_cache_write(c_kv, cdt))
            k_rope_q = cache["k_rope"].at[bidx, rows].set(_cache_write(k_rope, cdt))
            Sk = c_kv_q.shape[1]
            slot_rows = jnp.arange(Sk, dtype=jnp.int32)[None, :]
            k_pos = jnp.where(slot_rows < (pos + S)[:, None], slot_rows, jnp.int32(2**30))
        else:
            c_kv_q = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], _cache_write(c_kv, cdt), pos, axis=1)
            k_rope_q = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], _cache_write(k_rope, cdt), pos, axis=1)
            Sk = c_kv_q.shape[1]
            k_pos = jnp.arange(Sk, dtype=jnp.int32)
            k_pos = jnp.where(k_pos < pos + S, k_pos, jnp.int32(2**30))
            k_pos = jnp.broadcast_to(k_pos, (B, Sk))
        new_cache = {"c_kv": c_kv_q, "k_rope": k_rope_q, "pos": pos + S}
        c_kv = _cache_read(c_kv_q, x.dtype)
        k_rope = _cache_read(k_rope_q, x.dtype)
    else:
        new_cache = None
        k_pos = positions

    # decompress latent → per-head K_nope, V (chunk-local inside blockwise core
    # would be cheaper; baseline decompresses once — hillclimb lever)
    k_nope = linear(c_kv, p["k_up"], odin).reshape(B, -1, H, cfg.qk_nope_dim)
    v = linear(c_kv, p["v_up"], odin).reshape(B, -1, H, cfg.v_head_dim)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_nope.shape[:3], cfg.qk_rope_dim))], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = sdpa(qfull, k, v, positions, k_pos, cfg.window)
    o = o.reshape(B, S, H * cfg.v_head_dim)
    return linear(o, p["o"], odin), new_cache


def attention(p, x, cfg: AttnConfig, positions=None, pos3d=None, cache=None,
              odin: Optional[OdinConfig] = None, tables=None,
              spec_decode: bool = False, q_lens=None, q_decode=None):
    """Returns (output [B,S,d_model], new_cache).  ``tables`` are the per-slot
    block tables of the paged serving cache (ignored by dense/MLA caches).
    ``spec_decode``: the S tokens are an in-flight speculative draft — paged
    caches attend through the multi-token-query kernel instead of the prefill
    gather (dense/MLA caches already handle S > 1 with decode semantics).
    ``q_lens``: per-slot real-row counts of a mixed prefill+decode tile
    (right-aligned; paged GQA caches only); ``q_decode`` [B] bool flags the
    slots whose single real row is a decode step and must take the decode
    kernel's numerics — see :func:`_paged_gqa_core`."""
    B, S, _ = x.shape
    if q_lens is not None and (cache is None or "k_pool" not in cache):
        raise ValueError("q_lens (mixed dispatch) requires a paged GQA cache")
    if positions is None:
        start = cache["pos"] if cache is not None else jnp.int32(0)
        if getattr(start, "ndim", 0) == 1:      # per-slot positions [B]
            start = start[:, None]
        positions = _positions(B, start, S)
    if cfg.kind == "mla":
        return _mla_attention(p, x, cfg, positions, cache, odin)
    return _gqa_attention(p, x, cfg, positions, pos3d, cache, odin, tables,
                          spec_decode=spec_decode, q_lens=q_lens,
                          q_decode=q_decode)
