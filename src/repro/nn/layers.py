"""Core layers: ODIN-aware Linear, norms, embeddings, activations, RoPE."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.odin_linear import OdinConfig, odin_linear
from repro.nn.module import ParamSpec

__all__ = [
    "linear_spec", "linear", "norm_spec", "rmsnorm", "layernorm",
    "embed_spec", "embed", "activation", "rope_freqs", "apply_rope", "apply_mrope",
]


# ---------------------------------------------------------------------------
# Linear — the ODIN integration point (paper's technique as a drop-in mode)
# ---------------------------------------------------------------------------

def linear_spec(d_in: int, d_out: int, axes: Tuple[Optional[str], Optional[str]],
                dtype=jnp.bfloat16, scale: float = 1.0) -> ParamSpec:
    return ParamSpec((d_in, d_out), axes, dtype, init="fan_in", scale=scale)


def linear(x: jax.Array, w: jax.Array, odin: Optional[OdinConfig] = None,
           drift_step: int = 0) -> jax.Array:
    """``x @ w`` routed through the configured ODIN execution mode.

    ``exact`` stays in the compute dtype (bf16 on TPU ⇒ MXU); ``int8``/``sc``
    run the paper's quantized pipeline and cast back.  ``drift_step`` keys
    the PCRAM drift-noise pattern in time (traced ints are fine under jit);
    0 keeps the excursion fixed per seed.
    """
    if odin is None or odin.mode == "exact":
        return jnp.matmul(x, w.astype(x.dtype))
    y = odin_linear(x.astype(jnp.float32), w.astype(jnp.float32), odin,
                    drift_step=drift_step)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# norms / embeddings / activations
# ---------------------------------------------------------------------------

def norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), jnp.float32, init="ones")


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * g).astype(x.dtype)


def layernorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * g).astype(x.dtype)


def embed_spec(vocab: int, d: int) -> ParamSpec:
    return ParamSpec((vocab, d), ("vocab", "embed"), jnp.bfloat16, init="normal")


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "relu2":                      # Nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)                    # swiglu gate handled by caller


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    # x: [..., S, H, D]; angles: broadcastable to [..., S, 1, D/2]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (int)."""
    freqs = rope_freqs(x.shape[-1], theta)                       # [D/2]
    angles = positions[..., None, None].astype(jnp.float32) * freqs  # [B, S, 1, D/2]
    return _rotate(x, angles)


def apply_mrope(x: jax.Array, positions_3d: jax.Array, sections: Tuple[int, ...],
                theta: float = 10_000.0) -> jax.Array:
    """Qwen2-VL multimodal RoPE: per-section (t, h, w) position ids.

    x: [B, S, H, D]; positions_3d: [B, S, 3]; sections sum to D/2.
    """
    freqs = rope_freqs(x.shape[-1], theta)                       # [D/2]
    splits = [int(s) for s in np.cumsum(sections)[:-1]]
    parts = jnp.split(freqs, splits)
    angle_parts = [
        positions_3d[..., i, None].astype(jnp.float32) * parts[i][None, None, :]
        for i in range(len(sections))
    ]
    angles = jnp.concatenate(angle_parts, axis=-1)[..., None, :]  # [B, S, 1, D/2]
    return _rotate(x, angles)
