"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar).

mLSTM keeps a matrix memory ``C [B, H, dk, dv]`` with exponential input/forget
gates and a max-state stabilizer; sLSTM keeps per-head scalar state.  Both run
as ``lax.scan`` over time (O(1) state ⇒ the sub-quadratic path for long_500k)
and expose single-step decode.  Projections route through ODIN linear modes.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.odin_linear import OdinConfig
from repro.nn.layers import linear, linear_spec, norm_spec, rmsnorm
from repro.nn.module import ParamSpec
from repro.nn.pcontext import constrain
from repro.nn.scan_utils import chunked_scan

__all__ = ["mlstm_spec", "mlstm_block", "slstm_spec", "slstm_block", "init_mlstm_state", "init_slstm_state"]


def mlstm_spec(n_heads: int, d_model: int) -> Dict[str, ParamSpec]:
    dh = d_model // n_heads
    return {
        "q": linear_spec(d_model, d_model, ("embed", "heads_flat")),
        "k": linear_spec(d_model, d_model, ("embed", "heads_flat")),
        "v": linear_spec(d_model, d_model, ("embed", "heads_flat")),
        "gates": linear_spec(d_model, 2 * n_heads, ("embed", None)),  # i, f per head
        "o_gate": linear_spec(d_model, d_model, ("embed", "heads_flat")),
        "out": linear_spec(d_model, d_model, ("heads_flat", "embed")),
        "out_norm": norm_spec(d_model),
    }


def init_mlstm_state(n_heads: int, d_model: int, batch: int):
    dh = d_model // n_heads
    return {
        "C": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def mlstm_block(p, x: jax.Array, n_heads: int, state=None,
                odin: Optional[OdinConfig] = None, impl: str = "chunkwise",
                chunk: int = 512):
    """``impl``: 'scan' (token-sequential reference) or 'chunkwise'
    (telescoped per-chunk parallel form — identical math, §Perf lever:
    state IO drops ÷chunk and the inner work becomes MXU matmuls)."""
    B, S, d = x.shape
    dh = d // n_heads
    q = linear(x, p["q"], odin).reshape(B, S, n_heads, dh).astype(jnp.float32)
    k = linear(x, p["k"], odin).reshape(B, S, n_heads, dh).astype(jnp.float32) / jnp.sqrt(dh)
    v = linear(x, p["v"], odin).reshape(B, S, n_heads, dh).astype(jnp.float32)
    gates = linear(x, p["gates"], odin).astype(jnp.float32).reshape(B, S, n_heads, 2)
    i_pre, f_pre = gates[..., 0], gates[..., 1]                  # [B,S,H]
    o_gate = jax.nn.sigmoid(linear(x, p["o_gate"], odin).astype(jnp.float32))

    st = state if state is not None else init_mlstm_state(n_heads, d, B)
    # pin batch sharding of the matrix-memory carry — a replicated
    # [B, H, dk, dv] carry is the dominant memory term otherwise
    st = {k: constrain(v, ("batch",) + (None,) * (v.ndim - 1)) for k, v in st.items()}

    if impl == "chunkwise" and S > 1:
        (C, n, m), ys = _mlstm_chunkwise(q, k, v, i_pre, f_pre,
                                         (st["C"], st["n"], st["m"]), chunk)
        h = ys.reshape(B, S, d).astype(x.dtype) * o_gate.astype(x.dtype)
        out = linear(rmsnorm(h, p["out_norm"]), p["out"], odin)
        new_state = {"C": C, "n": n, "m": m} if state is not None else None
        return out, new_state

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp                                 # [B,H,dh], [B,H]
        log_f = -jax.nn.softplus(-ft)                            # log σ(f)
        m_new = jnp.maximum(log_f + m, it)
        i_sc = jnp.exp(it - m_new)
        f_sc = jnp.exp(log_f + m - m_new)
        C = f_sc[..., None, None] * C + i_sc[..., None, None] * (kt[..., :, None] * vt[..., None, :])
        n = f_sc[..., None] * n + i_sc[..., None] * kt
        num = jnp.einsum("bhkv,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    (C, n, m), ys = chunked_scan(
        step,
        (st["C"], st["n"], st["m"]),
        (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
         i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1)),
        chunk=256,
    )
    h = ys.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype) * o_gate.astype(x.dtype)
    out = linear(rmsnorm(h, p["out_norm"]), p["out"], odin)
    new_state = {"C": C, "n": n, "m": m} if state is not None else None
    return out, new_state


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, st0, chunk: int):
    """Chunkwise-parallel mLSTM — exact telescoping of the per-token
    recurrence (GLA/mLSTM-chunkwise form with max-stabilizer chaining).

    Within a chunk, relative to the chunk-entry state (C₀, n₀, m₀) and the
    in-chunk cumulative log-forget B_t = Σ_{s≤t} log f_s:

        m_t  = max(B_t + m₀, max_{s≤t}(B_t − B_s + i_s))
        h_t∝ e^{B_t+m₀−m_t}(q_t·C₀) + Σ_{s≤t} e^{B_t−B_s+i_s−m_t}(q_t·k_s)v_s
        n_t  = e^{B_t+m₀−m_t} n₀ + Σ_{s≤t} e^{B_t−B_s+i_s−m_t} k_s

    The Σ terms are C×C masked matmuls (MXU); the carry updates once per
    chunk, so HBM state traffic drops by the chunk length versus the
    token-sequential scan (the measured 36,000× memory-vs-compute imbalance
    of the xlstm train cell — EXPERIMENTS.md §Perf-1).
    """
    B, S, H, dh = q.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        zq = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zq(q), zq(k), zq(v)
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        f_pre = jnp.pad(f_pre, ((0, 0), (0, pad), (0, 0)), constant_values=30.0)
    Sp = S + pad
    nc = Sp // c

    def rs(a):  # [B,Sp,...] → [nc, B, c, ...]
        return a.reshape(B, nc, c, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = rs(q), rs(k), rs(v)
    ic, fc = rs(i_pre), rs(f_pre)
    mask = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(carry, inp):
        C0, n0, m0 = carry                       # [B,H,dh,dh], [B,H,dh], [B,H]
        qt, kt, vt, it, ft = inp                 # [B,c,H,dh], [B,c,H]
        log_f = -jax.nn.softplus(-ft)            # [B,c,H]
        Bc = jnp.cumsum(log_f, axis=1)           # B_t
        # a[t,s] = B_t − B_s + i_s  (valid s ≤ t)
        a = Bc[:, :, None] - Bc[:, None, :] + it[:, None, :]     # [B,t,s,H]
        a = jnp.where(mask[None, :, :, None], a, -jnp.inf)
        m_intra = a.max(axis=2)                                  # [B,c,H]
        m_t = jnp.maximum(Bc + m0[:, None], m_intra)
        # decay matrices
        D = jnp.exp(a - m_t[:, :, None])                         # [B,t,s,H]
        inter_w = jnp.exp(Bc + m0[:, None] - m_t)                # [B,c,H]
        scores = jnp.einsum("bthd,bshd->btsh", qt, kt) * D
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, vt)
        n_intra = jnp.einsum("btsh,bshd->bthd", D, kt)
        y_inter = jnp.einsum("bthd,bhde->bthe", qt, C0) * inter_w[..., None]
        n_inter = n0[:, None] * inter_w[..., None]
        num = y_intra + y_inter                                  # [B,c,H,dv]
        nvec = n_intra + n_inter
        den = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", qt, nvec)),
                          jnp.exp(-m_t))
        ys = num / den[..., None]
        # carry to next chunk (t = c row)
        m_new = m_t[:, -1]
        w_end = jnp.exp(Bc[:, -1:, :] - Bc + it - m_new[:, None])    # [B,s,H]
        C_new = (C0 * jnp.exp(Bc[:, -1] + m0 - m_new)[..., None, None]
                 + jnp.einsum("bshd,bshe->bhde", w_end[..., None] * kt, vt))
        n_new = (n0 * jnp.exp(Bc[:, -1] + m0 - m_new)[..., None]
                 + jnp.einsum("bsh,bshd->bhd", w_end, kt))
        return (C_new, n_new, m_new), ys

    carry, ys = jax.lax.scan(chunk_step, st0, (qc, kc, vc, ic, fc))
    ys = ys.swapaxes(0, 1).reshape(B, Sp, H, dh)[:, :S]
    return carry, ys


def slstm_spec(n_heads: int, d_model: int) -> Dict[str, ParamSpec]:
    return {
        "zifo": linear_spec(d_model, 4 * d_model, ("embed", "heads_flat")),
        "r_zifo": ParamSpec((4, d_model), (None, "heads_flat"), jnp.float32, init="fan_in"),
        "out": linear_spec(d_model, d_model, ("heads_flat", "embed")),
        "out_norm": norm_spec(d_model),
    }


def init_slstm_state(d_model: int, batch: int):
    return {
        "c": jnp.zeros((batch, d_model), jnp.float32),
        "n": jnp.ones((batch, d_model), jnp.float32),
        "h": jnp.zeros((batch, d_model), jnp.float32),
        "m": jnp.zeros((batch, d_model), jnp.float32),
    }


def slstm_block(p, x: jax.Array, state=None, odin: Optional[OdinConfig] = None):
    """Scalar-memory LSTM with exponential gating and recurrent h-feedback."""
    B, S, d = x.shape
    pre = linear(x, p["zifo"], odin).astype(jnp.float32).reshape(B, S, 4, d)
    st = state if state is not None else init_slstm_state(d, B)
    st = {k: constrain(v, ("batch",) + (None,) * (v.ndim - 1)) for k, v in st.items()}
    r = p["r_zifo"]                                              # [4, d] diagonal recurrence

    def step(carry, zifo_t):
        c, n, h, m = carry
        zt = jnp.tanh(zifo_t[:, 0] + r[0] * h)
        it = zifo_t[:, 1] + r[1] * h
        ft = zifo_t[:, 2] + r[2] * h
        ot = jax.nn.sigmoid(zifo_t[:, 3] + r[3] * h)
        log_f = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(log_f + m, it)
        i_sc = jnp.exp(it - m_new)
        f_sc = jnp.exp(log_f + m - m_new)
        c = f_sc * c + i_sc * zt
        n = f_sc * n + i_sc
        h_new = ot * c / jnp.maximum(n, 1e-6)
        return (c, n, h_new, m_new), h_new

    (c, n, h, m), ys = chunked_scan(step, (st["c"], st["n"], st["h"], st["m"]), pre.swapaxes(0, 1), chunk=256)
    y = ys.swapaxes(0, 1).astype(x.dtype)
    out = linear(rmsnorm(y, p["out_norm"]), p["out"], odin)
    new_state = {"c": c, "n": n, "h": h, "m": m} if state is not None else None
    return out, new_state
