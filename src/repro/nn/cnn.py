"""CNN/VGG models for the paper's benchmark topologies (Table 4), in JAX.

These are the *runnable* counterparts of the ``repro.pim.trace`` topologies:
same layer stacks, executable forward/train on CPU, with the ODIN execution
modes (exact | int8 | sc) applied to every MAC layer.  Convolution lowers to
im2col + ``odin_linear`` so the stochastic pipeline covers conv MACs exactly
the way ODIN maps them onto PINATUBO row ops (weight-stationary operand
pairs).  Pooling and ReLU go through the fused ``act_pool``/binary path —
the paper's hybrid boundary.

Used by: tests (SC-vs-int8-vs-fp32 accuracy gap), examples/odin_inference.py,
and the fig6 benchmark (operand counts cross-check the trace model).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.odin_linear import OdinConfig, odin_linear
from repro.nn.module import ParamSpec
from repro.pim.trace import CNN1, CNN2, VGG1, VGG2, Conv, FC, Pool, Topology

__all__ = ["cnn_param_spec", "cnn_forward", "cnn_loss", "topology_input_hw",
           "RUNNABLE_CNN1", "RUNNABLE_CNN2"]

# The paper's Table 4 strings are dimensionally inconsistent as printed
# (e.g. CNN1 "conv5x5-pool-784": no conv5 output-map count makes the pooled
# map flatten to 784 under one padding convention).  The *trace* topologies
# (pim/trace.py) follow the printed strings because command counts only need
# per-layer sizes; the *runnable* models below choose the unique nearby
# reading that makes dimensions consistent, documented here:
#   CNN1: 5×5 conv, 4 maps, SAME pad  → pool2 → 14·14·4 = 784 → 70 → 10
#   CNN2: 7×7 conv, 10 maps, VALID pad → pool2 → 11·11·10 = 1210 → 120 → 10
RUNNABLE_CNN1 = Topology(
    "CNN1-run",
    [Conv(28, 28, 1, 5, 4, 1, 2), Pool(28, 28, 4, 2), FC(784, 70), FC(70, 10)],
    "synthetic-digits",
)
RUNNABLE_CNN2 = Topology(
    "CNN2-run",
    [Conv(28, 28, 1, 7, 10, 1, 0), Pool(22, 22, 10, 2), FC(1210, 120), FC(120, 10)],
    "synthetic-digits",
)


def topology_input_hw(topo: Topology) -> Tuple[int, int, int]:
    first = topo.layers[0]
    if isinstance(first, Conv):
        return first.h, first.w, first.c_in
    # FC-first topology: treat as flat input
    return 1, 1, first.n_in


def _im2col(x: jax.Array, k: int, stride: int, pad: int) -> jax.Array:
    """NHWC → [B, OH, OW, k·k·C] patch matrix."""
    B, H, W, C = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    OH = (H + 2 * pad - k) // stride + 1
    OW = (W + 2 * pad - k) // stride + 1
    patches = [
        xp[:, i : i + OH * stride : stride, j : j + OW * stride : stride, :]
        for i in range(k)
        for j in range(k)
    ]
    return jnp.concatenate(patches, axis=-1).reshape(B, OH, OW, k * k * C)


def cnn_param_spec(topo: Topology) -> Dict[str, ParamSpec]:
    """ParamSpec tree mirroring the trace topology's MAC layers."""
    spec: Dict[str, ParamSpec] = {}
    for idx, layer in enumerate(topo.layers):
        if isinstance(layer, Conv):
            spec[f"conv{idx}"] = ParamSpec(
                (layer.k * layer.k * layer.c_in, layer.c_out),
                ("embed", "mlp"), jnp.float32, init="fan_in",
            )
        elif isinstance(layer, FC):
            spec[f"fc{idx}"] = ParamSpec(
                (layer.n_in, layer.n_out), ("embed", "mlp"), jnp.float32, init="fan_in"
            )
    return spec


def _relu_pool_binary(y: jax.Array, pool: int) -> jax.Array:
    """The paper's binary-domain ReLU + max-pool (jnp path; the Pallas
    ``act_pool`` kernel implements the same op for the int popcount domain)."""
    r = jax.nn.relu(y)
    B, H, W, C = r.shape
    r = r.reshape(B, H // pool, pool, W // pool, pool, C)
    return r.max(axis=(2, 4))


def cnn_forward(params: Dict, x: jax.Array, topo: Topology,
                odin: Optional[OdinConfig] = None) -> jax.Array:
    """x: [B, H, W, C] (or [B, n_in] for FC-first) → logits [B, n_classes].

    Layer-by-layer execution in the paper's order; conv/FC MACs run under the
    configured ODIN mode, ReLU between layers, Pool as binary max.
    ``signed_activations=False`` after the first ReLU (unipolar, the paper's
    CNN case) is handled by the caller's OdinConfig.
    """
    h = x
    flat = False
    for idx, layer in enumerate(topo.layers):
        if isinstance(layer, Conv):
            patches = _im2col(h, layer.k, layer.stride, layer.pad)
            B, OH, OW, P = patches.shape
            y = _linear(patches.reshape(-1, P), params[f"conv{idx}"], odin)
            h = jax.nn.relu(y.reshape(B, OH, OW, layer.c_out))
        elif isinstance(layer, Pool):
            h = _relu_pool_binary(h, layer.size)
        elif isinstance(layer, FC):
            if not flat:
                h = h.reshape(h.shape[0], -1)
                flat = True
            y = _linear(h, params[f"fc{idx}"], odin)
            is_last = idx == len(topo.layers) - 1
            h = y if is_last else jax.nn.relu(y)
    return h


def _linear(x: jax.Array, w: jax.Array, odin: Optional[OdinConfig]) -> jax.Array:
    if odin is None or odin.mode == "exact":
        return x @ w
    return odin_linear(x, w, odin)


def cnn_loss(params: Dict, batch: Dict, topo: Topology) -> Tuple[jax.Array, Dict]:
    logits = cnn_forward(params, batch["image"], topo, odin=None)
    lp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(lp, batch["label"][:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == batch["label"]).mean()
    return loss, {"loss": loss, "acc": acc}
