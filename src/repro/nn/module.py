"""Functional parameter-tree module system with logical-axis sharding.

MaxText-style: modules build trees of :class:`ParamSpec` descriptors carrying
*logical* axis names; the tree can be

* ``abstract()``-ed into ``jax.ShapeDtypeStruct``s (dry-run lowering — no
  allocation ever happens for the full-size configs),
* ``materialize()``-d into real arrays (tests, examples, training),
* mapped to ``PartitionSpec``s via a per-config rule table (``pspec_tree``).

Sharding rules map logical axis → mesh axis (or None).  A mesh axis may not
appear twice in one param's spec; later (lower-priority) occurrences are
dropped — this keeps rule tables small and lets one table serve every layer.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["ParamSpec", "abstract", "materialize", "pspec_tree", "shardings", "count_params"]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones | fan_in
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (self.shape, self.logical_axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract(tree):
    """ParamSpec tree → ShapeDtypeStruct tree (no device memory touched)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree, is_leaf=_is_spec
    )


def _init_one(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "fan_in":
        fan_in = spec.shape[0] if len(spec.shape) <= 2 else int(np.prod(spec.shape[:-1]))
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)
    return (jax.random.normal(key, spec.shape, jnp.float32) * (0.02 * spec.scale)).astype(spec.dtype)


def materialize(tree, key):
    """ParamSpec tree → initialized array tree (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_one(s, k) for s, k in zip(leaves, keys)])


def logical_to_pspec(axes: Sequence[Optional[str]], rules: Dict[str, Optional[str]]) -> P:
    """Map logical axes → PartitionSpec under ``rules``, dropping repeats.

    A rule value may be a single mesh axis, a tuple of mesh axes (e.g.
    ``("data", "model")`` for fully-sharded giant tables), or None.
    """
    used: set = set()
    out = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m is None:
            out.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(x for x in ms if x not in used)
        if not ms:
            out.append(None)
        elif len(ms) == 1:
            out.append(ms[0])
            used.add(ms[0])
        else:
            out.append(ms)
            used.update(ms)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def pspec_tree(tree, rules: Dict[str, Optional[str]]):
    return jax.tree.map(
        lambda s: logical_to_pspec(s.logical_axes, rules), tree, is_leaf=_is_spec
    )


def shardings(tree, mesh, rules: Dict[str, Optional[str]]):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, logical_to_pspec(s.logical_axes, rules)),
        tree,
        is_leaf=_is_spec,
    )


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=_is_spec)
    return sum(int(np.prod(l.shape)) for l in leaves)
