"""ODIN execution modes for a linear layer — the paper's technique as a drop-in.

Three modes, sharing one quantization boundary (DESIGN.md §2):

``exact``  — plain matmul (fp32/bf16), the reference numerics.
``int8``   — deterministic *expected value* of the stochastic pipeline: int8
             operands, integer dot (TPU MXU ``int8×int8→int32``), identical
             1/K̂ MUX-tree scaling and optional 8-bit popcount rounding.  This
             is the deployment surrogate for large models.
``sc``     — bit-faithful stochastic arithmetic: B→S LUTs, bit-parallel AND,
             MUX tree, popcount (paper §IV).  Runs the fused Pallas kernel on
             TPU (kernels/sc_mac) or the jnp reference; intended for
             paper-scale layers, not 100B-parameter matmuls.

Signed operands use two-rail decomposition with binary-domain recombination
(core/quant.py docstring), mirroring ODIN's hybrid binary/stochastic split.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from repro.core import stochastic as sc
from repro.core.quant import quantize_signed_tworail, quantize_unipolar

__all__ = ["OdinConfig", "odin_linear", "get_luts"]


@dataclass(frozen=True)
class OdinConfig:
    mode: str = "exact"                   # exact | int8 | sc
    stream_len: int = 256
    n_levels: int = 256
    signed_activations: bool = True       # False after ReLU (paper's CNN case)
    round_popcount: bool = False          # model 8-bit S_TO_B output rounding
    use_pallas: bool = False              # sc mode: fused kernel vs jnp reference
    interpret: bool = True                # Pallas interpret mode (CPU container)
    lut_seed: int = 0
    # SC accumulation granularity.  0 ⇒ one full MUX tree over K (the naive
    # reading of the paper — at K ≳ stream_len the 1/K̂ subsampling leaves
    # <1 stream bit per product and deep-layer signal collapses; measured in
    # examples/odin_inference.py).  >0 ⇒ per-block MUX subtree + popcount +
    # *binary* accumulation across blocks — consistent with ODIN's own
    # 32-operand row/command granularity (B_TO_S/S_TO_B move 32 operands;
    # one PINATUBO row activation covers 32 operand pairs), and the reading
    # that reproduces the paper's "minimal accuracy loss" claim.
    sc_block_k: int = 32
    # PCRAM resistance-drift analog (fault injection): >0 perturbs the SC/int8
    # output multiplicatively with seeded Gaussian noise of this relative σ —
    # the readout excursion a drifted cell produces, NOT a reprogrammed
    # weight.  0.0 (default) is a no-op; ``exact`` mode is never perturbed
    # (it is the reference numerics the guards compare against).
    drift_noise: float = 0.0
    drift_seed: int = 0

    @property
    def spec(self) -> sc.StreamSpec:
        return sc.StreamSpec(self.stream_len, self.n_levels)


@functools.lru_cache(maxsize=16)
def get_luts(stream_len: int, n_levels: int, lut_seed: int, max_depth: int = 20):
    """Deterministic LUT/select-stream constants (the per-bank SRAM contents)."""
    spec = sc.StreamSpec(stream_len, n_levels)
    k = jax.random.PRNGKey(lut_seed)
    ka, kw, ks = jax.random.split(k, 3)
    lut_a = sc.make_lut(ka, spec)
    lut_w = sc.make_lut(kw, spec)
    selects = sc.make_select_streams(ks, max_depth, spec)
    return lut_a, lut_w, selects


def _rail_matmul(a_q, w_q, cfg: OdinConfig, luts=None):
    """One unipolar rail-pair product, returned in integer-dot units (Σ a·w).

    ``luts`` is the shared ``(lut_a, lut_w, selects)`` bundle for sc mode —
    fetched ONCE per :func:`odin_linear` call and reused across the four
    signed-rail products instead of being re-derived per rail.
    """
    spec = cfg.spec
    K = a_q.shape[-1]
    khat = 1 << sc.tree_depth(K)
    if cfg.mode == "sc":
        lut_a, lut_w, selects = luts
        block_k = cfg.sc_block_k
        if block_k and khat > block_k:
            # hybrid: per-block MUX subtree + popcount, binary accumulate
            if cfg.use_pallas:
                from repro.kernels.sc_mac.ops import sc_matmul_pallas

                pop = sc_matmul_pallas(a_q, w_q, lut_a, lut_w, selects, spec,
                                       interpret=cfg.interpret, max_tree_k=block_k)
                # ops.py rescales hybrid pops to full-tree units (× bk/K̂)
                return pop.astype(jnp.float32) * (khat * spec.n_levels**2 / spec.stream_len)
            from repro.kernels.sc_mac.ref import sc_matmul_hybrid_ref

            pop = sc_matmul_hybrid_ref(a_q, w_q, lut_a, lut_w, selects, spec, block_k)
            return pop.astype(jnp.float32) * (block_k * spec.n_levels**2 / spec.stream_len)
        if cfg.use_pallas:
            from repro.kernels.sc_mac.ops import sc_matmul_pallas

            pop = sc_matmul_pallas(a_q, w_q, lut_a, lut_w, selects, spec, interpret=cfg.interpret)
        else:
            pop = sc.sc_matmul(a_q, w_q, lut_a, lut_w, selects, spec)
        # popcount → integer-dot units: Σ a·w ≈ pop · K̂ L² / stream_len
        return pop.astype(jnp.float32) * (khat * spec.n_levels**2 / spec.stream_len)
    # int8 expected surrogate — identical scaling; optionally round to the
    # 8-bit popcount grid to model S_TO_B precision loss faithfully.
    dot = jnp.matmul(a_q.astype(jnp.int32), w_q.astype(jnp.int32), preferred_element_type=jnp.int32)
    if cfg.round_popcount:
        pop_scale = spec.stream_len / (khat * spec.n_levels**2)
        pop = jnp.round(dot.astype(jnp.float32) * pop_scale)
        return pop * (khat * spec.n_levels**2 / spec.stream_len)
    return dot.astype(jnp.float32)


def odin_linear(x: jax.Array, w: jax.Array, cfg: OdinConfig = OdinConfig(),
                drift_step: int = 0) -> jax.Array:
    """``x @ w`` under the configured ODIN execution mode.

    x: [..., K] activations; w: [K, N] weights.  Returns fp32 [..., N].

    ``drift_step`` keys the PCRAM drift-noise excursion in *time*: real
    resistance drift evolves between reads, so each dispatch should see a
    fresh perturbation pattern, not the same frozen one.  Callers fold their
    step counter in (a traced int32 is fine under jit); the default 0
    reproduces the old per-call-identical behavior for a fixed seed.
    """
    if cfg.mode == "exact":
        return jnp.matmul(x, w)
    if cfg.mode not in ("int8", "sc"):
        raise ValueError(f"unknown ODIN mode: {cfg.mode}")

    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)

    luts = (get_luts(cfg.stream_len, cfg.n_levels, cfg.lut_seed)
            if cfg.mode == "sc" else None)
    w_pos, w_neg, wq = quantize_signed_tworail(w)
    if cfg.signed_activations:
        a_pos, a_neg, aq = quantize_signed_tworail(x2)
        # (A⁺−A⁻)(W⁺−W⁻) — four unipolar trees, recombined in binary domain.
        out = (
            _rail_matmul(a_pos, w_pos, cfg, luts)
            + _rail_matmul(a_neg, w_neg, cfg, luts)
            - _rail_matmul(a_pos, w_neg, cfg, luts)
            - _rail_matmul(a_neg, w_pos, cfg, luts)
        )
    else:
        a_q, aq = quantize_unipolar(x2)
        out = _rail_matmul(a_q, w_pos, cfg, luts) - _rail_matmul(a_q, w_neg, cfg, luts)

    y = out * (aq.scale * wq.scale)
    if cfg.drift_noise > 0.0:
        # fold the step counter into the key so the excursion pattern moves
        # over time like real drift (PRNGKey(seed) alone froze it per call)
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.drift_seed),
                                 drift_step)
        y = y * (1.0 + cfg.drift_noise
                 * jax.random.normal(key, y.shape, jnp.float32))
    return y.reshape(*lead, w.shape[-1]).astype(jnp.float32)
