"""8-bit quantization — the paper's "fixed 8-bit operand" adjustment (§IV-B.1).

ODIN's SN format is *unipolar* (densities in [0, 1]); the paper fixes operands
to 8 bits and notes results always lie in [0, 1].  Real ANN weights are signed,
which the paper leaves implicit.  We complete the design the standard SC way
(two-rail): split a signed weight matrix into its positive and negative parts,
run two unipolar MAC trees, and subtract in the *binary* domain (inside the
same add-on block that applies ReLU) — consistent with ODIN's hybrid
binary/stochastic boundary.  Activations after ReLU are naturally unipolar.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["QuantParams", "quantize_unipolar", "quantize_signed_tworail", "dequantize"]


@dataclass(frozen=True)
class QuantParams:
    scale: jax.Array          # per-tensor ([]) or per-channel ([C]) fp32
    n_levels: int = 256


def _amax(x: jax.Array, axis) -> jax.Array:
    m = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(m, 1e-12)


def quantize_unipolar(x: jax.Array, n_levels: int = 256, axis=None) -> Tuple[jax.Array, QuantParams]:
    """Quantize non-negative ``x`` to integers in [0, n_levels-1].

    ``x ≈ q * scale`` with ``scale = max(x)/(n_levels-1)``.
    """
    scale = _amax(x, axis) / (n_levels - 1)
    q = jnp.clip(jnp.round(x / scale), 0, n_levels - 1).astype(jnp.uint8)
    return q, QuantParams(jnp.squeeze(scale) if axis is None else scale, n_levels)


def quantize_signed_tworail(
    w: jax.Array, n_levels: int = 256, axis=None
) -> Tuple[jax.Array, jax.Array, QuantParams]:
    """Split signed ``w`` into unipolar (pos, neg) integer rails.

    ``w ≈ (q_pos - q_neg) * scale``; exactly one rail is nonzero per element.
    """
    scale = _amax(w, axis) / (n_levels - 1)
    q = jnp.clip(jnp.round(w / scale), -(n_levels - 1), n_levels - 1)
    q_pos = jnp.clip(q, 0, None).astype(jnp.uint8)
    q_neg = jnp.clip(-q, 0, None).astype(jnp.uint8)
    return q_pos, q_neg, QuantParams(jnp.squeeze(scale) if axis is None else scale, n_levels)


def dequantize(q: jax.Array, params: QuantParams) -> jax.Array:
    return q.astype(jnp.float32) * params.scale
