from repro.core.stochastic import (
    StreamSpec,
    make_lut,
    make_select_streams,
    b_to_s,
    s_to_b,
    sc_mul,
    sc_mux,
    sc_mac_tree,
    sc_matmul,
    expected_matmul,
    pack_bits,
    unpack_bits,
    tree_depth,
)
from repro.core.quant import QuantParams, quantize_unipolar, quantize_signed_tworail, dequantize
from repro.core.odin_linear import OdinConfig, odin_linear, get_luts
