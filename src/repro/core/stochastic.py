"""Stochastic-number (SN) arithmetic — the heart of ODIN (paper §III-C, §IV-B).

An 8-bit binary operand ``v`` (``0 <= v < n_levels``) is represented as a
``stream_len``-bit pseudorandom bitstream whose density (fraction of ones) is
``v / n_levels``.  In this format

* multiplication  = bit-parallel AND                       (paper Fig. 2a)
* scaled addition = bit-parallel MUX, ``c = s·a + s̄·b``   (paper Fig. 2b, s = 0.5)
* B→S conversion  = LUT lookup (paper's 256×256 SRAM LUT)
* S→B conversion  = popcount   (paper's PISO + level counter)

TPU adaptation (DESIGN.md §2): streams are packed little-endian into ``uint32``
words so a 256-bit PCRAM row block becomes 8 lanes of a vector register; the
bit-parallel PCRAM row ops become VPU bitwise ops.  The PISO serialization of
the paper's pop counter is *not* ported — ``lax.population_count`` is parallel.

Stream-generation model ("comparator SNG"): each LUT draws one random
permutation ``perm`` of stream positions; position ``i`` of row ``v`` is set
iff ``rank(i) < v``.  Hence row ``v`` has *exactly* ``v`` ones (popcount is
exact: ``s_to_b(b_to_s(v)) == v``), rows are nested, and two *independent*
LUTs give ``E[popcount(AND)] = a·b/n_levels`` exactly with hypergeometric
variance.  The paper does not specify its LUT contents; this is the minimal
completion that makes AND a product (a single shared LUT would compute
``min(a, b)`` — see tests).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32

__all__ = [
    "StreamSpec",
    "make_lut",
    "make_select_streams",
    "b_to_s",
    "s_to_b",
    "sc_mul",
    "sc_mux",
    "sc_not",
    "sc_mac_tree",
    "sc_matmul",
    "expected_matmul",
    "pack_bits",
    "unpack_bits",
]


@dataclass(frozen=True)
class StreamSpec:
    """Geometry of the stochastic representation.

    ``stream_len`` — bits per stream (paper: 256 = one PCRAM row block).
    ``n_levels``   — quantization levels (paper: 256 = 8-bit operands).
    """

    stream_len: int = 256
    n_levels: int = 256

    def __post_init__(self):
        if self.stream_len % WORD_BITS:
            raise ValueError(f"stream_len must be a multiple of {WORD_BITS}")
        if self.n_levels > self.stream_len + 1:
            raise ValueError("n_levels cannot exceed stream_len + 1 (density is k/stream_len)")

    @property
    def n_words(self) -> int:
        return self.stream_len // WORD_BITS


# ---------------------------------------------------------------------------
# packing helpers
# ---------------------------------------------------------------------------

def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a little-endian bool/int array ``[..., L]`` into ``uint32 [..., L/32]``."""
    *lead, L = bits.shape
    assert L % WORD_BITS == 0, L
    b = bits.astype(jnp.uint32).reshape(*lead, L // WORD_BITS, WORD_BITS)
    weights = (jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32))
    return (b * weights).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_bits` — ``uint32 [..., W]`` → bool ``[..., W*32]``."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], words.shape[-1] * WORD_BITS).astype(bool)


# ---------------------------------------------------------------------------
# LUT construction (the paper's 256x256 SRAM block)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(1, 2))
def _make_lut(key: jax.Array, stream_len: int, n_levels: int) -> jax.Array:
    ranks = jax.random.permutation(key, stream_len)          # rank of each position
    levels = jnp.arange(n_levels)[:, None]                   # [V, 1]
    bits = ranks[None, :] < levels                           # [V, L] row v has v ones
    return pack_bits(bits)


def make_lut(key: jax.Array, spec: StreamSpec = StreamSpec()) -> jax.Array:
    """Build one B→S lookup table: ``uint32 [n_levels, n_words]``.

    Weights and activations must use LUTs built from *different* keys
    (decorrelation — DESIGN.md §2).  8 KB at the paper's geometry: trivially
    VMEM-resident on TPU, exactly like the paper's per-bank SRAM block.
    """
    return _make_lut(key, spec.stream_len, spec.n_levels)


def make_select_streams(key: jax.Array, depth: int, spec: StreamSpec = StreamSpec()) -> jax.Array:
    """Per-tree-level ``s = 0.5`` select streams, ``uint32 [depth, n_words]``.

    The paper pre-stores S and S' in two Compute-Partition rows; we generate
    one independent half-density stream per MUX-tree level (exactly
    ``stream_len/2`` ones) so each level's subsampling is unbiased.
    """
    keys = jax.random.split(key, depth)

    def one(k):
        ranks = jax.random.permutation(k, spec.stream_len)
        return pack_bits(ranks < spec.stream_len // 2)

    return jax.vmap(one)(keys)


# ---------------------------------------------------------------------------
# conversions
# ---------------------------------------------------------------------------

def b_to_s(values: jax.Array, lut: jax.Array) -> jax.Array:
    """Binary → stochastic: gather LUT rows. ``values`` int in [0, n_levels)."""
    return lut[values]


def s_to_b(streams: jax.Array) -> jax.Array:
    """Stochastic → binary: popcount over packed words (paper's PISO+counter)."""
    return jax.lax.population_count(streams).sum(axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# arithmetic (bit-parallel, over packed words)
# ---------------------------------------------------------------------------

def sc_mul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Stochastic multiply: bitwise AND (paper Fig. 2a)."""
    return jnp.bitwise_and(a, b)


def sc_not(a: jax.Array) -> jax.Array:
    return jnp.bitwise_not(a)


def sc_mux(a: jax.Array, b: jax.Array, select: jax.Array) -> jax.Array:
    """Stochastic scaled add ``0.5·a + 0.5·b``: MUX = (S∧a) ∨ (S̄∧b) (Fig. 2b).

    This is exactly the paper's ANN_ACC decomposition: two bit-parallel ANDs
    followed by one bit-parallel OR (PINATUBO row ops).
    """
    return jnp.bitwise_or(jnp.bitwise_and(select, a), jnp.bitwise_and(jnp.bitwise_not(select), b))


def sc_mac_tree(streams: jax.Array, select_streams: jax.Array) -> jax.Array:
    """Balanced MUX tree over ``streams [K, W]`` → one stream ``[W]``.

    Computes a stream of density ``(1/K̂)·Σ densities`` where ``K̂`` is K
    rounded up to a power of two (zero-padded).  ``select_streams [depth, W]``
    must have ``depth >= ceil(log2 K)`` levels.
    """
    K = streams.shape[-2]
    depth = max(1, int(np.ceil(np.log2(max(K, 2)))))
    pad = (1 << depth) - K
    if pad:
        streams = jnp.concatenate(
            [streams, jnp.zeros((*streams.shape[:-2], pad, streams.shape[-1]), streams.dtype)],
            axis=-2,
        )
    for level in range(depth):
        half = streams.shape[-2] // 2
        sel = select_streams[level]
        streams = sc_mux(streams[..., 0::2, :], streams[..., 1::2, :], sel)
        assert streams.shape[-2] == half
    return streams[..., 0, :]


def tree_depth(k: int) -> int:
    return max(1, int(np.ceil(np.log2(max(int(k), 2)))))


# ---------------------------------------------------------------------------
# full stochastic GEMM (reference semantics; the Pallas kernel fuses this)
# ---------------------------------------------------------------------------

def sc_matmul(
    a_q: jax.Array,          # uint8/int32 [M, K] quantized unipolar activations
    w_q: jax.Array,          # uint8/int32 [K, N] quantized unipolar weights
    lut_a: jax.Array,
    lut_w: jax.Array,
    select_streams: jax.Array,
    spec: StreamSpec = StreamSpec(),
) -> jax.Array:
    """ODIN MAC array in SN format.  Returns int32 popcounts ``[M, N]``.

    out[m, n] = popcount( MUXtree_k( AND(lut_a[a[m,k]], lut_w[w[k,n]]) ) )

    so ``out/stream_len ≈ (1/K̂)·Σ_k (a/L)(w/L)``.  Materializes streams —
    intended for reference/tests; large shapes go through the fused Pallas
    kernel (kernels/sc_mac) or the ``expected`` surrogate.
    """
    sa = b_to_s(a_q.astype(jnp.int32), lut_a)                # [M, K, W]
    sw = b_to_s(w_q.astype(jnp.int32), lut_w)                # [K, N, W]
    prod = sc_mul(sa[:, None, :, :], jnp.moveaxis(sw, 0, 1)[None, :, :, :])  # [M,N,K,W]
    acc = sc_mac_tree(prod, select_streams)                  # [M, N, W]
    return s_to_b(acc)


def expected_matmul(
    a_q: jax.Array,
    w_q: jax.Array,
    spec: StreamSpec = StreamSpec(),
) -> jax.Array:
    """Deterministic expected value of :func:`sc_matmul` (DESIGN.md §2).

    E[popcount] = stream_len · (1/K̂) · Σ_k (a_k/L)(w_k/L).  Computed as an
    integer dot (MXU int8 path on TPU) with the same scaling semantics, so the
    quantization boundary is bit-identical between the two execution modes.
    """
    K = a_q.shape[-1]
    khat = 1 << tree_depth(K)
    dot = jnp.matmul(
        a_q.astype(jnp.int32), w_q.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    scale = spec.stream_len / (khat * spec.n_levels * spec.n_levels)
    return dot.astype(jnp.float32) * scale
