"""Atomic, manifest-verified checkpointing with elastic re-sharding.

Fault-tolerance substrate (DESIGN.md §4):

* **Atomicity** — a checkpoint is written into ``step_<n>.tmp/`` and
  ``os.rename``-d to ``step_<n>/`` only after every array and the manifest
  have been fsync'd.  A crash mid-save leaves a ``.tmp`` dir that restore
  ignores and the next save garbage-collects; the previous complete
  checkpoint is never touched.
* **Integrity** — the manifest records per-leaf shape/dtype/sha256 and a
  whole-tree hash; ``restore`` verifies structure (and content hashes when
  ``verify=True``) before returning anything.
* **Elastic re-shard** — checkpoints are mesh-agnostic (leaves stored as
  host arrays keyed by pytree path).  ``restore`` takes an optional
  ``shardings`` tree and ``device_put``s each leaf onto it, so a run saved
  on a (16,16) mesh restores onto (2,16,16) or (8,) without conversion —
  the GSPMD partitioner re-shards on first use.
* **Resume-exactness** — the train step counter and data-pipeline step live
  inside the saved tree; with the stateless step-indexed pipeline
  (data/synthetic.py) a restart replays the identical batch sequence.

Storage layout::

    <dir>/step_000420/
        manifest.json        # step, leaf table, tree hash
        arrays.npz           # one entry per leaf, keyed by escaped path
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step", "all_steps", "CheckpointError"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


class CheckpointError(RuntimeError):
    pass


def _leaf_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = leaf
    return out


def _to_host(x) -> np.ndarray:
    if isinstance(x, jax.Array):
        # fully-addressable (single-process) gather; multi-host would use
        # per-shard files keyed by shard index — single-process container.
        return np.asarray(jax.device_get(x))
    return np.asarray(x)


# npz cannot round-trip ml_dtypes (bf16 → void16); store a bit-view and the
# logical dtype in the manifest instead.
_EXOTIC_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _encode(a: np.ndarray):
    dt = str(a.dtype)
    if dt in _EXOTIC_VIEW:
        return a.view(_EXOTIC_VIEW[dt]), dt
    return a, dt


def _decode(raw: np.ndarray, dtype: str) -> np.ndarray:
    if dtype in _EXOTIC_VIEW:
        return raw.view(np.dtype(dtype))
    return raw


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def save(directory: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomically write ``tree`` as ``<directory>/step_<step>``.

    Returns the final path.  Keeps the newest ``keep`` checkpoints, removes
    older ones and any orphaned ``.tmp`` dirs (crash debris).
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = {}
    manifest_leaves = {}
    for k, v in _leaf_paths(tree).items():
        raw, logical_dtype = _encode(_to_host(v))
        leaves[k] = raw
        manifest_leaves[k] = {"shape": list(raw.shape), "dtype": logical_dtype,
                              "sha256": _sha(raw)}
    manifest = {"step": int(step), "leaves": manifest_leaves}
    tree_hash = hashlib.sha256(
        json.dumps(manifest["leaves"], sort_keys=True).encode()
    ).hexdigest()[:16]
    manifest["tree_hash"] = tree_hash

    npz_path = os.path.join(tmp, _ARRAYS)
    np.savez(npz_path, **{_escape(k): a for k, a in leaves.items()})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # GC: drop stale tmp dirs and old checkpoints beyond ``keep``
    steps = all_steps(directory)
    for name in os.listdir(directory):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"), ignore_errors=True)
    return final


def _escape(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.\-]", "_", key)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, _MANIFEST)):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, template, *, shardings=None,
            verify: bool = False):
    """Load ``step`` into the structure of ``template``.

    ``template`` — pytree of arrays/ShapeDtypeStructs defining structure and
    expected shapes/dtypes (mismatch ⇒ CheckpointError, never silent).
    ``shardings`` — optional matching tree of (Named)Shardings for elastic
    placement; None keeps leaves as host-backed committed arrays.
    """
    path = os.path.join(directory, f"step_{step:09d}")
    try:
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
    except FileNotFoundError as e:
        raise CheckpointError(f"no complete checkpoint at {path}") from e
    data = np.load(os.path.join(path, _ARRAYS))

    tpl_leaves = _leaf_paths(template)
    if set(tpl_leaves) != set(manifest["leaves"]):
        missing = set(tpl_leaves) - set(manifest["leaves"])
        extra = set(manifest["leaves"]) - set(tpl_leaves)
        raise CheckpointError(f"tree mismatch: missing={sorted(missing)[:4]} extra={sorted(extra)[:4]}")

    shard_leaves = _leaf_paths(shardings) if shardings is not None else {}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, tpl in flat:
        key = jax.tree_util.keystr(p)
        raw = data[_escape(key)]
        meta = manifest["leaves"][key]
        if verify and _sha(raw) != meta["sha256"]:
            raise CheckpointError(f"{key}: content hash mismatch (corrupt checkpoint)")
        a = _decode(raw, meta["dtype"])
        if list(a.shape) != list(tpl.shape) or str(a.dtype) != str(jnp.dtype(tpl.dtype)):
            raise CheckpointError(
                f"{key}: checkpoint {a.shape}/{a.dtype} vs template {tpl.shape}/{tpl.dtype}"
            )
        if key in shard_leaves:
            out.append(jax.device_put(a, shard_leaves[key]))
        else:
            out.append(jnp.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
