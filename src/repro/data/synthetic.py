"""Deterministic, stateless, step-indexed data pipelines.

Fault-tolerance substrate (DESIGN.md §4): every batch is a pure function of
``(seed, step)`` — no iterator state, no files — so restart-from-checkpoint
resumes the *exact* token stream (tests/test_checkpoint.py asserts this).
Sharded loading: each data-parallel host slices its rows of the global batch
by ``process_index`` arithmetic; on one host the global batch is returned
whole.

Pipelines:

* ``lm_batch``        — language-model token/label batches.  Tokens follow a
  deterministic mixture of structured sequences (affine progressions, motif
  repeats) so a model can actually *learn* (loss drops — used by the e2e
  training example), not i.i.d. noise.
* ``digits_batch``    — the MNIST stand-in for the paper's CNN1/2 accuracy
  experiments (no dataset downloads offline): 10 procedural glyph classes on
  a 28×28 canvas with per-sample jitter, scale noise, and pixel noise.
  Accuracy claims in EXPERIMENTS.md are framed as SC-vs-int8-vs-fp32 *gaps*
  on this task, not absolute MNIST numbers.
* ``vlm_stub_batch`` / ``audio_stub_batch`` — modality-frontend stubs per the
  assignment: precomputed patch/frame embeddings with the right shapes.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["lm_batch", "digits_batch", "vlm_stub_batch", "audio_stub_batch"]


def _fold(seed: int, step: int, salt: int = 0) -> jax.Array:
    k = jax.random.PRNGKey(seed)
    return jax.random.fold_in(jax.random.fold_in(k, step), salt)


# ---------------------------------------------------------------------------
# LM tokens
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("batch", "seq", "vocab", "n_codebooks"))
def lm_batch(seed: int, step, *, batch: int, seq: int, vocab: int,
             n_codebooks: int = 1) -> Dict[str, jax.Array]:
    """Deterministic learnable token stream → {tokens, labels} [B, S].

    Mixture per row (chosen by hash): (a) affine ramps ``t_i = (a·i+b) % V``,
    (b) repeated motifs of period p ∈ [3, 16].  Both are next-token
    predictable, so cross-entropy falls fast — the e2e driver's check.
    """
    key = _fold(seed, step)
    kk = jax.random.split(key, 6)
    B, S = batch, seq + 1
    i = jnp.arange(S)[None, :]

    a = jax.random.randint(kk[0], (B, 1), 1, 7)
    b = jax.random.randint(kk[1], (B, 1), 0, vocab)
    ramps = (a * i + b) % vocab

    period = jax.random.randint(kk[2], (B, 1), 3, 17)
    motif = jax.random.randint(kk[3], (B, 32), 0, vocab)
    motif_tokens = jnp.take_along_axis(motif, i % period, axis=1)

    use_ramp = jax.random.bernoulli(kk[4], 0.5, (B, 1))
    toks = jnp.where(use_ramp, ramps, motif_tokens).astype(jnp.int32)

    if n_codebooks > 1:
        shift = jnp.arange(n_codebooks, dtype=jnp.int32)[None, :, None]
        toks = (toks[:, None, :] + shift) % vocab                  # [B, K, S]
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# ---------------------------------------------------------------------------
# synthetic digits (MNIST stand-in)
# ---------------------------------------------------------------------------

def _glyph_bank() -> np.ndarray:
    """10 class templates, 20×20, drawn with numpy strokes (deterministic)."""
    g = np.zeros((10, 20, 20), np.float32)
    y, x = np.mgrid[0:20, 0:20]

    def ring(cy, cx, r0, r1):
        d = np.sqrt((y - cy) ** 2 + (x - cx) ** 2)
        return ((d >= r0) & (d <= r1)).astype(np.float32)

    g[0] = ring(10, 10, 5, 8)
    g[1][:, 9:12] = 1.0
    g[2] = ring(6, 10, 3, 6) * (y <= 8) ; g[2][8:18][np.eye(10, 20, 8, dtype=bool)[:, ::-1]] = 1; g[2][16:19, 4:16] = 1
    g[3] = ring(5, 10, 3, 6) * (x >= 9) + ring(13, 10, 3, 6) * (x >= 9)
    g[4][:12, 4:7] = 1; g[4][9:12, 4:16] = 1; g[4][:, 12:15] = 1
    g[5][2:5, 4:16] = 1; g[5][2:10, 4:7] = 1; g[5][8:11, 4:14] = 1; g[5] += ring(13, 9, 3, 6) * (x >= 7)
    g[6] = ring(13, 10, 3, 6); g[6][2:13, 6:9] = 1
    g[7][2:5, 4:16] = 1; g[7] += ((np.abs((19 - y) * 0.6 + 4 - (x - 8)) < 1.6) & (y >= 4)).astype(np.float32)
    g[8] = ring(6, 10, 2.5, 5) + ring(14, 10, 2.5, 5.5)
    g[9] = ring(6, 10, 3, 6); g[9][6:18, 13:16] = 1
    return np.clip(g, 0, 1)


_GLYPHS = jnp.asarray(_glyph_bank())


@functools.partial(jax.jit, static_argnames=("batch",))
def digits_batch(seed: int, step, *, batch: int) -> Dict[str, jax.Array]:
    """{image [B,28,28,1] in [0,1], label [B]} — jittered procedural digits."""
    key = _fold(seed, step, salt=1)
    kl, kdx, kdy, ka, kn = jax.random.split(key, 5)
    B = batch
    labels = jax.random.randint(kl, (B,), 0, 10)
    dx = jax.random.randint(kdx, (B,), 0, 9)         # placement on 28×28
    dy = jax.random.randint(kdy, (B,), 0, 9)
    amp = jax.random.uniform(ka, (B, 1, 1), minval=0.7, maxval=1.0)
    noise = jax.random.uniform(kn, (B, 28, 28), maxval=0.15)

    canvas = jnp.zeros((B, 28, 28))
    glyphs = _GLYPHS[labels] * amp                    # [B, 20, 20]

    def place(c, g, ox, oy):
        return jax.lax.dynamic_update_slice(c, g, (oy, ox))

    canvas = jax.vmap(place)(canvas, glyphs, dx, dy)
    img = jnp.clip(canvas + noise, 0.0, 1.0)
    return {"image": img[..., None], "label": labels}


# ---------------------------------------------------------------------------
# modality-frontend stubs (assignment: backbone only)
# ---------------------------------------------------------------------------

def vlm_stub_batch(seed: int, step, *, batch: int, seq: int, vocab: int,
                   d_model: int, n_patches: int = 64) -> Dict[str, jax.Array]:
    """Qwen2-VL stub: text batch + precomputed patch embeddings + M-RoPE ids.

    ``n_patches`` snaps down to a perfect square (the dynamic-resolution
    patch grid is h×w).
    """
    side = max(1, int(np.sqrt(n_patches)))
    n_patches = side * side
    out = lm_batch(seed, step, batch=batch, seq=seq, vocab=vocab)
    key = _fold(seed, step, salt=2)
    kp, _ = jax.random.split(key)
    out["patch_embeds"] = jax.random.normal(kp, (batch, n_patches, d_model), jnp.float32) * 0.02
    t = jnp.zeros((n_patches,), jnp.int32)
    hh = jnp.repeat(jnp.arange(side), side)
    ww = jnp.tile(jnp.arange(side), side)
    patch_pos = jnp.stack([t, hh, ww], axis=-1)                     # [P, 3]
    text_pos = jnp.arange(seq, dtype=jnp.int32)[:, None] + side
    text3 = jnp.broadcast_to(text_pos, (seq, 3))
    pos3d = text3.at[:n_patches].set(patch_pos)
    out["pos3d"] = jnp.broadcast_to(pos3d[None], (batch, seq, 3))
    return out


def audio_stub_batch(seed: int, step, *, batch: int, seq: int, vocab: int,
                     n_codebooks: int = 4) -> Dict[str, jax.Array]:
    """MusicGen stub: EnCodec-token batches across K codebooks [B, K, S]."""
    return lm_batch(seed, step, batch=batch, seq=seq, vocab=vocab,
                    n_codebooks=n_codebooks)
