"""Batched KV-cache serving driver: prefill → decode loop.

Serves a model over a batch of synthetic requests: one jitted prefill step
fills the caches for the prompt, then a jitted decode step generates tokens
greedily.  The same step functions are what the dry-run lowers at the
decode_32k / long_500k cells, so this driver is the runnable witness that
the serving path works end to end.

Continuous-batching shape discipline: prompts are right-aligned into a fixed
[B, S_prompt] window and generation always runs the same [B, 1] step, so one
compiled executable serves every request mix (no recompiles mid-flight).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.launch import specs as specs_mod
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import lm, registry
from repro.nn import module as nnmod

__all__ = ["serve", "main"]


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          params=None, verbose: bool = True):
    """Returns (generated [B, gen] int32, tokens/s)."""
    if params is None:
        params = nnmod.materialize(lm.param_spec(cfg), jax.random.PRNGKey(seed))
    max_len = prompt_len + gen
    shape = ShapeConfig("serve", prompt_len, batch, "prefill")
    batch_data = specs_mod.concrete_batch(cfg, shape, seed, 0)

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    last_logits, caches = prefill(params, batch_data)
    if cfg.n_codebooks > 1:
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, :, None]  # [B,K,1]
    else:
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]     # [B,1]
    t_prefill = time.time() - t0

    outs = []
    t1 = time.time()
    for _ in range(gen):
        outs.append(tok)
        tok, caches = decode(params, caches, tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    gen_axis = -1
    generated = jnp.concatenate(outs, axis=gen_axis)
    tps = batch * gen / max(t_decode, 1e-9)
    if verbose:
        print(f"[serve] prefill {batch}×{prompt_len} in {t_prefill*1e3:.0f} ms; "
              f"decode {gen} steps in {t_decode*1e3:.0f} ms  ({tps:.1f} tok/s)")
    return generated, tps


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = registry.get_smoke(args.arch) if args.smoke else registry.get_config(args.arch)
    generated, tps = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                           gen=args.gen, seed=args.seed)
    print("[serve] first request tokens:", np.asarray(generated)[0].ravel()[:16])


if __name__ == "__main__":
    main()
