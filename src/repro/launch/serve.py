"""Serving driver — thin CLI over the continuous-batching engine.

``serve()`` now routes through :class:`repro.serving.ServingEngine`: each
prompt becomes a request, the engine admits them into cache slots, chunked
prefill interleaves with the fixed ``[B, 1]`` decode step, and freed slots
re-admit queued work.  The old one-shot static-batch loop survives as
``serve_static()`` — it is the baseline the serving benchmark beats and the
parity witness the engine tests decode against.

Continuous-batching shape discipline: the serving caches are fixed
``[slots, max_len]`` and generation always runs the same ``[slots, 1]`` step,
so one compiled executable serves every request mix (no recompiles
mid-flight); only distinct prefill chunk lengths trace separately.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
      --batch 4 --prompt-len 32 --gen 16
  # mixed-length open-loop workload with a constrained KV pool:
  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
      --scenario mixed --requests 16 --slots 4 --kv-blocks 20
  # record a dispatch/lifecycle timeline, open trace.json in ui.perfetto.dev:
  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
      --scenario mixed --trace-out trace.json
  # expose the engine as a streaming HTTP front door (SSE, 429 on overload):
  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
      --listen --port 8080 --max-queue 32 --tenant-rate 50
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.launch import specs as specs_mod
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import lm, registry
from repro.nn import module as nnmod
from repro.serving import (SCENARIOS, FaultPlan, ReliabilityConfig, Request,
                           ServingEngine, Tracer, make_requests)

__all__ = ["serve", "serve_static", "serve_listen", "main"]


def serve_listen(cfg, *, host: str = "127.0.0.1", port: int = 8080,
                 slots: int = 4, max_len: int = 128, block_size: int = 16,
                 max_queue: int = 64, tenant_rate: float | None = None,
                 tenant_burst: float | None = None,
                 heartbeat_s: float | None = None, params=None,
                 verbose: bool = True, **engine_kwargs):
    """Expose the engine as a streaming HTTP front door.

    ``POST /generate`` with ``{"prompt": [ids]}`` (or ``{"prompt_len": n}``
    for a random prompt) streams token/heartbeat/done events as SSE; an
    overloaded queue or an over-quota tenant gets ``429`` + ``Retry-After``,
    submissions during shutdown get ``503``.  SIGTERM/SIGINT drain
    gracefully: in-flight streams flush, then the engine summary prints.
    Blocks until shutdown; returns the final summary.
    """
    import asyncio

    from repro.serving.frontdoor import FrontDoor, run_server

    engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                           block_size=block_size, params=params,
                           **engine_kwargs)
    fd = FrontDoor(engine, max_queue=max_queue, tenant_rate=tenant_rate,
                   tenant_burst=tenant_burst, heartbeat_s=heartbeat_s)
    if verbose:
        print(f"[serve] front door on http://{host}:{port}/generate  "
              f"(slots={slots}, max_len={max_len}, queue≤{max_queue}"
              + (f", tenant quota {tenant_rate}/s" if tenant_rate else "")
              + ")  SIGTERM drains gracefully")
    try:
        asyncio.run(run_server(fd, host, port, vocab=cfg.vocab))
    except KeyboardInterrupt:
        pass
    summary = engine.summary()
    if verbose:
        print(f"[serve] drained: terminal {summary['terminal']}, "
              f"front door {fd.summary()}")
    return summary


def serve_static(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
                 params=None, verbose: bool = True):
    """The original static-batch loop: one prefill, ``gen`` lockstep decode
    steps, every slot runs to the end even if its request is done.

    Returns (generated [B, gen] int32, decode tokens/s).
    """
    if params is None:
        params = nnmod.materialize(lm.param_spec(cfg), jax.random.PRNGKey(seed))
    max_len = prompt_len + gen
    shape = ShapeConfig("serve", prompt_len, batch, "prefill")
    batch_data = specs_mod.concrete_batch(cfg, shape, seed, 0)

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    last_logits, caches = prefill(params, batch_data)
    if cfg.n_codebooks > 1:
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, :, None]  # [B,K,1]
    else:
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]     # [B,1]
    t_prefill = time.time() - t0

    outs = []
    t1 = time.time()
    for _ in range(gen):
        outs.append(tok)
        tok, caches = decode(params, caches, tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    generated = jnp.concatenate(outs, axis=-1)
    tps = batch * gen / max(t_decode, 1e-9)
    if verbose:
        print(f"[serve] static prefill {batch}×{prompt_len} in {t_prefill*1e3:.0f} ms; "
              f"decode {gen} steps in {t_decode*1e3:.0f} ms  ({tps:.1f} tok/s)")
    return generated, tps


def _batch_requests(cfg, batch: int, prompt_len: int, gen: int, seed: int):
    """The static driver's workload as engine requests: same concrete batch,
    all arriving at t=0."""
    shape = ShapeConfig("serve", prompt_len, batch, "prefill")
    data = specs_mod.concrete_batch(cfg, shape, seed, 0)
    toks = np.asarray(data["tokens"])
    reqs = []
    for i in range(batch):
        extras = None
        if cfg.vision_stub:
            extras = {"patch_embeds": np.asarray(data["patch_embeds"])[i],
                      "pos3d": np.asarray(data["pos3d"])[i]}
        reqs.append(Request(rid=i, prompt=toks[i], max_new=gen, extras=extras))
    return reqs


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          params=None, verbose: bool = True, slots: int | None = None,
          block_size: int | None = None, **engine_kwargs):
    """Serve the static driver's workload through the continuous-batching
    engine.  Returns (generated [B, gen] int32, decode tokens/s) — the same
    contract as ``serve_static`` (token-for-token equal on a fixed seed when
    no preemption occurs; asserted in tests/test_serving.py).
    """
    slots = slots or batch
    max_len = prompt_len + gen
    if block_size is None:
        block_size = next(b for b in (16, 8, 4, 2, 1) if max_len % b == 0)
    engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                           block_size=block_size, params=params, seed=seed,
                           **engine_kwargs)
    reqs = _batch_requests(cfg, batch, prompt_len, gen, seed)
    summary = engine.run(reqs)
    generated = jnp.asarray(
        np.stack([np.stack(r.generated, axis=-1) for r in sorted(reqs, key=lambda r: r.rid)]))
    tps = summary["decode_tokens_per_s"]
    if verbose:
        print(f"[serve] engine {batch} reqs×{prompt_len}+{gen} over {slots} slots: "
              f"prefill {summary['prefill_time_s']*1e3:.0f} ms, "
              f"decode {summary['decode_steps']} steps in "
              f"{summary['decode_time_s']*1e3:.0f} ms  ({tps:.1f} tok/s, "
              f"occupancy {summary['slot_occupancy']:.2f})")
    return generated, tps


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--static", action="store_true",
                    help="run the legacy static-batch loop instead of the engine")
    ap.add_argument("--odin-mode", choices=["exact", "int8", "sc"], default=None,
                    help="execution mode for Linear layers (default: config's)")
    ap.add_argument("--no-paged", action="store_true",
                    help="keep the dense [slots, max_len] live caches instead "
                         "of the paged physical block store")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable shared-prompt block dedup (refcounted "
                         "prefix cache; auto-enabled for fully paged models)")
    ap.add_argument("--no-mixed", action="store_true",
                    help="disable the fused mixed prefill+decode dispatch "
                         "(token-budget packed tiles; auto-enabled for fully "
                         "paged models) and fall back to separate prefill "
                         "and decode launches")
    ap.add_argument("--mixed-budget", type=int, default=None,
                    help="total query-row budget of one mixed dispatch "
                         "(default: prefill chunk + slots)")
    ap.add_argument("--horizon", type=int, default=1,
                    help="max decode steps fused into one dispatch (power-of-"
                         "two grants; 1 = per-token parity baseline)")
    ap.add_argument("--spec-ngram", type=int, default=0, metavar="K",
                    help="n-gram self-speculative decode: draft K tokens per "
                         "inner step by prompt-lookup and verify them in one "
                         "multi-token forward (greedy only; 0 = off)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="token id that ends a request early (default: none)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation for sampling (0 = full vocab)")
    ap.add_argument("--sample-seed", type=int, default=0)
    # open-loop scenario mode (ignores --batch/--prompt-len/--gen)
    ap.add_argument("--scenario", choices=sorted(SCENARIOS), default=None,
                    help="serve a synthetic open-loop workload instead")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="device KV budget in blocks (forces preemption when low)")
    ap.add_argument("--swap-blocks", type=int, default=0)
    ap.add_argument("--block-size", type=int, default=None,
                    help="KV block granularity (default: 16 for scenarios, "
                         "auto-picked to divide prompt+gen otherwise)")
    ap.add_argument("--chunk", type=int, default=None, help="prefill chunk length")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record a structured event trace and write it as "
                         "Chrome trace-event JSON (open in ui.perfetto.dev)")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="trace ring-buffer capacity (oldest events drop "
                         "beyond it; drops are counted in the file)")
    ap.add_argument("--metrics-window", type=float, default=1.0,
                    help="windowed-metrics snapshot period in seconds")
    ap.add_argument("--xla-annotations", action="store_true",
                    help="wrap each compiled dispatch in a jax.profiler "
                         "TraceAnnotation (aligns XLA profiles with spans)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="default per-request deadline after arrival; past-"
                         "deadline requests finish as TIMEOUT (slot freed "
                         "mid-run, KV blocks released)")
    ap.add_argument("--queue-timeout-ms", type=float, default=None,
                    help="max queue wait before admission; expired waiters "
                         "finish as TIMEOUT without ever running")
    ap.add_argument("--degrade", action="store_true",
                    help="enable the graceful-degradation ladder (spec off → "
                         "horizon shrink → prefix release → admission denial)")
    ap.add_argument("--reliability", action="store_true",
                    help="enable the PCRAM reliability layer with defaults "
                         "(wear-leveled allocation; no endurance budget, no "
                         "scrub unless the flags below say so)")
    ap.add_argument("--endurance-budget", type=int, default=None,
                    help="per-block PCRAM write budget in cache rows; a block "
                         "crossing it is drained (contents copied, tables "
                         "remapped) and retired (implies --reliability)")
    ap.add_argument("--no-wear-leveling", action="store_true",
                    help="keep the seed LIFO free-list order instead of "
                         "min-wear allocation (only meaningful with the "
                         "reliability layer on)")
    ap.add_argument("--scrub-rate", type=int, default=0, metavar="N",
                    help="drift-refresh scrubber: rewrite up to N oldest-"
                         "written resident blocks per step once past the "
                         "drift deadline (implies --reliability; needs "
                         "--drift-deadline-ms)")
    ap.add_argument("--drift-deadline-ms", type=float, default=None,
                    help="resistance-drift deadline: a resident block older "
                         "than this since its last write is due for a scrub "
                         "rewrite (implies --reliability)")
    ap.add_argument("--fault-plan", default=None, metavar="PATH",
                    help="seeded fault-injection plan (JSON, see repro.serving"
                         ".faults.FaultPlan); scenario mode only — faults are "
                         "a test instrument, not a serving feature")
    # streaming front-door mode (ignores --batch/--scenario; clients bring
    # their own prompts over HTTP)
    ap.add_argument("--listen", action="store_true",
                    help="serve POST /generate as an SSE token stream through "
                         "the asyncio front door (429 + Retry-After on "
                         "overload, 503 while draining, SIGTERM drains)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--max-queue", type=int, default=64,
                    help="waiting-queue bound before typed 429 rejection")
    ap.add_argument("--max-len", type=int, default=None,
                    help="per-request prompt+gen cap for --listen "
                         "(default: --prompt-len + --gen)")
    ap.add_argument("--tenant-rate", type=float, default=None,
                    help="per-tenant emitted-token quota (tokens/s; off by "
                         "default)")
    ap.add_argument("--tenant-burst", type=float, default=None,
                    help="per-tenant bucket burst (default: --tenant-rate)")
    ap.add_argument("--heartbeat-ms", type=float, default=None,
                    help="idle-stream heartbeat period")
    args = ap.parse_args()
    if args.fault_plan and not args.scenario:
        ap.error("--fault-plan requires --scenario (fault injection is bench/"
                 "test-mode only)")
    cfg = registry.get_smoke(args.arch) if args.smoke else registry.get_config(args.arch)

    reliability = None
    if (args.reliability or args.endurance_budget is not None
            or args.scrub_rate or args.drift_deadline_ms is not None):
        reliability = ReliabilityConfig(
            endurance_budget=args.endurance_budget,
            wear_leveling=not args.no_wear_leveling,
            scrub_rate=args.scrub_rate,
            drift_deadline_s=(args.drift_deadline_ms / 1e3
                              if args.drift_deadline_ms is not None else None))

    tracer = Tracer(capacity=args.trace_capacity) if args.trace_out else None
    obs_kw = {"tracer": tracer, "metrics_window": args.metrics_window,
              "reliability": reliability,
              "xla_annotations": args.xla_annotations,
              "deadline_s": (args.deadline_ms / 1e3
                             if args.deadline_ms is not None else None),
              "queue_timeout_s": (args.queue_timeout_ms / 1e3
                                  if args.queue_timeout_ms is not None else None),
              "degrade": args.degrade}

    if args.listen:
        block_size = args.block_size or 16
        max_len = args.max_len or (args.prompt_len + args.gen)
        max_len = -(-max_len // block_size) * block_size
        serve_listen(
            cfg, host=args.host, port=args.port,
            slots=args.slots or 4, max_len=max_len, block_size=block_size,
            max_queue=args.max_queue, tenant_rate=args.tenant_rate,
            tenant_burst=args.tenant_burst,
            heartbeat_s=(args.heartbeat_ms / 1e3
                         if args.heartbeat_ms is not None else None),
            n_blocks=args.kv_blocks, swap_blocks=args.swap_blocks,
            prefill_chunk=args.chunk, seed=args.seed,
            odin_mode=args.odin_mode, paged=not args.no_paged,
            prefix_sharing=False if args.no_prefix_sharing else None,
            mixed=False if args.no_mixed else None,
            mixed_budget=args.mixed_budget,
            horizon=args.horizon, spec_ngram=args.spec_ngram,
            eos_id=args.eos_id, temperature=args.temperature,
            top_k=args.top_k, sample_seed=args.sample_seed, **obs_kw)
        if tracer is not None:
            tracer.export(args.trace_out)
            print(f"[serve] wrote {len(tracer)} trace events to "
                  f"{args.trace_out} ({tracer.dropped_events} dropped)")
        return

    if args.scenario:
        if args.fault_plan:
            with open(args.fault_plan) as fh:
                obs_kw["fault_plan"] = FaultPlan.from_json(fh.read())
        spec = dataclasses.replace(SCENARIOS[args.scenario], n_requests=args.requests)
        block_size = args.block_size or 16
        max_len = max(spec.prompt_buckets) + spec.shared_prefix + max(spec.gen_buckets)
        max_len = -(-max_len // block_size) * block_size
        engine = ServingEngine(
            cfg, slots=args.slots or 4, max_len=max_len,
            block_size=block_size, n_blocks=args.kv_blocks,
            swap_blocks=args.swap_blocks, prefill_chunk=args.chunk,
            seed=args.seed, odin_mode=args.odin_mode,
            paged=not args.no_paged,
            prefix_sharing=False if args.no_prefix_sharing else None,
            mixed=False if args.no_mixed else None,
            mixed_budget=args.mixed_budget,
            horizon=args.horizon, spec_ngram=args.spec_ngram,
            eos_id=args.eos_id,
            temperature=args.temperature,
            top_k=args.top_k, sample_seed=args.sample_seed, **obs_kw)
        summary = engine.run(make_requests(cfg, spec, seed=args.seed))
        if tracer is not None:
            tracer.export(args.trace_out)
            print(f"[serve] wrote {len(tracer)} trace events to "
                  f"{args.trace_out} ({tracer.dropped_events} dropped)")
        print(json.dumps({k: v for k, v in summary.items() if k != "requests"},
                         indent=2, allow_nan=False))
        return

    if args.static and tracer is not None:
        ap.error("--trace-out requires the engine path (drop --static)")
    fn = serve_static if args.static else serve
    kw = {} if args.static else {"slots": args.slots,
                                 "block_size": args.block_size,
                                 "n_blocks": args.kv_blocks,
                                 "swap_blocks": args.swap_blocks,
                                 "prefill_chunk": args.chunk,
                                 "odin_mode": args.odin_mode,
                                 "paged": not args.no_paged,
                                 "prefix_sharing": False if args.no_prefix_sharing else None,
                                 "mixed": False if args.no_mixed else None,
                                 "mixed_budget": args.mixed_budget,
                                 "horizon": args.horizon,
                                 "spec_ngram": args.spec_ngram,
                                 "eos_id": args.eos_id,
                                 "temperature": args.temperature,
                                 "top_k": args.top_k,
                                 "sample_seed": args.sample_seed,
                                 **obs_kw}
    generated, tps = fn(cfg, batch=args.batch, prompt_len=args.prompt_len,
                        gen=args.gen, seed=args.seed, **kw)
    if tracer is not None:
        tracer.export(args.trace_out)
        print(f"[serve] wrote {len(tracer)} trace events to {args.trace_out} "
              f"({tracer.dropped_events} dropped)")
    print("[serve] first request tokens:", np.asarray(generated)[0].ravel()[:16])


if __name__ == "__main__":
    main()
