"""Abstract input/state specs and sharding trees per (arch × shape) cell.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins for every model
input of a cell — weak-type-correct, shardable, zero device allocation — so
the dry-run lowers full-size cells (671B params, 500k contexts) on a laptop.
``concrete_batch`` produces the matching real batch for runnable sizes
(smoke tests, examples) from the deterministic pipeline.

Sharding trees: batch-bearing leaves shard their leading batch axis over the
data(+pod) mesh axes; decode caches shard sequence over ``model``
(flash-decode) and batch over data.  Any axis that does not divide its mesh
axes is left unsharded (the ``long_500k`` B=1 cell).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LM_SHAPES, ModelConfig, ShapeConfig
from repro.models import lm, registry

__all__ = [
    "input_specs",
    "concrete_batch",
    "batch_pspecs",
    "cache_pspecs",
    "abstract_caches",
    "DRYRUN_ACCUM",
]

# Gradient-accumulation factor per arch for the train_4k cell: keeps saved
# layer-boundary activations under the 16 GB/chip budget (DESIGN.md §4).
# batch 256 = accum × microbatch; napkin: saved acts ≈ L·tokens·d·2B/chips,
# but accum > 1 adds an fp32 grad buffer (params·4B/chips) — so the MoE
# giants (671B/235B: fp32 grads alone ≥ 10 GB/chip) run accum=1 and rely on
# remat + expert sharding instead, while dense 405B takes accum=16
# (fp32 grad buffer 6.3 GB + activations 0.5 GB fits).
DRYRUN_ACCUM = {
    "deepseek-v3-671b": 1,
    "qwen3-moe-235b-a22b": 1,
    "llama3-405b": 4,
    "nemotron-4-15b": 4,
    "phi3-medium-14b": 4,
    "phi4-mini-3.8b": 2,
    "qwen2-vl-2b": 1,
    "hymba-1.5b": 1,
    "musicgen-medium": 1,
    "xlstm-350m": 1,
}

# Accumulation dtype per arch: bf16 halves the per-layer dW reduce payload
# and the carry (the 405B cell does not fit 16 GB/chip with an fp32 carry;
# EXPERIMENTS.md §Perf records the fp32-baseline vs bf16 numbers).
DRYRUN_ACCUM_DTYPE = {
    "llama3-405b": "bfloat16",
}


def _fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh: Mesh, dim: int, axes):
    """Shard ``dim`` over ``axes`` only when divisible (else replicate)."""
    return axes if dim % max(1, _axis_size(mesh, axes)) == 0 else None


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def _batch_shapes(cfg: ModelConfig, shape: ShapeConfig, accum: int) -> Dict[str, Tuple]:
    """Shape tuples of the training/prefill batch for this cell."""
    B, S = shape.global_batch, shape.seq_len
    lead = (accum, B // accum) if accum > 1 else (B,)
    shapes: Dict[str, Tuple] = {}
    if cfg.n_codebooks > 1:
        shapes["tokens"] = (*lead, cfg.n_codebooks, S)
        shapes["labels"] = (*lead, cfg.n_codebooks, S)
    else:
        shapes["tokens"] = (*lead, S)
        shapes["labels"] = (*lead, S)
    if cfg.vision_stub:
        side = max(1, int(np.sqrt(min(1024, S // 4))))   # square patch grid
        shapes["patch_embeds"] = (*lead, side * side, cfg.d_model)
        shapes["pos3d"] = (*lead, S, 3)
    return shapes


def _batch_dtypes(name: str):
    return jnp.float32 if name == "patch_embeds" else jnp.int32


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, accum: int) -> Dict[str, P]:
    fsdp = _fsdp_axes(mesh)
    out = {}
    for name, shp in _batch_shapes(cfg, shape, accum).items():
        batch_dim = shp[1] if accum > 1 else shp[0]
        ax = _maybe(mesh, batch_dim, fsdp)
        if accum > 1:
            out[name] = P(None, ax, *([None] * (len(shp) - 2)))
        else:
            out[name] = P(ax, *([None] * (len(shp) - 1)))
    return out


def _train_specs(cfg: ModelConfig, shape: ShapeConfig, accum: int):
    return {
        name: jax.ShapeDtypeStruct(shp, _batch_dtypes(name))
        for name, shp in _batch_shapes(cfg, shape, accum).items()
    }


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------

def abstract_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """ShapeDtypeStruct tree of the stacked decode caches (no allocation)."""
    return jax.eval_shape(
        functools.partial(lm.init_caches, cfg, batch, max_len, dtype)
    )


def cache_pspecs(cfg: ModelConfig, caches_tpl, mesh: Mesh) -> object:
    """PartitionSpec tree for the stacked caches.

    Leading axis is always ``layers`` (unsharded); batch shards on
    data(+pod); the long sequence axis of KV/latent caches shards on
    ``model`` (flash-decode); head/state minor axes stay local.
    """
    fsdp = _fsdp_axes(mesh)

    def leaf_spec(path, leaf):
        name = jax.tree_util.keystr(path[-1:]).strip("[]'\"")
        shp = leaf.shape
        if name == "pos":
            return P(*([None] * len(shp)))
        b_ax = _maybe(mesh, shp[1], fsdp) if len(shp) >= 2 else None
        if name in ("k", "v"):              # [L, B, S, Hkv, D]
            s_ax = _maybe(mesh, shp[2], "model")
            return P(None, b_ax, s_ax, None, None)
        if name in ("c_kv", "k_rope"):      # [L, B, S, r]
            s_ax = _maybe(mesh, shp[2], "model")
            return P(None, b_ax, s_ax, None)
        if name == "h" and len(shp) == 4:    # SSM state [L, B, d_inner, N]
            d_ax = _maybe(mesh, shp[2], "model")
            return P(None, b_ax, d_ax, None)
        if name == "h" and len(shp) == 3:    # sLSTM hidden [L, B, d]
            return P(None, b_ax, _maybe(mesh, shp[2], "model"))
        if name == "conv":                   # [L, B, K-1, d_inner]
            d_ax = _maybe(mesh, shp[3], "model")
            return P(None, b_ax, None, d_ax)
        if name == "C":                      # [L, B, H, dk, dv]
            return P(None, b_ax, _maybe(mesh, shp[2], "model"), None, None)
        if name in ("n", "m", "c"):
            rest = [None] * (len(shp) - 2)
            if len(shp) >= 3:
                rest[0] = _maybe(mesh, shp[2], "model")
            return P(None, b_ax, *rest)
        return P(*([None] * len(shp)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_tpl)
    return jax.tree_util.tree_unflatten(treedef, [leaf_spec(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# public: per-cell abstract inputs
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape_name: str, *, smoke: bool = False,
                accum: Optional[int] = None,
                kv_dtype: Optional[str] = None) -> Dict:
    """Abstract inputs for one (arch × shape) cell.

    Returns a dict with ``kind`` plus the ShapeDtypeStructs the matching step
    function lowers against:
      train   → {batch}
      prefill → {batch}  (forward-only, fresh caches built inside the step)
      decode  → {tokens, caches}
    """
    cfg = registry.get_smoke(arch) if smoke else registry.get_config(arch)
    if kv_dtype is not None:
        cfg = cfg.with_overrides(kv_dtype=kv_dtype)
    shape = LM_SHAPES[shape_name]
    if smoke:
        shape = ShapeConfig(shape.name, min(shape.seq_len, 64), min(shape.global_batch, 4), shape.kind)
    acc = accum if accum is not None else (DRYRUN_ACCUM.get(arch, 1) if shape.kind == "train" and not smoke else 1)

    if shape.kind == "train":
        return {"kind": "train", "cfg": cfg, "shape": shape, "accum": acc,
                "batch": _train_specs(cfg, shape, acc)}
    if shape.kind == "prefill":
        return {"kind": "prefill", "cfg": cfg, "shape": shape, "accum": 1,
                "batch": _train_specs(cfg, shape, 1)}
    # decode: one new token against a seq_len-deep cache
    B, S = shape.global_batch, shape.seq_len
    tok_shape = (B, cfg.n_codebooks, 1) if cfg.n_codebooks > 1 else (B, 1)
    return {
        "kind": "decode", "cfg": cfg, "shape": shape, "accum": 1,
        "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32),
        "caches": abstract_caches(cfg, B, S),
    }


def concrete_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int, step: int,
                   accum: int = 1) -> Dict[str, jax.Array]:
    """Real batch matching ``_train_specs`` (runnable sizes only)."""
    from repro.data import synthetic

    B, S = shape.global_batch, shape.seq_len
    if cfg.vision_stub:
        out = synthetic.vlm_stub_batch(seed, step, batch=B, seq=S, vocab=cfg.vocab,
                                       d_model=cfg.d_model,
                                       n_patches=max(1, min(1024, S // 4)))
    elif cfg.n_codebooks > 1:
        out = synthetic.audio_stub_batch(seed, step, batch=B, seq=S,
                                         vocab=cfg.vocab, n_codebooks=cfg.n_codebooks)
    else:
        out = synthetic.lm_batch(seed, step, batch=B, seq=S, vocab=cfg.vocab)
    if accum > 1:
        out = jax.tree.map(lambda a: a.reshape(accum, a.shape[0] // accum, *a.shape[1:]), out)
    return out
