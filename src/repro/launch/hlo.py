"""Compiled-artifact analysis: trip-count-aware FLOPs / memory / collectives.

``compiled.cost_analysis()`` counts every while-loop body ONCE — useless for
scan-over-layers models (a 126-layer scan under-counts 126×).  This module
parses the optimized (post-SPMD) HLO text into its computation graph,
extracts ``known_trip_count`` from while backend_configs, propagates
execution multipliers through the call graph (fusion/while/conditional/
to_apply), and accumulates:

* **dot FLOPs** — ``2 · numel(result) · K`` per dot, K = product of the lhs
  contracting dims (shapes resolved from each computation's symbol table).
  Elementwise FLOPs are ignored (≤ a few % of any MAC-dominated step;
  documented modeling choice).
* **memory traffic** — per top-level instruction: Σ operand bytes + result
  bytes, skipping fusion-internal instructions (register-resident), control
  ops, and parameters; dynamic-update-slice counts 2× its update (in-place).
* **collective bytes** — per-chip payload per collective type, × trip count.

The partitioned module is per-device, so all returned numbers are per-chip.

Hardware model (TPU v5e class, per assignment):
  peak 197 TFLOP/s bf16 · 819 GB/s HBM · ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "analyze_module", "ModuleCosts", "collective_bytes",
           "roofline_terms", "parse_dtype_bytes"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12         # bf16 FLOP/s per chip
    hbm_bw: float = 819e9              # bytes/s per chip
    ici_bw: float = 50e9               # bytes/s per link (per chip, effective)
    hbm_bytes: float = 16e9            # v5e capacity


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"(%[\w.\-]+)\s*=\s*((?:\([^)]*\)|[^\s(]+))\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=(%[\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_MEMORY_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "while", "conditional", "call", "iota", "partition-id",
    "replica-id", "opt-barrier",
}


def parse_dtype_bytes(dtype: str) -> Optional[int]:
    return _DTYPE_BYTES.get(dtype)


def _shape_bytes_dims(text: str) -> Tuple[int, List[List[int]]]:
    """Total bytes and per-shape dims lists in a type string (tuples ok)."""
    total = 0
    all_dims = []
    for dtype, dims in _SHAPE_RE.findall(text):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        dl = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in dl:
            n *= d
        total += n * b
        all_dims.append(dl)
    return total, all_dims


@dataclass
class _Instr:
    name: str
    opcode: str
    rtype: str
    rbytes: int
    rdims: List[List[int]]
    operands: List[str]
    attrs: str


@dataclass
class _Comp:
    name: str
    instrs: List[_Instr] = field(default_factory=list)
    table: Dict[str, _Instr] = field(default_factory=dict)
    is_fused_body: bool = False
    root: Optional[_Instr] = None


@dataclass
class ModuleCosts:
    flops: float = 0.0
    memory_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    collective_total: float = 0.0
    collective_wire: float = 0.0
    n_whiles: int = 0
    n_unknown_trip: int = 0


def _parse(hlo_text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.rstrip().endswith("{"):
            cur = _Comp(hdr.group(2))
            comps[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _DEF_RE.search(line)
        if not m:
            continue
        name, rtype, opcode = m.group(1), m.group(2), m.group(3)
        rbytes, rdims = _shape_bytes_dims(rtype)
        # operand text: up to the matching close paren after opcode(
        args = line[m.end():]
        depth, end = 1, len(args)
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND_RE.findall(args[:end])
        inst = _Instr(name, opcode, rtype, rbytes, rdims, operands, args[end:])
        cur.instrs.append(inst)
        cur.table[name] = inst
        if line.lstrip().startswith("ROOT "):
            cur.root = inst
    return comps, entry


def _multipliers(comps: Dict[str, _Comp], entry: str) -> Tuple[Dict[str, float], int, int]:
    """Execution count per computation, via call-graph propagation."""
    callers: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    n_whiles = n_unknown = 0
    for comp in comps.values():
        for inst in comp.instrs:
            if inst.opcode == "fusion":
                m = _CALLS_RE.search(inst.attrs)
                if m and m.group(1) in comps:
                    comps[m.group(1)].is_fused_body = True
                    callers[m.group(1)].append((comp.name, 1.0))
            elif inst.opcode == "while":
                n_whiles += 1
                trip = _TRIP_RE.search(inst.attrs)
                t = float(trip.group(1)) if trip else 1.0
                if not trip:
                    n_unknown += 1
                b = _BODY_RE.search(inst.attrs)
                c = _COND_RE.search(inst.attrs)
                if b and b.group(1) in comps:
                    callers[b.group(1)].append((comp.name, t))
                if c and c.group(1) in comps:
                    callers[c.group(1)].append((comp.name, t + 1.0))
            elif inst.opcode == "conditional":
                m = _BRANCH_RE.search(inst.attrs)
                if m:
                    for b in _OPERAND_RE.findall(m.group(1)):
                        if b in comps:
                            callers[b].append((comp.name, 1.0))
            else:
                m = _TOAPPLY_RE.search(inst.attrs) or _CALLS_RE.search(inst.attrs)
                if m and m.group(1) in comps:
                    callers[m.group(1)].append((comp.name, 1.0))

    mult: Dict[str, float] = {}

    def get(name: str, stack=()) -> float:
        if name in mult:
            return mult[name]
        if name == entry:
            mult[name] = 1.0
            return 1.0
        if name in stack:          # defensive: HLO call graphs are acyclic
            return 0.0
        total = 0.0
        for caller, factor in callers.get(name, []):
            total += get(caller, stack + (name,)) * factor
        mult[name] = total if callers.get(name) else 1.0
        return mult[name]

    for c in comps:
        get(c)
    return mult, n_whiles, n_unknown


def _dot_flops(inst: _Instr, comp: _Comp) -> float:
    numel = 1
    for d in (inst.rdims[0] if inst.rdims else []):
        numel *= d
    k = 1
    m = _LHS_CONTRACT_RE.search(inst.attrs)
    lhs = comp.table.get(inst.operands[0]) if inst.operands else None
    if m and lhs is not None and lhs.rdims:
        dims = lhs.rdims[0]
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(dims):
                k *= dims[idx]
    return 2.0 * numel * k


def _conv_flops(inst: _Instr, comp: _Comp) -> float:
    numel = 1
    for d in (inst.rdims[0] if inst.rdims else []):
        numel *= d
    rhs = comp.table.get(inst.operands[1]) if len(inst.operands) > 1 else None
    if rhs is None or not rhs.rdims:
        return 0.0
    kernel = 1
    for d in rhs.rdims[0]:
        kernel *= d
    # approx: per output element, 2 · (kernel / C_out) MAC flops; C_out is
    # the largest kernel dim matching a result dim — use result minor dim.
    c_out = inst.rdims[0][-1] if inst.rdims and inst.rdims[0] else 1
    return 2.0 * numel * max(1, kernel // max(c_out, 1))


def _instr_memory(inst: _Instr, comp: _Comp, comps: Dict[str, _Comp]) -> float:
    if inst.opcode in _SKIP_MEMORY_OPS:
        return 0.0
    if inst.opcode == "dynamic-update-slice":
        upd = comp.table.get(inst.operands[1]) if len(inst.operands) > 1 else None
        return 2.0 * (upd.rbytes if upd else 0)
    if inst.opcode == "dynamic-slice":
        return 2.0 * inst.rbytes
    if inst.opcode == "fusion":
        m = _CALLS_RE.search(inst.attrs)
        body = comps.get(m.group(1)) if m else None
        ob = sum(comp.table[o].rbytes for o in inst.operands if o in comp.table)
        if body is not None and body.root is not None and \
                body.root.opcode == "dynamic-update-slice":
            upd = body.table.get(body.root.operands[1]) if len(body.root.operands) > 1 else None
            ub = upd.rbytes if upd else 0
            # in-place scatter fusion: inputs stream in, only the slice writes
            big = max((comp.table[o].rbytes for o in inst.operands if o in comp.table), default=0)
            return (ob - big) + 2.0 * ub
        return ob + inst.rbytes
    ob = sum(comp.table[o].rbytes for o in inst.operands if o in comp.table)
    return ob + inst.rbytes


def analyze_module(hlo_text: str) -> ModuleCosts:
    comps, entry = _parse(hlo_text)
    if entry is None:
        return ModuleCosts()
    mult, n_whiles, n_unknown = _multipliers(comps, entry)

    out = ModuleCosts(collectives={c: 0.0 for c in _COLLECTIVES})
    out.n_whiles, out.n_unknown_trip = n_whiles, n_unknown
    for comp in comps.values():
        m = mult.get(comp.name, 1.0)
        if m == 0.0:
            continue
        for inst in comp.instrs:
            if inst.opcode == "dot":
                out.flops += m * _dot_flops(inst, comp)
            elif inst.opcode == "convolution":
                out.flops += m * _conv_flops(inst, comp)
            base = inst.opcode
            for suffix in ("-start", "-done"):
                if base.endswith(suffix):
                    base = base[: -len(suffix)]
            if base in _COLLECTIVES and not inst.opcode.endswith("-done"):
                ob = sum(comp.table[o].rbytes for o in inst.operands if o in comp.table)
                out.collectives[base] += m * ob
            if not comp.is_fused_body:
                out.memory_bytes += m * _instr_memory(inst, comp, comps)
    out.collective_total = sum(out.collectives.values())
    # ring-algorithm wire model: all-reduce moves ≈ 2× its payload per chip
    out.collective_wire = out.collective_total + out.collectives["all-reduce"]
    return out


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Trip-count-aware per-chip collective payload bytes by type."""
    costs = analyze_module(hlo_text)
    out = dict(costs.collectives)
    out["total"] = costs.collective_total
    out["wire_total"] = costs.collective_wire
    return out


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float, hw: HW = HW()) -> Dict[str, float]:
    """The three roofline times (seconds) for one executed step, per chip."""
    t_compute = flops_per_device / hw.peak_flops
    t_memory = bytes_per_device / hw.hbm_bw
    t_coll = coll_bytes_per_device / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    terms["step_time_lb_s"] = max(t_compute, t_memory, t_coll)
    return terms
