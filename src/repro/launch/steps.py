"""Step functions: train (grad-accum, AdamW), prefill, decode.

These are the units the dry-run lowers and the drivers jit:

* ``make_train_step``  — microbatched ``lax.scan`` gradient accumulation
  (mean over microbatches), AdamW with int8 moments, cosine LR.  Params,
  optimizer state and batch come in pre-sharded (pjit in_shardings); GSPMD
  inserts the gradient reduce-scatter/all-gathers the roofline analyzes.
* ``make_prefill_step`` — forward-only; builds fresh caches and fills them.
* ``make_decode_step``  — one token against a deep cache (the decode cells).
* ``make_dp_train_step`` — pure-DP variant under ``shard_map`` with the
  int8 stochastic-rounded compressed gradient all-reduce *in the compiled
  graph* (optim/compress.py).  Used by the elastic/compressed driver and
  the 8-device tests; the big pjit path keeps compression at the DP axis.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax ≥ 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map_raw
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_raw


def shard_map(f, *, mesh, in_specs, out_specs):
    """shard_map with replication checking off (kwarg renamed across jax)."""
    try:
        return _shard_map_raw(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
    except TypeError:  # pragma: no cover — older jax
        return _shard_map_raw(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

from repro.configs.base import BlockConfig, ModelConfig
from repro.models import lm
from repro.nn.attention import POOL_LEAVES, init_paged_cache
from repro.nn.module import ParamSpec
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import compressed_psum

__all__ = [
    "lr_schedule",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "make_dp_train_step",
    "optimizer_pspecs",
    "init_serving_caches",
    "make_slot_prefill_step",
    "make_serving_decode_step",
    "make_serving_mixed_step",
    "make_serving_decode_guarded",
    "make_serving_decode_horizon",
    "make_serving_spec_horizon",
    "ngram_propose",
    "pageable_block",
    "speculable",
]


def lr_schedule(step, base: float = 3e-4, warmup: int = 100, total: int = 10_000):
    s = step.astype(jnp.float32)
    warm = (s + 1.0) / max(warmup, 1)        # step 0 trains at base/warmup, not 0
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return base * jnp.where(s < warmup, warm, 0.1 + 0.9 * cos)


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    accum: int = 1, base_lr: float = 3e-4,
                    grad_shardings=None, accum_dtype=jnp.float32,
                    warmup: int = 100) -> Callable:
    """(params, opt_state, batch) → (params, opt_state, metrics).

    ``batch`` leaves are [B, ...] when ``accum == 1`` else [accum, B/accum, ...];
    the accumulation loop is a ``lax.scan`` so HLO stays O(1 microbatch).
    ``grad_shardings`` (tree of NamedShardings matching params) pins the
    accumulation carry and the per-microbatch grads — without it the
    partitioner may replicate the buffers (1.6 TB/device at the 405B cell).
    ``accum_dtype``: fp32 is exact; bf16 halves both the carry and the
    per-layer dW reduction payload (§Perf lever for the 405B cell — the
    mean-of-16-microbatches loses <1 bf16 ulp of the per-leaf sum).
    """

    grad_fn = jax.value_and_grad(lm.loss_fn, has_aux=True)

    def _pin(g_tree):
        if grad_shardings is None:
            return g_tree
        return jax.tree.map(jax.lax.with_sharding_constraint, g_tree, grad_shardings)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch, cfg)
            grads = _pin(grads)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb, cfg)
                # pinning g (not just the carry) pushes the sharding back
                # through the scan-transpose dW accumulation buffers
                g = _pin(g)
                g_acc = _pin(jax.tree.map(lambda a, b: (a + b.astype(accum_dtype)).astype(accum_dtype), g_acc, g))
                return (g_acc, l_acc + l), None

            g0 = _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (grads, loss_sum), _ = jax.lax.scan(micro, (g0, jnp.float32(0)), batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / accum, grads)
            loss = loss_sum / accum
            metrics = {"loss_total": loss}

        lr = lr_schedule(opt_state["step"], base_lr, warmup=warmup)
        new_params, new_opt = adamw_update(grads, params, opt_state, lr, opt_cfg)
        # NB: shape-preserving reduce — vdot/flatten of a 2-D-sharded grad
        # would force a full all-gather per leaf (measured 11 GB/device of
        # replicated fp32 at phi4 scale before this form was used).
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_opt, out_metrics

    return train_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    """(params, batch) → (last_logits, caches): fill caches for S tokens."""

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        caches = lm.init_caches(cfg, B, max_len)
        logits, caches, _ = lm.forward(
            params, tokens, cfg, caches=caches,
            patch_embeds=batch.get("patch_embeds"), pos3d=batch.get("pos3d"),
        )
        return logits[:, -1], caches

    return prefill_step


def _cache_start(caches):
    """Absolute position of the incoming token(s), from the attn ``pos`` leaf.

    Every attention layer advances its cache position in lockstep, so the
    first segment's layer-0 entry is authoritative.  Returns a scalar (static
    batch), a [B] vector (serving caches), or None (recurrent-only stacks,
    where positions only feed RoPE and there is no RoPE without attention).
    """
    for seg in caches:
        if isinstance(seg, dict) and "attn" in seg and "pos" in seg["attn"]:
            return seg["attn"]["pos"][0]
    return None


def _argmax_tokens(logits, cfg: ModelConfig):
    if cfg.n_codebooks > 1:
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)       # [B, K]
        return nxt[:, :, None]
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)           # [B]
    return nxt[:, None]


def make_decode_step(cfg: ModelConfig) -> Callable:
    """(params, caches, tokens [B,1][, tables]) → (next_tokens [B,1], caches).

    The query position is read from the cache ``pos`` leaf — without it the
    decoded token runs at position 0: wrong RoPE phase AND a causal mask that
    hides every cache row but the first.  ``tables`` (per-slot block tables)
    only matter when the caches carry the paged block pool.
    """

    def decode_step(params, caches, tokens, tables=None):
        start = _cache_start(caches)
        if start is not None and start.ndim:
            start = start[:, None]
        logits, caches, _ = lm.forward(params, tokens, cfg, caches=caches,
                                       start_pos=start, tables=tables)
        return _argmax_tokens(logits, cfg), caches

    return decode_step


# ---------------------------------------------------------------------------
# continuous-batching serving steps (repro.serving)
# ---------------------------------------------------------------------------

def _leaf_name(path) -> str:
    return jax.tree_util.keystr(path[-1:]).strip("[]'\"")


def pageable_block(b: BlockConfig) -> bool:
    """Whether a segment's attention cache can use the paged block pool.

    Non-windowed GQA only: sliding-window layers already hold O(window) ring
    state, and MLA's compressed latent keeps its dense layout (both stay on
    the existing cache-family dispatch).
    """
    return (b.kind in ("dense", "moe", "hymba") and b.attn is not None
            and b.attn.kind == "gqa" and b.attn.window == 0)


def _pool_trash_block(caches) -> Optional[int]:
    """Index of the write-off block of the paged pool (None ⇒ no paged leaves)."""
    for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
        if _leaf_name(path) in POOL_LEAVES:
            return leaf.shape[1] - 1
    return None


def init_serving_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
                        window_headroom: int = 0, round_to: int = 1,
                        block_size: int = 0, n_blocks: int = 0):
    """Stacked decode caches with *per-slot* position vectors.

    Identical to ``lm.init_caches`` except:

    * attention ``pos`` leaves are [L, B] int32 vectors instead of [L]
      scalars, so each batch slot tracks its own sequence length
      (nn/attention.py takes the batched-scatter write path and builds
      per-slot visibility masks) — every per-slot leaf then carries the slot
      axis at position 1, which is what the slot slice/update helpers rely on;
    * with ``n_blocks > 0``, paged-capable segments (``pageable_block``) get
      the **physical block pool** instead of a dense ``[B, max_len]`` live
      cache: ``k_pool/v_pool [L, n_blocks+1, block_size, H_kv, D]`` shared by
      every slot and addressed through per-slot block tables — device KV
      memory scales with the pool, not ``slots × max_len``;
    * sliding-window ring buffers get ``window_headroom`` extra rows (rounded
      up to ``round_to``, capped at ``max_len``).  A prefill chunk of C
      tokens through a ring of exactly ``window`` rows overwrites keys its
      own early queries still need; ``window + C`` rows keep every key alive
      until every query that may attend to it has run, making chunked prefill
      exact for window attention.  (Masking is position-based, so extra rows
      only cost memory.)
    """
    if dtype is None:
        dtype = jnp.dtype(cfg.kv_dtype)
    override = None
    if n_blocks:
        override = lambda b: (init_paged_cache(b.attn, n_blocks, block_size, dtype)
                              if pageable_block(b) else None)
    caches = lm.init_caches(cfg, batch, max_len, dtype, attn_override=override)

    def fix(path, leaf):
        name = _leaf_name(path)
        if name == "pos":
            return jnp.zeros((*leaf.shape, batch), jnp.int32)
        if window_headroom and name in ("k", "v") and leaf.shape[2] < max_len:
            size = leaf.shape[2] + window_headroom
            size = min(max_len, -(-size // round_to) * round_to)
            if size > leaf.shape[2]:
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, size - leaf.shape[2])
                return jnp.pad(leaf, pad)
        return leaf

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(treedef, [fix(p, l) for p, l in flat])


def make_slot_prefill_step(cfg: ModelConfig, max_len: int,
                           window_headroom: int = 0, round_to: int = 1,
                           block_size: int = 0, paged: bool = False) -> Callable:
    """Chunked prefill of ONE batch slot of a serving cache.

    (params, caches, tokens [1,C], slot, start, reset, tables)
        → (last_logits, caches)

    Per-slot leaves are sliced out ([L, 1, ...] per leaf), the chunk runs the
    ordinary forward at absolute positions [start, start+C), and the slices
    are written back.  Paged pool leaves have no slot axis: they pass through
    whole, and the forward **writes the chunk's K/V blocks directly into the
    pool** via the slot's block-table row — there is no dense staging copy.
    ``reset`` (traced bool) restores the slot's per-slot leaves to their true
    initial state first (mLSTM/sLSTM states do not initialize to zeros and
    the slot may hold a previous request's state); pool blocks never need a
    reset because rows at or beyond the slot's ``pos`` are invisible, and the
    rows below it are overwritten by this very prefill.  A reset at
    ``start > 0`` starts the slot *mid-sequence*: ``pos`` leaves reset to
    ``start`` instead of 0, so a tail-only prefill behind a shared resident
    prefix (prefix sharing) writes and attends exactly like the later chunks
    of a full prefill — rows below ``start`` are read through the block
    table, never recomputed.
    ``slot``/``start`` are traced scalars so one executable serves every slot
    and chunk offset; only distinct chunk *lengths* compile separately.
    """

    def prefill_chunk(params, caches, tokens, slot, start, reset, tables=None,
                      patch_embeds=None, pos3d=None):
        flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
        init = init_serving_caches(cfg, 1, max_len,
                                   window_headroom=window_headroom,
                                   round_to=round_to, block_size=block_size,
                                   n_blocks=1 if paged else 0)
        init_flat = [l for _, l in jax.tree_util.tree_flatten_with_path(init)[0]]
        sl = []
        for (path, leaf), ini in zip(flat, init_flat):
            if _leaf_name(path) in POOL_LEAVES:
                sl.append(leaf)                      # shared pool: pass whole
            else:
                s = jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=1)
                if _leaf_name(path) == "pos":
                    ini = ini + start                # mid-sequence reset
                sl.append(jnp.where(reset, ini, s))
        sl = jax.tree_util.tree_unflatten(treedef, sl)
        trow = (jax.lax.dynamic_slice_in_dim(tables, slot, 1, axis=0)
                if paged else None)
        logits, sl, _ = lm.forward(params, tokens, cfg, caches=sl,
                                   patch_embeds=patch_embeds, pos3d=pos3d,
                                   start_pos=start, moe_no_drop=True,
                                   tables=trow)
        out = []
        for (path, old), (_, new) in zip(
                flat, jax.tree_util.tree_flatten_with_path(sl)[0]):
            if _leaf_name(path) in POOL_LEAVES:
                out.append(new)                      # updated in place
            else:
                out.append(jax.lax.dynamic_update_slice_in_dim(
                    old, new, slot, axis=1))
        return logits[:, -1], jax.tree_util.tree_unflatten(treedef, out)

    return prefill_chunk


def _sample_tokens(logits, cfg: ModelConfig, key, temperature, top_k: int):
    """Next-token pick: greedy argmax, or temperature + top-k sampling.

    ``key is None`` ⇒ compiled greedy-only path (no sampling ops in the
    graph).  Otherwise per-slot keys are derived by ``fold_in`` so each slot
    draws an independent stream, and a traced ``temperature == 0`` still
    selects the argmax (the engine passes one executable either way).
    """
    last = logits[:, -1]                         # [B, V] or [B, K, V]
    greedy = jnp.argmax(last, axis=-1).astype(jnp.int32)
    if key is None:
        nxt = greedy
    else:
        masked = last.astype(jnp.float32)
        if top_k:
            kth = jax.lax.top_k(masked, top_k)[0][..., -1:]
            masked = jnp.where(masked >= kth, masked, -1e30)
        B = last.shape[0]
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(B))
        scaled = masked / jnp.maximum(temperature, 1e-6)
        sampled = jax.vmap(lambda k, l: jax.random.categorical(k, l, axis=-1))(
            keys, scaled).astype(jnp.int32)
        nxt = jnp.where(temperature > 0, sampled, greedy)
    return nxt[:, :, None] if cfg.n_codebooks > 1 else nxt[:, None]


def make_serving_decode_step(cfg: ModelConfig, top_k: int = 0,
                             sample: bool = False) -> Callable:
    """One decode step over all serving slots with an activity mask.

    (params, caches, tokens [B,1], lengths [B], active [B], tables [B,P],
     key, temperature) → (next, caches)

    Inactive slots (free, draining, or mid-admission) still flow through the
    compiled step — the fixed [B, 1] shape is what keeps one executable
    serving every request mix — but their cache updates are discarded: per-
    slot leaves by a select, and paged pool writes by pointing the inactive
    slots' block tables at the pool's write-off block (the pool has no slot
    axis to select over, so masking happens at the write address).
    ``lengths`` must equal the per-slot cache ``pos`` (the scheduler's view
    of each slot's cached length).  ``sample=False`` compiles the pure greedy
    step (key/temperature accepted but unused); ``sample=True`` adds the
    temperature + top-k path of :func:`_sample_tokens`.
    """

    def decode_step(params, caches, tokens, lengths, active, tables=None,
                    key=None, temperature=0.0):
        nxt, caches = _masked_decode(params, caches, tokens, lengths, active,
                                     tables, key if sample else None,
                                     temperature, cfg, top_k)
        return nxt, caches

    return decode_step


def make_serving_mixed_step(cfg: ModelConfig, top_k: int = 0,
                            sample: bool = False) -> Callable:
    """ONE dispatch carrying decode rows AND prefill-chunk rows together.

    (params, caches, tokens [B,Q] (or [B,K,Q]), lengths [B], q_lens [B],
     decode [B], active [B], tables [B,P], key, temperature)
        → (next_tokens, last_logits [B,V] (or [B,K,V]), caches)

    The mixed tile: every slot contributes ``q_lens[s]`` real query rows,
    right-aligned in the fixed ``Q`` columns — a decode slot rides at
    ``q_lens = 1`` (its pending token in column Q-1, flagged in ``decode``),
    a prefilling slot carries a chunk of its prompt at ``q_lens = c ≤ Q``.
    Because tiles are right-aligned, ``logits[:, -1]`` is the last real
    token's logits for every slot, so the same :func:`_sample_tokens` serves
    both populations: for decode slots it is the next emitted token, for a
    slot that just finished its prompt it is the first generated token, and
    for a mid-prompt slot it is discarded by the engine.  ``lengths`` is the
    per-slot cached length *before* this dispatch (== cache ``pos``).
    Bit-identity with the separate paths is structural, not approximate:
    prefill rows run the chunked-prefill gather+sdpa core and decode rows
    run the decode kernel (``q_decode`` selection in the attention layer),
    so each emitted token is the argmax/sample over *the same floats* the
    separate prefill/decode dispatches would have produced.

    Inactive slots run with ``q_lens = 0``: every row of theirs is a pad row
    whose K/V writes land in the pool's write-off block (their tables are
    additionally redirected there), and their ``pos`` does not advance.
    ``last_logits`` rides back to the host so the engine can emit first
    tokens of finishing prefills with the same host-side argmax/sampling it
    uses on the separate path (bit-identical first tokens).
    """

    def mixed_step(params, caches, tokens, lengths, q_lens, decode, active,
                   tables=None, key=None, temperature=0.0):
        trash = _pool_trash_block(caches)
        Q = tokens.shape[-1]
        q_lens = jnp.where(active, q_lens, 0)
        tabs = tables
        if tabs is not None and trash is not None:
            tabs = jnp.where(active[:, None], tabs, jnp.int32(trash))
        # row 0 of the tile sits q_lens-Q rows *before* the slot's next
        # position (pad rows get earlier/negative positions; discarded)
        start = (lengths + q_lens - Q)[:, None]
        logits, new_caches, _ = lm.forward(params, tokens, cfg, caches=caches,
                                           start_pos=start, moe_no_drop=True,
                                           tables=tabs, q_lens=q_lens,
                                           q_decode=decode & active)

        def merge(path, old, new):
            if _leaf_name(path) in POOL_LEAVES:
                return new          # pad/inactive writes went to the trash block
            m = active.reshape((1, active.shape[0]) + (1,) * (old.ndim - 2))
            return jnp.where(m, new, old)

        caches = jax.tree_util.tree_map_with_path(merge, caches, new_caches)
        nxt = _sample_tokens(logits, cfg, key if sample else None,
                             temperature, top_k)
        return nxt, logits[:, -1], caches

    return mixed_step


def make_serving_decode_guarded(cfg: ModelConfig, top_k: int = 0,
                                sample: bool = False) -> Callable:
    """Single decode step with a per-slot NaN/Inf logit guard (+ optional
    fault injection).

    (params, caches, tokens [B,1], lengths [B], active [B], tables [B,P],
     key, temperature, poison [B]) → (next, bad [B], caches)

    ``bad[s]`` is True when slot ``s``'s final-row logits contain a
    non-finite value — the engine quarantines that request as FAILED and
    discards its token.  ``poison`` injects NaN into the marked slots'
    logits *after* the forward pass (the PCRAM-drift analog at the logit
    seam), so co-batched slots see bit-identical logits to an unguarded
    step and keep their streams.  The argmax/sampling path is unchanged for
    finite rows, so emitted tokens match :func:`make_serving_decode_step`
    exactly; the guard costs one ``isfinite`` reduction per slot, paid only
    by engines that opt into guarded decode.
    """

    def decode_step(params, caches, tokens, lengths, active, tables=None,
                    key=None, temperature=0.0, poison=None):
        trash = _pool_trash_block(caches)
        if tables is not None and trash is not None:
            tables = jnp.where(active[:, None], tables, jnp.int32(trash))
        logits, new_caches, _ = lm.forward(params, tokens, cfg, caches=caches,
                                           start_pos=lengths[:, None],
                                           moe_no_drop=True, tables=tables)
        if poison is not None:
            m = poison.reshape((-1,) + (1,) * (logits.ndim - 1))
            logits = jnp.where(m, jnp.nan, logits)
        last = logits[:, -1]
        bad = ~jnp.all(jnp.isfinite(last.reshape(last.shape[0], -1)), axis=-1)

        def merge(path, old, new):
            if _leaf_name(path) in POOL_LEAVES:
                return new
            m = active.reshape((1, active.shape[0]) + (1,) * (old.ndim - 2))
            return jnp.where(m, new, old)

        caches = jax.tree_util.tree_map_with_path(merge, caches, new_caches)
        nxt = _sample_tokens(logits, cfg, key if sample else None,
                             temperature, top_k)
        return nxt, bad, caches

    return decode_step


def _masked_decode(params, caches, tokens, lengths, active, tables, key,
                   temperature, cfg: ModelConfig, top_k: int):
    """One activity-masked decode over all slots (the shared body of the
    single-step and horizon serving decode).  Returns (next_tokens, caches)."""
    trash = _pool_trash_block(caches)
    if tables is not None and trash is not None:
        tables = jnp.where(active[:, None], tables, jnp.int32(trash))
    logits, new_caches, _ = lm.forward(params, tokens, cfg, caches=caches,
                                       start_pos=lengths[:, None],
                                       moe_no_drop=True, tables=tables)

    def merge(path, old, new):
        if _leaf_name(path) in POOL_LEAVES:
            return new              # inactive writes went to the trash block
        m = active.reshape((1, active.shape[0]) + (1,) * (old.ndim - 2))
        return jnp.where(m, new, old)

    caches = jax.tree_util.tree_map_with_path(merge, caches, new_caches)
    nxt = _sample_tokens(logits, cfg, key, temperature, top_k)
    return nxt, caches


def make_serving_decode_horizon(cfg: ModelConfig, H: int, top_k: int = 0,
                                sample: bool = False) -> Callable:
    """``H`` decode steps fused into ONE compiled dispatch (``lax.scan``).

    (params, caches, tokens [B,1], lengths [B], active [B], remaining [B],
     tables [B,P], key, temperature, step0, eos_id)
        → (token_block [B, H] (or [B, K, H]), counts [B],
           last_tokens [B, 1] (or [B, K, 1]), caches)

    Each inner step runs the same activity-masked decode as
    :func:`make_serving_decode_step` and feeds the sampled/argmaxed token back
    as the next step's input **on-device** — the host pays one dispatch and
    one sync for ``H`` tokens instead of ``H`` of each.  Per-slot freezing
    happens mid-horizon on-device: a slot leaves the activity mask once its
    ``remaining`` generation budget hits zero or it emits ``eos_id``
    (``eos_id < 0`` disables EOS).  Frozen slots keep flowing through the
    fixed-shape forward, but their cache updates are discarded, their lengths
    stop advancing, and their later tokens are not counted.

    ``counts[s]`` is the number of valid tokens for slot ``s`` — because
    freezing is monotone, slot ``s``'s valid tokens are exactly
    ``token_block[s, ..., :counts[s]]``.  ``step0`` is the engine's global
    decode-step counter at horizon entry: inner step ``h`` draws its sampling
    key as ``fold_in(key, step0 + h)``, the same schedule the single-step
    path uses, so a horizon run is token-identical to ``H`` single steps
    (greedy always; sampled whenever the slot schedule matches).
    """

    def horizon_step(params, caches, tokens, lengths, active, remaining,
                     tables=None, key=None, temperature=0.0,
                     step0=0, eos_id=-1):
        B = lengths.shape[0]
        tok_mask_shape = (B,) + (1,) * (tokens.ndim - 1)

        def inner(carry, h):
            caches, tok, lengths, act, rem = carry
            k = jax.random.fold_in(key, step0 + h) if sample else None
            nxt, caches = _masked_decode(params, caches, tok, lengths, act,
                                         tables, k, temperature, cfg, top_k)
            # EOS on the first codebook (single-codebook: the token itself)
            first = nxt.reshape(B, -1)[:, 0]
            hit_eos = (eos_id >= 0) & (first == eos_id)
            rem = rem - act.astype(jnp.int32)
            lengths = lengths + act.astype(jnp.int32)
            new_act = act & (rem > 0) & ~hit_eos
            tok = jnp.where(act.reshape(tok_mask_shape), nxt, tok)
            return (caches, tok, lengths, new_act, rem), (nxt, act)

        (caches, tok, lengths, act, rem), (toks, emitted) = jax.lax.scan(
            inner, (caches, tokens, lengths, active, remaining),
            jnp.arange(H, dtype=jnp.int32))
        counts = emitted.astype(jnp.int32).sum(axis=0)              # [B]
        # toks: [H, B, 1] or [H, B, K, 1] → [B, H] / [B, K, H]
        block = jnp.moveaxis(toks[..., 0], 0, -1)
        return block, counts, tok, caches

    return horizon_step


# ---------------------------------------------------------------------------
# n-gram self-speculative decode (draft-free prompt-lookup verification)
# ---------------------------------------------------------------------------

def speculable(cfg: ModelConfig) -> bool:
    """Whether the config supports n-gram self-speculative serving decode.

    Speculation rolls back rejected KV writes by *not advancing* per-slot
    lengths — sound exactly when every piece of decode state is
    position-addressed (paged pool blocks, dense KV rows, MLA latents: stale
    rows past the length are invisible to every later query).  Recurrent
    state (Hymba's SSM branch, xLSTM cells) advances per token and cannot be
    truncated, and multi-codebook token frames have no scalar n-gram to
    match, so both stay on the plain decode paths.
    """
    return cfg.n_codebooks == 1 and all(
        b.kind in ("dense", "moe") and b.attn is not None for b in cfg.blocks)


def ngram_propose(hist, K: int, n: int = 2):
    """Draft ``K`` tokens per slot by prompt-lookup over the token history.

    ``hist [B, W]`` holds each slot's most recent context tokens
    right-aligned (prompt tail + generated ids, ``-1`` padding on the left).
    The final ``n``-gram is matched against every earlier offset in one
    vectorized comparison; the draft is the ``K`` tokens that followed the
    most recent match — the classic prompt-lookup heuristic, entirely
    on-device (no host round-trip inside the horizon scan).  No match (or a
    match into padding) degenerates to repeating the last token, which the
    verify step simply rejects.
    """
    B, W = hist.shape
    J = W - n - K + 1               # candidate starts; excludes the tail itself
    if J < 1:
        raise ValueError(f"history window {W} too short for n={n}, K={K}")
    tail = hist[:, W - n:]
    m = jnp.ones((B, J), bool)
    for i in range(n):
        m = m & (hist[:, i:i + J] == tail[:, i:i + 1])
    best = jnp.max(jnp.where(m, jnp.arange(J, dtype=jnp.int32), -1), axis=1)
    has = best >= 0
    cols = jnp.maximum(best, 0)[:, None] + n + jnp.arange(K, dtype=jnp.int32)
    draft = jnp.take_along_axis(hist, cols, axis=1)            # [B, K]
    draft = jnp.where(has[:, None], draft, hist[:, -1:])
    return jnp.maximum(draft, 0)    # padding can leak into a boundary draft


def _spec_merge(old_caches, new_caches, active, m):
    """Merge a K+1-token verify forward's cache updates with per-slot
    rollback: ``pos`` leaves advance by the per-slot accepted count ``m``
    (not the K+1 rows the forward wrote — rows past ``pos + m`` hold
    rejected-draft K/V and stay invisible to every later query), pool leaves
    keep their writes (inactive slots wrote to the trash block), and other
    per-slot leaves select by the activity mask."""

    def merge(path, old, new):
        name = _leaf_name(path)
        if name in POOL_LEAVES:
            return new
        if name == "pos":
            return old + m[None, :]             # [L, B] + [1, B]
        mask = active.reshape((1, active.shape[0]) + (1,) * (old.ndim - 2))
        return jnp.where(mask, new, old)

    return jax.tree_util.tree_map_with_path(merge, old_caches, new_caches)


def make_serving_spec_horizon(cfg: ModelConfig, H: int, K: int,
                              n: int = 2) -> Callable:
    """``H`` draft→verify→accept steps fused into ONE compiled dispatch.

    (params, caches, tokens [B,1], lengths [B], active [B], remaining [B],
     hist [B,W], tables [B,P], eos_id)
        → (token_block [B, H, K+1], counts [B, H], last_tokens [B, 1],
           hist, caches)

    Each inner step of the ``lax.scan``:

    1. **draft** — :func:`ngram_propose` reads the slot's on-device token
       history and emits ``K`` draft tokens;
    2. **verify** — ONE forward over ``[pending, d_1..d_K]`` (the
       multi-token-query paged kernel / batched dense decode) yields
       ``K+1`` greedy logits at positions ``len..len+K``;
    3. **accept** — the longest prefix of drafts matching their greedy
       argmax is accepted; the next argmax rides along as the *bonus* token,
       so the step emits ``a+1 ∈ [1, K+1]`` tokens — every one of them an
       argmax of model logits, which is what makes greedy speculation
       token-identical to plain decode by construction;
    4. **rollback** — per-slot lengths advance by the emitted count only
       (clamped by the slot's ``remaining`` budget and a mid-run EOS);
       rejected rows were written into the slot's own pre-extended tail
       blocks and stay invisible, so rollback is a length decrement, never a
       copy;
    5. the bonus/last-emitted token feeds back as the next step's pending
       input and the history ring shifts the emitted run in — all on-device.

    ``counts[s, h]`` is the number of valid tokens in ``token_block[s, h]``
    (0 once the slot froze); freezing is monotone over ``h``.  Greedy only:
    the accept rule compares argmaxes, so there is no sampling path here
    (the engine enforces ``temperature == 0`` for speculation).
    """
    if K < 1:
        raise ValueError(f"spec draft length K must be >= 1, got {K}")

    def spec_step(params, caches, tokens, lengths, active, remaining, hist,
                  tables=None, eos_id=-1):
        B = lengths.shape[0]
        W = hist.shape[1]
        trash = _pool_trash_block(caches)

        def inner(carry, _):
            caches, tok, lengths, act, rem, hist = carry
            draft = ngram_propose(hist, K, n)                   # [B, K]
            tabs = tables
            if tabs is not None and trash is not None:
                tabs = jnp.where(act[:, None], tabs, jnp.int32(trash))
            tin = jnp.concatenate([tok, draft], axis=1)         # [B, K+1]
            logits, new_caches, _ = lm.forward(
                params, tin, cfg, caches=caches, start_pos=lengths[:, None],
                moe_no_drop=True, tables=tabs, spec_decode=True)
            g = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # [B, K+1]
            # longest accepted draft prefix: d_j must equal the argmax of the
            # logits one position earlier (the token that would have been
            # decoded there)
            match = (draft == g[:, :K]).astype(jnp.int32)
            acc = jnp.cumprod(match, axis=1).sum(axis=1)        # [B] ∈ [0, K]
            is_eos = (eos_id >= 0) & (g == eos_id)
            has_eos = is_eos.any(axis=1)
            eos_cut = jnp.where(has_eos, jnp.argmax(is_eos, axis=1) + 1, K + 1)
            m = jnp.minimum(jnp.minimum(acc + 1, rem), eos_cut)
            m = jnp.where(act, m, 0)                            # emitted count
            caches = _spec_merge(caches, new_caches, act, m)
            lengths = lengths + m
            rem = rem - m
            last = jnp.take_along_axis(g, jnp.maximum(m - 1, 0)[:, None], axis=1)
            tok = jnp.where((m > 0)[:, None], last, tok)
            hit_eos = has_eos & (eos_cut <= m)                  # eos was emitted
            act = act & (rem > 0) & ~hit_eos
            ext = jnp.concatenate([hist, g], axis=1)            # [B, W+K+1]
            hist = jnp.take_along_axis(
                ext, m[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :], axis=1)
            return (caches, tok, lengths, act, rem, hist), (g, m)

        (caches, tok, lengths, act, rem, hist), (toks, counts) = jax.lax.scan(
            inner, (caches, tokens, lengths, active, remaining, hist),
            jnp.arange(H, dtype=jnp.int32))
        # toks: [H, B, K+1] → [B, H, K+1]; counts: [H, B] → [B, H]
        return toks.swapaxes(0, 1), counts.T, tok, hist, caches

    return spec_step


# ---------------------------------------------------------------------------
# sharding trees for optimizer state
# ---------------------------------------------------------------------------

def optimizer_pspecs(param_pspec_tree, opt_cfg: AdamWConfig):
    """PartitionSpec tree matching ``adamw_init``'s structure.

    Moment ``q`` mirrors the param spec; blockwise scales ``s`` replace the
    (possibly sharded) trailing axis with None — scales are tiny.
    """

    def moment(ps: P):
        if opt_cfg.moment_dtype == "float32":
            return {"q": ps}
        entries = list(ps)
        s_spec = P(*entries[:-1], None) if entries else P()
        return {"q": ps, "s": s_spec}

    is_p = lambda x: isinstance(x, P)
    return {
        "mu": jax.tree.map(moment, param_pspec_tree, is_leaf=is_p),
        "nu": jax.tree.map(moment, param_pspec_tree, is_leaf=is_p),
        "step": P(),
    }


# ---------------------------------------------------------------------------
# pure-DP path with real compressed gradient all-reduce (shard_map)
# ---------------------------------------------------------------------------

def make_dp_train_step(cfg: ModelConfig, mesh: Mesh,
                       opt_cfg: AdamWConfig = AdamWConfig(moment_dtype="float32"),
                       base_lr: float = 3e-4, compress: bool = True) -> Callable:
    """Data-parallel train step with int8-compressed gradient all-reduce.

    Params replicated, batch sharded over every mesh axis; each shard
    computes local grads and the cross-shard reduction goes through
    ``compressed_psum`` (int8 payload — 4× fewer wire bytes than fp32,
    visible in the compiled HLO).  This is the honest, compiled realization
    of the paper-adjacent 8-bit theme at the distribution layer.
    """
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def local(params, opt_state, batch, key):
        (loss, _), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(params, batch, cfg)
        if compress:
            keys = jax.random.split(key, len(jax.tree.leaves(grads)))
            flat, treedef = jax.tree.flatten(grads)
            flat = [
                compressed_psum(g.astype(jnp.float32).reshape(1, -1), axes, k).reshape(g.shape) / n_shards
                for g, k in zip(flat, keys)
            ]
            grads = jax.tree.unflatten(treedef, flat)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axes), grads)
        loss = jax.lax.pmean(loss, axes)
        lr = lr_schedule(opt_state["step"], base_lr)
        new_params, new_opt = adamw_update(grads, params, opt_state, lr, opt_cfg)
        return new_params, new_opt, {"loss": loss}

    batch_spec = P(axes)
    rep = P()

    def specs_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def dp_step(params, opt_state, batch, key):
        fn = shard_map(
            local, mesh=mesh,
            in_specs=(specs_like(params, rep), specs_like(opt_state, rep),
                      specs_like(batch, batch_spec), rep),
            out_specs=(specs_like(params, rep), specs_like(opt_state, rep),
                       {"loss": rep}),
        )
        return fn(params, opt_state, batch, key)

    return dp_step
