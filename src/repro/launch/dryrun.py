import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and extract the roofline inputs (deliverables e and g).

The two lines above run before ANY other import — jax locks the device count
at first init, and the dry-run needs 512 placeholder host devices to build
the (2, 16, 16) pod mesh.  Nothing here allocates full-size arrays: inputs
are ShapeDtypeStructs, and compilation is the proof that the distribution
config is coherent (sharding mismatches, unsupported collectives and
compile-time OOM all fail here).

Per cell this records into ``experiments/dryrun/<cell>.json``:
  * per-device memory breakdown (argument/output/temp/code bytes),
  * cost_analysis flops + bytes accessed (per-device, post-SPMD),
  * collective op bytes parsed from the optimized HLO (launch/hlo.py),
  * MODEL_FLOPS = 6·N_active·D (or 2· for inference) and useful-flops ratio,
  * lower/compile wall times.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out experiments/dryrun
"""
import argparse
import functools
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import LM_SHAPES
from repro.launch import specs as specs_mod
from repro.launch.hlo import HW, analyze_module, roofline_terms
from repro.launch.mesh import make_production_mesh, param_pspecs, sharding_rules
from repro.launch.steps import (
    make_decode_step, make_prefill_step, make_train_step, optimizer_pspecs,
)
from repro.models import lm, registry
from repro.nn import module as nnmod
from repro.nn.pcontext import logical_sharding
from repro.optim.adamw import AdamWConfig, adamw_init

__all__ = ["lower_cell", "run_cell", "main"]


def _sh(mesh, pspec_tree):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               mesh=None, smoke: bool = False, accum: Optional[int] = None,
               odin_mode: Optional[str] = None, remat: Optional[str] = None,
               kv_dtype: Optional[str] = None,
               rules: Optional[Dict] = None, donate: bool = True):
    """Lower one cell.  Returns (lowered, meta dict)."""
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    info = specs_mod.input_specs(arch, shape_name, smoke=smoke, accum=accum,
                                 kv_dtype=kv_dtype)
    cfg, shape = info["cfg"], info["shape"]
    if odin_mode is not None:
        cfg = cfg.with_overrides(odin_mode=odin_mode)
    if remat is not None:
        cfg = cfg.with_overrides(remat=remat)
    meta_kv = cfg.kv_dtype
    kind = info["kind"]
    rules = rules if rules is not None else sharding_rules(mesh, kind)

    spec_tree = lm.param_spec(cfg)
    aparams = nnmod.abstract(spec_tree)
    p_ps = param_pspecs(spec_tree, rules, mesh)
    param_sh = _sh(mesh, p_ps)
    n_params = nnmod.count_params(spec_tree)

    meta = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": dict(mesh.shape), "accum": info["accum"], "params": n_params,
        "smoke": smoke, "odin_mode": cfg.odin_mode, "remat": cfg.remat,
        "kv_dtype": cfg.kv_dtype,
    }

    with mesh, logical_sharding(mesh, rules):
        if kind == "train":
            opt_cfg = AdamWConfig()
            aopt = jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg), aparams)
            opt_ps = optimizer_pspecs(p_ps, opt_cfg)
            opt_sh = _sh(mesh, opt_ps)
            batch_sh = _sh(mesh, specs_mod.batch_pspecs(cfg, shape, mesh, info["accum"]))
            acc_dt = jnp.dtype(specs_mod.DRYRUN_ACCUM_DTYPE.get(arch, "float32")) \
                if not smoke else jnp.float32
            step = make_train_step(cfg, opt_cfg, accum=info["accum"],
                                   grad_shardings=param_sh, accum_dtype=acc_dt)
            meta["accum_dtype"] = str(acc_dt)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(aparams, aopt, info["batch"])
            tokens = shape.global_batch * shape.seq_len
            meta["model_flops"] = lm.model_flops(cfg, tokens, train=True)
        elif kind == "prefill":
            batch_sh = _sh(mesh, specs_mod.batch_pspecs(cfg, shape, mesh, 1))
            step = make_prefill_step(cfg, max_len=shape.seq_len)
            caches_tpl = specs_mod.abstract_caches(cfg, shape.global_batch, shape.seq_len)
            cache_sh = _sh(mesh, specs_mod.cache_pspecs(cfg, caches_tpl, mesh))
            fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
            b_ax = fsdp if shape.global_batch % _ax(mesh, fsdp) == 0 else None
            v_ax = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
            # last-position logits: [B, V] or [B, K, V] for multi-codebook
            logits_ps = (P(b_ax, None, v_ax) if cfg.n_codebooks > 1
                         else P(b_ax, v_ax))
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, batch_sh),
                out_shardings=(NamedSharding(mesh, logits_ps), cache_sh),
            )
            lowered = jitted.lower(aparams, info["batch"])
            tokens = shape.global_batch * shape.seq_len
            meta["model_flops"] = lm.model_flops(cfg, tokens, train=False)
        else:  # decode
            caches_tpl = info["caches"]
            cache_sh = _sh(mesh, specs_mod.cache_pspecs(cfg, caches_tpl, mesh))
            fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
            B = shape.global_batch
            tok_ps = P(fsdp if B % _ax(mesh, fsdp) == 0 else None,
                       *([None] * (len(info["tokens"].shape) - 1)))
            tok_sh = NamedSharding(mesh, tok_ps)
            step = make_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, cache_sh, tok_sh),
                out_shardings=(tok_sh, cache_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(aparams, caches_tpl, info["tokens"])
            meta["model_flops"] = lm.model_flops(cfg, B, train=False)
    return lowered, meta


def _ax(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False, mesh=None,
             smoke: bool = False, accum: Optional[int] = None,
             odin_mode: Optional[str] = None, remat: Optional[str] = None,
             kv_dtype: Optional[str] = None,
             rules: Optional[Dict] = None, hw: HW = HW()) -> Dict:
    """Lower + compile + analyze one cell; returns the JSON-able record."""
    t0 = time.time()
    try:
        lowered, meta = lower_cell(
            arch, shape_name, multi_pod=multi_pod, mesh=mesh, smoke=smoke,
            accum=accum, odin_mode=odin_mode, remat=remat, kv_dtype=kv_dtype,
            rules=rules,
        )
    except Exception as e:  # a lowering failure is a bug — record it loudly
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "LOWER_FAILED", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}
    t1 = time.time()
    try:
        compiled = lowered.compile()
    except Exception as e:
        return {**meta, "multi_pod": multi_pod, "status": "COMPILE_FAILED",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}
    t2 = time.time()

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "code_bytes": ma.generated_code_size_in_bytes,
    }
    mem["total_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                          + mem["temp_bytes"] - mem["alias_bytes"])
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # jax < 0.5 returns one dict per computation
        ca = ca[0] if ca else {}
    cost = {"xla_flops_once": float(ca.get("flops", -1.0)),
            "xla_bytes_once": float(ca.get("bytes accessed", -1.0))}

    # trip-count-aware structural analysis (launch/hlo.py) — XLA's own
    # cost_analysis counts while bodies once, useless under scan-over-layers.
    costs = analyze_module(compiled.as_text())
    cost.update({"flops": costs.flops, "bytes_accessed": costs.memory_bytes,
                 "n_whiles": costs.n_whiles,
                 "n_unknown_trip": costs.n_unknown_trip})
    coll = dict(costs.collectives)
    coll["total"] = costs.collective_total
    coll["wire_total"] = costs.collective_wire

    n_dev = int(jax.tree.reduce(lambda a, b: a * b, list(meta["mesh"].values()), 1))
    # analyzer numbers are per-partition (post-SPMD) ⇒ per-chip roofline;
    # collective term uses ring-model wire bytes (all-reduce ≈ 2× payload).
    terms = roofline_terms(costs.flops, costs.memory_bytes, costs.collective_wire, hw)
    model_flops_per_dev = meta["model_flops"] / n_dev
    terms["useful_flops_ratio"] = (
        model_flops_per_dev / costs.flops if costs.flops > 0 else -1.0
    )
    terms["mfu_upper_bound"] = (
        model_flops_per_dev / hw.peak_flops / terms["step_time_lb_s"]
        if terms["step_time_lb_s"] > 0 else -1.0
    )

    rec = {**meta, "multi_pod": multi_pod, "status": "OK",
           "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
           "n_devices": n_dev, "memory": mem, "cost": cost,
           "collectives": coll, "roofline": terms,
           "fits_hbm": mem["total_bytes"] <= hw.hbm_bytes}
    return rec


def cell_id(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=registry.ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(LM_SHAPES) + [None])
    ap.add_argument("--all", action="store_true", help="every runnable cell")
    ap.add_argument("--multi-pod", dest="mp", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args()

    if args.all:
        cells = [(a, s.name) for a, s in registry.cells()]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        reason = registry.skip_reason(args.arch, args.shape)
        if reason:
            print(f"SKIP {args.arch} × {args.shape}: {reason}")
            return
        cells = [(args.arch, args.shape)]

    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.mp]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in pods:
            cid = cell_id(arch, shape, mp)
            path = os.path.join(args.out, cid + ".json")
            if os.path.exists(path) and not args.force:
                print(f"cached  {cid}")
                continue
            rec = run_cell(arch, shape, multi_pod=mp, smoke=args.smoke)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            ok = rec["status"] == "OK"
            failures += 0 if ok else 1
            if ok:
                r = rec["roofline"]
                print(f"{rec['status']:4} {cid}: compile {rec['compile_s']}s  "
                      f"mem {rec['memory']['total_bytes']/1e9:.2f} GB/dev "
                      f"(fits={rec['fits_hbm']})  bottleneck={r['bottleneck']} "
                      f"[c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
                      f"x={r['collective_s']:.2e}]s")
            else:
                print(f"FAIL {cid}: {rec['error']}")
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
