"""End-to-end training driver with checkpoint-restart fault tolerance.

Runnable on this CPU container for smoke/small configs and the ~100M example
model; the same code path jits with the production mesh shardings when real
devices are present.

Fault-tolerance features (DESIGN.md §4):
  * ``--resume``: picks up the latest complete checkpoint (atomic saves —
    a crash mid-save never corrupts the run) and replays the *exact* data
    stream (stateless step-indexed pipeline).
  * watchdog: if a step exceeds ``--step-deadline`` seconds the driver
    checkpoints-and-exits with code 75 (temp failure) so a supervisor
    (launch/supervise.py or any cluster agent) relaunches it — straggler
    mitigation by restart, the standard large-fleet policy.
  * ``--max-wall``: graceful preemption — checkpoint and exit 75.
  * ``--simulate-crash-at``: kills the process *without* checkpointing at a
    given step (tests/failure injection).
  * ``--grad-compress``: pure-DP mode routes gradients through the int8
    stochastic-rounded compressed all-reduce (optim/compress.py).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1 --resume
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ShapeConfig
from repro.data import synthetic
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_mesh, param_pspecs, sharding_rules
from repro.launch.steps import make_dp_train_step, make_train_step, optimizer_pspecs
from repro.models import lm, registry
from repro.nn import module as nnmod
from repro.optim.adamw import AdamWConfig, adamw_init

__all__ = ["main", "train_loop"]


def build_state(cfg, key, opt_cfg):
    spec = lm.param_spec(cfg)
    params = nnmod.materialize(spec, key)
    opt = adamw_init(params, opt_cfg)
    return {"params": params, "opt": opt, "data_step": jnp.zeros((), jnp.int32)}


def train_loop(cfg, *, steps: int, batch: int, seq: int, ckpt_dir: str,
               resume: bool = False, accum: int = 1, seed: int = 0,
               save_every: int = 20, keep: int = 3,
               opt_cfg: AdamWConfig = AdamWConfig(moment_dtype="float32"),
               grad_compress: bool = False, mesh=None,
               step_deadline: float = 0.0, max_wall: float = 0.0,
               simulate_crash_at: int = -1, log_every: int = 10,
               base_lr: float = 3e-4, warmup: int = 0):
    """Returns (final_state, losses).  Exits 75 on watchdog/preemption."""
    key = jax.random.PRNGKey(seed)
    state = build_state(cfg, key, opt_cfg)
    start_step = 0
    if resume:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            tpl = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
            state, start_step = ckpt.restore(ckpt_dir, last, tpl)
            print(f"[train] resumed from step {start_step}")

    shape = ShapeConfig("train", seq, batch, "train")
    warmup = warmup or max(5, steps // 10)
    if grad_compress:
        assert mesh is not None, "--grad-compress needs a device mesh"
        step_fn = jax.jit(make_dp_train_step(cfg, mesh, opt_cfg, base_lr=base_lr,
                                             compress=True))
    else:
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, accum=accum, base_lr=base_lr,
                                          warmup=warmup))

    losses = []
    t_start = time.time()
    step = start_step
    for step in range(start_step, steps):
        data_step = int(state["data_step"])
        b = specs_mod.concrete_batch(cfg, shape, seed, data_step, accum=accum)
        t0 = time.time()
        if grad_compress:
            k = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
            p, o, metrics = step_fn(state["params"], state["opt"], b, k)
        else:
            p, o, metrics = step_fn(state["params"], state["opt"], b)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        state = {"params": p, "opt": o,
                 "data_step": state["data_step"] + 1}
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d}  loss {loss:.4f}  {dt*1e3:.0f} ms")

        if simulate_crash_at == step:
            print("[train] simulated crash (no checkpoint)!", flush=True)
            os._exit(137)

        deadline_hit = step_deadline and dt > step_deadline
        wall_hit = max_wall and (time.time() - t_start) > max_wall
        if (step + 1) % save_every == 0 or step == steps - 1 or deadline_hit or wall_hit:
            ckpt.save(ckpt_dir, step + 1, state, keep=keep)
        if deadline_hit:
            print(f"[train] watchdog: step took {dt:.1f}s > {step_deadline}s — "
                  "checkpointed, exiting 75 for relaunch", flush=True)
            sys.exit(75)
        if wall_hit:
            print("[train] wall-clock preemption — checkpointed, exiting 75", flush=True)
            sys.exit(75)
    return state, losses


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--mesh", default="", help="e.g. 4x2 (axes data,model)")
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--step-deadline", type=float, default=0.0)
    ap.add_argument("--max-wall", type=float, default=0.0)
    ap.add_argument("--simulate-crash-at", type=int, default=-1)
    args = ap.parse_args()

    cfg = registry.get_smoke(args.arch) if args.smoke else registry.get_config(args.arch)
    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model")[: len(dims)] if len(dims) <= 2 else ("pod", "data", "model")
        mesh = make_mesh(dims, names)
    opt_cfg = AdamWConfig(moment_dtype="int8" if args.int8_moments else "float32")
    train_loop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
               ckpt_dir=args.ckpt_dir, resume=args.resume, accum=args.accum,
               seed=args.seed, save_every=args.save_every, opt_cfg=opt_cfg,
               grad_compress=args.grad_compress, mesh=mesh,
               step_deadline=args.step_deadline, max_wall=args.max_wall,
               simulate_crash_at=args.simulate_crash_at, base_lr=args.lr)


if __name__ == "__main__":
    main()
