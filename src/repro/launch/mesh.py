"""Production mesh + sharding rule tables (DESIGN.md §4).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).

Rule tables map *logical* axes (nn/module.py ParamSpec) → mesh axes:

* train/prefill — TP on ``model`` (heads/mlp/experts/vocab), FSDP on ``data``
  (+``pod`` when present) for the embed dimension; batch on data(+pod).
* decode — same parameter layout (weights stay sharded; GSPMD inserts the
  per-layer gathers we analyze in §Roofline); KV caches shard batch on
  data(+pod) and sequence on ``model``(flash-decode style).

1-D params (norm gains, biases) are always replicated — sub-kilobyte, and
uneven shardings of tiny vectors buy nothing.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.module import ParamSpec, logical_to_pspec

__all__ = [
    "make_production_mesh",
    "sharding_rules",
    "param_pspecs",
    "param_shardings",
    "batch_axes",
]


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — the dry-run "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import (launch/dryrun.py does)."
        )
    if hasattr(jax.sharding, "AxisType"):   # jax ≥ 0.5
        auto = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=auto, devices=devs[:n])
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests, elastic re-shard targets)."""
    return _mk(shape, axes)


def batch_axes(mesh: Mesh):
    """Mesh axes the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def sharding_rules(mesh: Mesh, kind: str = "train", **overrides) -> Dict[str, object]:
    """Logical-axis → mesh-axis table for ``kind`` ∈ {train, prefill, decode}.

    ``act_seq`` governs the *layer-boundary activation carry* (models/lm.py):
    sharding it on ``model`` is Megatron-style sequence parallelism — the
    remat-saved [B, S, d] per layer drops 16×, at the price of per-layer
    gather/scatter collectives.  Default on for train/prefill (required to
    fit the 405B/671B train cells in 16 GB); the §Perf baseline measures the
    unsharded variant via ``overrides``.
    """
    multi = "pod" in mesh.axis_names
    fsdp = ("pod", "data") if multi else ("data",)
    rules: Dict[str, object] = {
        "batch": fsdp,
        "embed": fsdp,            # FSDP: weight rows sharded over data(+pod)
        "embed2": None,
        "heads_flat": "model",    # TP: flattened H·D (divisible by 16 everywhere)
        "mlp": "model",
        "experts": "model",       # EP: routed experts over model
        "vocab": "model",
        "layers": None,           # scanned axis — never sharded
        "seq": None,
        "act_seq": "model" if kind in ("train", "prefill") else None,
        "kv_seq": "model",        # decode caches: sequence-sharded (flash-decode)
        "capacity": fsdp,         # MoE dispatch buffer token axis
    }
    rules.update(overrides)
    return rules


def param_pspecs(spec_tree, rules: Dict[str, object], mesh: Optional[Mesh] = None):
    """ParamSpec tree → PartitionSpec tree; 1-D params replicated.

    With ``mesh`` given, any dim not divisible by its assigned mesh axes is
    left unsharded (e.g. hymba's vocab 32001 — prime-ish table sizes exist
    in the wild and must not crash the launcher).
    """

    def one(s: ParamSpec):
        if len(s.shape) <= 1:
            return P()
        spec = logical_to_pspec(s.logical_axes, rules)
        if mesh is None:
            return spec
        entries = list(spec) + [None] * (len(s.shape) - len(spec))
        out = []
        for dim, ax in zip(s.shape, entries):
            axes = (ax,) if isinstance(ax, str) else (ax or ())
            size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
            out.append(ax if size and dim % size == 0 else None)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    return jax.tree.map(one, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_shardings(spec_tree, mesh: Mesh, rules: Dict[str, object]):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        param_pspecs(spec_tree, rules),
        is_leaf=lambda x: isinstance(x, P),
    )
