"""Benchmark aggregator — one section per paper table/figure + beyond-paper.

  table1  — PIMC command latencies (paper Table 1, exact)
  table2  — topology memory/read/write counts (paper Table 2)
  table3  — add-on logic overhead roll-up (paper Table 3)
  fig6    — ODIN vs CPU/ISAAC time+energy, dual energy accounting (Fig. 6)
  odin_lm — the ODIN cost model on the 10 assigned LM archs (beyond paper)
  kernels — Pallas kernel microbench + structural TPU model
  roofline— per-cell roofline terms from the cached dry-run artifacts
  serving — continuous-batching engine vs static loop + PIMC attribution
"""
import functools
import sys
import traceback

from benchmarks import (fig6_comparison, kernel_bench, odin_lm_cost, roofline,
                        serving_bench, table1_commands, table2_topologies,
                        table3_overheads)

SECTIONS = [
    ("table1", table1_commands.run),
    ("table2", table2_topologies.run),
    ("table3", table3_overheads.run),
    ("fig6", fig6_comparison.run),
    ("odin_lm", odin_lm_cost.run),
    ("kernels", kernel_bench.run),
    ("roofline", roofline.run),
    ("serving", functools.partial(serving_bench.run, n_requests=8, slots_sweep=(2,))),
]


def main() -> None:
    failures = []
    for name, fn in SECTIONS:
        print(f"\n{'='*72}\n== {name}\n{'='*72}")
        try:
            fn(verbose=True)
        except Exception:  # report all sections even if one breaks
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED sections: {failures}")
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == '__main__':
    main()
