"""Serving benchmark: paged block-store engine vs dense-cache engine vs the
static-batch loop, plus the horizon-batched decode sweep.

Sweeps arrival rate × batch slots over a mixed-length request stream and
reports decode throughput, TTFT/TPOT percentiles, slot occupancy, peak device
KV bytes, and the per-request ODIN PIMC energy bill.  Three configurations
per cell, all token-for-token identical (greedy + deterministic schedule):

* ``static``       — the seed's static-batch loop (pad to the longest prompt,
                     decode until the longest generation finishes);
* ``dense engine`` — PR-1 continuous batching over dense ``[slots, max_len]``
                     live caches (``paged=False``);
* ``paged engine`` — the block pool as the physical KV store (Pallas paged
                     decode kernel), at the full block budget AND at a tight
                     pool (≈ half the dense-equivalent rows) that shows the
                     memory win the paged store exists for.

The **horizon sweep** then runs the paged engine at ``horizon ∈ {1, 4, 16}``
on the same mixed stream: each engine does one warmup pass (compiling every
granted power-of-two executable) and one measured pass, reporting
steady-state decode tok/s and tokens-per-dispatch.  ``--check-horizon``
gates on ``H=16`` decode throughput ≥ 1.5× ``H=1`` with bit-identical greedy
token streams.

The **prefix-sharing cell** serves a shared-system-prompt stream (96 shared
tokens + unique tails) with refcounted block dedup on vs off.
``--check-prefix`` gates on token-identical outputs AND ≥ 1.5× logical
prefill throughput (prompt tokens per prefill second — sharing skips the
resident rows) or ≥ 1.5× lower steady-state pool occupancy (mean distinct
blocks referenced by running tables).

The **speculation cell** sweeps n-gram self-speculative decode
(``spec_ngram`` K ∈ {0, 2, 4}) over a repetition-heavy stream (periodic
prompts, long generations — greedy continuations cycle, so prompt-lookup
drafts verify deep; horizon 4) and the standard mixed stream (horizon 1 —
the per-token dispatch baseline, where each accepted draft saves a whole
dispatch).  Every K compares against the same-horizon K=0 baseline.
``--check-spec`` gates on greedy spec-on streams bit-identical to the K=0
baseline AND ≥ 1.8× decode tok/s at K=4 on the repetitive scenario
(≥ 1.2× at the best K on mixed), with ``accept_rate`` reported per cell.

The **tracing cell** measures the structured tracer's overhead (paged engine
on the mixed stream, trace-off vs trace-on, best-of-3) and checks the trace
artifact's integrity: Perfetto-loadable Chrome trace JSON whose per-dispatch
``odin_energy_mj`` args sum to the run's ``odin_total`` within 1%.
``--trace-out`` writes the artifact; ``--check-trace`` gates on schema
validity, energy-sum agreement, and trace-on ≥ 0.98× trace-off decode tok/s.

The **robustness cell** measures the failure-semantics machinery two ways:
guards-off vs guards-on decode throughput (deadline watch + NaN logit guard
+ degradation observer on the mixed stream, bit-identical greedy streams
required) and a seeded chaos sweep — the flaky scenario against a tight
pool under generated ``FaultPlan``s with degradation live, requiring zero
crashes and exact terminal-state conservation.  ``--check-robust`` gates on
≥ 0.98× guards-on throughput, stream identity, a clean sweep, and the
degradation ladder actually engaging.

The **front-door cell** drives the same mixed stream through the asyncio
streaming front door (``repro.serving.frontdoor``) — every token crossing
an ``asyncio.Queue`` into a per-request consumer task — and compares decode
throughput and token streams against the bare synchronous engine, plus a
burst-storm sub-check (``max_queue=1``) asserting every admission rejection
is typed and carries a ``retry_after`` hint.  ``--check-frontdoor`` gates
on event-stream tokens bit-identical to the bare engine AND front-door-on
decode throughput ≥ 0.95× bare AND fully-typed storm rejections.

The **mixed-dispatch cell** replays the bursty scenario through the fused
mixed prefill+decode dispatch (token-budget packed tiles) vs the alternating
separate-launch baseline, warmup + best-of-3 per mode.  ``--check-mixed``
gates on bit-identical greedy streams AND burst p99 TPOT ≤ 0.6× the
alternating baseline — the fused tile must keep decode emitting through
admission bursts.

The **reliability cell** measures the PCRAM reliability layer three ways:
wear-leveled allocation (min-wear free-list order) vs the seed LIFO order
over repeated passes against a constrained pool — the per-block wear Gini
must *narrow* under wear leveling with bit-identical greedy streams; the
drift-refresh scrubber on vs off (decode tok/s ratio, streams bit-identical
— scrub copies identical bytes between dispatches); and a
``wear_exhaustion`` retirement storm against a tight pool with degradation
live — every request must land in exactly one terminal state (capacity
failures typed, never a livelock) with the ladder engaging before pool
exhaustion.  ``--check-reliability`` gates on all three.

Results merge into ``BENCH_serving.json`` (section "serving") next to the
kernel microbench so the perf trajectory is machine-readable across PRs.

  PYTHONPATH=src python benchmarks/serving_bench.py --bench-json BENCH_serving.json
"""
import argparse
import asyncio
import json

import numpy as np

try:
    from benchmarks.bench_io import DEFAULT_BENCH_JSON, update_bench_json
except ImportError:                      # run as a script: benchmarks/ on path
    from bench_io import DEFAULT_BENCH_JSON, update_bench_json

from repro.launch.serve import serve_static
from repro.models import registry
from repro.serving import (OdinCostModel, Request, ServingEngine, Tracer,
                           WorkloadSpec, make_requests, validate_chrome_trace)


def _mixed_spec(n_requests: int) -> WorkloadSpec:
    return WorkloadSpec(n_requests=n_requests, rate=1e9,
                        prompt_buckets=(16, 32), gen_buckets=(4, 16, 48),
                        gen_weights=(0.4, 0.35, 0.25))


def static_baseline(cfg, requests, slots: int, params=None, seed: int = 0):
    """Run the request stream with the static-batch loop.

    Useful tokens = what each request actually asked for; the loop still
    decodes max(gen) steps per batch, so utilization drops as length mix
    widens.  Returns (useful_tokens_per_s, decode_time_s).
    """
    useful = sum(r.max_new for r in requests)
    t_decode = 0.0
    for i in range(0, len(requests), slots):
        group = requests[i:i + slots]
        prompt_len = max(r.prompt_len for r in group)
        gen = max(r.max_new for r in group)
        _, tps = serve_static(cfg, batch=len(group), prompt_len=prompt_len,
                              gen=gen, seed=seed, params=params, verbose=False)
        t_decode += len(group) * gen / tps
    return useful / max(t_decode, 1e-9), t_decode


def engine_run(cfg, requests, slots: int, rate: float, params=None,
               attribution_cfg=None, paged: bool = True, n_blocks=None,
               block_size: int = 16):
    spec_max = max(r.prompt_len + r.max_new for r in requests)
    max_len = -(-spec_max // block_size) * block_size
    engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                           block_size=block_size, params=params,
                           attribution_cfg=attribution_cfg, paged=paged,
                           n_blocks=n_blocks)
    # re-stamp arrivals for the requested rate (virtual → wall seconds)
    rng = np.random.default_rng(7)
    gaps = rng.exponential(1.0 / rate, len(requests)) if np.isfinite(rate) else np.zeros(len(requests))
    arrivals = np.cumsum(gaps)
    reqs = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                    arrival=float(a)) for r, a in zip(requests, arrivals)]
    summary = engine.run(reqs)
    toks = tuple(tuple(tuple(np.asarray(t).ravel().tolist()) for t in r.generated)
                 for r in sorted(reqs, key=lambda r: r.rid))
    return summary, toks


def horizon_sweep(cfg, base_requests, slots: int, params=None,
                  horizons=(1, 4, 16), block_size: int = 16,
                  verbose: bool = True):
    """Paged engine at each horizon: warmup pass + measured pass.

    The warmup pass compiles every horizon executable the schedule grants;
    the measured pass re-runs the identical stream (all-arrived, greedy,
    deterministic) and reads steady-state throughput off the stats deltas.
    Greedy streams must be bit-identical across horizons.
    """
    if not horizons or horizons[0] != 1:
        raise SystemExit(
            f"--horizons must start with 1 (the parity/speedup baseline), "
            f"got {list(horizons)}")
    spec_max = max(r.prompt_len + r.max_new for r in base_requests)
    max_len = -(-spec_max // block_size) * block_size

    def fresh(rid0):
        return [Request(rid=rid0 + r.rid, prompt=r.prompt, max_new=r.max_new,
                        arrival=0.0) for r in base_requests]

    cells, streams = [], []
    for H in horizons:
        engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                               block_size=block_size, params=params,
                               paged=True, horizon=H)
        engine.run(fresh(0))                       # warmup: compile all grants
        st = engine.stats
        toks0, time0 = st.decode_tokens, st.decode_time
        disp0, sync0, steps0 = st.decode_dispatches, st.host_syncs, st.decode_steps
        reqs = fresh(10_000)
        engine.run(reqs)
        d_toks = st.decode_tokens - toks0
        cell = {
            "horizon": H,
            "tokens_per_s": d_toks / max(st.decode_time - time0, 1e-9),
            "tokens_per_dispatch": d_toks / max(st.decode_dispatches - disp0, 1),
            "decode_dispatches": st.decode_dispatches - disp0,
            "host_syncs": st.host_syncs - sync0,
            "decode_steps": st.decode_steps - steps0,
            "decode_tokens": d_toks,
        }
        cells.append(cell)
        streams.append(tuple(
            tuple(tuple(np.asarray(t).ravel().tolist()) for t in r.generated)
            for r in sorted(reqs, key=lambda r: r.rid)))
        if verbose:
            print(f"horizon H={H:2d}: {cell['tokens_per_s']:8.1f} tok/s  "
                  f"{cell['tokens_per_dispatch']:6.2f} tok/dispatch  "
                  f"{cell['decode_dispatches']:4d} dispatches")
    base_tps = cells[0]["tokens_per_s"]
    out = {
        "slots": slots,
        "cells": cells,
        "tokens_match": bool(all(s == streams[0] for s in streams)),
        "speedup_vs_h1": {c["horizon"]: c["tokens_per_s"] / max(base_tps, 1e-9)
                          for c in cells},
    }
    if verbose:
        best = max(out["speedup_vs_h1"].values())
        print(f"horizon sweep: best {best:.2f}× decode tok/s vs H=1, "
              f"tokens_match={out['tokens_match']}")
    return out


def prefix_cell(cfg, slots: int, params=None, n_requests: int = 12,
                shared_prefix: int = 96, block_size: int = 16,
                verbose: bool = True):
    """Shared-system-prompt stream with prefix sharing on vs off.

    Both engines serve the identical all-arrived stream (greedy,
    deterministic); sharing must be invisible in the tokens and visible in
    the prefill clock and the pool occupancy.
    """
    spec = WorkloadSpec(n_requests=n_requests, rate=1e9,
                        shared_prefix=shared_prefix,
                        prompt_buckets=(16, 32), gen_buckets=(8, 16))
    base_requests = make_requests(cfg, spec, seed=13)
    spec_max = max(r.prompt_len + r.max_new for r in base_requests)
    max_len = -(-spec_max // block_size) * block_size
    logical_prompt_tokens = sum(r.prompt_len for r in base_requests)

    def fresh(rid0):
        return [Request(rid=rid0 + r.rid, prompt=r.prompt, max_new=r.max_new,
                        arrival=0.0) for r in base_requests]

    def one(sharing: bool):
        """Two warmup passes (the first compiles the cold-cache chunk
        lengths and seeds the resident chains; the second compiles the
        steady-state *tail* lengths those chains produce), then a measured
        pass read off the stats deltas — the horizon sweep's protocol."""
        engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                               block_size=block_size, params=params,
                               paged=True, prefix_sharing=sharing)
        engine.run(fresh(0))
        engine.run(fresh(10_000))
        st = engine.stats
        t0, n0 = st.prefill_time, st.prefill_tokens
        hit0, fork0 = st.prefix_hit_tokens, st.cow_forks
        sb0 = st.shared_prefix_blocks
        tb0, ps0 = st.table_block_steps, st.pool_steps
        reqs = fresh(20_000)
        engine.run(reqs)
        toks = tuple(tuple(tuple(np.asarray(t).ravel().tolist())
                           for t in r.generated)
                     for r in sorted(reqs, key=lambda r: r.rid))
        return {
            "prefill_time_s": st.prefill_time - t0,
            "prefill_tokens": st.prefill_tokens - n0,
            "prefix_hit_tokens": st.prefix_hit_tokens - hit0,
            "cow_forks": st.cow_forks - fork0,
            "shared_blocks": st.shared_prefix_blocks - sb0,
            "mean_referenced_blocks": ((st.table_block_steps - tb0)
                                       / max(1, st.pool_steps - ps0)),
        }, toks

    base, base_toks = one(False)
    shared, shared_toks = one(True)
    prefill_tps = lambda s: logical_prompt_tokens / max(s["prefill_time_s"], 1e-9)
    cell = {
        "slots": slots,
        "n_requests": n_requests,
        "shared_prefix_tokens": shared_prefix,
        "tokens_match": bool(base_toks == shared_toks),
        "prefill_tokens_computed": {"baseline": base["prefill_tokens"],
                                    "shared": shared["prefill_tokens"]},
        "prefix_hit_tokens": shared["prefix_hit_tokens"],
        "shared_blocks": shared["shared_blocks"],
        "cow_forks": shared["cow_forks"],
        "prefill_tokens_per_s": {"baseline": prefill_tps(base),
                                 "shared": prefill_tps(shared)},
        "prefill_speedup": prefill_tps(shared) / max(prefill_tps(base), 1e-9),
        "mean_referenced_blocks": {
            "baseline": base["mean_referenced_blocks"],
            "shared": shared["mean_referenced_blocks"]},
        "occupancy_ratio": (base["mean_referenced_blocks"]
                            / max(shared["mean_referenced_blocks"], 1e-9)),
    }
    if verbose:
        print(f"prefix sharing: prefill {prefill_tps(base):8.1f} → "
              f"{prefill_tps(shared):8.1f} tok/s ({cell['prefill_speedup']:.2f}×)  "
              f"pool occupancy {cell['mean_referenced_blocks']['baseline']:.1f} → "
              f"{cell['mean_referenced_blocks']['shared']:.1f} blocks "
              f"({cell['occupancy_ratio']:.2f}× less)  "
              f"hits {cell['prefix_hit_tokens']} tok, forks {cell['cow_forks']}, "
              f"tokens_match={cell['tokens_match']}")
    return cell


def speculation_cell(cfg, slots: int, params=None, ks=(0, 2, 4),
                     block_size: int = 16,
                     n_requests: int = 6, repeats: int = 3,
                     verbose: bool = True):
    """n-gram self-speculative decode sweep: K ∈ ``ks`` on a repetition-heavy
    stream and the standard mixed stream, each at a fixed per-scenario
    horizon (every K compares against the SAME-horizon K=0 baseline).

    The repetitive scenario runs at horizon 4 — speculation composed with
    the fused scan, the deployment shape for repetition-heavy traffic.  The
    mixed scenario runs at horizon 1, isolating the speculation win at the
    per-token dispatch baseline: every accepted draft saves a whole
    dispatch, which is the regime where low-accept traffic still profits
    (at deep horizons the scan has already amortized dispatch overhead, so
    smoke-scale mixed streams show little extra headroom — an honest
    property of the workload, recorded here rather than hidden).

    K=0 is the plain horizon scan; K>0 adds draft→verify→accept inner
    steps.  Greedy streams must be bit-identical across K per scenario —
    speculation may only change *when* tokens arrive, never which.
    Protocol per engine: one warmup pass (compiles every granted (h, K)
    executable, settles the jit cache), then ``repeats`` measured passes
    read off the stats deltas, keeping the fastest per K (the measured
    windows are fractions of a second at smoke scale, so best-of-R filters
    scheduler/GC hiccups; accept counts are schedule-deterministic and
    identical across passes).
    """
    if not ks or ks[0] != 0:
        raise SystemExit(
            f"--spec-ks must start with 0 (the no-speculation baseline), "
            f"got {list(ks)}")
    streams = {
        "repetitive": (4, WorkloadSpec(n_requests=n_requests, rate=1e9,
                                       pattern_period=8, prompt_buckets=(32,),
                                       gen_buckets=(160,))),
        "mixed": (1, _mixed_spec(max(2 * n_requests, 16))),
    }
    out = {"slots": slots, "scenarios": {}}
    for name, (horizon, wspec) in streams.items():
        base_requests = make_requests(cfg, wspec, seed=13)
        spec_max = max(r.prompt_len + r.max_new for r in base_requests)
        max_len = -(-spec_max // block_size) * block_size

        def fresh(rid0):
            return [Request(rid=rid0 + r.rid, prompt=r.prompt,
                            max_new=r.max_new, arrival=0.0)
                    for r in base_requests]

        cells, streams_seen = [], []
        for K in ks:
            engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                                   block_size=block_size, params=params,
                                   paged=True, horizon=horizon, spec_ngram=K)
            engine.run(fresh(0))                   # warmup: compile grants
            st = engine.stats
            best = None
            for rep in range(max(1, repeats)):
                toks0, time0 = st.decode_tokens, st.decode_time
                disp0 = st.decode_dispatches
                drafted0, accepted0 = st.spec_drafted, st.spec_accepted
                reqs = fresh(10_000 * (rep + 1))
                engine.run(reqs)
                d_toks = st.decode_tokens - toks0
                d_drafted = st.spec_drafted - drafted0
                cell = {
                    "spec_ngram": K,
                    "tokens_per_s": d_toks / max(st.decode_time - time0, 1e-9),
                    "tokens_per_dispatch": d_toks / max(st.decode_dispatches - disp0, 1),
                    "drafted": d_drafted,
                    "accepted": st.spec_accepted - accepted0,
                    "accept_rate": (st.spec_accepted - accepted0) / max(1, d_drafted),
                    "decode_tokens": d_toks,
                }
                if best is None or cell["tokens_per_s"] > best["tokens_per_s"]:
                    best = cell
                streams_seen.append(tuple(
                    tuple(tuple(np.asarray(t).ravel().tolist()) for t in r.generated)
                    for r in sorted(reqs, key=lambda r: r.rid)))
            cells.append(best)
            if verbose:
                print(f"spec {name:>10} K={K}: {best['tokens_per_s']:8.1f} tok/s  "
                      f"{best['tokens_per_dispatch']:6.2f} tok/dispatch  "
                      f"accept_rate {best['accept_rate']:.2f}")
        base_tps = cells[0]["tokens_per_s"]
        out["scenarios"][name] = {
            "horizon": horizon,
            "cells": cells,
            "tokens_match": bool(all(t == streams_seen[0] for t in streams_seen)),
            "speedup_vs_k0": {c["spec_ngram"]: c["tokens_per_s"] / max(base_tps, 1e-9)
                              for c in cells},
        }
        if verbose:
            sc = out["scenarios"][name]
            print(f"spec {name}: best {max(sc['speedup_vs_k0'].values()):.2f}× "
                  f"vs K=0, tokens_match={sc['tokens_match']}")
    return out


def tracing_cell(cfg, base_requests, slots: int, params=None,
                 block_size: int = 16, repeats: int = 3,
                 trace_out=None, verbose: bool = True):
    """Observability cell: tracing overhead + trace-artifact integrity.

    Overhead: paged engine on the mixed stream, trace-off vs trace-on, each
    with one warmup pass then ``repeats`` measured passes read off the stats
    deltas (best-of-R, the horizon sweep's protocol); reports the decode
    tok/s ratio.  Integrity: a dedicated single-run traced engine (so events
    and stats cover the same window) must produce a schema-valid Chrome
    trace whose per-dispatch ``odin_energy_mj`` args sum to the summary's
    ``odin_total`` within 1%; that trace is the artifact ``trace_out`` (and
    CI's Perfetto-schema validator input).
    """
    spec_max = max(r.prompt_len + r.max_new for r in base_requests)
    max_len = -(-spec_max // block_size) * block_size

    def fresh(rid0):
        return [Request(rid=rid0 + r.rid, prompt=r.prompt, max_new=r.max_new,
                        arrival=0.0) for r in base_requests]

    def best_tps(tracer):
        engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                               block_size=block_size, params=params,
                               paged=True, horizon=4, tracer=tracer)
        engine.run(fresh(0))                       # warmup: compile grants
        st = engine.stats
        best = 0.0
        for rep in range(max(1, repeats)):
            toks0, time0 = st.decode_tokens, st.decode_time
            engine.run(fresh(10_000 * (rep + 1)))
            best = max(best, (st.decode_tokens - toks0)
                       / max(st.decode_time - time0, 1e-9))
        return best

    tps_off = best_tps(None)
    tps_on = best_tps(Tracer(capacity=1 << 20))

    # artifact + energy-attribution integrity on a fresh single-run engine
    tracer = Tracer(capacity=1 << 20)
    engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                           block_size=block_size, params=params,
                           paged=True, horizon=4, tracer=tracer)
    summary = engine.run(fresh(0))
    obj = tracer.to_chrome()
    schema_errors = validate_chrome_trace(obj)
    span_energy = sum((ev.args or {}).get("odin_energy_mj", 0.0)
                      for ev in tracer.events() if ev.ph == "X")
    odin_total = summary["odin_total"]["energy_mj"]
    energy_rel_err = abs(span_energy - odin_total) / max(odin_total, 1e-12)
    if trace_out:
        tracer.export(trace_out)
    cell = {
        "slots": slots,
        "tokens_per_s": {"trace_off": tps_off, "trace_on": tps_on},
        "overhead_ratio": tps_on / max(tps_off, 1e-9),
        "trace_events": len(tracer),
        "dropped_events": tracer.dropped_events,
        "schema_valid": not schema_errors,
        "schema_errors": schema_errors[:5],
        "span_energy_mj": span_energy,
        "odin_total_energy_mj": odin_total,
        "energy_rel_err": energy_rel_err,
        "trace_out": trace_out,
    }
    if verbose:
        print(f"tracing: {tps_off:8.1f} tok/s off → {tps_on:8.1f} on "
              f"({cell['overhead_ratio']:.3f}×)  {cell['trace_events']} events"
              f"  schema_valid={cell['schema_valid']}  "
              f"span-energy err {energy_rel_err*100:.3f}%"
              + (f"  wrote {trace_out}" if trace_out else ""))
    return cell


def robustness_cell(cfg, base_requests, slots: int, params=None,
                    block_size: int = 16, repeats: int = 6,
                    chaos_seeds=(0, 1, 2, 3, 4), verbose: bool = True):
    """Robustness cell: lifecycle-guard overhead + chaos containment.

    Overhead: the mixed stream, guards off (no deadlines, no degradation —
    the prior PRs' hot path) vs guards on (every request watched by a
    never-firing deadline + queue timeout, the NaN logit guard armed, the
    degradation controller observing every step).  The guard-on controller
    uses unreachable thresholds so the ladder never actually sheds work —
    the cell measures what the *machinery* costs, not what degradation
    saves — and greedy streams must stay bit-identical.  Unlike the sweep
    cells (best-of-R per side), the ratio here is *aggregate* decode tok/s
    over R interleaved off/on repetitions with the measurement order
    alternating each rep: a ≤2% gate is finer than independent best-of
    runs can resolve on a busy host — aggregation cancels per-dispatch
    jitter and the order flip cancels monotone machine drift (whichever
    side runs second would otherwise eat any slowdown accrued across the
    pair).  Gated at ≥ 0.98× by ``--check-robust``.

    Chaos: the flaky scenario (bursty impatient clients) against a tight
    pool with a generated ``FaultPlan`` per seed, degradation live: zero
    crashes, every request in exactly one terminal state, and the ladder
    engaging somewhere across the sweep.  A falsifying plan is embedded in
    the cell (``failures``) so the committed bench JSON doubles as the
    replay artifact.
    """
    import dataclasses as _dc

    from repro.serving import SCENARIOS, DegradeConfig, FaultPlan

    spec_max = max(r.prompt_len + r.max_new for r in base_requests)
    max_len = -(-spec_max // block_size) * block_size

    def fresh(rid0, deadline=None):
        return [Request(rid=rid0 + r.rid, prompt=r.prompt, max_new=r.max_new,
                        arrival=0.0, deadline=deadline,
                        queue_timeout=deadline) for r in base_requests]

    never = DegradeConfig(pool_hi=1.1, queue_hi=1 << 30, churn_hi=1 << 30)

    def make_engine(guarded: bool):
        kw = (dict(degrade=never, deadline_s=1e9, queue_timeout_s=1e9,
                   nan_guard=True) if guarded else {})
        engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                               block_size=block_size, params=params,
                               paged=True, horizon=4, **kw)
        engine.run(fresh(0, 1e9 if guarded else None))  # warmup: compile grants
        return engine

    engines = {False: make_engine(False), True: make_engine(True)}
    totals = {False: [0.0, 0.0], True: [0.0, 0.0]}   # [tokens, seconds]
    streams = {False: None, True: None}
    for rep in range(max(1, repeats)):
        order = (False, True) if rep % 2 == 0 else (True, False)
        for guarded in order:                      # interleaved: shared noise
            engine, st = engines[guarded], engines[guarded].stats
            toks0, time0 = st.decode_tokens, st.decode_time
            reqs = fresh(10_000 * (rep + 1) + (5_000 if guarded else 0),
                         1e9 if guarded else None)
            engine.run(reqs)
            totals[guarded][0] += st.decode_tokens - toks0
            totals[guarded][1] += st.decode_time - time0
            streams[guarded] = tuple(
                tuple(tuple(np.asarray(t).ravel().tolist()) for t in r.generated)
                for r in sorted(reqs, key=lambda r: r.rid))
    tps_off = totals[False][0] / max(totals[False][1], 1e-9)
    tps_on = totals[True][0] / max(totals[True][1], 1e-9)
    streams_off, streams_on = streams[False], streams[True]

    flaky = _dc.replace(SCENARIOS["flaky"], n_requests=8,
                        prompt_buckets=(8, 16), gen_buckets=(8, 24))
    chaos_max = (max(flaky.prompt_buckets) + max(flaky.gen_buckets))
    chaos_max = -(-chaos_max // block_size) * block_size
    chaos_blocks = max(slots * (chaos_max // block_size) * 2 // 3,
                       chaos_max // block_size + 1)
    runs, failures, transitions = [], [], 0
    for seed in chaos_seeds:
        plan = FaultPlan.generate(seed, n_steps=64, rate=0.3)
        engine = ServingEngine(cfg, slots=slots, max_len=chaos_max,
                               block_size=block_size, params=params,
                               paged=True, horizon=4, n_blocks=chaos_blocks,
                               swap_blocks=2 * chaos_blocks, fault_plan=plan,
                               degrade=True)
        reqs = make_requests(cfg, flaky, seed=seed)
        try:
            s = engine.run(reqs)
        except Exception as e:                     # noqa: BLE001 — the gate
            failures.append({"seed": seed, "error": repr(e),
                             "plan": json.loads(plan.to_json())})
            continue
        term = s["terminal"]
        if sum(term.values()) != len(reqs):
            failures.append({"seed": seed,
                             "error": f"terminal leak: {term}",
                             "plan": json.loads(plan.to_json())})
            continue
        transitions += s["degradation"]["transitions"]
        runs.append({"seed": seed, "terminal": term,
                     "faults": s["faults"],
                     "degrade_transitions": s["degradation"]["transitions"]})
    cell = {
        "slots": slots,
        "tokens_per_s": {"guards_off": tps_off, "guards_on": tps_on},
        "overhead_ratio": tps_on / max(tps_off, 1e-9),
        "tokens_match": bool(streams_off == streams_on),
        "chaos_runs": runs,
        "chaos_failures": failures,
        "chaos_degrade_transitions": transitions,
    }
    if verbose:
        print(f"robustness: {tps_off:8.1f} tok/s guards-off → {tps_on:8.1f} on "
              f"({cell['overhead_ratio']:.3f}×)  tokens_match="
              f"{cell['tokens_match']}  chaos {len(runs)}/{len(chaos_seeds)} "
              f"clean, {transitions} degrade transitions")
    return cell


def frontdoor_cell(cfg, base_requests, slots: int, params=None,
                   block_size: int = 16, repeats: int = 3,
                   verbose: bool = True):
    """Front-door cell: async streaming overhead + backpressure typing.

    Overhead: the mixed stream on the bare synchronous engine vs the same
    engine driven through the asyncio front door — one consumer task per
    request, every token crossing an ``asyncio.Queue`` — each side with one
    warmup pass then ``repeats`` measured passes read off the stats deltas
    (best-of-R, the tracing cell's protocol).  The greedy event-stream
    tokens must be bit-identical to the bare engine's generated streams.

    Backpressure: a burst storm against ``max_queue=1`` — all but the first
    submission must bounce with a typed :class:`Overloaded` carrying a
    non-negative ``retry_after`` hint (the 429 contract the HTTP wrapper
    forwards as a ``Retry-After`` header).
    """
    from repro.serving import FrontDoor, Overloaded

    spec_max = max(r.prompt_len + r.max_new for r in base_requests)
    max_len = -(-spec_max // block_size) * block_size

    def fresh(rid0):
        return [Request(rid=rid0 + r.rid, prompt=r.prompt, max_new=r.max_new,
                        arrival=0.0) for r in base_requests]

    def make_engine():
        # mixed dispatch pinned off: this cell isolates async-streaming
        # overhead via decode_tokens/decode_time deltas, and mixed tiles
        # bill decode rows into walls shared with prefill rows — the bare
        # side (all arrivals before run()) and the front-door side
        # (submits staggered across event-loop turns) would then pack
        # different tiles and the attribution, not the streaming layer,
        # would move the ratio.  The mixed_dispatch cell gates mixed-on
        # behavior on end-to-end inter-token gaps instead.
        engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                               block_size=block_size, params=params,
                               paged=True, horizon=4, mixed=False)
        engine.run(fresh(0))                       # warmup: compile grants
        return engine

    def stream_key(streams):
        return tuple(tuple(s) for s in streams)

    # bare side: the synchronous step loop
    eng = make_engine()
    st = eng.stats
    best_bare, streams_bare = 0.0, None
    for rep in range(max(1, repeats)):
        toks0, time0 = st.decode_tokens, st.decode_time
        reqs = fresh(10_000 * (rep + 1))
        eng.run(reqs)
        best_bare = max(best_bare, (st.decode_tokens - toks0)
                        / max(st.decode_time - time0, 1e-9))
        streams_bare = stream_key(
            tuple(tuple(np.asarray(t).ravel().tolist()) for t in r.generated)
            for r in sorted(reqs, key=lambda r: r.rid))

    # front-door side: same engine config, every token through the asyncio
    # stream; the tokens compared are the *event* payloads the consumer saw
    eng_fd = make_engine()
    st = eng_fd.stats

    async def drive(reqs):
        fd = FrontDoor(eng_fd, max_queue=len(reqs) + 1)
        await fd.start()

        async def consume(r):
            toks = []
            async for ev in fd.submit(r):
                if ev.kind == "token":
                    toks.append(ev.token)
            return tuple(toks)

        outs = await asyncio.gather(*[consume(r) for r in reqs])
        await fd.aclose()
        return outs

    best_fd, streams_fd = 0.0, None
    for rep in range(max(1, repeats)):
        toks0, time0 = st.decode_tokens, st.decode_time
        reqs = fresh(10_000 * (rep + 1))
        outs = asyncio.run(drive(reqs))
        best_fd = max(best_fd, (st.decode_tokens - toks0)
                      / max(st.decode_time - time0, 1e-9))
        streams_fd = stream_key(
            out for _, out in sorted(zip((r.rid for r in reqs), outs)))

    # burst storm: queue bound 1, submissions back-to-back with no await in
    # between — deterministic: exactly one admission, the rest bounce typed
    eng_storm = ServingEngine(cfg, slots=2, max_len=max_len,
                              block_size=block_size, params=params,
                              paged=True, horizon=4)

    async def _drain_stream(stream):
        async for _ in stream:
            pass

    async def storm(reqs):
        fd = FrontDoor(eng_storm, max_queue=1)
        await fd.start()
        admitted, rejections = [], []
        for r in reqs:
            try:
                stream = fd.submit(r)
            except Overloaded as e:
                rejections.append(e)
            else:
                admitted.append(asyncio.ensure_future(_drain_stream(stream)))
        await asyncio.gather(*admitted)
        await fd.aclose()
        return len(admitted), rejections

    n_admitted, rejections = asyncio.run(storm(fresh(50_000)))
    storm_typed = all(e.retry_after is not None and e.retry_after >= 0.0
                      for e in rejections)
    cell = {
        "slots": slots,
        "tokens_per_s": {"bare": best_bare, "frontdoor": best_fd},
        "overhead_ratio": best_fd / max(best_bare, 1e-9),
        "tokens_match": bool(streams_bare == streams_fd),
        "storm_admitted": n_admitted,
        "storm_rejected": len(rejections),
        "storm_rejections_typed": bool(rejections) and storm_typed,
        "storm_retry_after_s": [round(e.retry_after, 6) for e in rejections[:3]],
    }
    if verbose:
        print(f"frontdoor: {best_bare:8.1f} tok/s bare → {best_fd:8.1f} "
              f"streamed ({cell['overhead_ratio']:.3f}×)  tokens_match="
              f"{cell['tokens_match']}  storm {n_admitted} in / "
              f"{len(rejections)} typed-429")
    return cell


def mixed_dispatch_cell(cfg, slots: int, params=None, block_size: int = 16,
                        n_requests: int = 16, repeats: int = 3,
                        verbose: bool = True):
    """Fused mixed prefill+decode dispatch vs alternating separate launches
    on a bursty arrival stream.

    The pathology mixed dispatch removes: with separate launches, every
    admission burst runs whole prefill-chunk dispatches during which no
    in-flight decode emits a token, so decode inter-token gaps — TPOT —
    spike at each burst.  The fused tile packs decode rows into the SAME
    dispatch as the prefill chunks (token-budget packed, decode-priority),
    so streams keep emitting through bursts and burst-p99 TPOT collapses
    toward the steady-state gap.

    Protocol: the ``bursty`` scenario tuned to chunked-prefill pressure
    (long prompts, short generations, burst arrivals replayed on the wall
    clock so admissions land while earlier requests are mid-decode), one
    warmup pass per engine (compiles the tile shapes), then ``repeats``
    measured passes keeping the best (lowest) per-run p99 TPOT — the 99th
    percentile over inter-token gaps — and the best decode tok/s.  Greedy
    streams must be bit-identical across reps AND across modes — the fused
    tile is a scheduling change, never a numerics change.
    """
    import dataclasses as _dc

    from repro.serving import SCENARIOS

    # the bursty scenario tuned to the chunked-prefill regime: long prompts
    # against short generations at an arrival rate that lands bursts while
    # earlier requests are mid-decode — every decode window overlaps an
    # admission, so the stall (or its absence) dominates per-request TPOT
    spec = _dc.replace(SCENARIOS["bursty"], n_requests=n_requests, rate=30.0,
                       prompt_buckets=(96,), gen_buckets=(8, 16),
                       gen_weights=(0.5, 0.5))
    chunk = 16
    base_requests = make_requests(cfg, spec, seed=17)
    spec_max = max(r.prompt_len + r.max_new for r in base_requests)
    max_len = -(-spec_max // block_size) * block_size

    def fresh(rid0):
        return [Request(rid=rid0 + r.rid, prompt=r.prompt, max_new=r.max_new,
                        arrival=r.arrival) for r in base_requests]

    def one(mixed: bool):
        # per-token emit timestamps: burst-p99 TPOT is the 99th percentile
        # over *inter-token gaps* (the serving-benchmark ITL convention) —
        # a per-request mean would smear each admission stall over the
        # request's whole life and hide exactly the spike the fused tile
        # removes
        emits = {}

        def on_token(req, tok, now):
            emits.setdefault(req.rid, []).append(now)

        # prefix sharing off: repeated passes reuse the same prompts, and
        # resident chains would erase the very prefill work whose dispatch
        # scheduling this cell measures.  Both modes chunk prefill at the
        # same small size — with chunk = max_len a whole admission is one
        # dispatch in either mode and the cell measures nothing.
        engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                               block_size=block_size, params=params,
                               paged=True, mixed=mixed, prefix_sharing=False,
                               prefill_chunk=chunk, on_token=on_token)
        engine.run(fresh(0))                   # warmup: compile tile shapes
        st = engine.stats
        best_p99, best_tps, streams = None, 0.0, []
        for rep in range(max(1, repeats)):
            emits.clear()
            toks0, time0 = st.decode_tokens, st.decode_time
            reqs = fresh(10_000 * (rep + 1))
            engine.run(reqs)
            gaps = [b - a for ts in emits.values()
                    for a, b in zip(ts, ts[1:])]   # TTFT gap excluded
            p99 = float(np.percentile(np.asarray(gaps, np.float64), 99))
            best_p99 = p99 if best_p99 is None else min(best_p99, p99)
            best_tps = max(best_tps, (st.decode_tokens - toks0)
                           / max(st.decode_time - time0, 1e-9))
            streams.append(tuple(
                tuple(tuple(np.asarray(t).ravel().tolist()) for t in r.generated)
                for r in sorted(reqs, key=lambda r: r.rid)))
        return {"tpot_p99_s": best_p99, "tokens_per_s": best_tps,
                "mixed_dispatches": st.mixed_dispatches,
                "mixed_decode_rows": st.mixed_decode_rows,
                "mixed_prefill_rows": st.mixed_prefill_rows}, streams

    sep, sep_streams = one(False)
    fused, fused_streams = one(True)
    cell = {
        "slots": slots,
        "n_requests": n_requests,
        "tokens_match": bool(all(s == sep_streams[0]
                                 for s in sep_streams + fused_streams)),
        "tpot_p99_s": {"separate": sep["tpot_p99_s"],
                       "mixed": fused["tpot_p99_s"]},
        "tpot_p99_ratio": fused["tpot_p99_s"] / max(sep["tpot_p99_s"], 1e-12),
        "tokens_per_s": {"separate": sep["tokens_per_s"],
                         "mixed": fused["tokens_per_s"]},
        "mixed_dispatches": fused["mixed_dispatches"],
        "mixed_decode_rows": fused["mixed_decode_rows"],
        "mixed_prefill_rows": fused["mixed_prefill_rows"],
    }
    if verbose:
        print(f"mixed dispatch: burst p99 TPOT "
              f"{sep['tpot_p99_s']*1e3:7.1f} ms separate → "
              f"{fused['tpot_p99_s']*1e3:7.1f} ms fused "
              f"({cell['tpot_p99_ratio']:.2f}×)  "
              f"{fused['mixed_dispatches']} mixed dispatches "
              f"({fused['mixed_decode_rows']} decode + "
              f"{fused['mixed_prefill_rows']} prefill rows)  "
              f"tokens_match={cell['tokens_match']}")
    return cell


def reliability_cell(cfg, base_requests, slots: int, params=None,
                     block_size: int = 16, repeats: int = 10,
                     verbose: bool = True):
    """Reliability cell: wear narrowing + scrub overhead + retirement storm.

    Wear leveling: the mixed stream replayed for three passes against a
    constrained pool (block reuse is what spreads — or concentrates —
    wear), once on the seed LIFO free-list order and once with the
    min-wear allocator.  Per-block write accounting is always on, so both
    runs report a wear Gini coefficient over ``pool.wear``; the min-wear
    order must *narrow* it (gini_wl < gini_lifo) with bit-identical
    greedy streams — allocation order is a placement choice, never a
    numerics change.

    Scrub: drift-refresh on (a small ``drift_deadline_s`` so resident
    blocks actually come due, ``scrub_rate`` bounding copies per step) vs
    reliability off, one warmup pass each then ``repeats`` interleaved
    measured passes timed end-to-end (first pair discarded as cold); the
    gate statistic is the aggregate on/off tok/s ratio over the measured
    pairs.  The scrubber moves identical bytes between
    dispatches, so decode tok/s must hold ≥ 0.95× and streams stay
    bit-identical; the cell also reports the scrub rows billed to the
    ``scrub`` ODIN energy phase.

    Storm: a ``wear_exhaustion`` fault burst against a tight pool with
    degradation live — the most-worn live blocks burn out mid-flight,
    drain through replacement copies, and capacity shrinks under load.
    Every request must land in exactly one terminal state (capacity
    failures typed, never a livelock) and the ladder must engage before
    the pool exhausts.
    """
    from repro.serving import FaultEvent, FaultPlan, ReliabilityConfig, wear_gini

    spec_max = max(r.prompt_len + r.max_new for r in base_requests)
    max_len = -(-spec_max // block_size) * block_size
    req_blocks = -(-spec_max // block_size)

    def fresh(rid0):
        return [Request(rid=rid0 + r.rid, prompt=r.prompt, max_new=r.max_new,
                        arrival=0.0) for r in base_requests]

    def streams_of(reqs):
        return tuple(
            tuple(tuple(np.asarray(t).ravel().tolist()) for t in r.generated)
            for r in sorted(reqs, key=lambda r: r.rid))

    # -- wear leveling: tight pool so passes recycle blocks through the free
    # list — with a roomy pool every block is written once and both orders
    # report the same (flat) wear profile.  Prefix sharing off: resident
    # cache chains pin blocks across passes, so which prompts stay cached —
    # not the allocator's free-list order — would dominate the wear spread
    # and can even invert the comparison on small streams
    churn_blocks = max(slots * req_blocks * 2 // 3, req_blocks + 1)

    def wear_run(leveled: bool):
        engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                               block_size=block_size, params=params,
                               paged=True, horizon=4, n_blocks=churn_blocks,
                               swap_blocks=2 * churn_blocks,
                               prefix_sharing=False,
                               reliability=(ReliabilityConfig() if leveled
                                            else None))
        streams = []
        for p in range(3):
            reqs = fresh(10_000 * (p + 1))
            engine.run(reqs)
            streams.append(streams_of(reqs))
        return float(wear_gini(engine.pool.wear)), streams

    gini_lifo, streams_lifo = wear_run(False)
    gini_wl, streams_wl = wear_run(True)
    wear_match = bool(streams_lifo == streams_wl)

    # -- scrub overhead: interleaved pairwise protocol — each rep runs both
    # sides back-to-back (order flipped every rep) so a load spike hits
    # both sides, and the gate ratio aggregates total tokens over total
    # wall across reps, shrinking per-pass jitter by √reps where a single
    # pair's ratio swings ±7% on a busy host.  The timer is *end-to-end*
    # pass wall time, not the
    # decode-dispatch stats delta: scrub copies run between dispatches and
    # drain the async device queue, so dispatch-window timing systematically
    # under-bills them (and can even flip the sign).  The stream leans on
    # long generations: drift refresh is amortized against block residency
    # (a block is rewritten every ``drift_deadline_s`` it stays resident),
    # so overhead ≈ copy_cost / deadline per block — a deadline shorter
    # than the smoke-scale pass would measure a pathological cadence no
    # deployment would run, not the background-refresh regime
    import dataclasses as _dc
    import time as _time

    scrub_spec = _dc.replace(_mixed_spec(max(len(base_requests) * 3 // 4, 6)),
                             gen_buckets=(32, 64), gen_weights=(0.5, 0.5))
    scrub_requests = make_requests(cfg, scrub_spec, seed=23)
    scrub_spec_max = max(r.prompt_len + r.max_new for r in scrub_requests)
    scrub_max_len = -(-scrub_spec_max // block_size) * block_size

    def fresh_scrub(rid0):
        return [Request(rid=rid0 + r.rid, prompt=r.prompt, max_new=r.max_new,
                        arrival=0.0) for r in scrub_requests]

    scrub_rel = ReliabilityConfig(scrub_rate=1, drift_deadline_s=0.8)

    def make_scrub_engine(scrub: bool):
        engine = ServingEngine(cfg, slots=slots, max_len=scrub_max_len,
                               block_size=block_size, params=params,
                               paged=True, horizon=4,
                               reliability=scrub_rel if scrub else None)
        engine.run(fresh_scrub(0))             # warmup: compile grants
        return engine

    scrub_engines = {False: make_scrub_engine(False),
                     True: make_scrub_engine(True)}
    totals = {False: [0.0, 0.0], True: [0.0, 0.0]}   # [tokens, seconds]
    scrub_streams = {False: None, True: None}
    # rep 0 is a throwaway: caches, allocator free lists and the page cache
    # are still cold after warmup, and its pair lands far off the steady
    # state — it participates in the interleave but not in the statistic
    for rep in range(max(1, repeats) + 1):
        order = (False, True) if rep % 2 == 0 else (True, False)
        for scrub in order:
            engine, st = scrub_engines[scrub], scrub_engines[scrub].stats
            toks0 = st.decode_tokens
            reqs = fresh_scrub(10_000 * (rep + 1) + (5_000 if scrub else 0))
            t0 = _time.perf_counter()
            engine.run(reqs)
            wall = _time.perf_counter() - t0
            if rep > 0:
                totals[scrub][0] += st.decode_tokens - toks0
                totals[scrub][1] += wall
            scrub_streams[scrub] = streams_of(reqs)
    tps_off = totals[False][0] / max(totals[False][1], 1e-9)
    tps_on = totals[True][0] / max(totals[True][1], 1e-9)
    ratio = tps_on / max(tps_off, 1e-9)
    streams_off, streams_on = scrub_streams[False], scrub_streams[True]
    st_on = scrub_engines[True].stats
    scrub_match = bool(streams_off == streams_on)

    # -- retirement storm: wear_exhaustion bursts against a tight pool,
    # degradation live — capacity shrinks while requests are mid-flight
    storm_blocks = max(slots * req_blocks * 3 // 4, req_blocks + 2)
    plan = FaultPlan(events=tuple(
        FaultEvent(site="wear_exhaustion", step=st, count=2)
        for st in (4, 7, 10, 13)))
    engine = ServingEngine(cfg, slots=slots, max_len=max_len,
                           block_size=block_size, params=params,
                           paged=True, horizon=4, n_blocks=storm_blocks,
                           swap_blocks=2 * storm_blocks, fault_plan=plan,
                           degrade=True, reliability=ReliabilityConfig())
    reqs = fresh(0)
    s = engine.run(reqs)
    term = s["terminal"]
    failed = [r for r in s["requests"] if r["state"] == "failed"]
    storm = {
        "n_blocks": storm_blocks,
        "terminal": term,
        "all_terminal": bool(sum(term.values()) == len(reqs)),
        "retired_blocks": s["reliability"]["retired_blocks"],
        "failures_typed": bool(all(r["finish_reason"] == "capacity"
                                   for r in failed)),
        "degrade_transitions": s["degradation"]["transitions"],
    }

    cell = {
        "slots": slots,
        "wear_gini": {"lifo": gini_lifo, "min_wear": gini_wl},
        "wear_tokens_match": wear_match,
        "tokens_per_s": {"scrub_off": tps_off, "scrub_on": tps_on},
        "scrub_overhead_ratio": ratio,
        "scrub_tokens_match": scrub_match,
        "scrub_copies": st_on.scrub_copies,
        "scrub_rows": st_on.scrub_rows,
        "storm": storm,
    }
    if verbose:
        print(f"reliability: wear gini {gini_lifo:.3f} lifo → {gini_wl:.3f} "
              f"min-wear  scrub {tps_off:8.1f} → {tps_on:8.1f} tok/s "
              f"({cell['scrub_overhead_ratio']:.3f}×, {st_on.scrub_copies} "
              f"copies)  storm {term} retired={storm['retired_blocks']} "
              f"degrade={storm['degrade_transitions']}  tokens_match="
              f"{wear_match and scrub_match}")
    return cell


def run(verbose: bool = True, n_requests: int = 16, slots_sweep=(2, 4),
        rates=(float("inf"),), arch: str = "phi4-mini-3.8b",
        json_path=None, bench_json=None, check: bool = False,
        check_paged: bool = False, check_horizon: bool = False,
        check_prefix: bool = False, check_spec: bool = False,
        check_trace: bool = False, check_robust: bool = False,
        check_frontdoor: bool = False, check_mixed: bool = False,
        check_reliability: bool = False,
        trace_out=None, horizons=(1, 4, 16), spec_ks=(0, 2, 4)):
    block_size = 16
    cfg = registry.get_smoke(arch)
    attribution_cfg = registry.get_config(arch)   # bill energy at full scale
    import jax
    from repro.models import lm
    from repro.nn import module as nnmod
    params = nnmod.materialize(lm.param_spec(cfg), jax.random.PRNGKey(0))
    base_requests = make_requests(cfg, _mixed_spec(n_requests), seed=11)
    spec_max = max(r.prompt_len + r.max_new for r in base_requests)
    max_len = -(-spec_max // block_size) * block_size
    req_blocks = -(-spec_max // block_size)       # largest single request

    out = {"arch": arch, "n_requests": n_requests, "cells": []}
    for slots in slots_sweep:
        tps_static, t_static = static_baseline(cfg, base_requests, slots, params=params)
        for rate in rates:
            dense, dense_toks = engine_run(
                cfg, base_requests, slots, rate, params=params,
                attribution_cfg=attribution_cfg, paged=False)
            paged, paged_toks = engine_run(
                cfg, base_requests, slots, rate, params=params,
                attribution_cfg=attribution_cfg, paged=True)
            # tight pool: ≈ half the dense-equivalent block budget (the +1
            # write-off block counts against the ratio), when the largest
            # request still fits
            dense_blocks = slots * (max_len // block_size)
            tight_blocks = dense_blocks // 2 - 1
            tight = tight_toks = None
            if tight_blocks >= req_blocks:
                tight, tight_toks = engine_run(
                    cfg, base_requests, slots, rate, params=params,
                    attribution_cfg=attribution_cfg, paged=True,
                    n_blocks=tight_blocks)
            cell = {
                "slots": slots,
                "arrival_rate": None if not np.isfinite(rate) else rate,
                "static_useful_tokens_per_s": tps_static,
                "engine_tokens_per_s": paged["decode_tokens_per_s"],
                "speedup": paged["decode_tokens_per_s"] / max(tps_static, 1e-9),
                "dense_engine_tokens_per_s": dense["decode_tokens_per_s"],
                "paged_vs_dense_speedup": paged["decode_tokens_per_s"]
                    / max(dense["decode_tokens_per_s"], 1e-9),
                "dense_kv_bytes": dense["kv_cache_bytes"],
                "paged_kv_bytes": paged["kv_cache_bytes"],
                "paged_tight_kv_bytes": tight["kv_cache_bytes"] if tight else None,
                "kv_bytes_ratio": (dense["kv_cache_bytes"]
                                   / max(tight["kv_cache_bytes"], 1)) if tight else None,
                "paged_tight_tokens_per_s": tight["decode_tokens_per_s"] if tight else None,
                "tokens_match": bool(dense_toks == paged_toks
                                     and (tight_toks is None or tight_toks == dense_toks)),
                "ttft_s": paged["ttft_s"],
                "tpot_s": paged["tpot_s"],
                "slot_occupancy": paged["slot_occupancy"],
                "preemptions": paged["preemptions"],
                "tight_preemptions": tight["preemptions"] if tight else None,
                "odin_total": paged["odin_total"],
                "per_request": [
                    {k: rec[k] for k in ("rid", "prompt_tokens", "generated_tokens",
                                         "ttft_s", "tpot_s", "odin")}
                    for rec in paged["requests"]
                ],
            }
            out["cells"].append(cell)
            if verbose:
                r = "∞" if cell["arrival_rate"] is None else f"{rate:g}/s"
                ratio = cell["kv_bytes_ratio"]
                print(f"slots={slots} rate={r:>6}: static {tps_static:7.1f} → "
                      f"dense {cell['dense_engine_tokens_per_s']:7.1f} → "
                      f"paged {cell['engine_tokens_per_s']:7.1f} tok/s  "
                      f"kv {cell['dense_kv_bytes']/1e3:.0f}→{cell['paged_kv_bytes']/1e3:.0f} KB"
                      + (f" (tight {cell['paged_tight_kv_bytes']/1e3:.0f} KB, "
                         f"{ratio:.2f}× less)" if ratio else "")
                      + f"  tokens_match={cell['tokens_match']}")
    out["best_speedup"] = max(c["speedup"] for c in out["cells"])
    out["best_paged_vs_dense_speedup"] = max(
        c["paged_vs_dense_speedup"] for c in out["cells"])
    ratios = [c["kv_bytes_ratio"] for c in out["cells"] if c["kv_bytes_ratio"]]
    out["best_kv_bytes_ratio"] = max(ratios) if ratios else None
    out["all_tokens_match"] = all(c["tokens_match"] for c in out["cells"])
    out["horizon"] = horizon_sweep(cfg, base_requests, max(slots_sweep),
                                   params=params, horizons=tuple(horizons),
                                   block_size=block_size, verbose=verbose)
    out["prefix_sharing"] = prefix_cell(cfg, max(slots_sweep), params=params,
                                        n_requests=max(n_requests * 3 // 4, 4),
                                        block_size=block_size, verbose=verbose)
    out["speculation"] = speculation_cell(cfg, max(slots_sweep), params=params,
                                          ks=tuple(spec_ks),
                                          n_requests=max(n_requests * 3 // 8, 6),
                                          block_size=block_size, verbose=verbose)
    out["tracing"] = tracing_cell(cfg, base_requests, max(slots_sweep),
                                  params=params, block_size=block_size,
                                  trace_out=trace_out, verbose=verbose)
    out["robustness"] = robustness_cell(cfg, base_requests, max(slots_sweep),
                                        params=params, block_size=block_size,
                                        verbose=verbose)
    out["frontdoor"] = frontdoor_cell(cfg, base_requests, max(slots_sweep),
                                      params=params, block_size=block_size,
                                      verbose=verbose)
    out["mixed_dispatch"] = mixed_dispatch_cell(
        cfg, max(slots_sweep), params=params, block_size=block_size,
        n_requests=n_requests, verbose=verbose)
    out["reliability"] = reliability_cell(cfg, base_requests, max(slots_sweep),
                                          params=params, block_size=block_size,
                                          verbose=verbose)
    if verbose:
        print(f"best decode-throughput speedup over static batching: "
              f"{out['best_speedup']:.2f}×; paged vs dense engine: "
              f"{out['best_paged_vs_dense_speedup']:.2f}× tok/s, "
              f"{out['best_kv_bytes_ratio'] or float('nan'):.2f}× less peak KV")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        if verbose:
            print(f"wrote {json_path}")
    if bench_json:
        update_bench_json(bench_json, "serving", out)
        if verbose:
            print(f"merged section 'serving' into {bench_json}")
    if check and out["best_speedup"] < 1.5:
        raise SystemExit(f"speedup {out['best_speedup']:.2f}× < required 1.5×")
    if check_paged:
        if not out["all_tokens_match"]:
            raise SystemExit("paged engine token streams diverge from dense")
        ok = (out["best_paged_vs_dense_speedup"] >= 1.3
              or (out["best_kv_bytes_ratio"] or 0) >= 2.0)
        if not ok:
            raise SystemExit(
                f"paged engine shows neither ≥1.3× decode throughput "
                f"({out['best_paged_vs_dense_speedup']:.2f}×) nor ≥2× lower "
                f"peak KV ({out['best_kv_bytes_ratio']}) vs the dense engine")
    if check_horizon:
        hz = out["horizon"]
        if not hz["tokens_match"]:
            raise SystemExit("horizon decode token streams diverge from H=1")
        top = max(hz["speedup_vs_h1"].values())
        if top < 1.5:
            raise SystemExit(
                f"horizon decode speedup {top:.2f}× < required 1.5× vs H=1")
    if check_prefix:
        px = out["prefix_sharing"]
        if not px["tokens_match"]:
            raise SystemExit(
                "prefix-shared token streams diverge from the no-sharing run")
        ok = px["prefill_speedup"] >= 1.5 or px["occupancy_ratio"] >= 1.5
        if not ok:
            raise SystemExit(
                f"prefix sharing shows neither ≥1.5× prefill throughput "
                f"({px['prefill_speedup']:.2f}×) nor ≥1.5× lower steady-state "
                f"pool occupancy ({px['occupancy_ratio']:.2f}×) on the "
                f"shared-prompt stream")
    if check_spec:
        top_k = max(spec_ks)
        for name, sc in out["speculation"]["scenarios"].items():
            if not sc["tokens_match"]:
                raise SystemExit(
                    f"speculative token streams diverge from K=0 on the "
                    f"{name} scenario — the greedy accept rule must be "
                    f"token-identity-preserving")
        rep = out["speculation"]["scenarios"]["repetitive"]
        got = rep["speedup_vs_k0"][top_k]
        if got < 1.8:
            raise SystemExit(
                f"speculation speedup {got:.2f}× at K={top_k} on the "
                f"repetitive scenario < required 1.8× (accept_rate "
                f"{rep['cells'][-1]['accept_rate']:.2f})")
        mx = out["speculation"]["scenarios"]["mixed"]
        got = max(v for k, v in mx["speedup_vs_k0"].items() if k)
        if got < 1.2:
            raise SystemExit(
                f"speculation speedup {got:.2f}× (best K) on the mixed "
                f"scenario < required 1.2×")
    if check_trace:
        tr = out["tracing"]
        if not tr["schema_valid"]:
            raise SystemExit("trace artifact failed Perfetto schema "
                             "validation: " + "; ".join(tr["schema_errors"]))
        if tr["energy_rel_err"] > 0.01:
            raise SystemExit(
                f"per-dispatch ODIN energy args sum {tr['span_energy_mj']:.4f} "
                f"mJ differs from odin_total "
                f"{tr['odin_total_energy_mj']:.4f} mJ by "
                f"{tr['energy_rel_err']*100:.2f}% (> 1%)")
        if tr["overhead_ratio"] < 0.98:
            raise SystemExit(
                f"trace-on decode throughput {tr['overhead_ratio']:.3f}× "
                f"trace-off < required 0.98× (tracing must stay <2% overhead)")
    if check_robust:
        rb = out["robustness"]
        if not rb["tokens_match"]:
            raise SystemExit("guard-on greedy streams diverge from guards-off")
        if rb["overhead_ratio"] < 0.98:
            raise SystemExit(
                f"guards-on decode throughput {rb['overhead_ratio']:.3f}× "
                f"guards-off < required 0.98× (lifecycle guards must stay "
                f"<2% overhead)")
        if rb["chaos_failures"]:
            seeds = [f["seed"] for f in rb["chaos_failures"]]
            raise SystemExit(
                f"chaos sweep not contained for seeds {seeds} — falsifying "
                f"plans are embedded under robustness.chaos_failures")
        if rb["chaos_degrade_transitions"] < 1:
            raise SystemExit(
                "degradation never engaged across the chaos sweep — the "
                "flaky scenario must exercise the ladder")
    if check_frontdoor:
        fdc = out["frontdoor"]
        if not fdc["tokens_match"]:
            raise SystemExit(
                "front-door event-stream tokens diverge from the bare "
                "synchronous engine — streaming must be content-neutral")
        if fdc["overhead_ratio"] < 0.95:
            raise SystemExit(
                f"front-door decode throughput {fdc['overhead_ratio']:.3f}× "
                f"bare engine < required 0.95× (async streaming must stay "
                f"<5% overhead)")
        if not fdc["storm_rejections_typed"]:
            raise SystemExit(
                "burst-storm rejections were not all typed Overloaded with "
                "a retry_after hint — the 429 contract is broken")
    if check_mixed:
        mx = out["mixed_dispatch"]
        if not mx["tokens_match"]:
            raise SystemExit(
                "mixed-dispatch greedy streams diverge from the separate "
                "prefill/decode launches — fused tiles must be bit-identical")
        if mx["tpot_p99_ratio"] > 0.6:
            raise SystemExit(
                f"mixed-dispatch burst p99 TPOT {mx['tpot_p99_ratio']:.2f}× "
                f"the alternating baseline > allowed 0.6× — fused tiles must "
                f"keep decode emitting through admission bursts")
    if check_reliability:
        rl = out["reliability"]
        if not rl["wear_tokens_match"]:
            raise SystemExit(
                "min-wear allocation changed greedy streams vs the seed LIFO "
                "order — placement must be a numerics no-op")
        if rl["wear_gini"]["min_wear"] >= rl["wear_gini"]["lifo"]:
            raise SystemExit(
                f"wear-leveled Gini {rl['wear_gini']['min_wear']:.3f} did not "
                f"narrow vs the seed LIFO allocator "
                f"{rl['wear_gini']['lifo']:.3f}")
        if not rl["scrub_tokens_match"]:
            raise SystemExit(
                "scrub-on greedy streams diverge from scrub-off — the "
                "drift-refresh scrubber must only move identical bytes")
        if rl["scrub_overhead_ratio"] < 0.95:
            raise SystemExit(
                f"scrub-on decode throughput {rl['scrub_overhead_ratio']:.3f}× "
                f"scrub-off < required 0.95× (bounded background refresh must "
                f"stay <5% overhead)")
        st = rl["storm"]
        if not (st["all_terminal"] and st["failures_typed"]):
            raise SystemExit(
                f"retirement storm leaked requests or untyped failures: "
                f"terminal={st['terminal']} typed={st['failures_typed']}")
        if st["retired_blocks"] < 1:
            raise SystemExit(
                "retirement storm burned no blocks — the wear_exhaustion "
                "plan must actually shrink capacity")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=registry.ARCH_IDS)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--rates", type=float, nargs="+", default=None,
                    help="arrival rates (req/s); default: unthrottled")
    ap.add_argument("--json", default=None)
    ap.add_argument("--bench-json", default=DEFAULT_BENCH_JSON,
                    help="merged cross-bench JSON (section 'serving')")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless engine ≥ 1.5× static decode throughput")
    ap.add_argument("--check-paged", action="store_true",
                    help="exit non-zero unless the paged engine matches dense "
                         "token streams AND shows ≥1.3× tok/s or ≥2× lower "
                         "peak KV memory")
    ap.add_argument("--check-horizon", action="store_true",
                    help="exit non-zero unless horizon-batched decode shows "
                         "≥1.5× tok/s at the top horizon vs H=1 with "
                         "bit-identical greedy token streams")
    ap.add_argument("--check-prefix", action="store_true",
                    help="exit non-zero unless the prefix-sharing cell is "
                         "token-identical to the no-sharing baseline AND "
                         "shows ≥1.5× prefill tok/s or ≥1.5× lower "
                         "steady-state pool occupancy")
    ap.add_argument("--check-spec", action="store_true",
                    help="exit non-zero unless n-gram speculation is "
                         "token-identical to K=0 AND shows ≥1.8× decode "
                         "tok/s at the top K on the repetitive scenario "
                         "(≥1.2× on mixed)")
    ap.add_argument("--check-trace", action="store_true",
                    help="exit non-zero unless the trace artifact passes the "
                         "Perfetto schema check, per-dispatch ODIN energy "
                         "args sum to odin_total within 1%%, and trace-on "
                         "decode tok/s ≥ 0.98× trace-off")
    ap.add_argument("--check-robust", action="store_true",
                    help="exit non-zero unless guards-on (deadlines + NaN "
                         "guard + degradation observer) decode tok/s ≥ 0.98× "
                         "guards-off with bit-identical streams, AND the "
                         "flaky chaos sweep is crash-free, terminal-state "
                         "conserving, with degradation engaging")
    ap.add_argument("--check-frontdoor", action="store_true",
                    help="exit non-zero unless front-door event streams are "
                         "bit-identical to the bare engine, streamed decode "
                         "tok/s ≥ 0.95× bare, and burst-storm rejections are "
                         "all typed with a retry_after hint")
    ap.add_argument("--check-mixed", action="store_true",
                    help="exit non-zero unless fused mixed prefill+decode "
                         "dispatch streams are bit-identical to separate "
                         "launches AND burst p99 TPOT ≤ 0.6× the alternating "
                         "baseline on the bursty scenario")
    ap.add_argument("--check-reliability", action="store_true",
                    help="exit non-zero unless wear-leveled allocation "
                         "narrows the wear Gini vs the seed LIFO order, "
                         "scrub-on decode tok/s ≥ 0.95× scrub-off (both with "
                         "bit-identical streams), and a wear_exhaustion "
                         "retirement storm leaves every request in exactly "
                         "one terminal state with typed capacity failures")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the tracing cell's Chrome trace JSON artifact")
    ap.add_argument("--horizons", type=int, nargs="+", default=[1, 4, 16],
                    help="horizon sweep values (first must be 1, the baseline)")
    ap.add_argument("--spec-ks", type=int, nargs="+", default=[0, 2, 4],
                    help="speculation sweep draft lengths (first must be 0, "
                         "the baseline)")
    args = ap.parse_args()
    rates = tuple(args.rates) if args.rates else (float("inf"),)
    run(n_requests=args.requests, slots_sweep=tuple(args.slots), rates=rates,
        arch=args.arch, json_path=args.json, bench_json=args.bench_json,
        check=args.check, check_paged=args.check_paged,
        check_horizon=args.check_horizon, check_prefix=args.check_prefix,
        check_spec=args.check_spec, check_trace=args.check_trace,
        check_robust=args.check_robust, check_frontdoor=args.check_frontdoor,
        check_mixed=args.check_mixed,
        check_reliability=args.check_reliability,
        trace_out=args.trace_out,
        horizons=tuple(args.horizons), spec_ks=tuple(args.spec_ks))


if __name__ == "__main__":
    main()
