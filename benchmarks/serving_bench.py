"""Serving benchmark: continuous batching vs the static-batch loop.

Sweeps arrival rate × batch slots over a mixed-length request stream and
reports decode throughput, TTFT/TPOT percentiles, slot occupancy, and the
per-request ODIN PIMC energy bill (JSON like the other benches).

The baseline is the seed's static-batch discipline (``serve_static``): group
requests into consecutive batches of ``slots``, pad every batch to its
longest prompt, and decode until its *longest* generation finishes — slots
whose request retired early keep burning decode steps.  The engine re-admits
freed slots instead; on the ``mixed`` stream its useful decode throughput
must be ≥ 1.5× (asserted when --check is passed; the repo's serving test
asserts the same at smoke scale).

  PYTHONPATH=src python benchmarks/serving_bench.py --json serving.json
"""
import argparse
import json
import time

import numpy as np

from repro.launch.serve import serve_static
from repro.models import registry
from repro.serving import (OdinCostModel, Request, ServingEngine, WorkloadSpec,
                           make_requests)


def _mixed_spec(n_requests: int) -> WorkloadSpec:
    return WorkloadSpec(n_requests=n_requests, rate=1e9,
                        prompt_buckets=(16, 32), gen_buckets=(4, 16, 48),
                        gen_weights=(0.4, 0.35, 0.25))


def static_baseline(cfg, requests, slots: int, params=None, seed: int = 0):
    """Run the request stream with the static-batch loop.

    Useful tokens = what each request actually asked for; the loop still
    decodes max(gen) steps per batch, so utilization drops as length mix
    widens.  Returns (useful_tokens_per_s, decode_time_s).
    """
    useful = sum(r.max_new for r in requests)
    t_decode = 0.0
    for i in range(0, len(requests), slots):
        group = requests[i:i + slots]
        prompt_len = max(r.prompt_len for r in group)
        gen = max(r.max_new for r in group)
        _, tps = serve_static(cfg, batch=len(group), prompt_len=prompt_len,
                              gen=gen, seed=seed, params=params, verbose=False)
        t_decode += len(group) * gen / tps
    return useful / max(t_decode, 1e-9), t_decode


def engine_run(cfg, requests, slots: int, rate: float, params=None,
               attribution_cfg=None):
    spec_max = max(r.prompt_len + r.max_new for r in requests)
    max_len = -(-spec_max // 16) * 16
    engine = ServingEngine(cfg, slots=slots, max_len=max_len, block_size=16,
                           params=params, attribution_cfg=attribution_cfg)
    # re-stamp arrivals for the requested rate (virtual → wall seconds)
    rng = np.random.default_rng(7)
    gaps = rng.exponential(1.0 / rate, len(requests)) if np.isfinite(rate) else np.zeros(len(requests))
    arrivals = np.cumsum(gaps)
    reqs = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                    arrival=float(a)) for r, a in zip(requests, arrivals)]
    summary = engine.run(reqs)
    return summary


def run(verbose: bool = True, n_requests: int = 16, slots_sweep=(2, 4),
        rates=(float("inf"),), arch: str = "phi4-mini-3.8b",
        json_path=None, check: bool = False):
    cfg = registry.get_smoke(arch)
    attribution_cfg = registry.get_config(arch)   # bill energy at full scale
    import jax
    from repro.models import lm
    from repro.nn import module as nnmod
    params = nnmod.materialize(lm.param_spec(cfg), jax.random.PRNGKey(0))
    base_requests = make_requests(cfg, _mixed_spec(n_requests), seed=11)

    out = {"arch": arch, "n_requests": n_requests, "cells": []}
    for slots in slots_sweep:
        tps_static, t_static = static_baseline(cfg, base_requests, slots, params=params)
        for rate in rates:
            summary = engine_run(cfg, base_requests, slots, rate, params=params,
                                 attribution_cfg=attribution_cfg)
            cell = {
                "slots": slots,
                "arrival_rate": None if not np.isfinite(rate) else rate,
                "static_useful_tokens_per_s": tps_static,
                "engine_tokens_per_s": summary["decode_tokens_per_s"],
                "speedup": summary["decode_tokens_per_s"] / max(tps_static, 1e-9),
                "ttft_s": summary["ttft_s"],
                "tpot_s": summary["tpot_s"],
                "slot_occupancy": summary["slot_occupancy"],
                "preemptions": summary["preemptions"],
                "odin_total": summary["odin_total"],
                "per_request": [
                    {k: rec[k] for k in ("rid", "prompt_tokens", "generated_tokens",
                                         "ttft_s", "tpot_s", "odin")}
                    for rec in summary["requests"]
                ],
            }
            out["cells"].append(cell)
            if verbose:
                r = "∞" if cell["arrival_rate"] is None else f"{rate:g}/s"
                print(f"slots={slots} rate={r:>6}: static {tps_static:7.1f} tok/s → "
                      f"engine {cell['engine_tokens_per_s']:7.1f} tok/s "
                      f"({cell['speedup']:.2f}×)  occ {cell['slot_occupancy']:.2f}  "
                      f"ttft_p50 {cell['ttft_s']['p50']*1e3:6.1f} ms  "
                      f"energy {cell['odin_total']['energy_mj']/1e3:.2f} J")
    best = max(c["speedup"] for c in out["cells"])
    out["best_speedup"] = best
    if verbose:
        print(f"best decode-throughput speedup over static batching: {best:.2f}×")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        if verbose:
            print(f"wrote {json_path}")
    if check and best < 1.5:
        raise SystemExit(f"speedup {best:.2f}× < required 1.5×")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=registry.ARCH_IDS)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--rates", type=float, nargs="+", default=None,
                    help="arrival rates (req/s); default: unthrottled")
    ap.add_argument("--json", default=None)
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero unless engine ≥ 1.5× static decode throughput")
    args = ap.parse_args()
    rates = tuple(args.rates) if args.rates else (float("inf"),)
    run(n_requests=args.requests, slots_sweep=tuple(args.slots), rates=rates,
        arch=args.arch, json_path=args.json, check=args.check)


if __name__ == "__main__":
    main()
