"""Beyond-paper: the ODIN PCRAM cost model applied to the 10 assigned LMs.

Maps each architecture's per-token MAC workload (from the ModelConfig) onto
the ODIN command stream — the analysis the paper would have needed to do to
claim LLM relevance.  Output: per-token latency/energy on one ODIN module,
plus the module count needed to hit an interactive 10 tok/s.
"""
from repro.models import lm, registry
from repro.nn.module import count_params
from repro.pim.geometry import OdinModule
from repro.pim.trace import FC, Topology, trace_topology


def lm_as_topology(arch: str) -> Topology:
    """One decode step ≈ the active-parameter matmul stack as FC layers."""
    cfg = registry.get_config(arch)
    total = count_params(lm.param_spec(cfg))
    active = int(lm.model_flops(cfg, 1, train=False) / 2)  # 2·N_active per token
    # model the active matmul work as FC(d_model → active/d_model)
    d = cfg.d_model
    return Topology(arch, [FC(d, max(1, active // d))], "lm"), total, active


def run(verbose: bool = True):
    mod = OdinModule()
    out = {}
    for arch in registry.ARCH_IDS:
        topo, total, active = lm_as_topology(arch)
        cost = trace_topology(topo, mod, accounting="full")
        t_ms = cost.total_latency_ns / 1e6
        e_mj = cost.total_energy_pj / 1e9
        modules_10tps = max(1, round(t_ms / 100.0))
        # capacity: two-rail 8-bit weights, 8 GB/module accelerator channel
        mem_gb = total * 2 / 1e9
        out[arch] = dict(params=total, active=active, ms_per_token=t_ms,
                         mj_per_token=e_mj, modules_for_10tps=modules_10tps,
                         weight_gb_tworail=mem_gb,
                         modules_for_capacity=max(1, -(-int(mem_gb) // 8)))
    if verbose:
        print("\n# ODIN cost model on the assigned LM pool (per decoded token)")
        print(f"{'arch':22} {'params':>9} {'active':>9} {'ms/tok':>9} "
              f"{'mJ/tok':>9} {'mods@10tps':>10} {'mods@cap':>9}")
        for a, r in out.items():
            print(f"{a:22} {r['params']/1e9:8.1f}B {r['active']/1e9:8.1f}B "
                  f"{r['ms_per_token']:9.2f} {r['mj_per_token']:9.2f} "
                  f"{r['modules_for_10tps']:10d} {r['modules_for_capacity']:9d}")
        print("⇒ MoE archs are ODIN's best case: weights stay resident in PCRAM"
              " and only the active-expert rows are read (in-situ advantage).")
    return out


if __name__ == "__main__":
    run()
