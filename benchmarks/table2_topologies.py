"""Reproduces paper Table 2: per-topology memory, reads, writes.

Paper cells that parse cleanly from the OCR'd table are compared directly;
the CNN/conv columns are mangled in the source, so we print our first-
principles derivation beside whatever is comparable and flag the rest
(DESIGN.md §6.2).
"""
from repro.pim.geometry import OdinModule
from repro.pim.trace import PAPER_TOPOLOGIES, trace_topology

# cleanly parseable cells of paper Table 2 (reads/writes ×1e6, memory Gb)
PAPER = {
    "VGG1": dict(fc_mem_gbit=1.93, fc_reads=247e6, fc_writes=248e6,
                 conv_reads=58.8e6, conv_writes=30.3e6),
    "VGG2": dict(fc_mem_gbit=1.96, fc_reads=251e6, fc_writes=252e6,
                 conv_reads=60.01e6, conv_writes=30.9e6),
    "CNN1": dict(fc_mem_gbit=0.00095 * 8, fc_reads=1.22e6, fc_writes=1.226e6),
    "CNN2": dict(fc_mem_gbit=0.00098 * 8, fc_reads=1.254e6, fc_writes=1.257e6),
}


def run(verbose: bool = True):
    mod = OdinModule()
    out = {}
    for name, topo in PAPER_TOPOLOGIES.items():
        cost = trace_topology(topo, mod, accounting="paper")
        full = trace_topology(topo, mod, accounting="full")
        rec = dict(
            fc_mem_gbit=cost.fc_mem_gbit, conv_mem_gbit=cost.conv_mem_gbit,
            fc_reads=cost.fc_reads, fc_writes=cost.fc_writes,
            conv_reads=cost.conv_reads, conv_writes=cost.conv_writes,
            total_macs=cost.total_macs,
            latency_ms_full=full.total_latency_ns / 1e6,
            energy_mj_full=full.total_energy_pj / 1e9,
        )
        paper = PAPER.get(name, {})
        rec["vs_paper"] = {
            k: round(rec[k] / v, 3) for k, v in paper.items() if v and k in rec
        }
        out[name] = rec
    if verbose:
        print("\n# Table 2 — topology costs on ODIN (ours / paper ratio)")
        for name, r in out.items():
            print(f"{name}: fc_mem {r['fc_mem_gbit']:.4f} Gb | "
                  f"fc R/W {r['fc_reads']/1e6:.1f}/{r['fc_writes']/1e6:.1f} M | "
                  f"conv R/W {r['conv_reads']/1e6:.2f}/{r['conv_writes']/1e6:.2f} M | "
                  f"lat {r['latency_ms_full']:.3f} ms | E {r['energy_mj_full']:.3f} mJ")
            if r["vs_paper"]:
                print(f"   ratio vs paper: {r['vs_paper']}")
    return out


if __name__ == "__main__":
    run()
