"""Kernel microbenchmarks: wall time (interpret mode — correctness path) and
the STRUCTURAL model of the TPU kernel (VMEM footprint, op counts, arithmetic
intensity) that the §Roofline analysis uses.  On CPU the wall numbers only
order implementations; the structural numbers are the hardware claim.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stochastic as sc
from repro.core.odin_linear import get_luts
from repro.kernels.int8_mm import int8_mm_pallas
from repro.kernels.sc_mac import sc_matmul_pallas


def _time(f, *args, reps=3):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def sc_mac_structure(M, K, N, bm=8, bn=8, bk=256, W=8):
    """Per-tile op/byte model of the fused SC-MAC kernel (DESIGN.md §2)."""
    khat = 1 << sc.tree_depth(bk)
    tiles = (M // bm) * (N // bn) * (K // bk)
    vmem = (bm * bk + bk * bn) * W * 4 + bm * bn * bk * W * 4
    bit_ops_per_tile = (
        bm * bk * W * 32 + bk * bn * W * 32          # comparator SNG
        + bm * bn * bk * W                           # AND
        + bm * bn * (bk - 1) * W * 3                 # MUX tree (2 AND + OR)
        + bm * bn * W                                # popcount words
    )
    hbm_bytes_per_tile = (bm * bk + bk * bn) * 4 + bm * bn * 4
    return dict(tiles=tiles, vmem_bytes=vmem,
                bit_ops=tiles * bit_ops_per_tile,
                hbm_bytes=tiles * hbm_bytes_per_tile,
                arithmetic_intensity=bit_ops_per_tile / hbm_bytes_per_tile,
                bit_ops_per_mac=bit_ops_per_tile / (bm * bn * bk))


def run(verbose: bool = True):
    lut_a, lut_w, selects = get_luts(256, 256, 0)
    spec = sc.StreamSpec()
    rng = np.random.default_rng(0)
    M, K, N = 16, 64, 16
    a = jnp.asarray(rng.integers(0, 256, (M, K)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 256, (K, N)), jnp.int32)

    t_ref = _time(lambda a, w: sc.sc_matmul(a, w, lut_a, lut_w, selects, spec), a, w)
    t_pal = _time(lambda a, w: sc_matmul_pallas(a, w, lut_a, lut_w, selects, spec,
                                                interpret=True), a, w)
    t_exp = _time(lambda a, w: sc.expected_matmul(a, w, spec), a, w)

    a8 = jnp.asarray(rng.integers(-127, 128, (128, 256)), jnp.int8)
    w8 = jnp.asarray(rng.integers(-127, 128, (256, 128)), jnp.int8)
    sa = jnp.ones((128,), jnp.float32)
    sw = jnp.ones((128,), jnp.float32)
    t_int8 = _time(lambda a, w: int8_mm_pallas(a, w, sa, sw), a8, w8)

    struct = sc_mac_structure(512, 4096, 512)
    out = {
        "sc_matmul_jnp_ms": t_ref * 1e3,
        "sc_matmul_pallas_interpret_ms": t_pal * 1e3,
        "expected_int_surrogate_ms": t_exp * 1e3,
        "int8_mm_pallas_interpret_ms": t_int8 * 1e3,
        "sc_mac_structure": struct,
    }
    if verbose:
        print("\n# Kernel microbench (interpret-mode wall; structural TPU model)")
        for k, v in out.items():
            if k != "sc_mac_structure":
                print(f"  {k:34s} {v:9.2f}")
        s = struct
        print(f"  sc_mac tile VMEM {s['vmem_bytes']/1e3:.0f} KB; "
              f"{s['bit_ops_per_mac']:.0f} bit-ops/MAC; "
              f"AI {s['arithmetic_intensity']:.0f} ops/byte")
        print("  ⇒ SC-MAC trades each MXU MAC for ~{:.0f} VPU bit-ops: on PCRAM "
              "(no multipliers) that wins; on TPU the int8 MXU surrogate is the "
              "deployment path (DESIGN.md §2).".format(s["bit_ops_per_mac"]))
    return out


if __name__ == "__main__":
    run()
