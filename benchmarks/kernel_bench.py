"""Kernel microbenchmarks: wall time (interpret mode — correctness path) and
the STRUCTURAL model of the TPU kernel (VMEM footprint, op counts, arithmetic
intensity) that the §Roofline analysis uses.  On CPU the wall numbers only
order implementations; the structural numbers are the hardware claim.

The paged-attention section doubles as the kernel-vs-reference gate: any
mismatch beyond tolerance raises, so a CI bench-smoke run fails loudly.
Results merge into ``BENCH_serving.json`` (section "kernels") with
``--bench-json``.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.bench_io import DEFAULT_BENCH_JSON, update_bench_json
except ImportError:                      # run as a script: benchmarks/ on path
    from bench_io import DEFAULT_BENCH_JSON, update_bench_json

from repro.core import stochastic as sc
from repro.core.odin_linear import get_luts
from repro.kernels.int8_mm import int8_mm_pallas
from repro.kernels.paged_attn import paged_attention, paged_attn_ref
from repro.kernels.sc_mac import sc_matmul_pallas


def _time(f, *args, reps=3):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def sc_mac_structure(M, K, N, bm=8, bn=8, bk=256, W=8):
    """Per-tile op/byte model of the fused SC-MAC kernel (DESIGN.md §2)."""
    khat = 1 << sc.tree_depth(bk)
    tiles = (M // bm) * (N // bn) * (K // bk)
    vmem = (bm * bk + bk * bn) * W * 4 + bm * bn * bk * W * 4
    bit_ops_per_tile = (
        bm * bk * W * 32 + bk * bn * W * 32          # comparator SNG
        + bm * bn * bk * W                           # AND
        + bm * bn * (bk - 1) * W * 3                 # MUX tree (2 AND + OR)
        + bm * bn * W                                # popcount words
    )
    hbm_bytes_per_tile = (bm * bk + bk * bn) * 4 + bm * bn * 4
    return dict(tiles=tiles, vmem_bytes=vmem,
                bit_ops=tiles * bit_ops_per_tile,
                hbm_bytes=tiles * hbm_bytes_per_tile,
                arithmetic_intensity=bit_ops_per_tile / hbm_bytes_per_tile,
                bit_ops_per_mac=bit_ops_per_tile / (bm * bn * bk))


def paged_attn_structure(B, Hkv, G, D, bs, P):
    """Per-decode-token traffic model of the paged kernel vs the dense path.

    Dense decode reads the whole [slots, max_len] cache; the paged kernel
    reads only the pages the block tables reference — HBM bytes scale with
    the *active* tokens, and the pool is the entire device KV footprint.
    """
    page_bytes = bs * D * 2                          # one K or V page, bf16
    pages = B * Hkv * P
    hbm_bytes = pages * 2 * page_bytes + B * Hkv * G * D * 4 * 2
    flops = 2 * B * Hkv * G * P * bs * D * 2         # qk + pv per page
    vmem = (G * D + 2 * bs * D) * 4 + G * (D + 2) * 4
    return dict(hbm_bytes=hbm_bytes, flops=flops, vmem_bytes=vmem,
                arithmetic_intensity=flops / hbm_bytes)


def paged_attn_bench(tol: float = 2e-5):
    """Time the paged decode kernel (interpret) vs its jnp reference and GATE
    on the max abs error — raises on mismatch (the CI bench-smoke contract)."""
    rng = np.random.default_rng(0)
    B, H, Hkv, D, bs, P = 4, 8, 2, 64, 16, 8
    N = B * P + 8
    q = jnp.asarray(rng.normal(size=(B, H, D)) * 0.5, jnp.float32)
    kp = jnp.asarray(rng.normal(size=(N, bs, Hkv, D)) * 0.5, jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, bs, Hkv, D)) * 0.5, jnp.float32)
    tables = jnp.asarray(rng.permutation(N)[:B * P].reshape(B, P), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, P * bs + 1, B), jnp.int32)

    t_kernel = _time(lambda q: paged_attention(q, kp, vp, tables, lengths), q)
    qg = q.reshape(B, Hkv, H // Hkv, D)
    ref = jax.jit(lambda q: paged_attn_ref(q, kp, vp, tables, lengths))
    t_ref = _time(ref, qg)
    max_err = float(np.abs(
        np.asarray(paged_attention(q, kp, vp, tables, lengths))
        - np.asarray(ref(qg)).reshape(B, H, D)).max())
    if max_err > tol:
        raise AssertionError(
            f"paged_attn kernel mismatch vs reference: {max_err:.2e} > {tol:.0e}")
    return {
        "paged_attn_kernel_interpret_ms": t_kernel * 1e3,
        "paged_attn_ref_ms": t_ref * 1e3,
        "paged_attn_max_err": max_err,
        "paged_attn_structure": paged_attn_structure(64, 8, 4, 128, 16, 256),
    }


def run(verbose: bool = True, bench_json=None):
    lut_a, lut_w, selects = get_luts(256, 256, 0)
    spec = sc.StreamSpec()
    rng = np.random.default_rng(0)
    M, K, N = 16, 64, 16
    a = jnp.asarray(rng.integers(0, 256, (M, K)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 256, (K, N)), jnp.int32)

    t_ref = _time(lambda a, w: sc.sc_matmul(a, w, lut_a, lut_w, selects, spec), a, w)
    t_pal = _time(lambda a, w: sc_matmul_pallas(a, w, lut_a, lut_w, selects, spec,
                                                interpret=True), a, w)
    t_exp = _time(lambda a, w: sc.expected_matmul(a, w, spec), a, w)

    a8 = jnp.asarray(rng.integers(-127, 128, (128, 256)), jnp.int8)
    w8 = jnp.asarray(rng.integers(-127, 128, (256, 128)), jnp.int8)
    sa = jnp.ones((128,), jnp.float32)
    sw = jnp.ones((128,), jnp.float32)
    t_int8 = _time(lambda a, w: int8_mm_pallas(a, w, sa, sw), a8, w8)

    struct = sc_mac_structure(512, 4096, 512)
    out = {
        "sc_matmul_jnp_ms": t_ref * 1e3,
        "sc_matmul_pallas_interpret_ms": t_pal * 1e3,
        "expected_int_surrogate_ms": t_exp * 1e3,
        "int8_mm_pallas_interpret_ms": t_int8 * 1e3,
        "sc_mac_structure": struct,
    }
    out.update(paged_attn_bench())
    if verbose:
        print("\n# Kernel microbench (interpret-mode wall; structural TPU model)")
        for k, v in out.items():
            if not isinstance(v, dict):
                print(f"  {k:34s} {v:9.2f}")
        s = struct
        print(f"  sc_mac tile VMEM {s['vmem_bytes']/1e3:.0f} KB; "
              f"{s['bit_ops_per_mac']:.0f} bit-ops/MAC; "
              f"AI {s['arithmetic_intensity']:.0f} ops/byte")
        print("  ⇒ SC-MAC trades each MXU MAC for ~{:.0f} VPU bit-ops: on PCRAM "
              "(no multipliers) that wins; on TPU the int8 MXU surrogate is the "
              "deployment path (DESIGN.md §2).".format(s["bit_ops_per_mac"]))
        p = out["paged_attn_structure"]
        print(f"  paged_attn decode (64 slots × 4k ctx): {p['hbm_bytes']/1e6:.0f} MB "
              f"HBM/step, AI {p['arithmetic_intensity']:.1f} flop/byte — the "
              f"bandwidth-bound regime the block pool keeps minimal; "
              f"kernel==ref to {out['paged_attn_max_err']:.1e}")
    if bench_json:
        update_bench_json(bench_json, "kernels", out)
        if verbose:
            print(f"merged section 'kernels' into {bench_json}")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench-json", default=DEFAULT_BENCH_JSON,
                    help="merged cross-bench JSON (section 'kernels')")
    args = ap.parse_args()
    run(bench_json=args.bench_json)


if __name__ == "__main__":
    main()
