"""Reproduces paper Table 1: PIMC command reads/writes/latency (exact)."""
from repro.pim.commands import TABLE1_EXPECTED, command_set
from repro.pim.geometry import OdinModule


def run(verbose: bool = True):
    mod = OdinModule()
    cs = command_set()
    rows = []
    ok = True
    for name, exp in TABLE1_EXPECTED.items():
        c = cs[name]
        lat = c.latency_ns(mod)
        match = (c.reads == exp["reads"] and c.writes == exp["writes"]
                 and abs(lat - exp["latency_ns"]) < 1e-9)
        ok &= match
        rows.append(dict(command=name, reads=c.reads, writes=c.writes,
                         latency_ns=lat, paper_latency_ns=exp["latency_ns"],
                         energy_pj=round(c.energy_pj(mod), 1),
                         match="EXACT" if match else "MISMATCH"))
    if verbose:
        print("\n# Table 1 — ODIN PIMC commands (derived t_R=48ns, t_W=60ns)")
        print(f"{'command':10} {'R':>3} {'W':>3} {'lat(ns)':>9} {'paper':>7} "
              f"{'E(pJ)':>10} match")
        for r in rows:
            print(f"{r['command']:10} {r['reads']:3d} {r['writes']:3d} "
                  f"{r['latency_ns']:9.0f} {r['paper_latency_ns']:7d} "
                  f"{r['energy_pj']:10.1f} {r['match']}")
    return {"rows": rows, "all_exact": ok}


if __name__ == "__main__":
    run()
