"""Merged benchmark JSON: every bench writes its section into one file
(``BENCH_serving.json``) so the perf trajectory is machine-readable across
PRs — CI uploads the file as an artifact."""
import json
import os

DEFAULT_BENCH_JSON = "BENCH_serving.json"


def update_bench_json(path: str, section: str, payload) -> dict:
    """Read-merge-write ``payload`` under ``section``; tolerates a missing or
    corrupt file (each bench only owns its own section)."""
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
    data[section] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
    return data
