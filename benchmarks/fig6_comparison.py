"""Reproduces paper Fig. 6: ODIN vs CPU-32b/CPU-8b/ISAAC± execution time
and energy, normalized to ODIN, for CNN1/2 (MNIST) and VGG1/2 (ImageNet).

Paper bands (abstract + §VI-B):
  vs ISAAC:  VGG 5.8× faster / 1554× more energy-efficient,
             CNN 90.8× faster / 23.2× more energy-efficient.
  vs CPUs:   up to 438× (VGG) / 569× (CNN) faster,
             up to 1530× (VGG) / 30.6× (CNN) more energy-efficient.

Energy accounting (EXPERIMENTS.md §Fig6): with *literature* PCRAM array
energies (0.5 pJ/bit read / 5 pJ/bit write, 14 nm-scaled [29][30]) ODIN's
VGG energy is ~430 mJ — 12× MORE than the ISAAC model, so the paper's
1554× band is unreachable: it implies array access below ~0.2 fJ/bit.  The
paper prints no PCRAM energy constants; its band is reproducible only under
ADD-ON-ONLY accounting (Table 3 CMOS energy, array access free).  We report
BOTH: ``literature`` (default, physically grounded) and ``paper_implied``
(add-on only, reproduces the paper's bands) — a documented calibration, not
a fudge.
"""
from dataclasses import replace

from repro.pim.baselines import CPU32, CPU8, ISAAC_PIPE, ISAAC_UNPIPE
from repro.pim.geometry import OdinModule, PCRAMEnergy
from repro.pim.trace import PAPER_TOPOLOGIES, trace_topology

SYSTEMS = [CPU32, CPU8, ISAAC_PIPE, ISAAC_UNPIPE]

MODULES = {
    "literature": OdinModule(),
    "paper_implied": OdinModule(energy=PCRAMEnergy(e_read_pj=0.0, e_write_pj=0.0)),
}


def _one_accounting(mod: OdinModule):
    out = {}
    for name, topo in PAPER_TOPOLOGIES.items():
        odin_cost = trace_topology(topo, mod, accounting="full")
        odin_t = odin_cost.total_latency_ns * 1e-9
        odin_e = odin_cost.total_energy_pj * 1e-12
        rec = {"odin_time_s": odin_t, "odin_energy_j": odin_e, "speedup": {},
               "energy_ratio": {}}
        for sys_ in SYSTEMS:
            t, e = sys_.execute(topo)
            rec["speedup"][sys_.name] = t / odin_t
            rec["energy_ratio"][sys_.name] = e / odin_e
        out[name] = rec
    return out


def run(verbose: bool = True):
    results = {k: _one_accounting(m) for k, m in MODULES.items()}

    def band(res, names, syss, field):
        vals = [res[n][field][s.name] for n in names for s in syss]
        return min(vals), max(vals)

    lit, imp = results["literature"], results["paper_implied"]
    vgg, cnn = ("VGG1", "VGG2"), ("CNN1", "CNN2")
    isaac = (ISAAC_PIPE, ISAAC_UNPIPE)
    cpus = (CPU32, CPU8)
    bands = {
        # speed is energy-accounting-independent
        "isaac_speed_vgg": band(lit, vgg, isaac, "speedup"),
        "isaac_speed_cnn": band(lit, cnn, isaac, "speedup"),
        "cpu_speed_max": band(lit, vgg + cnn, cpus, "speedup")[1],
        "isaac_energy_vgg_lit": band(lit, vgg, isaac, "energy_ratio"),
        "isaac_energy_vgg_implied": band(imp, vgg, isaac, "energy_ratio"),
        "isaac_energy_cnn_implied": band(imp, cnn, isaac, "energy_ratio"),
        "cpu_energy_max_lit": band(lit, vgg + cnn, cpus, "energy_ratio")[1],
        "paper": dict(isaac_speed_vgg=5.8, isaac_speed_cnn=90.8,
                      isaac_energy_vgg=1554, isaac_energy_cnn=23.2,
                      cpu_speed_max=(438, 569), cpu_energy_max=(30.6, 1530)),
    }
    bands["checks"] = dict(
        odin_always_faster=bands["isaac_speed_vgg"][0] > 1
        and bands["cpu_speed_max"] > 1,
        isaac_speed_vgg_scale=2 < bands["isaac_speed_vgg"][0] < 30,
        isaac_speed_cnn_scale=10 < bands["isaac_speed_cnn"][1] < 200,
        paper_energy_band_needs_addon_only=(
            bands["isaac_energy_vgg_lit"][1] < 23.2
            and bands["isaac_energy_vgg_implied"][1] > 23.2
        ),
    )
    if verbose:
        for acct, res in results.items():
            print(f"\n# Fig. 6 [{acct}] — normalized to ODIN (>1 = ODIN wins)")
            for name, r in res.items():
                print(f"{name}: ODIN {r['odin_time_s']*1e3:.3f} ms / "
                      f"{r['odin_energy_j']*1e3:.4f} mJ")
                for s in SYSTEMS:
                    print(f"   vs {s.name:17s} speed {r['speedup'][s.name]:8.1f}×   "
                          f"energy {r['energy_ratio'][s.name]:10.1f}×")
        print("\nbands:", {k: v for k, v in bands.items() if k != "paper"})
    return {"results": results, "bands": bands}


if __name__ == "__main__":
    run()
