"""§Roofline table: per (arch × shape × mesh) three-term roofline from the
cached dry-run artifacts (experiments/dryrun/*.json).

Terms (per chip, per step):  compute = FLOPs/peak,  memory = bytes/HBM-BW,
collective = wire-bytes/ICI-BW.  MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (inference); useful-flops ratio flags remat/redundancy waste.
Run ``python -m repro.launch.dryrun --all --multi-pod both`` first (or let
run.py use whatever cells are cached).
"""
import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return [r for r in recs if r.get("status") == "OK"]


def run(verbose: bool = True, dryrun_dir: str = DRYRUN_DIR):
    recs = load_records(dryrun_dir)
    rows = []
    for r in recs:
        t = r["roofline"]
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], mesh="pod2" if r["multi_pod"] else "pod1",
            compute_s=t["compute_s"], memory_s=t["memory_s"],
            collective_s=t["collective_s"], bottleneck=t["bottleneck"],
            useful=t["useful_flops_ratio"], mfu_ub=t["mfu_upper_bound"],
            mem_gb=r["memory"]["total_bytes"] / 1e9, fits=r["fits_hbm"],
        ))
    rows.sort(key=lambda x: (x["arch"], x["shape"], x["mesh"]))
    if verbose:
        print("\n# §Roofline — per-cell terms (seconds/step/chip) from the dry-run")
        print(f"{'arch':22} {'shape':12} {'mesh':5} {'compute':>10} {'memory':>10} "
              f"{'coll':>10} {'bound':>10} {'useful':>7} {'MFU-UB':>7} {'GB/dev':>7} fits")
        for x in rows:
            print(f"{x['arch']:22} {x['shape']:12} {x['mesh']:5} "
                  f"{x['compute_s']:10.3e} {x['memory_s']:10.3e} "
                  f"{x['collective_s']:10.3e} {x['bottleneck']:>10} "
                  f"{x['useful']:7.3f} {x['mfu_ub']:7.4f} {x['mem_gb']:7.2f} "
                  f"{'Y' if x['fits'] else 'N'}")
        if not rows:
            print("(no cached dry-run cells — run python -m repro.launch.dryrun --all)")
    return rows


if __name__ == "__main__":
    run()
