"""Reproduces paper Table 3: add-on logic overheads and their roll-up.

Table 3's per-component values are model *inputs* (CACTI / [25] constants);
the reproduction here is the roll-up: per-command add-on energy and the
total per-bank area overhead, which is the paper's "lightweight" claim."""
from repro.pim.commands import TABLE3_PJ, command_set
from repro.pim.geometry import OdinModule

# Table 3 area column (mm²) — the components a bank actually instantiates
AREA_MM2 = {
    "sram_lut": 0.402, "mux_256_8": 0.639, "demux_8_256": 0.493,
    "relu": 0.02, "pool": 3.06,
}


def run(verbose: bool = True):
    mod = OdinModule()
    cs = command_set()
    addon = {name: c.addon_pj for name, c in cs.items()}
    per_bank_area = sum(AREA_MM2.values())
    out = {
        "component_pj": dict(TABLE3_PJ),
        "per_command_addon_pj": addon,
        "per_bank_addon_area_mm2": per_bank_area,
        # ISAAC-class accelerators pay ~98 mm² of ADC per chip (ISCA'16);
        # ODIN's per-bank add-on is ~4.6 mm² with zero ADC/DAC.
        "adc_free": True,
    }
    if verbose:
        print("\n# Table 3 — add-on logic roll-up")
        print(f"per-bank add-on area: {per_bank_area:.2f} mm² (no ADC/DAC)")
        for k, v in addon.items():
            print(f"  {k:10s} add-on energy {v:9.1f} pJ/invocation")
    return out


if __name__ == "__main__":
    run()
